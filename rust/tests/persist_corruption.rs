//! Corruption suite: decoding untrusted bytes must **never panic or
//! OOM** — truncations, bit-flips, wrong magic/version, fingerprint
//! tampering and length-field lies over every summary's encoding (and
//! over the committed golden vectors) all map to typed
//! [`worp::Error::Codec`] / [`worp::Error::Incompatible`] values.
//!
//! The envelope checksum covers the header fields and the payload, so
//! every single-bit flip anywhere in an envelope is caught
//! deterministically.

use worp::api::{Persist, StreamSummary};
use worp::data::zipf::zipf_exact_stream;
use worp::data::Element;
use worp::sampler::exact::ExactWor;
use worp::sampler::SamplerConfig;
use worp::sketch::countmin::CountMin;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::spacesaving::SpaceSaving;
use worp::sketch::topk::TopK;
use worp::sketch::window::WindowedCountSketch;
use worp::sketch::{RhhSketch, SketchParams};

/// Every summary encoding under test, with a decoder that must reject
/// all corrupted variants (returning, never panicking).
fn vectors() -> Vec<(&'static str, Vec<u8>, fn(&[u8]) -> bool)> {
    let elems = zipf_exact_stream(100, 1.2, 1e3, 2, 3);

    let mut cs = CountSketch::with_shape(3, 32, 7);
    let mut cm = CountMin::with_shape(3, 32, 7);
    let mut ss: SpaceSaving<u64> = SpaceSaving::new(8);
    let mut tk = TopK::new(4, 6);
    let mut ws = WindowedCountSketch::new(SketchParams::new(3, 32, 7), 50, 5);
    for (i, e) in elems.iter().enumerate() {
        RhhSketch::process(&mut cs, e);
        RhhSketch::process(&mut cm, &Element::new(e.key, e.val.abs()));
        ss.process(e.key, e.val.abs());
        tk.process(e.key, e.val.abs(), (e.key % 13) as f64);
        ws.process_at(e, i as u64);
    }
    let cfg = SamplerConfig::new(1.0, 6)
        .with_seed(5)
        .with_domain(100)
        .with_sketch_shape(3, 64);
    let mut ex = ExactWor::new(cfg);
    let mut w1 = worp::Worp::p(1.0)
        .k(6)
        .seed(5)
        .domain(100)
        .sketch_shape(3, 64)
        .one_pass()
        .build()
        .unwrap();
    for e in &elems {
        ex.process(e);
        StreamSummary::process(&mut w1, e);
    }

    fn rejects<T: Persist>(bytes: &[u8]) -> bool {
        matches!(
            T::decode(bytes),
            Err(worp::Error::Codec(_)) | Err(worp::Error::Incompatible(_))
        )
    }
    fn rejects_dyn(bytes: &[u8]) -> bool {
        matches!(
            worp::codec::decode_sampler(bytes),
            Err(worp::Error::Codec(_)) | Err(worp::Error::Incompatible(_))
        )
    }

    vec![
        ("countsketch", cs.encode(), rejects::<CountSketch> as fn(&[u8]) -> bool),
        ("countmin", cm.encode(), rejects::<CountMin>),
        ("spacesaving", ss.encode(), rejects::<SpaceSaving<u64>>),
        ("topk", tk.encode(), rejects::<TopK>),
        ("windowsketch", ws.encode(), rejects::<WindowedCountSketch>),
        ("exact", ex.encode(), rejects::<ExactWor>),
        ("worp1", Persist::encode(&w1), rejects_dyn),
    ]
}

#[test]
fn truncation_at_every_length_is_rejected() {
    for (name, bytes, rejects) in vectors() {
        // every strict prefix, exhaustively for the header region and
        // sampled beyond it (long vectors)
        for cut in 0..bytes.len() {
            if cut > 64 && cut % 7 != 0 && cut != bytes.len() - 1 {
                continue;
            }
            assert!(
                rejects(&bytes[..cut]),
                "{name}: truncation to {cut}/{} bytes was not rejected",
                bytes.len()
            );
        }
        assert!(rejects(&[]), "{name}: empty input");
    }
}

#[test]
fn every_single_bit_flip_is_rejected() {
    for (name, bytes, rejects) in vectors() {
        for i in 0..bytes.len() {
            // exhaustive over the header, sampled over long payloads
            if i >= 64 && i % 5 != 0 {
                continue;
            }
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    rejects(&bad),
                    "{name}: flip of byte {i} bit {bit} was not rejected"
                );
            }
        }
    }
}

#[test]
fn wrong_magic_version_and_fingerprint_are_rejected() {
    for (name, bytes, rejects) in vectors() {
        let mut bad = bytes.clone();
        bad[..4].copy_from_slice(b"NOPE");
        assert!(rejects(&bad), "{name}: wrong magic accepted");

        let mut bad = bytes.clone();
        bad[4..6].copy_from_slice(&999u16.to_le_bytes());
        assert!(rejects(&bad), "{name}: future version accepted");

        // tamper the embedded fingerprint (bytes 16..24)
        let mut bad = bytes.clone();
        for b in &mut bad[16..24] {
            *b = b.wrapping_add(1);
        }
        assert!(rejects(&bad), "{name}: fingerprint tampering accepted");
    }
}

#[test]
fn length_field_lies_are_rejected_without_oom() {
    for (name, bytes, rejects) in vectors() {
        // envelope payload-length lies: every interesting value
        for lie in [0u64, 1, u32::MAX as u64, u64::MAX] {
            let mut bad = bytes.clone();
            bad[8..16].copy_from_slice(&lie.to_le_bytes());
            assert!(rejects(&bad), "{name}: payload length lie {lie} accepted");
        }
        // raw interior overwrites are caught by the checksum
        let start = 32;
        let mut off = start;
        while off + 8 <= bytes.len() {
            let mut bad = bytes.clone();
            bad[off..off + 8].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
            assert!(
                rejects(&bad),
                "{name}: raw interior overwrite at offset {off} accepted"
            );
            off += 8;
        }
    }
}

/// Length lies behind a *valid* checksum (a hostile writer, not random
/// corruption): the payload is tampered and re-wrapped in a fresh,
/// checksum-correct envelope — the per-type payload parsers must still
/// reject it via `seq_len` / shape validation, allocating nothing.
#[test]
fn hostile_length_fields_with_valid_checksums_are_rejected() {
    use worp::codec::{read_envelope, write_envelope};

    let rewrap = |bytes: &[u8], mutate: &dyn Fn(&mut Vec<u8>)| -> Vec<u8> {
        let env = read_envelope(bytes, None).unwrap();
        let mut payload = env.payload.to_vec();
        mutate(&mut payload);
        let mut out = Vec::new();
        write_envelope(env.type_tag, env.fingerprint, &payload, &mut out);
        out
    };

    // CountSketch payload: rows@0, width@8, seed@16, processed@24,
    // table_len@32 — lie in the table length and in the shape
    let mut cs = CountSketch::with_shape(3, 32, 7);
    RhhSketch::process(&mut cs, &Element::new(1, 1.0));
    let enc = cs.encode();
    for (off, lie) in [(32usize, u64::MAX), (32, u64::MAX / 8), (0, u64::MAX), (8, 0u64)] {
        let bad = rewrap(&enc, &|p: &mut Vec<u8>| {
            p[off..off + 8].copy_from_slice(&lie.to_le_bytes());
        });
        assert!(
            matches!(CountSketch::decode(&bad), Err(worp::Error::Codec(_))),
            "countsketch: hostile field at {off} = {lie} accepted"
        );
    }

    // SpaceSaving payload: capacity@0, processed@8, n@16
    let mut ss: SpaceSaving<u64> = SpaceSaving::new(4);
    ss.process(9, 2.0);
    let enc = ss.encode();
    for (off, lie) in [(16usize, u64::MAX), (16, 1u64 << 40), (0, u64::MAX)] {
        let bad = rewrap(&enc, &|p: &mut Vec<u8>| {
            p[off..off + 8].copy_from_slice(&lie.to_le_bytes());
        });
        assert!(
            matches!(SpaceSaving::<u64>::decode(&bad), Err(worp::Error::Codec(_))),
            "spacesaving: hostile field at {off} = {lie} accepted"
        );
    }

    // truncating a payload behind a fresh envelope still fails cleanly
    let bad = rewrap(&enc, &|p: &mut Vec<u8>| {
        p.truncate(12);
    });
    assert!(SpaceSaving::<u64>::decode(&bad).is_err());

    // NaN injected into a sketch table cell behind a valid checksum must
    // be rejected at decode (it would panic the median comparators on
    // the first est() otherwise)
    let mut cs = CountSketch::with_shape(3, 8, 7);
    RhhSketch::process(&mut cs, &Element::new(1, 1.0));
    let enc = cs.encode();
    let bad = rewrap(&enc, &|p: &mut Vec<u8>| {
        // payload: rows@0, width@8, seed@16, processed@24, len@32, cells@40
        p[40..48].copy_from_slice(&f64::NAN.to_bits().to_le_bytes());
    });
    assert!(
        matches!(CountSketch::decode(&bad), Err(worp::Error::Codec(_))),
        "NaN table cell behind a valid checksum accepted"
    );
}

#[test]
fn random_garbage_is_rejected() {
    use worp::util::rng::Rng;
    let mut rng = Rng::new(0xBAD5EED);
    for (name, bytes, rejects) in vectors() {
        for trial in 0..50 {
            let len = (rng.below(2 * bytes.len() as u64 + 1)) as usize;
            let garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            assert!(
                rejects(&garbage),
                "{name}: random garbage of {len} bytes accepted (trial {trial})"
            );
        }
    }
}

#[test]
fn golden_vectors_survive_the_corruption_suite() {
    // the committed fixtures are also fuzzed: every header bit flip and
    // truncation must be rejected by the dynamic decoder or the typed one
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).expect("tests/golden directory exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("worp") {
            continue;
        }
        found += 1;
        let bytes = std::fs::read(&path).unwrap();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let env = worp::codec::read_envelope(&bytes, None)
            .unwrap_or_else(|e| panic!("{name}: pristine golden vector rejected: {e}"));
        let _ = env;
        for i in 0..bytes.len().min(64) {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    worp::codec::read_envelope(&bad, None).is_err(),
                    "{name}: header flip byte {i} bit {bit} accepted"
                );
            }
        }
        for cut in 0..bytes.len().min(64) {
            assert!(
                worp::codec::read_envelope(&bytes[..cut], None).is_err(),
                "{name}: truncation to {cut} accepted"
            );
        }
    }
    assert!(found >= 10, "expected the golden fixtures, found {found}");
}

#[test]
fn checkpoint_file_corruption_is_rejected() {
    use worp::pipeline::{run_sharded_checkpointed, CheckpointPolicy, PipelineOpts};
    let dir = std::env::temp_dir().join("worp_corrupt_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let policy = CheckpointPolicy::new(2, &dir).unwrap();
    let opts = PipelineOpts::new(2, 16).unwrap();
    let elems: Vec<Element> = (0..500u64).map(|i| Element::new(i % 40, 1.0)).collect();
    let proto = |_w: usize| CountSketch::with_shape(3, 32, 9);
    let (_, metrics) =
        run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    assert!(metrics.snapshots() > 0);
    // flip one payload byte of a snapshot: the resume must fail loudly
    let path = policy.shard_path(0);
    let pristine = std::fs::read(&path).unwrap();
    let mut bytes = pristine.clone();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    let err = run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap_err();
    assert!(matches!(err, worp::Error::Codec(_)), "{err}");
    // flip one bit of the element *cursor* (checkpoint header bytes
    // 14..22): the header checksum must reject it — a silently wrong
    // skip count would double-process elements
    let mut bytes = pristine.clone();
    bytes[17] ^= 0x04;
    std::fs::write(&path, &bytes).unwrap();
    let err = run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap_err();
    assert!(matches!(err, worp::Error::Codec(_)), "cursor corruption accepted: {err}");
    std::fs::write(&path, &pristine).unwrap();
    // a snapshot from a different topology is Incompatible, not silent
    let _ = std::fs::remove_dir_all(&dir);
    let (_, _) = run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    let other_opts = PipelineOpts::new(2, 32).unwrap(); // different batch
    let err =
        run_sharded_checkpointed(&elems, other_opts, &policy, proto).unwrap_err();
    assert!(matches!(err, worp::Error::Incompatible(_)), "{err}");
    // a stale snapshot from a different *configuration* (here: sketch
    // seed) is also Incompatible — never a silent mixed-run resume
    let other_proto = |_w: usize| CountSketch::with_shape(3, 32, 999);
    let err = run_sharded_checkpointed(&elems, opts, &policy, other_proto).unwrap_err();
    assert!(matches!(err, worp::Error::Incompatible(_)), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
