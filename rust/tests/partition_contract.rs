//! Parallel-partitioning contract: the scan-based pipeline (every worker
//! scans the source and keeps its own hash-partition, packed into SoA
//! blocks) must produce **exactly** the output of the retired
//! single-threaded router (route-and-copy into per-shard AoS batches,
//! pushed over channels) — for every worker count and batch size, down to
//! per-shard element order, block boundaries, and bit-identical summary
//! state.
//!
//! The reference below reimplements the old router's semantics verbatim
//! in one thread; `run_sharded` is compared against it over a topology
//! grid, with three sinks of increasing strictness: an order-recording
//! sink (exact per-shard subsequence + flush boundaries), a CountSketch
//! (bit-identical tables), and a 1-pass WORp sampler (batch-boundary
//! sensitive — candidate shrink timing depends on block edges, so
//! bit-identical encodes prove the boundaries match too).

use worp::api::{Persist, StreamSummary};
use worp::data::zipf::ZipfStream;
use worp::data::Element;
use worp::pipeline::shard::Router;
use worp::pipeline::{run_sharded, PipelineOpts, ScanFn};
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::SamplerConfig;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::SketchParams;

/// The old router, reimplemented as the reference: one sequential pass
/// over the stream, hash-routing each element into a per-shard buffer
/// that is flushed (via `process_batch`) whenever it reaches `batch`
/// elements, with partial buffers flushed at end-of-stream.
fn reference_router<S, F>(stream: &[Element], opts: PipelineOpts, make: F) -> Vec<S>
where
    S: StreamSummary,
    F: Fn(usize) -> S,
{
    let router = Router::new(opts.workers);
    let mut states: Vec<S> = (0..opts.workers).map(&make).collect();
    let mut buffers: Vec<Vec<Element>> = (0..opts.workers)
        .map(|_| Vec::with_capacity(opts.batch))
        .collect();
    for e in stream {
        let w = router.route(e.key);
        buffers[w].push(*e);
        if buffers[w].len() == opts.batch {
            states[w].process_batch(&buffers[w]);
            buffers[w].clear();
        }
    }
    for (w, buf) in buffers.iter().enumerate() {
        if !buf.is_empty() {
            states[w].process_batch(buf);
        }
    }
    states
}

/// An order-recording sink: every element in arrival order, plus the
/// flush boundaries (so block edges are part of the comparison).
#[derive(Clone, Default)]
struct TraceSink {
    elems: Vec<Element>,
    boundaries: Vec<usize>,
}

impl StreamSummary for TraceSink {
    fn process(&mut self, e: &Element) {
        self.elems.push(*e);
    }

    fn process_batch(&mut self, batch: &[Element]) {
        self.elems.extend_from_slice(batch);
        self.boundaries.push(self.elems.len());
    }

    fn process_block(&mut self, block: &worp::data::ElementBlock) {
        self.elems.extend(block.iter());
        self.boundaries.push(self.elems.len());
    }

    fn size_words(&self) -> usize {
        0
    }

    fn processed(&self) -> u64 {
        self.elems.len() as u64
    }
}

fn topology_grid() -> Vec<PipelineOpts> {
    let mut grid = Vec::new();
    for workers in [1usize, 2, 3, 5] {
        for batch in [1usize, 7, 64, 1000, 100_000] {
            grid.push(PipelineOpts::new(workers, batch).unwrap());
        }
    }
    grid
}

#[test]
fn partitioning_preserves_per_shard_order_and_block_edges() {
    let stream: Vec<Element> = ZipfStream::new(500, 1.1, 30_000, 5).collect();
    for opts in topology_grid() {
        let reference = reference_router(&stream, opts, |_| TraceSink::default());
        let (parallel, metrics) = run_sharded(&stream, opts, |_| TraceSink::default()).unwrap();
        assert_eq!(metrics.elements() as usize, stream.len());
        for (w, (r, p)) in reference.iter().zip(&parallel).enumerate() {
            assert_eq!(
                r.elems, p.elems,
                "shard {w} order diverged (workers={} batch={})",
                opts.workers, opts.batch
            );
            assert_eq!(
                r.boundaries, p.boundaries,
                "shard {w} block edges diverged (workers={} batch={})",
                opts.workers, opts.batch
            );
        }
    }
}

#[test]
fn partitioning_is_bit_identical_for_sketch_state() {
    let stream: Vec<Element> = ZipfStream::new(300, 1.0, 20_000, 9).collect();
    for opts in topology_grid() {
        let make = |_w: usize| CountSketch::new(SketchParams::new(5, 128, 7));
        let reference = reference_router(&stream, opts, make);
        let (parallel, _) = run_sharded(&stream, opts, make).unwrap();
        for (w, (r, p)) in reference.iter().zip(&parallel).enumerate() {
            assert_eq!(
                r.table(),
                p.table(),
                "shard {w} table diverged (workers={} batch={})",
                opts.workers,
                opts.batch
            );
            assert_eq!(r.processed(), p.processed());
        }
    }
}

#[test]
fn partitioning_is_bit_identical_for_batch_sensitive_sampler() {
    // worp1's candidate shrink fires on block edges: only identical
    // per-shard subsequences AND identical block boundaries reproduce the
    // old router's state bit-for-bit (compared via canonical encoding)
    let stream: Vec<Element> = ZipfStream::new(2_000, 1.2, 15_000, 3).collect();
    let cfg = SamplerConfig::new(1.0, 8)
        .with_seed(13)
        .with_domain(2_000)
        .with_sketch_shape(5, 512);
    for opts in topology_grid() {
        let make = |_w: usize| OnePassWorp::new(cfg.clone());
        let reference = reference_router(&stream, opts, make);
        let (parallel, _) = run_sharded(&stream, opts, make).unwrap();
        for (w, (r, p)) in reference.iter().zip(&parallel).enumerate() {
            assert_eq!(
                r.encode(),
                p.encode(),
                "shard {w} worp1 state diverged (workers={} batch={})",
                opts.workers,
                opts.batch
            );
        }
    }
}

#[test]
fn generator_and_vec_sources_agree() {
    // the same stream through a materialized Vec and through a per-worker
    // regenerating ScanFn must land in identical shard states
    let n = 20_000u64;
    let opts = PipelineOpts::new(3, 256).unwrap();
    let make = |_w: usize| CountSketch::new(SketchParams::new(5, 64, 21));
    let vec_stream: Vec<Element> = ZipfStream::new(400, 1.0, n, 17).collect();
    let (from_vec, _) = run_sharded(&vec_stream, opts, make).unwrap();
    let (from_gen, _) =
        run_sharded(&ScanFn(|| ZipfStream::new(400, 1.0, n, 17)), opts, make).unwrap();
    for (a, b) in from_vec.iter().zip(&from_gen) {
        assert_eq!(a.table(), b.table());
        assert_eq!(a.processed(), b.processed());
    }
}

#[test]
fn degenerate_topologies() {
    // empty stream: every worker returns its pristine state
    let empty: Vec<Element> = Vec::new();
    let opts = PipelineOpts::new(4, 16).unwrap();
    let (states, metrics) = run_sharded(&empty, opts, |_| TraceSink::default()).unwrap();
    assert_eq!(metrics.elements(), 0);
    assert!(states.iter().all(|s| s.elems.is_empty()));

    // more workers than distinct keys: idle shards stay empty, totals add
    let stream: Vec<Element> = (0..100u64).map(|_| Element::new(1, 1.0)).collect();
    let opts = PipelineOpts::new(8, 7).unwrap();
    let reference = reference_router(&stream, opts, |_| TraceSink::default());
    let (parallel, _) = run_sharded(&stream, opts, |_| TraceSink::default()).unwrap();
    for (r, p) in reference.iter().zip(&parallel) {
        assert_eq!(r.elems, p.elems);
        assert_eq!(r.boundaries, p.boundaries);
    }
    let total: usize = parallel.iter().map(|s| s.elems.len()).sum();
    assert_eq!(total, 100);
}
