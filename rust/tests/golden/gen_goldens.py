#!/usr/bin/env python3
"""Generator for the committed golden-vector fixtures (tests/golden/*.worp).

This script is an independent, bit-exact reimplementation of the crate's
persistence codec (rust/src/codec/) for a fixed set of summaries. The
Rust test suite (tests/persist_golden.rs) builds the same summaries
through the real encoder and asserts byte equality with these files —
locking the wire format against silent drift from *either* side.

Every fixture is chosen so that no transcendental floating-point
operation enters any payload (empty sketches, or integer-valued inputs
whose sums are exact in IEEE-754), so the bytes are reproducible from
first principles with plain integer arithmetic plus struct.pack.

Regenerate with:  python3 rust/tests/golden/gen_goldens.py
"""

import math
import os
import struct

M = (1 << 64) - 1

# --- the crate's hashing substrate (util/rng.rs, util/hashing.rs) ---------


def rotl(x, n):
    return ((x << n) | (x >> (64 - n))) & M


def splitmix_next(state):
    state = (state + 0x9E3779B97F4A7C15) & M
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M
    z = z ^ (z >> 31)
    return state, z


def mix64(x):
    _, z = splitmix_next(x)
    return z


def hash64(seed, key):
    h = seed ^ 0x9E3779B97F4A7C15
    h = mix64(h ^ key)
    h = mix64(((h + 0x6A09E667F3BCC909) & M) ^ rotl(key, 32))
    return h


def fnv_fold(seed, chunks):
    """hash_bytes / hash_bytes2: keyed FNV-1a over the concatenated
    chunks, finished with one SplitMix round (util/hashing.rs)."""
    h = 0xCBF29CE484222325 ^ seed
    for data in chunks:
        for b in data:
            h ^= b
            h = (h * 0x00000100000001B3) & M
    return mix64(h ^ rotl(seed, 17))


def hash_bytes(seed, data):
    return fnv_fold(seed, [data])


CHECKSUM_SEED = 0xC0DEC0DE5EED0001
FP_SEED = 0xF16E5EED


def fp_new(tag):
    return hash_bytes(FP_SEED, tag.encode())


def fp_with(fp, x):
    return hash64(fp, x)


def f64_bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def fp_with_f64(fp, v):
    return fp_with(fp, f64_bits(v))


def rng_state(seed):
    sm = seed
    out = []
    for _ in range(4):
        sm, z = splitmix_next(sm)
        out.append(z)
    return out


# --- SketchHasher (util/hashing.rs) ---------------------------------------


def coords_of(seed, key):
    h1 = hash64(seed, key)
    h2 = hash64(seed ^ 0x5851F42D4C957F2D, key) | 1
    return h1, h2


def row_word(c, row):
    h1, h2 = c
    m = (h1 + row * h2) & M
    m = ((m ^ (m >> 30)) * 0xBF58476D1CE4E5B9) & M
    return m ^ (m >> 31)


def bucket_sign(seed, width, key, row):
    m = row_word(coords_of(seed, key), row)
    b = (m * width) >> 64
    s = 1.0 if (m & 1) == 0 else -1.0
    return b, s


# --- wire primitives (codec/wire.rs) --------------------------------------


def u8(x):
    return struct.pack("<B", x)


def u16(x):
    return struct.pack("<H", x)


def u64(x):
    return struct.pack("<Q", x)


def f64(x):
    return struct.pack("<d", x)


MAGIC = b"WORP"
VERSION = 1

TAG = {
    "countsketch": 1,
    "countmin": 2,
    "anyrhh": 3,
    "spacesaving": 4,
    "topk": 5,
    "windowsketch": 6,
    "exact": 7,
    "worp1": 8,
    "worp2pass1": 9,
    "worp2pass2": 10,
    "worp2": 11,
    "tv": 12,
    "windowed": 13,
    "oracle": 14,
    "precision": 15,
    "wr": 20,
    "decayed": 21,
}


def envelope(tag, fingerprint, payload):
    head = MAGIC + u16(VERSION) + u16(tag) + u64(len(payload)) + u64(fingerprint)
    assert len(head) == 24
    checksum = fnv_fold(CHECKSUM_SEED, [head, payload])
    return head + u64(checksum) + payload


def nested(env):
    return u64(len(env)) + env


# --- per-type payloads (mirroring each Persist impl) ----------------------


def countsketch_env(rows, width, seed, elements=()):
    """CountSketch::with_shape(rows, width, seed) after processing
    `elements` (hasher seed == params seed)."""
    table = [0.0] * (rows * width)
    for key, val in elements:
        for r in range(rows):
            b, s = bucket_sign(seed, width, key, r)
            table[r * width + b] += s * val
    payload = u64(rows) + u64(width) + u64(seed) + u64(len(elements)) + u64(len(table))
    for c in table:
        payload += f64(c)
    fp = fp_with(fp_with(fp_with(fp_new("countsketch"), rows), width), seed)
    return envelope(TAG["countsketch"], fp, payload)


def countmin_env(rows, width, seed, elements=()):
    """CountMin::with_shape(rows, width, seed): hasher seed is
    params.seed ^ 0xC0FFEE; no signs."""
    hseed = seed ^ 0xC0FFEE
    table = [0.0] * (rows * width)
    for key, val in elements:
        for r in range(rows):
            b, _ = bucket_sign(hseed, width, key, r)
            table[r * width + b] += val
    payload = u64(rows) + u64(width) + u64(seed) + u64(len(elements)) + u64(len(table))
    for c in table:
        payload += f64(c)
    fp = fp_with(fp_with(fp_with(fp_new("countmin"), rows), width), seed)
    return envelope(TAG["countmin"], fp, payload)


def anyrhh_env(q, rows, width, seed, inner_env):
    variant = 1 if q >= 2.0 else 2
    payload = u8(variant) + nested(inner_env)
    fp = fp_new("anyrhh")
    fp = fp_with_f64(fp, q)
    fp = fp_with(fp_with(fp_with(fp, rows), width), seed)
    return envelope(TAG["anyrhh"], fp, payload)


def spacesaving_env(capacity, processed, counters):
    payload = u64(capacity) + u64(processed) + u64(len(counters))
    for key in sorted(counters):
        count, over = counters[key]
        payload += u64(key) + f64(count) + f64(over)
    fp = fp_with(fp_new("spacesaving"), capacity)
    return envelope(TAG["spacesaving"], fp, payload)


def topk_env(cap, merge_cap, entries):
    payload = u64(cap) + u64(merge_cap) + u64(len(entries))
    for key in sorted(entries):
        pri, val = entries[key]
        payload += u64(key) + f64(pri) + f64(val)
    fp = fp_with(fp_with(fp_new("topk"), cap), merge_cap)
    return envelope(TAG["topk"], fp, payload)


def windowsketch_env(rows, width, seed, window, buckets, now=0):
    span = window // buckets
    active = countsketch_env(rows, width, seed)
    payload = (
        u64(rows)
        + u64(width)
        + u64(seed)
        + u64(window)
        + u64(span)
        + u64(now)
        + nested(active)
        + u64(0)  # empty ring
    )
    fp = fp_new("windowsketch")
    for x in (rows, width, seed, window, span):
        fp = fp_with(fp, x)
    return envelope(TAG["windowsketch"], fp, payload)


DIST_EXP = 1


def sampler_config_bytes(cfg):
    return (
        f64(cfg["p"])
        + u64(cfg["k"])
        + f64(cfg["q"])
        + u64(cfg["seed"])
        + u64(cfg["n"])
        + f64(cfg["delta"])
        + f64(cfg["eps"])
        + u64(cfg["rows"])
        + u64(cfg["width"])
        + u8(cfg["dist"])
    )


def config_fp(tag, cfg):
    fp = fp_new(tag)
    fp = fp_with_f64(fp, cfg["p"])
    fp = fp_with(fp, cfg["k"])
    fp = fp_with_f64(fp, cfg["q"])
    fp = fp_with(fp, cfg["seed"])
    fp = fp_with(fp, cfg["n"])
    fp = fp_with_f64(fp, cfg["delta"])
    fp = fp_with_f64(fp, cfg["eps"])
    fp = fp_with(fp, cfg["rows"])
    fp = fp_with(fp, cfg["width"])
    fp = fp_with(fp, cfg["dist"])  # with_dist: Exp -> 1, Uniform -> 2
    return fp


def make_cfg(p, k, seed, n, rows=0, width=0):
    return {
        "p": p,
        "k": k,
        "q": 2.0,
        "seed": seed,
        "n": n,
        "delta": 0.01,
        "eps": 1.0 / 3.0,
        "rows": rows,
        "width": width,
        "dist": DIST_EXP,
    }


def exact_env(cfg, processed, freqs):
    payload = sampler_config_bytes(cfg) + u64(processed) + u64(len(freqs))
    for key in sorted(freqs):
        payload += u64(key) + f64(freqs[key])
    return envelope(TAG["exact"], config_fp("exact", cfg), payload)


def worp1_env(cfg):
    """OnePassWorp::new(cfg), empty. Sketch: AnyRhh CountSketch with
    params (resolved_rows, resolved_width, cfg.seed ^ 0x1AB5)."""
    rows, width = cfg["rows"], cfg["width"]
    sseed = cfg["seed"] ^ 0x1AB5
    inner = countsketch_env(rows, width, sseed)
    any_env = anyrhh_env(2.0, rows, width, sseed, inner)
    payload = sampler_config_bytes(cfg) + u64(0) + nested(any_env) + u64(0)
    return envelope(TAG["worp1"], config_fp("worp1", cfg), payload)


def worp2pass1_env(cfg):
    rows, width = cfg["rows"], cfg["width"]
    sseed = cfg["seed"] ^ 0x2AB5
    inner = countsketch_env(rows, width, sseed)
    any_env = anyrhh_env(2.0, rows, width, sseed, inner)
    payload = sampler_config_bytes(cfg) + u64(0) + nested(any_env)
    return envelope(TAG["worp2pass1"], config_fp("worp2-pass1", cfg), payload)


def worp2_env(cfg):
    """TwoPassWorp::new(cfg), empty (pass I)."""
    payload = u8(0) + nested(worp2pass1_env(cfg))
    fp = fp_with(config_fp("worp2", cfg), 0)  # .with(pass_index)
    return envelope(TAG["worp2"], fp, payload)


def worp2pass2_env(cfg):
    """TwoPassWorpPass1::new(cfg).into_pass2(), empty: TopK(4(k+1), 6(k+1))."""
    rows, width = cfg["rows"], cfg["width"]
    sseed = cfg["seed"] ^ 0x2AB5
    inner = countsketch_env(rows, width, sseed)
    any_env = anyrhh_env(2.0, rows, width, sseed, inner)
    cap, merge_cap = 4 * (cfg["k"] + 1), 6 * (cfg["k"] + 1)
    tk = topk_env(cap, merge_cap, {})
    payload = sampler_config_bytes(cfg) + u64(0) + nested(any_env) + nested(tk)
    return envelope(TAG["worp2pass2"], config_fp("worp2-pass2", cfg), payload)


def oracle_env(p, seed, processed, freqs):
    payload = f64(p) + u64(seed) + u64(processed)
    for s in rng_state(seed ^ 0x0AC1E):
        payload += u64(s)
    payload += u64(len(freqs))
    for key in sorted(freqs):
        payload += u64(key) + f64(freqs[key])
    fp = fp_with(fp_with_f64(fp_new("oracle-lp"), p), seed)
    return envelope(TAG["oracle"], fp, payload)


def precision_env(p, seed, rows, width):
    """PrecisionSampler::new(p, seed, rows, width), empty: sketch seed is
    seed ^ 0x9C13, cand_cap = 4 * width."""
    sk = countsketch_env(rows, width, seed ^ 0x9C13)
    payload = f64(p) + u64(seed) + u64(4 * width) + u64(0) + nested(sk) + u64(0)
    fp = fp_new("precision-lp")
    fp = fp_with_f64(fp, p)
    for x in (seed, rows, width):
        fp = fp_with(fp, x)
    return envelope(TAG["precision"], fp, payload)


def tv_env(p, k, n_domain, seed, r):
    """TvSampler::new(TvSamplerConfig::new(p, k, n_domain, seed,
    Oracle).with_r(r)), empty."""
    rhh_rows, rhh_width = 7, max(8 * k, 64)
    inner_rows, inner_width = 5, max(4 * k, 128)
    rhh = countsketch_env(rhh_rows, rhh_width, seed ^ 0x0FF5E7)
    payload = (
        f64(p)
        + u64(k)
        + u64(r)
        + u64(seed)
        + u8(1)  # Oracle
        + u64(rhh_rows)
        + u64(rhh_width)
        + u64(inner_rows)
        + u64(inner_width)
        + u64(0)  # processed
        + nested(rhh)
        + u64(r)
    )
    for i in range(r):
        oseed = seed ^ ((i * 0xD1E5) & M)
        payload += nested(oracle_env(p, oseed, 0, {}))
    fp = fp_with_f64(fp_new("tv1pass"), p)
    for x in (k, r, seed, 1, rhh_rows, rhh_width, inner_rows, inner_width):
        fp = fp_with(fp, x)
    return envelope(TAG["tv"], fp, payload)


def windowed_env(cfg, window, buckets):
    """WindowedWorp::new(cfg, window, buckets), empty. Sketch params:
    (resolved_rows, resolved_width_one_pass, cfg.seed ^ 0x3AB5)."""
    rows, width = cfg["rows"], cfg["width"]
    ws = windowsketch_env(rows, width, cfg["seed"] ^ 0x3AB5, window, buckets)
    payload = sampler_config_bytes(cfg) + u64(window) + u64(0) + nested(ws) + u64(0)
    span = window // buckets
    fp = fp_with(fp_with(config_fp("windowed", cfg), window), span)
    return envelope(TAG["windowed"], fp, payload)


def wr_env(cfg):
    """WrReservoir::new(cfg), empty. The reservoir RNG is seeded with
    cfg.seed ^ "wRES" and consumes nothing before the first element; the
    frequency sketch is a CountSketch at the config's explicit shape with
    seed cfg.seed ^ "WRSk" (the 0x5EED_0057_5253_6B01 salt). Every slot
    is (exponent=+inf, key=0, next_jump=0.0)."""
    rows, width = cfg["rows"], cfg["width"]
    sk = countsketch_env(rows, width, cfg["seed"] ^ 0x5EED00575253_6B01)
    payload = sampler_config_bytes(cfg) + f64(0.0) + u64(0)
    for s in rng_state(cfg["seed"] ^ 0x77524553):
        payload += u64(s)
    payload += u64(cfg["k"])
    for _ in range(cfg["k"]):
        payload += f64(math.inf) + u64(0) + f64(0.0)
    payload += nested(sk)
    return envelope(TAG["wr"], config_fp("wr", cfg), payload)


def decayed_env(cfg, kind, rate, now, processed, entries):
    """DecayedWorp after *single-touch* updates only: each key's stored
    sum is `0.0 * carry + val == val` exactly, so no transcendental
    enters the payload. `entries` maps key -> (last_tick, acc)."""
    payload = (
        sampler_config_bytes(cfg)
        + u8(kind)
        + f64(rate)
        + u64(now)
        + u64(processed)
        + u64(len(entries))
    )
    for key in sorted(entries):
        last, acc = entries[key]
        payload += u64(key) + u64(last) + f64(acc)
    fp = fp_with_f64(fp_with(config_fp("decayed", cfg), kind), rate)
    return envelope(TAG["decayed"], fp, payload)


# --- fixtures -------------------------------------------------------------


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    cfg8 = make_cfg(1.0, 4, 42, 100, rows=3, width=16)
    fixtures = {
        # fed fixtures: integer-exact arithmetic only
        "countsketch.worp": countsketch_env(
            3, 8, 42, [(1, 2.0), (2, -3.0), (1, 1.0)]
        ),
        "countmin.worp": countmin_env(3, 8, 42, [(1, 2.0), (2, 3.0)]),
        "spacesaving.worp": spacesaving_env(
            4, 3, {5: (2.0, 0.0), 7: (2.5, 0.0)}
        ),
        "topk.worp": topk_env(3, 4, {1: (10.0, 5.0), 2: (5.0, 1.0)}),
        "exact.worp": exact_env(make_cfg(1.0, 8, 42, 100), 3, {1: 3.0, 2: 3.0}),
        "oracle.worp": oracle_env(1.0, 42, 1, {1: 2.0}),
        # empty fixtures: lock layout + fingerprints + nested composition
        "anyrhh.worp": anyrhh_env(1.0, 3, 8, 42, countmin_env(3, 8, 42)),
        "windowsketch.worp": windowsketch_env(3, 8, 42, 100, 10),
        "worp1.worp": worp1_env(cfg8),
        "worp2.worp": worp2_env(cfg8),
        "worp2pass2.worp": worp2pass2_env(cfg8),
        "tv.worp": tv_env(1.0, 2, 16, 42, 3),
        "windowed.worp": windowed_env(cfg8, 50, 5),
        "precision.worp": precision_env(1.0, 42, 3, 8),
        "wr.worp": wr_env(cfg8),
        # three scalar process() calls on distinct keys: ticks 1, 2, 3
        "decayed.worp": decayed_env(
            make_cfg(1.0, 8, 42, 100),
            1,  # DecayKind::Exponential
            0.5,
            3,
            3,
            {1: (1, 2.0), 5: (2, -3.0), 9: (3, 4.0)},
        ),
    }
    for name, data in fixtures.items():
        path = os.path.join(here, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes")
    # sanity: r used by tv matches ceil-formula floor (documentation only)
    assert max(2 * 2, math.ceil(4 * 2 * math.log(16))) == 23


if __name__ == "__main__":
    main()
