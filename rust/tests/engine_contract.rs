//! Engine contract suite (ISSUE 5 acceptance):
//!
//! 1. **Concurrent ingest ≡ offline run** — N connections feeding one
//!    instance produce the same merged summary as a single offline
//!    `Coordinator` run over the same stream: merge-law for every path,
//!    *bit-identical encodes* for order-insensitive summaries, both at
//!    the library level and through real TCP connections.
//! 2. **Snapshot → restore → continue ≡ uninterrupted** — including
//!    pending (unflushed) elements, over the wire.
//! 3. **Malformed / truncated protocol frames** are answered with typed
//!    errors and a closed connection — never a panic, never a hang, and
//!    the server keeps serving fresh connections afterwards.
//!
//! Plus the wire-speed serving contract (ISSUE 7):
//!
//! 4. **Pipelined ≡ lockstep ≡ offline**, bit-identical, over real TCP.
//! 5. **Accept-path liveness** against never-reading over-cap peers.
//! 6. **Idle eviction** with a typed error frame, server keeps serving.
//! 7. **Client poisoning** after a transport error; typed engine errors
//!    do not poison.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use worp::coordinator::{Coordinator, VecSource};
use worp::data::zipf::zipf_exact_stream;
use worp::data::{Element, ElementBlock};
use worp::engine::client::Client;
use worp::engine::proto::{self, op};
use worp::engine::server::{ServeOpts, Server};
use worp::engine::{Engine, EngineOpts};
use worp::pipeline::PipelineOpts;
use worp::{Error, WorSampler, Worp};

const SHARDS: usize = 3;
const BATCH: usize = 128;

fn spec(seed: u64) -> Worp {
    Worp::p(1.0).k(16).seed(seed).domain(600).sketch_shape(7, 1024)
}

fn proto_spec(method: &str, seed: u64) -> proto::InstanceSpec {
    let mut cfg = worp::config::PipelineConfig::default();
    cfg.method = method.into();
    cfg.k = 16;
    cfg.seed = seed;
    cfg.n = 600;
    cfg.rows = 7;
    cfg.width = 1024;
    proto::InstanceSpec::from_config(&cfg)
}

fn stream() -> Vec<Element> {
    zipf_exact_stream(600, 1.2, 1e4, 3, 21) // 1800 elements
}

fn blocks_of(elems: &[Element], chunk: usize) -> Vec<ElementBlock> {
    elems.chunks(chunk).map(ElementBlock::from_elements).collect()
}

fn merged_encode(engine: &Engine, name: &str) -> Vec<u8> {
    let mut out = Vec::new();
    engine
        .instance(name)
        .unwrap()
        .merged()
        .unwrap()
        .encode_state(&mut out);
    out
}

fn start_server(engine: Arc<Engine>) -> Server {
    Server::start(engine, "127.0.0.1:0", ServeOpts::default()).unwrap()
}

fn connect(srv: &Server) -> Client {
    Client::connect(&srv.local_addr().to_string())
        .unwrap()
        .with_timeout(Duration::from_secs(20))
        .unwrap()
}

// ---------------------------------------------------------------------------
// 1. concurrent ingest ≡ offline run

#[test]
fn concurrent_ingest_equals_offline_run_bit_identical() {
    // the exact baseline is ingest-order-insensitive per key, so with
    // key-disjoint connections the merged state must be BIT-identical to
    // one offline pass — the merge law with no tolerance at all
    let elems = stream();
    let conns = 4;
    let w = spec(5).exact();
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    engine.create("live", &w).unwrap();
    engine.create("offline", &w).unwrap();
    std::thread::scope(|scope| {
        for c in 0..conns {
            let engine = Arc::clone(&engine);
            let part: Vec<Element> = elems
                .iter()
                .filter(|e| e.key % conns as u64 == c as u64)
                .copied()
                .collect();
            scope.spawn(move || {
                for b in blocks_of(&part, 97) {
                    engine.ingest("live", &b).unwrap();
                }
            });
        }
    });
    engine.flush("live").unwrap();
    let m = engine.ingest_source("offline", &elems).unwrap();
    assert_eq!(m.elements() as usize, elems.len());
    assert_eq!(
        merged_encode(&engine, "live"),
        merged_encode(&engine, "offline"),
        "4 concurrent connections must merge to the offline summary bit-for-bit"
    );
    // ... and the offline engine path is the coordinator path
    let coord = Coordinator::new(
        w.sampler_config().unwrap(),
        PipelineOpts::new(SHARDS, BATCH).unwrap(),
    );
    let (coord_sample, _) = coord
        .run_dyn(&VecSource(elems), w.build().unwrap())
        .unwrap();
    let live = engine.sample("live").unwrap();
    assert_eq!(live.entries, coord_sample.entries);
    assert_eq!(live.tau.to_bits(), coord_sample.tau.to_bits());
}

#[test]
fn concurrent_wire_ingest_equals_offline_run() {
    // the same law through real TCP connections
    let elems = stream();
    let conns = 3;
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    connect(&srv).create("wire", &proto_spec("exact", 5)).unwrap();
    std::thread::scope(|scope| {
        for c in 0..conns {
            let part: Vec<Element> = elems
                .iter()
                .filter(|e| e.key % conns as u64 == c as u64)
                .copied()
                .collect();
            let mut client = connect(&srv);
            scope.spawn(move || {
                for b in blocks_of(&part, 211) {
                    client.ingest("wire", &b).unwrap();
                }
            });
        }
    });
    let mut client = connect(&srv);
    client.flush("wire").unwrap();
    assert_eq!(client.stats("wire").unwrap().processed as usize, elems.len());

    let w = spec(5).exact();
    let coord = Coordinator::new(
        w.sampler_config().unwrap(),
        PipelineOpts::new(SHARDS, BATCH).unwrap(),
    );
    let (offline, _) = coord.run_dyn(&VecSource(elems), w.build().unwrap()).unwrap();
    let served = client.sample("wire").unwrap();
    assert_eq!(served.entries, offline.entries);
    assert_eq!(served.tau.to_bits(), offline.tau.to_bits());
}

#[test]
fn sequential_served_one_pass_is_bit_identical_to_offline() {
    // worp1 is block-boundary sensitive, so this holds only because the
    // engine reproduces the offline per-shard boundaries exactly —
    // through the whole network stack, with frame chunking (1000) that
    // is deliberately unaligned with the engine batch (128)
    let elems = stream();
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    let mut client = connect(&srv);
    client.create("seq", &proto_spec("1pass", 5)).unwrap();
    for b in blocks_of(&elems, 1000) {
        client.ingest("seq", &b).unwrap();
    }
    client.flush("seq").unwrap();
    let served = client.sample("seq").unwrap();

    let w = spec(5);
    let coord = Coordinator::new(
        w.sampler_config().unwrap(),
        PipelineOpts::new(SHARDS, BATCH).unwrap(),
    );
    let (offline, _) = coord.run_dyn(&VecSource(elems), w.build().unwrap()).unwrap();
    assert_eq!(served.entries, offline.entries);
    assert_eq!(served.tau.to_bits(), offline.tau.to_bits());
}

#[test]
fn served_two_pass_advances_like_the_coordinator() {
    let elems = stream();
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    let mut client = connect(&srv);
    client.create("tp", &proto_spec("2pass", 7)).unwrap();
    for b in blocks_of(&elems, 500) {
        client.ingest("tp", &b).unwrap();
    }
    client.flush("tp").unwrap();
    // mid-run sampling is a typed state error over the wire
    assert!(matches!(client.sample("tp"), Err(Error::State(_))));
    assert_eq!(client.advance("tp").unwrap(), 1);
    for b in blocks_of(&elems, 500) {
        client.ingest("tp", &b).unwrap();
    }
    client.flush("tp").unwrap();
    let served = client.sample("tp").unwrap();

    let w = spec(7).two_pass();
    let coord = Coordinator::new(
        w.sampler_config().unwrap(),
        PipelineOpts::new(SHARDS, BATCH).unwrap(),
    );
    let (offline, _) = coord.run_dyn(&VecSource(elems), w.build().unwrap()).unwrap();
    assert_eq!(served.entries, offline.entries);
    assert_eq!(served.tau.to_bits(), offline.tau.to_bits());
}

// ---------------------------------------------------------------------------
// 2. snapshot → restore → continue ≡ uninterrupted

#[test]
fn wire_snapshot_restore_continue_equals_uninterrupted() {
    let elems = stream();
    let (head, tail) = elems.split_at(777); // mid-block: pending travels too
    let engine_a = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv_a = start_server(Arc::clone(&engine_a));
    let mut ca = connect(&srv_a);
    ca.create("mv", &proto_spec("1pass", 11)).unwrap();
    for b in blocks_of(head, 250) {
        ca.ingest("mv", &b).unwrap();
    }
    let snap = ca.snapshot("mv").unwrap();

    // move the instance to a second server and finish the stream there
    let engine_b = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv_b = start_server(Arc::clone(&engine_b));
    let mut cb = connect(&srv_b);
    assert_eq!(cb.restore(&snap).unwrap(), "mv");
    for b in blocks_of(tail, 250) {
        cb.ingest("mv", &b).unwrap();
    }
    cb.flush("mv").unwrap();

    // the reference never stopped
    engine_b.create_from_proto("ref", spec(11).build().unwrap()).unwrap();
    for b in blocks_of(&elems, 250) {
        engine_b.ingest("ref", &b).unwrap();
    }
    engine_b.flush("ref").unwrap();
    assert_eq!(
        merged_encode(&engine_b, "mv"),
        merged_encode(&engine_b, "ref"),
        "snapshot -> restore -> continue must be bit-identical to never stopping"
    );
    // restoring over a live name is refused with a typed error
    assert!(matches!(cb.restore(&snap), Err(Error::Config(_))));
}

// ---------------------------------------------------------------------------
// 3. malformed frames: typed errors, no panic, no hang

/// Read one response frame off a raw socket (20 s cap so a hung server
/// fails the test instead of wedging it).
fn read_resp(stream: &mut TcpStream) -> worp::Result<Option<proto::Frame>> {
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    proto::read_frame(stream, proto::DEFAULT_MAX_FRAME)
}

#[test]
fn malformed_frames_get_typed_errors_never_a_panic_or_hang() {
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    let addr = srv.local_addr().to_string();

    // (a) garbage magic: one typed error frame, then the connection closes
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"NOPE-not-a-frame-at-all-xxxxxxxx").unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
        assert!(matches!(proto::decode_error(&f.payload), Error::Codec(_)));
        assert!(matches!(read_resp(&mut s), Ok(None) | Err(_)), "connection must close");
    }

    // (b) frame truncated mid-header: error frame (or clean close), no hang
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::PING, b"");
        s.write_all(&buf[..10]).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
        assert!(matches!(proto::decode_error(&f.payload), Error::Codec(_)));
    }

    // (c) checksum flip: typed error
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::LIST, b"");
        buf[20] ^= 0xFF; // inside the checksum field
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
        assert!(matches!(proto::decode_error(&f.payload), Error::Codec(_)));
    }

    // (d) absurd length field: refused before any allocation
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::PING, b"");
        buf[8..16].copy_from_slice(&(u64::MAX).to_le_bytes());
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
    }

    // (e) a well-framed but unknown opcode errors AND keeps the
    // connection usable (framing was fine)
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, 0x0666, b"");
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::PING, b"");
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("ping still answered");
        assert_eq!(f.opcode, proto::resp_ok(op::PING));
    }

    // (f) a malformed *payload* in a valid frame is a typed error, and
    // the connection survives
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::SAMPLE, &[0xFF; 3]); // truncated name
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("an error frame");
        assert_eq!(f.opcode, proto::RESP_ERR);
        assert!(matches!(proto::decode_error(&f.payload), Error::Codec(_)));
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::PING, b"");
        s.write_all(&buf).unwrap();
        assert_eq!(read_resp(&mut s).unwrap().unwrap().opcode, proto::resp_ok(op::PING));
    }

    // after all that abuse, the server still serves fresh clients
    let mut c = connect(&srv);
    c.ping().unwrap();
    assert!(c.list().unwrap().is_empty());
}

// ---------------------------------------------------------------------------
// 4–7. wire-speed serving: pipelining, liveness, eviction, poisoning

#[test]
fn pipelined_equals_lockstep_equals_offline_bit_identical() {
    // pipelining changes only ack scheduling: the server handles frames
    // in arrival order, so a windowed pipelined session must be
    // BIT-identical to lockstep calls and to an offline sharded run —
    // even for the block-boundary-sensitive 1pass method, and with
    // deliberately different frame chunkings (97 vs 250 vs whole-stream)
    let elems = stream();
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv = start_server(Arc::clone(&engine));

    let mut lock = connect(&srv);
    lock.create("lock", &proto_spec("1pass", 5)).unwrap();
    for b in blocks_of(&elems, 250) {
        lock.ingest("lock", &b).unwrap();
    }
    lock.flush("lock").unwrap();

    let mut piped = connect(&srv).with_pipeline_window(5);
    piped.create("pipe", &proto_spec("1pass", 5)).unwrap();
    let mut pipe = piped.ingest_pipe("pipe").unwrap();
    let mut sent = 0u64;
    for b in blocks_of(&elems, 97) {
        sent += b.len() as u64;
        pipe.send(&b).unwrap();
    }
    assert!(pipe.in_flight() > 0, "the window must actually pipeline");
    assert_eq!(pipe.finish().unwrap(), sent);
    assert!(!piped.is_broken());
    piped.flush("pipe").unwrap();

    engine.create_from_proto("offline", spec(5).build().unwrap()).unwrap();
    engine.ingest_source("offline", &elems).unwrap();

    let lock_bytes = merged_encode(&engine, "lock");
    assert_eq!(
        lock_bytes,
        merged_encode(&engine, "pipe"),
        "pipelined ingest must merge to the lockstep summary bit-for-bit"
    );
    assert_eq!(
        lock_bytes,
        merged_encode(&engine, "offline"),
        "served ingest must merge to the offline sharded run bit-for-bit"
    );

    // ... and the served sample is the coordinator's offline sample
    let w = spec(5);
    let coord = Coordinator::new(
        w.sampler_config().unwrap(),
        PipelineOpts::new(SHARDS, BATCH).unwrap(),
    );
    let (offline, _) = coord.run_dyn(&VecSource(elems), w.build().unwrap()).unwrap();
    let served = piped.sample("pipe").unwrap();
    assert_eq!(served.entries, offline.entries);
    assert_eq!(served.tau.to_bits(), offline.tau.to_bits());
}

#[test]
fn accept_path_survives_never_reading_over_cap_peers() {
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let opts = ServeOpts { max_connections: 1, ..ServeOpts::default() };
    let srv = Server::start(Arc::clone(&engine), "127.0.0.1:0", opts).unwrap();
    let addr = srv.local_addr().to_string();

    let mut held = connect(&srv);
    held.ping().unwrap();

    // over-cap peers that never read their refusal frame: the refusal is
    // written under a short budget, so the accept thread must not stall
    let peers: Vec<TcpStream> =
        (0..8).map(|_| TcpStream::connect(&addr).unwrap()).collect();

    // free the slot; a fresh client must get in promptly, proving the
    // accept loop outlived the hostile peers
    drop(held);
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let admitted = loop {
        if let Ok(c) = Client::connect(&addr) {
            if let Ok(mut c) = c.with_timeout(Duration::from_secs(5)) {
                if c.ping().is_ok() {
                    break true;
                }
            }
        }
        if std::time::Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(admitted, "accept path stalled behind never-reading over-cap peers");
    drop(peers);
}

#[test]
fn idle_connections_are_evicted_with_a_typed_error() {
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let opts = ServeOpts {
        idle_timeout: Some(Duration::from_millis(300)),
        ..ServeOpts::default()
    };
    let srv = Server::start(Arc::clone(&engine), "127.0.0.1:0", opts).unwrap();
    let addr = srv.local_addr().to_string();

    let mut s = TcpStream::connect(&addr).unwrap();
    let mut buf = Vec::new();
    proto::put_frame(&mut buf, op::PING, b"");
    s.write_all(&buf).unwrap();
    let f = read_resp(&mut s).unwrap().expect("ping answered");
    assert_eq!(f.opcode, proto::resp_ok(op::PING));

    // go idle: the server must evict with a typed state error frame,
    // then close — never hold the fd forever
    let f = read_resp(&mut s).unwrap().expect("an eviction frame");
    assert_eq!(f.opcode, proto::RESP_ERR);
    let e = proto::decode_error(&f.payload);
    assert!(matches!(e, Error::State(_)), "eviction must be typed state, got {e:?}");
    assert!(e.to_string().contains("idle"), "{e}");
    assert!(
        matches!(read_resp(&mut s), Ok(None) | Err(_)),
        "connection must close after eviction"
    );

    // eviction is per-connection: the server keeps serving fresh clients
    let mut c = connect(&srv);
    c.ping().unwrap();
}

#[test]
fn poisoned_client_refuses_reuse_after_transport_error() {
    // a fake server answering the first frame with garbage: the client
    // must surface a codec error, mark itself broken, and fail every
    // further call fast with a typed state error — a desynced stream is
    // never silently reused
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut buf = [0u8; 256];
        let _ = std::io::Read::read(&mut s, &mut buf);
        s.write_all(b"garbage-garbage-garbage-garbage!").unwrap();
        s // keep the socket open until the test is done asserting
    });
    let mut c = Client::connect(&addr)
        .unwrap()
        .with_timeout(Duration::from_secs(20))
        .unwrap();
    let err = c.ping().unwrap_err();
    assert!(matches!(err, Error::Codec(_)), "got {err:?}");
    assert!(c.is_broken());
    let err = c.ping().unwrap_err();
    assert!(matches!(err, Error::State(_)), "got {err:?}");
    let err = c.flush("x").unwrap_err();
    assert!(matches!(err, Error::State(_)), "got {err:?}");
    drop(fake.join().unwrap());

    // typed engine errors must NOT poison — the transport is intact
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    let mut c = connect(&srv);
    assert!(matches!(c.sample("nope"), Err(Error::Config(_))));
    assert!(!c.is_broken());
    c.ping().unwrap();
}

#[test]
fn engine_errors_cross_the_wire_typed() {
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let srv = start_server(Arc::clone(&engine));
    let mut c = connect(&srv);
    // unknown instance
    assert!(matches!(c.sample("nope"), Err(Error::Config(_))));
    assert!(matches!(c.flush("nope"), Err(Error::Config(_))));
    // duplicate create
    c.create("dup", &proto_spec("exact", 1)).unwrap();
    assert!(matches!(c.create("dup", &proto_spec("exact", 1)), Err(Error::Config(_))));
    // invalid spec (p out of range) — rejected by the shared validation
    let mut bad = proto_spec("1pass", 1);
    bad.p = 9.0;
    assert!(matches!(c.create("badp", &bad), Err(Error::Config(_))));
    // advancing a single-pass summary is a state error
    assert!(matches!(c.advance("dup"), Err(Error::State(_))));
    // bad name
    assert!(matches!(c.create("bad name", &proto_spec("exact", 1)), Err(Error::Config(_))));
}
