//! Statistical correctness guards for the paper's distributional claims.
//!
//! | test | paper claim |
//! |---|---|
//! | `worp1_inclusion_matches_exact_ppswor_chi_square` | §5: 1-pass WORp outputs (approximate) p-ppswor samples — inclusion frequencies match the exact successive-WOR probabilities (here p = 1, enumerated on a small Zipf domain) |
//! | `wor_beats_wr_nrmse_on_skewed_stream` | §1/§7 (Fig 1, Table 3; Braverman–Ostrovsky–Vorsanger-style comparison): at fixed k, WOR estimates strictly beat WR on heavy-tailed data |
//!
//! Everything is seeded: the empirical statistics are identical on every
//! run, so the thresholds are regression bounds rather than flaky
//! hypothesis tests. Half the WORp trials ingest through `process_batch`
//! to tie the distributional guarantee to the columnar hot path.

use worp::api::StreamSummary;
use worp::data::stream::unaggregate;
use worp::data::zipf::zipf_frequencies;
use worp::estimate::{moment_estimate, wr_moment_estimate};
use worp::sampler::ppswor::perfect_ppswor;
use worp::sampler::tv1pass::ppswor_subset_probs;
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::wr::perfect_wr;
use worp::sampler::SamplerConfig;
use worp::util::stats::nrmse;

/// Exact per-key inclusion probabilities of a ppswor bottom-k sample,
/// by enumeration over all ordered prefixes (n ≤ 12).
fn exact_inclusion_probs(freqs: &[f64], p: f64, k: usize) -> Vec<f64> {
    let subset_probs = ppswor_subset_probs(freqs, p, k);
    let mut incl = vec![0.0; freqs.len()];
    for (subset, pr) in &subset_probs {
        for &x in subset {
            incl[x as usize] += pr;
        }
    }
    incl
}

#[test]
fn worp1_inclusion_matches_exact_ppswor_chi_square() {
    // Zipf[1] frequencies over a small domain where exact successive-WOR
    // probabilities are enumerable
    let n = 8;
    let k = 3;
    let freqs = zipf_frequencies(n, 1.0, 10.0);
    let incl = exact_inclusion_probs(&freqs, 1.0, k);
    let total: f64 = incl.iter().sum();
    assert!((total - k as f64).abs() < 1e-9, "inclusions sum to k");

    // one stream realization, replayed under independent sampler seeds;
    // the sketch is generous, so the 1-pass sample equals the perfect
    // ppswor sample that shares its hash-defined randomization
    let elems = unaggregate(&freqs, 2, false, 0x5EED);
    let trials: u64 = 3000;
    let mut counts = vec![0u64; n];
    for t in 0..trials {
        let cfg = SamplerConfig::new(1.0, k)
            .with_seed(0xBEEF_0000 + t)
            .with_domain(n)
            .with_sketch_shape(5, 512);
        let mut s = OnePassWorp::new(cfg);
        if t % 2 == 0 {
            for e in &elems {
                StreamSummary::process(&mut s, e);
            }
        } else {
            // alternate trials take the columnar batch path
            for chunk in elems.chunks(5) {
                StreamSummary::process_batch(&mut s, chunk);
            }
        }
        for key in s.sample().keys() {
            counts[key as usize] += 1;
        }
    }

    // chi-square-style statistic over per-key binomial inclusion counts
    // (negatively associated across keys, so the chi2_8 comparison is
    // conservative); E[stat] ≈ n under H0, threshold leaves ~5 sigma
    let mut stat = 0.0;
    for i in 0..n {
        let e = trials as f64 * incl[i];
        let var = trials as f64 * incl[i] * (1.0 - incl[i]);
        if var > 1e-9 {
            let d = counts[i] as f64 - e;
            stat += d * d / var;
        }
    }
    assert!(
        stat < 30.0,
        "chi-square statistic {stat:.2} too large; counts={counts:?}, expected={:?}",
        incl.iter().map(|p| p * trials as f64).collect::<Vec<_>>()
    );

    // and the heaviest key must be sampled most often (sanity ordering)
    assert!(counts[0] >= counts[n - 1]);
}

#[test]
fn worp1_batch_and_scalar_trials_share_the_distribution() {
    // the two ingestion paths are the *same* sampler given a seed:
    // identical samples per seed, not merely similar aggregates
    let n = 8;
    let freqs = zipf_frequencies(n, 1.0, 10.0);
    let elems = unaggregate(&freqs, 2, false, 0x5EED);
    for t in 0..50u64 {
        let cfg = || {
            SamplerConfig::new(1.0, 3)
                .with_seed(0xABCD + t)
                .with_domain(n)
                .with_sketch_shape(5, 512)
        };
        let mut scalar = OnePassWorp::new(cfg());
        let mut batched = OnePassWorp::new(cfg());
        for e in &elems {
            StreamSummary::process(&mut scalar, e);
        }
        for chunk in elems.chunks(7) {
            StreamSummary::process_batch(&mut batched, chunk);
        }
        assert_eq!(scalar.sample().keys(), batched.sample().keys(), "seed offset {t}");
    }
}

#[test]
fn wor_beats_wr_nrmse_on_skewed_stream() {
    // Zipf[2]: the heavy key soaks up WR draws (repeats shrink the
    // effective sample), while WOR keeps k distinct keys — the paper's
    // headline motivation. NRMSE of the l1-moment estimate over many
    // seeded runs must be strictly better for WOR at the same k.
    let n = 2_000;
    let k = 50;
    let freqs = zipf_frequencies(n, 2.0, 1e4);
    let truth: f64 = freqs.iter().sum();
    let seeds = 200u64;
    let wor_ests: Vec<f64> = (0..seeds)
        .map(|s| moment_estimate(&perfect_ppswor(&freqs, 1.0, k, 0x11AA + s), 1.0))
        .collect();
    let wr_ests: Vec<f64> = (0..seeds)
        .map(|s| wr_moment_estimate(&perfect_wr(&freqs, 1.0, k, 0x11AA + s), 1.0))
        .collect();
    let wor = nrmse(&wor_ests, truth);
    let wr = nrmse(&wr_ests, truth);
    assert!(
        wor < wr,
        "WOR must beat WR at fixed k on skewed data: NRMSE wor={wor:.4} wr={wr:.4}"
    );
    // regression floor: WOR stays genuinely accurate, not merely "less bad"
    assert!(wor < 0.5, "WOR NRMSE {wor:.4} unreasonably large");
}
