//! Batch contract: for every `StreamSummary` implementation, the AoS
//! `process_batch` path **and** the SoA `process_block` path must be
//! equivalent to the per-element `process` loop — including
//! signed/turnstile updates and merges performed *after* batch ingestion.
//! The columnar sketch paths are held to **bit-identical** tables
//! (per-cell addition order is preserved by construction); sampler
//! outputs are held to exact sample equality with domains sized below the
//! candidate-truncation thresholds (truncation timing is the one place
//! the batch/block paths legitimately defer work).
//!
//! All cases are seeded and deterministic (`worp::util::proptest`).

use worp::api::{Mergeable, MultiPass, StreamSummary, WorSampler};
use worp::data::{Element, ElementBlock};
use worp::sampler::exact::ExactWor;
use worp::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use worp::sampler::windowed::WindowedWorp;
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::worp2::TwoPassWorp;
use worp::sampler::SamplerConfig;
use worp::sketch::countmin::CountMin;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::spacesaving::SpaceSaving;
use worp::sketch::{AnyRhh, RhhSketch, SketchParams};
use worp::util::hashing::LANE;
use worp::util::proptest::{run, Gen};

/// Drive a clone per path: per-element vs chunked AoS batches vs chunked
/// SoA blocks (identical chunk boundaries, so deferred bookkeeping fires
/// at the same points on both non-scalar paths).
fn scalar_vs_batch_vs_block<S: StreamSummary + Clone>(
    proto: &S,
    elems: &[Element],
    chunk: usize,
) -> (S, S, S) {
    let mut scalar = proto.clone();
    let mut batched = proto.clone();
    let mut blocked = proto.clone();
    for e in elems {
        scalar.process(e);
    }
    for c in elems.chunks(chunk.max(1)) {
        batched.process_batch(c);
        blocked.process_block(&ElementBlock::from_elements(c));
    }
    assert_eq!(scalar.processed(), batched.processed());
    assert_eq!(scalar.processed(), blocked.processed());
    (scalar, batched, blocked)
}

/// A seeded signed (turnstile) element stream.
fn signed_stream(g: &mut Gen, m: usize, keys: u64) -> Vec<Element> {
    (0..m)
        .map(|_| Element::new(g.u64_below(keys), g.f64_range(-20.0, 20.0)))
        .collect()
}

#[test]
fn countsketch_batch_contract() {
    run("countsketch batch ≡ scalar", 20, |g: &mut Gen| {
        let params = SketchParams::new(*g.choose(&[1usize, 5, 7]), g.usize_range(16, 256), g.u64_below(1 << 48));
        let proto = CountSketch::new(params);
        let m = g.usize_range(1, 800);
        let elems = signed_stream(g, m, 3000);
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 300));
        assert_eq!(s.table(), b.table(), "columnar batch path must be bit-identical");
        assert_eq!(s.table(), blk.table(), "SoA block path must be bit-identical");
    });
}

#[test]
fn countmin_batch_contract() {
    run("countmin batch ≡ scalar", 20, |g: &mut Gen| {
        let params = SketchParams::new(3, g.usize_range(16, 256), g.u64_below(1 << 48));
        let proto = CountMin::new(params);
        let m = g.usize_range(1, 800);
        let elems: Vec<Element> = (0..m)
            .map(|_| Element::new(g.u64_below(500), g.f64_range(0.0, 10.0)))
            .collect();
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 200));
        for key in 0..500u64 {
            assert_eq!(s.est(key), b.est(key));
            assert_eq!(s.est(key), blk.est(key));
        }
    });
}

#[test]
fn anyrhh_batch_contract_both_arms() {
    run("anyrhh batch ≡ scalar", 10, |g: &mut Gen| {
        for q in [1.0, 2.0] {
            let params = SketchParams::new(5, 128, g.u64_below(1 << 40));
            let proto = AnyRhh::for_q(q, params);
            let m = g.usize_range(1, 400);
            // CountMin arm requires non-negative values
            let elems: Vec<Element> = (0..m)
                .map(|_| Element::new(g.u64_below(400), g.f64_range(0.0, 8.0)))
                .collect();
            let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 100));
            for key in 0..400u64 {
                assert_eq!(s.est(key), b.est(key), "q={q}");
                assert_eq!(s.est(key), blk.est(key), "q={q}");
            }
        }
    });
}

#[test]
fn spacesaving_batch_contract() {
    run("spacesaving batch ≡ scalar", 20, |g: &mut Gen| {
        let proto: SpaceSaving<u64> = SpaceSaving::new(g.usize_range(2, 24));
        let m = g.usize_range(1, 800);
        let elems: Vec<Element> = (0..m)
            .map(|_| Element::new(g.u64_below(80), g.f64_range(0.0, 5.0)))
            .collect();
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 250));
        let (st, bt, kt) = (s.top(), b.top(), blk.top());
        assert_eq!(st.len(), bt.len());
        assert_eq!(st.len(), kt.len());
        for ((a, c), d) in st.iter().zip(&bt).zip(&kt) {
            assert_eq!(a.key, c.key);
            assert_eq!(a.key, d.key);
            assert!((a.count - c.count).abs() < 1e-9);
            assert_eq!(c.count.to_bits(), d.count.to_bits());
            assert!((a.overestimate - c.overestimate).abs() < 1e-9);
            assert_eq!(c.overestimate.to_bits(), d.overestimate.to_bits());
        }
    });
}

#[test]
fn worp1_batch_contract_signed() {
    run("worp1 batch ≡ scalar", 8, |g: &mut Gen| {
        // domain stays below the candidate capacity (8·(k+1)·2 with k=8)
        // so candidate truncation never fires on either path
        let cfg = SamplerConfig::new(2.0, 8)
            .with_seed(g.u64_below(1 << 40))
            .with_domain(120)
            .with_sketch_shape(5, 512);
        let proto = OnePassWorp::new(cfg);
        let m = g.usize_range(20, 600);
        let elems = signed_stream(g, m, 120);
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 200));
        let (ss, bs, ks) = (
            WorSampler::sample(&s).unwrap(),
            WorSampler::sample(&b).unwrap(),
            WorSampler::sample(&blk).unwrap(),
        );
        assert_eq!(ss.entries, bs.entries);
        assert_eq!(ss.entries, ks.entries);
        assert_eq!(ss.tau, bs.tau);
        assert_eq!(ss.tau, ks.tau);
    });
}

#[test]
fn worp2_batch_contract_both_passes() {
    run("worp2 batch ≡ scalar across passes", 8, |g: &mut Gen| {
        let cfg = SamplerConfig::new(1.0, 8)
            .with_seed(g.u64_below(1 << 40))
            .with_domain(200)
            .with_sketch_shape(5, 512);
        let mut scalar = TwoPassWorp::new(cfg.clone());
        let mut batched = TwoPassWorp::new(cfg.clone());
        let mut blocked = TwoPassWorp::new(cfg);
        let m = g.usize_range(20, 500);
        let elems = signed_stream(g, m, 200);
        let chunk = g.usize_range(1, 150);
        for pass in 0..2 {
            if pass > 0 {
                scalar.advance().unwrap();
                batched.advance().unwrap();
                blocked.advance().unwrap();
            }
            for e in &elems {
                StreamSummary::process(&mut scalar, e);
            }
            for c in elems.chunks(chunk) {
                StreamSummary::process_batch(&mut batched, c);
                StreamSummary::process_block(&mut blocked, &ElementBlock::from_elements(c));
            }
        }
        let (ss, bs, ks) = (
            scalar.sample().unwrap(),
            batched.sample().unwrap(),
            blocked.sample().unwrap(),
        );
        assert_eq!(ss.entries, bs.entries);
        assert_eq!(ss.entries, ks.entries);
        assert_eq!(ss.tau, bs.tau);
        assert_eq!(ss.tau, ks.tau);
    });
}

#[test]
fn tv_batch_contract() {
    run("tv batch ≡ scalar", 5, |g: &mut Gen| {
        let kind = *g.choose(&[SamplerKind::Oracle, SamplerKind::Precision]);
        let cfg = TvSamplerConfig::new(1.0, 4, 60, g.u64_below(1 << 40), kind).with_r(12);
        let proto = TvSampler::new(cfg);
        let m = g.usize_range(10, 200);
        let elems: Vec<Element> = (0..m)
            .map(|_| Element::new(g.u64_below(60), g.f64_range(0.1, 5.0)))
            .collect();
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 64));
        assert_eq!(s.produce_keys(), b.produce_keys());
        assert_eq!(s.produce_keys(), blk.produce_keys());
    });
}

#[test]
fn windowed_batch_contract() {
    run("windowed batch ≡ scalar", 8, |g: &mut Gen| {
        // k=4 → candidate prune threshold 2·16·5 = 160 > domain 100:
        // pruning never fires, so deferred pruning cannot diverge
        let cfg = SamplerConfig::new(1.0, 4)
            .with_seed(g.u64_below(1 << 40))
            .with_domain(100)
            .with_sketch_shape(5, 256);
        let window = *g.choose(&[50u64, 128, 1000]);
        let proto = WindowedWorp::new(cfg, window, 5);
        let m = g.usize_range(20, 600);
        let elems = signed_stream(g, m, 100);
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 200));
        let (ss, bs, ks) = (
            WorSampler::sample(&s).unwrap(),
            WorSampler::sample(&b).unwrap(),
            WorSampler::sample(&blk).unwrap(),
        );
        assert_eq!(ss.entries, bs.entries);
        assert_eq!(ss.entries, ks.entries);
        assert_eq!(ss.tau, bs.tau);
        assert_eq!(ss.tau, ks.tau);
    });
}

#[test]
fn exact_batch_contract() {
    run("exact batch ≡ scalar", 10, |g: &mut Gen| {
        let cfg = SamplerConfig::new(1.0, 10).with_seed(g.u64_below(1 << 40));
        let proto = ExactWor::new(cfg);
        let m = g.usize_range(1, 600);
        let elems = signed_stream(g, m, 300);
        let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, g.usize_range(1, 200));
        let (ss, bs, ks) = (
            WorSampler::sample(&s).unwrap(),
            WorSampler::sample(&b).unwrap(),
            WorSampler::sample(&blk).unwrap(),
        );
        assert_eq!(ss.entries, bs.entries);
        assert_eq!(ss.entries, ks.entries);
    });
}

#[test]
fn merge_after_batch_equals_whole_scalar() {
    // composability survives the batch path: two shards ingested through
    // process_batch, merged, must equal one scalar whole-stream summary
    run("merge-after-batch ≡ whole scalar", 8, |g: &mut Gen| {
        let seed = g.u64_below(1 << 40);
        let m = g.usize_range(50, 600);
        let elems = signed_stream(g, m, 150);
        let chunk = g.usize_range(1, 100);

        // CountSketch: merged table equals whole table up to fp rounding
        let params = SketchParams::new(5, 128, seed);
        let mut whole = CountSketch::new(params);
        for e in &elems {
            RhhSketch::process(&mut whole, e);
        }
        let mut a = CountSketch::new(params);
        let mut b = CountSketch::new(params);
        let (ea, eb): (Vec<_>, Vec<_>) = elems.iter().enumerate().partition(|(i, _)| i % 2 == 0);
        let ea: Vec<Element> = ea.into_iter().map(|(_, e)| *e).collect();
        let eb: Vec<Element> = eb.into_iter().map(|(_, e)| *e).collect();
        for c in ea.chunks(chunk) {
            StreamSummary::process_batch(&mut a, c);
        }
        for c in eb.chunks(chunk) {
            StreamSummary::process_batch(&mut b, c);
        }
        Mergeable::merge(&mut a, &b).unwrap();
        for (x, y) in a.table().iter().zip(whole.table()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }

        // ExactWor: exact aggregation — sample keys identical
        let cfg = SamplerConfig::new(2.0, 8).with_seed(seed);
        let mut whole = ExactWor::new(cfg.clone());
        for e in &elems {
            StreamSummary::process(&mut whole, e);
        }
        let mut a = ExactWor::new(cfg.clone());
        let mut b = ExactWor::new(cfg);
        for c in ea.chunks(chunk) {
            StreamSummary::process_batch(&mut a, c);
        }
        for c in eb.chunks(chunk) {
            StreamSummary::process_batch(&mut b, c);
        }
        Mergeable::merge(&mut a, &b).unwrap();
        assert_eq!(
            WorSampler::sample(&a).unwrap().keys(),
            WorSampler::sample(&whole).unwrap().keys()
        );
    });
}

// ---------------------------------------------------------------------------
// Lane-edge grid (PR 8): the unrolled kernels process LANE elements per
// straight-line chunk with a scalar remainder tail; every seam between
// the two paths is pinned here, bit-for-bit.

/// Block lengths that straddle every unroll seam: empty, single, one
/// short of a lane, exactly a lane, one past, and a multi-lane block
/// with a ragged tail.
fn lane_edge_lengths() -> [usize; 6] {
    [0, 1, LANE - 1, LANE, LANE + 1, 3 * LANE + 2]
}

#[test]
fn countsketch_lane_edges_bit_identical_across_shape_grid() {
    // rows odd/even (incl. the degenerate 1-row sketch), width both a
    // multiple of LANE and deliberately not (17), signed updates
    let mut g = Gen::new(0xC0FFEE);
    for &rows in &[1usize, 2, 5, 6] {
        for &width in &[17usize, 64] {
            for &len in &lane_edge_lengths() {
                let proto = CountSketch::with_shape(rows, width, 0xA5A5);
                let elems = signed_stream(&mut g, len, 500);
                // chunk == len: one block of exactly the edge length
                // drives a single process_cols/process_batch sweep
                let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, len.max(1));
                let bits = |t: &[f64]| t.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(s.table()),
                    bits(b.table()),
                    "rows={rows} width={width} len={len} (batch)"
                );
                assert_eq!(
                    bits(s.table()),
                    bits(blk.table()),
                    "rows={rows} width={width} len={len} (block)"
                );
            }
        }
    }
}

#[test]
fn countmin_lane_edges_bit_identical_across_shape_grid() {
    let mut g = Gen::new(0xBEEF);
    for &rows in &[1usize, 2, 5] {
        for &width in &[17usize, 64] {
            for &len in &lane_edge_lengths() {
                let proto = CountMin::with_shape(rows, width, 0xA5A5);
                let elems: Vec<Element> = (0..len)
                    .map(|_| Element::new(g.u64_below(500), g.f64_range(0.0, 10.0)))
                    .collect();
                let (s, b, blk) = scalar_vs_batch_vs_block(&proto, &elems, len.max(1));
                for key in 0..500u64 {
                    assert_eq!(
                        s.est(key).to_bits(),
                        b.est(key).to_bits(),
                        "rows={rows} width={width} len={len} key={key}"
                    );
                    assert_eq!(s.est(key).to_bits(), blk.est(key).to_bits());
                }
            }
        }
    }
}

#[test]
fn est_many_matches_est_bitwise_at_lane_edges() {
    // the lane-batched table-gather in est_many must reproduce the
    // per-key est exactly, for every query-column length seam and for
    // both the shared row-sweep sketches
    let mut g = Gen::new(0xF00D);
    let elems = signed_stream(&mut g, 2_000, 700);
    let mut cs = CountSketch::with_shape(5, 17, 31);
    let mut cm = CountMin::with_shape(4, 17, 31);
    for e in &elems {
        RhhSketch::process(&mut cs, e);
    }
    let pos: Vec<Element> = elems.iter().map(|e| Element::new(e.key, e.val.abs())).collect();
    for e in &pos {
        RhhSketch::process(&mut cm, e);
    }
    let all_keys: Vec<u64> = (0..700u64).collect();
    for &len in &lane_edge_lengths() {
        let keys = &all_keys[..len];
        let mut out = vec![0.0f64; len];
        cs.est_many(keys, &mut out);
        for (k, o) in keys.iter().zip(&out) {
            assert_eq!(o.to_bits(), cs.est(*k).to_bits(), "countsketch len={len} key={k}");
        }
        cm.est_many(keys, &mut out);
        for (k, o) in keys.iter().zip(&out) {
            assert_eq!(o.to_bits(), cm.est(*k).to_bits(), "countmin len={len} key={k}");
        }
    }
}

#[test]
fn boxed_dyn_sampler_signed_updates_at_lane_edge_chunks() {
    // the builder → Box<dyn WorSampler> route with turnstile (signed)
    // updates, chunked exactly on the unroll seams — the full CLI path
    // over the rewritten kernels
    let mut g = Gen::new(0xDEAD);
    let n = 120u64;
    let elems = signed_stream(&mut g, 400, n);
    let b = worp::Worp::p(2.0)
        .k(8)
        .seed(9)
        .domain(n as usize)
        .sketch_shape(5, 512);
    for chunk in [1usize, LANE - 1, LANE, LANE + 1, 3 * LANE + 2] {
        for method in [worp::Method::OnePass, worp::Method::Exact] {
            let mut chunked = b.clone().method(method).build().unwrap();
            let mut scalar = b.clone().method(method).build().unwrap();
            for c in elems.chunks(chunk) {
                chunked.process_block(&ElementBlock::from_elements(c));
            }
            for e in &elems {
                scalar.process(e);
            }
            let (cs, ss) = (chunked.sample().unwrap(), scalar.sample().unwrap());
            assert_eq!(cs.entries, ss.entries, "{method:?} chunk={chunk}");
            assert_eq!(cs.tau, ss.tau, "{method:?} chunk={chunk}");
        }
    }
}

#[test]
fn boxed_dyn_sampler_batch_and_block_contract() {
    // the builder → Box<dyn WorSampler> route (the CLI/pipeline path)
    // must hit the specialized overrides, not the default loops: both the
    // AoS batch path and the SoA block path through the trait object must
    // match the scalar loop exactly
    let n = 150;
    let elems: Vec<Element> = (0..400)
        .map(|i| Element::new((i * 17) % n, 1.0 + (i % 7) as f64))
        .collect();
    let b = worp::Worp::p(1.0)
        .k(8)
        .seed(9)
        .domain(n as usize)
        .sketch_shape(5, 512);
    for method in [worp::Method::OnePass, worp::Method::TwoPass, worp::Method::Exact] {
        let mut boxed = b.clone().method(method).build().unwrap();
        let mut blocked = b.clone().method(method).build().unwrap();
        let mut scalar = b.clone().method(method).build().unwrap();
        for pass in 0..boxed.passes() {
            if pass > 0 {
                boxed.advance().unwrap();
                blocked.advance().unwrap();
                scalar.advance().unwrap();
            }
            for c in elems.chunks(64) {
                boxed.process_batch(c);
                blocked.process_block(&ElementBlock::from_elements(c));
            }
            for e in &elems {
                scalar.process(e);
            }
        }
        assert_eq!(
            boxed.sample().unwrap().keys(),
            scalar.sample().unwrap().keys(),
            "{method:?}"
        );
        assert_eq!(
            blocked.sample().unwrap().keys(),
            scalar.sample().unwrap().keys(),
            "{method:?} (block)"
        );
    }
}
