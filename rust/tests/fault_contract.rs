//! Fault-tolerance contract suite (ISSUE 9 acceptance), driven by the
//! deterministic chaos proxy (`worp::cluster::chaos`):
//!
//! 1. **Backoff is deterministic** — same (seed, salt) ⇒ same schedule,
//!    exponential within jitter bounds, capped.
//! 2. **Kill an owner mid-ingest** — the connection to one member is
//!    severed after a scripted byte count; the session reconnects,
//!    reconciles against the instance's lifetime accepted count, replays
//!    exactly the unconfirmed suffix, and the final merged state is
//!    **bit-for-bit** the uninterrupted single-process reference (no row
//!    lost, none double-applied).
//! 3. **A dead member degrades queries, typed** — strict `merged` is
//!    `Error::Unavailable`; `query_partial` answers from the surviving
//!    slices and reports exactly the missing ones as a typed `Coverage`.
//! 4. **A blackholed member deadlines** instead of hanging forever.
//! 5. **A torn frame recovers** — the proxy forwards half a frame and
//!    severs; replay reproduces the reference bit-for-bit.
//! 6. **Op-targeted kills retry transparently** — severing exactly when
//!    FLUSH arrives makes the retry layer reconnect and re-issue it.
//! 7. **Zero cost on the happy path** — an undisturbed cluster run
//!    performs zero retries, reconnects, or replays.
//! 8. **Probe → failover → degraded-but-typed queries** — killing a
//!    member, probing it Down, and failing over onto the survivors
//!    reports exactly the dead member's slices as lost, after which
//!    partial queries answer with full knowledge of the gap.

use std::sync::Arc;
use std::time::{Duration, Instant};
use worp::cluster::chaos::{ChaosProxy, ConnFault, FaultPlan};
use worp::cluster::{ClusterClient, ClusterSpec, Health, Member, RetryPolicy};
use worp::data::zipf::zipf_exact_stream;
use worp::data::{Element, ElementBlock};
use worp::engine::proto::{op, InstanceSpec};
use worp::engine::server::{ServeOpts, Server};
use worp::engine::{Engine, EngineOpts};
use worp::{Error, WorSampler};

const SLICES: usize = 24;
const BATCH: usize = 128;
const CHUNK: usize = 97;

fn proto_spec(method: &str, seed: u64) -> InstanceSpec {
    let mut cfg = worp::config::PipelineConfig::default();
    cfg.method = method.into();
    cfg.k = 16;
    cfg.seed = seed;
    cfg.n = 600;
    cfg.rows = 7;
    cfg.width = 1024;
    InstanceSpec::from_config(&cfg)
}

fn stream() -> Vec<Element> {
    zipf_exact_stream(600, 1.2, 1e4, 3, 21) // 1800 elements
}

fn blocks_of(elems: &[Element], chunk: usize) -> Vec<ElementBlock> {
    elems.chunks(chunk).map(ElementBlock::from_elements).collect()
}

fn spec_of(names: &[&str]) -> ClusterSpec {
    ClusterSpec {
        name: "ct".into(),
        slices: SLICES,
        members: names
            .iter()
            .map(|n| Member { name: n.to_string(), addr: String::new() })
            .collect(),
    }
}

struct Node {
    #[allow(dead_code)]
    engine: Arc<Engine>,
    server: Server,
}

fn start_member(spec: &ClusterSpec, name: &str) -> Node {
    let owned = spec.owned_slices(name).unwrap();
    let engine = Arc::new(
        Engine::with_ownership(EngineOpts::new(1, BATCH).unwrap(), SLICES, &owned, spec.stamp())
            .unwrap(),
    );
    let server = Server::start(Arc::clone(&engine), "127.0.0.1:0", ServeOpts::default()).unwrap();
    Node { engine, server }
}

fn start_cluster(names: &[&str]) -> (ClusterSpec, Vec<Node>) {
    let mut spec = spec_of(names);
    let mut nodes = Vec::new();
    for i in 0..names.len() {
        let node = start_member(&spec, names[i]);
        spec.members[i].addr = node.server.local_addr().to_string();
        nodes.push(node);
    }
    (spec, nodes)
}

fn single_process_reference(method: &str, seed: u64, elems: &[Element]) -> Vec<u8> {
    let engine = Engine::new(EngineOpts::new(SLICES, BATCH).unwrap());
    let proto = proto_spec(method, seed).to_worp().unwrap().build().unwrap();
    engine.create_from_proto("ref", proto).unwrap();
    for b in blocks_of(elems, CHUNK) {
        engine.ingest("ref", &b).unwrap();
    }
    engine.flush("ref").unwrap();
    let mut out = Vec::new();
    engine.instance("ref").unwrap().merged().unwrap().encode_state(&mut out);
    out
}

fn cluster_merged_encode(cc: &mut ClusterClient, name: &str) -> Vec<u8> {
    let merged = cc.merged(name).unwrap();
    let mut out = Vec::new();
    merged.encode_state(&mut out);
    out
}

/// A fast-failing policy for tests that talk to dead or blackholed
/// members: tight deadline, millisecond backoff, always probe.
fn test_policy(attempts: u32) -> RetryPolicy {
    RetryPolicy {
        attempts,
        base_ms: 1,
        cap_ms: 4,
        op_deadline_ms: 2_000,
        probe_secs: 0,
        seed: 0xFA17,
    }
}

// ---------------------------------------------------------------------------
// 1. the backoff schedule is a pure function of (seed, salt, attempt)

#[test]
fn backoff_schedule_is_deterministic_exponential_and_capped() {
    let p = RetryPolicy { attempts: 8, ..RetryPolicy::default() };
    assert_eq!(p.schedule(3), p.schedule(3), "same salt must replay identically");
    assert_ne!(p.schedule(3), p.schedule(4), "different members must de-synchronise");
    let other = RetryPolicy { seed: p.seed ^ 0xDEAD, ..p.clone() };
    assert_ne!(p.schedule(3), other.schedule(3), "the seed keys the stream");
    for attempt in 1..=12u32 {
        let raw = p.base_ms.saturating_mul(1 << (attempt - 1).min(20)).min(p.cap_ms);
        let d = p.backoff(3, attempt).as_millis() as u64;
        assert!(
            d >= raw / 2 && d <= raw,
            "attempt {attempt}: {d}ms outside the [{}, {raw}] jitter window",
            raw / 2
        );
    }
}

// ---------------------------------------------------------------------------
// 2. sever an owner's connection mid-ingest: reconnect + replay ≡ never failed

#[test]
fn killed_owner_mid_ingest_replays_unacked_blocks_bit_identically() {
    let elems = stream();
    let (mut spec, nodes) = start_cluster(&["alpha", "beta", "gamma"]);

    // proxy the member owning the most slices, so enough rows route to
    // it that the byte-counted cut is guaranteed to land mid-ingest
    let victim = (0..spec.members.len())
        .max_by_key(|&m| spec.owned_slices(&spec.members[m].name).unwrap().len())
        .unwrap();

    // the victim sits behind the chaos proxy: the first connection
    // (which carries create, the ingest baseline, and the first ingest
    // frames) is severed after 2000 client→server bytes — mid-stream,
    // past the first full ingest frame (one CHUNK-row frame is ~1.6 KiB
    // and create + the baseline stats are ~0.3 KiB); every later
    // connection passes through untouched
    let proxy = ChaosProxy::start(
        &spec.members[victim].addr,
        FaultPlan::scripted(vec![ConnFault::CutAfter { c2s_bytes: 2_000 }]),
    )
    .unwrap();
    spec.members[victim].addr = proxy.addr();

    let mut cc = ClusterClient::connect_with(spec.clone(), test_policy(4)).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    let mut session = cc.ingest_session("t/keys", CHUNK).unwrap();
    for e in &elems {
        session.push(e.key, e.val).unwrap();
    }
    let sent = session.finish().unwrap();
    assert_eq!(sent as usize, elems.len(), "every row must be accepted exactly once");
    assert!(
        cc.replays() >= 1,
        "the cut must have forced at least one reconnect+replay recovery"
    );
    assert!(proxy.connections() >= 2, "recovery must have re-dialed through the proxy");

    cc.flush("t/keys").unwrap();
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems),
        "kill-owner-mid-ingest + replay must equal the uninterrupted run bit-for-bit"
    );
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 3. a dead member: strict query is typed Unavailable, partial query answers

#[test]
fn query_with_a_dead_member_returns_typed_partial_coverage() {
    let elems = stream();
    let (spec, mut nodes) = start_cluster(&["alpha", "beta", "gamma"]);
    let mut cc = ClusterClient::connect_with(spec.clone(), test_policy(2)).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    for b in blocks_of(&elems, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    cc.flush("t/keys").unwrap();
    // full coverage first: the degraded query agrees with the strict one
    let (full, cov) = cc.query_partial("t/keys").unwrap();
    assert!(cov.is_full(), "all members up ⇒ full coverage, got {cov:?}");
    let mut full_bytes = Vec::new();
    full.unwrap().encode_state(&mut full_bytes);
    assert_eq!(full_bytes, cluster_merged_encode(&mut cc, "t/keys"));

    // kill gamma for real
    let mut gamma = nodes.remove(2);
    gamma.server.stop();
    drop(gamma);
    let gamma_owned = spec.owned_slices("gamma").unwrap();

    // strict queries refuse, typed — never a silently partial answer
    let err = cc.merged("t/keys").unwrap_err();
    assert!(
        matches!(err, Error::Unavailable(_)),
        "merged with a dead member must be Unavailable, got {err}"
    );

    // the opt-in partial query answers and names the gap exactly
    let (merged, cov) = cc.query_partial("t/keys").unwrap();
    assert_eq!(cov.owned, SLICES);
    assert_eq!(cov.missing_slices, gamma_owned, "exactly gamma's slices are missing");
    assert_eq!(cov.answered, SLICES - gamma_owned.len());
    assert_eq!(cov.unreachable_members, vec!["gamma".to_string()]);
    assert!(!cov.is_full());
    let sample = merged.expect("surviving slices still answer").sample().unwrap();
    assert!(!sample.keys().is_empty(), "the degraded sample is still usable");
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 4. a blackholed member hits the op deadline instead of hanging

#[test]
fn blackholed_member_deadlines_instead_of_hanging() {
    let (mut spec, nodes) = start_cluster(&["solo"]);
    let proxy = ChaosProxy::start(
        &spec.members[0].addr,
        FaultPlan::scripted(vec![ConnFault::Blackhole, ConnFault::Blackhole]),
    )
    .unwrap();
    spec.members[0].addr = proxy.addr();

    let policy = RetryPolicy { op_deadline_ms: 300, ..test_policy(2) };
    let started = Instant::now();
    let mut cc = ClusterClient::connect_with(spec, policy).unwrap();
    let err = cc.ping().unwrap_err();
    assert!(
        matches!(err, Error::Unavailable(_)),
        "a blackholed member must exhaust retries into Unavailable, got {err}"
    );
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "the deadline must bound a blackhole ({:?} elapsed)",
        started.elapsed()
    );
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 5. a frame torn in half mid-pipeline recovers by replay, bit-identically

#[test]
fn truncated_frame_recovers_by_replay_bit_identically() {
    let elems = stream();
    let (mut spec, nodes) = start_cluster(&["solo"]);
    // connection 0 carries: frame 0 = create, frame 1 = the ingest
    // baseline stats, frames 2.. = ingest — tear the second ingest frame
    let proxy = ChaosProxy::start(
        &spec.members[0].addr,
        FaultPlan::scripted(vec![ConnFault::TruncateFrame { frame: 3 }]),
    )
    .unwrap();
    spec.members[0].addr = proxy.addr();

    let mut cc = ClusterClient::connect_with(spec.clone(), test_policy(4)).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    let mut session = cc.ingest_session("t/keys", CHUNK).unwrap();
    for e in &elems {
        session.push(e.key, e.val).unwrap();
    }
    assert_eq!(session.finish().unwrap() as usize, elems.len());
    assert!(cc.replays() >= 1, "the torn frame must have forced a replay");

    cc.flush("t/keys").unwrap();
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems),
        "a torn ingest frame + replay must equal the uninterrupted run bit-for-bit"
    );
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 6. severing exactly on FLUSH: the idempotent retry re-issues it unseen

#[test]
fn close_on_flush_op_retries_transparently() {
    let elems = stream();
    let (mut spec, nodes) = start_cluster(&["solo"]);
    let proxy = ChaosProxy::start(
        &spec.members[0].addr,
        FaultPlan::scripted(vec![ConnFault::CloseOnOp { op: op::FLUSH }]),
    )
    .unwrap();
    spec.members[0].addr = proxy.addr();

    let mut cc = ClusterClient::connect_with(spec.clone(), test_policy(3)).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    let mut session = cc.ingest_session("t/keys", CHUNK).unwrap();
    for e in &elems {
        session.push(e.key, e.val).unwrap();
    }
    session.finish().unwrap();

    // the proxy kills connection 0 the moment FLUSH arrives (the frame
    // is never forwarded); the retry layer reconnects and re-issues
    cc.flush("t/keys").unwrap();
    assert!(cc.retries() >= 1, "the killed FLUSH must have been retried");
    assert!(cc.reconnects() >= 1, "the retry must have re-dialed");
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems),
        "a retried flush must be invisible in the merged state"
    );
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 7. the retry layer costs the happy path nothing

#[test]
fn retry_layer_is_zero_cost_on_the_happy_path() {
    let elems = stream();
    let (spec, nodes) = start_cluster(&["alpha", "beta", "gamma"]);
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    let mut session = cc.ingest_session("t/keys", CHUNK).unwrap();
    for e in &elems {
        session.push(e.key, e.val).unwrap();
    }
    assert_eq!(session.finish().unwrap() as usize, elems.len());
    cc.flush("t/keys").unwrap();
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems)
    );
    assert_eq!(cc.retries(), 0, "an undisturbed run must never retry");
    assert_eq!(cc.reconnects(), 0, "an undisturbed run must never re-dial");
    assert_eq!(cc.replays(), 0, "an undisturbed run must never replay");
    for (member, h) in cc.health() {
        assert_eq!(h, Health::Healthy, "{member} should be healthy");
    }
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 8. probe a killed member Down, fail over, and query the survivors typed

#[test]
fn probe_then_failover_reports_lost_slices_and_recovers_partial_queries() {
    let elems = stream();
    let (spec, mut nodes) = start_cluster(&["alpha", "beta", "gamma"]);
    let mut cc = ClusterClient::connect_with(spec.clone(), test_policy(2)).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    for b in blocks_of(&elems, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    cc.flush("t/keys").unwrap();

    let mut gamma = nodes.remove(2);
    gamma.server.stop();
    drop(gamma);
    let gamma_owned = spec.owned_slices("gamma").unwrap();

    // two probe rounds march gamma Healthy → Suspect → Down
    cc.set_down_after(2);
    cc.probe();
    let health = cc.probe();
    assert_eq!(health[2], ("gamma".to_string(), Health::Down));
    assert_eq!(health[0].1, Health::Healthy);
    assert_eq!(health[1].1, Health::Healthy);

    // failover onto the survivors: nothing movable (the only changed
    // slices belonged to the dead member), so every one is reported lost
    let surviving = spec.surviving(&["gamma".to_string()]).unwrap();
    let report = cc.failover_to(surviving.clone()).unwrap();
    assert_eq!(report.moves, 0);
    assert_eq!(report.lost_slices, gamma_owned, "exactly the dead member's slices");
    assert_eq!(cc.spec(), &surviving, "the client re-routes by the surviving spec");

    // the surviving members answer with exact knowledge of the gap
    let (merged, cov) = cc.query_partial("t/keys").unwrap();
    assert_eq!(cov.missing_slices, gamma_owned);
    assert!(cov.unreachable_members.is_empty(), "every surviving member answered");
    assert_eq!(cov.answered, SLICES - gamma_owned.len());
    assert!(merged.is_some());
    // and the strict query names the gap, typed
    let err = cc.merged("t/keys").unwrap_err();
    assert!(matches!(err, Error::Unavailable(_)), "got {err}");
    drop(nodes);
}
