//! Checkpoint / crash-recovery suite: a sharded run killed mid-stream
//! after a checkpoint, then resumed from the snapshot directory, must
//! finish **bit-identical** to an uninterrupted run — and the
//! `end_to_end` topology-invariance property must keep holding with
//! checkpointing on.

use worp::api::{Mergeable, Persist};
use worp::coordinator::{Coordinator, VecSource};
use worp::data::zipf::zipf_exact_stream;
use worp::data::Element;
use worp::pipeline::merge::merge_all;
use worp::pipeline::{run_sharded, run_sharded_checkpointed, CheckpointPolicy, PipelineOpts};
use worp::sampler::exact::ExactWor;
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::SamplerConfig;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::SketchParams;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("worp_ckpt_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg(seed: u64) -> SamplerConfig {
    SamplerConfig::new(1.0, 10)
        .with_seed(seed)
        .with_domain(300)
        .with_sketch_shape(5, 512)
}

/// Simulated crash: run the pipeline over only a prefix of the stream
/// (checkpoints get written along the way), throw the in-memory result
/// away — that is the crash — then rerun over the *full* stream with the
/// same snapshot directory and compare against an uninterrupted run.
#[test]
fn killed_run_resumes_bit_identical_for_exact_summary() {
    let elems = zipf_exact_stream(300, 1.2, 1e4, 3, 11);
    let opts = PipelineOpts::new(3, 64).unwrap();
    let policy = CheckpointPolicy::new(2, tmp("exact")).unwrap();
    let proto = |_w: usize| ExactWor::new(cfg(7));

    // phase 1: process ~60% of the stream, then "crash" (drop the states)
    let cut = elems.len() * 6 / 10;
    let (_lost, m1) =
        run_sharded_checkpointed(&elems[..cut], opts, &policy, proto).unwrap();
    assert!(m1.snapshots() > 0, "no checkpoints were written before the crash");
    assert_eq!(m1.restores(), 0);

    // phase 2: resume over the full stream from the snapshot directory
    let (resumed, m2) =
        run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    assert_eq!(m2.restores() as usize, opts.workers, "all shards restore");

    // reference: one uninterrupted (non-checkpointed) run
    let (reference, _) = run_sharded(&elems, opts, proto).unwrap();

    assert_eq!(resumed.len(), reference.len());
    for (r, q) in resumed.iter().zip(&reference) {
        assert_eq!(r.encode(), q.encode(), "shard state diverged after resume");
    }
    // and the merged samples agree exactly
    let m = worp::pipeline::metrics::Metrics::default();
    let a = merge_all(resumed, &m).unwrap().unwrap();
    let b = merge_all(reference, &m).unwrap().unwrap();
    assert_eq!(a.sample().entries, b.sample().entries);
    assert_eq!(a.sample().tau.to_bits(), b.sample().tau.to_bits());
}

#[test]
fn killed_run_resumes_bit_identical_for_sketch_and_worp1() {
    let elems = zipf_exact_stream(300, 1.0, 1e4, 3, 13);
    let opts = PipelineOpts::new(2, 32).unwrap();

    // linear sketch
    let policy = CheckpointPolicy::new(3, tmp("sketch")).unwrap();
    let proto = |_w: usize| CountSketch::new(SketchParams::new(5, 128, 3));
    let cut = elems.len() / 2;
    run_sharded_checkpointed(&elems[..cut], opts, &policy, proto).unwrap();
    let (resumed, _) =
        run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    let (reference, _) = run_sharded(&elems, opts, proto).unwrap();
    for (r, q) in resumed.iter().zip(&reference) {
        assert_eq!(r.table(), q.table());
        assert_eq!(r.processed(), q.processed());
    }

    // 1-pass WORp: candidate-shrink timing depends on batch boundaries;
    // snapshots land on batch edges so the resumed run realigns exactly
    let policy = CheckpointPolicy::new(2, tmp("worp1")).unwrap();
    let proto = |_w: usize| OnePassWorp::new(cfg(17));
    run_sharded_checkpointed(&elems[..cut], opts, &policy, proto).unwrap();
    let (resumed, _) =
        run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    let (reference, _) = run_sharded(&elems, opts, proto).unwrap();
    for (r, q) in resumed.iter().zip(&reference) {
        assert_eq!(r.encode(), q.encode(), "worp1 shard state diverged");
    }
}

#[test]
fn repeated_crashes_still_converge() {
    // crash after every few batches, many times over — each resume picks
    // up from the latest snapshot and the final state is still exact
    let elems: Vec<Element> = (0..4000u64).map(|i| Element::new(i % 97, 1.0)).collect();
    let opts = PipelineOpts::new(2, 16).unwrap();
    let policy = CheckpointPolicy::new(1, tmp("repeated")).unwrap();
    let proto = |_w: usize| ExactWor::new(cfg(23));
    for frac in [2usize, 3, 5, 7] {
        let cut = elems.len() * (frac - 1) / frac;
        run_sharded_checkpointed(&elems[..cut], opts, &policy, proto).unwrap();
    }
    let (resumed, _) = run_sharded_checkpointed(&elems, opts, &policy, proto).unwrap();
    let (reference, _) = run_sharded(&elems, opts, proto).unwrap();
    for (r, q) in resumed.iter().zip(&reference) {
        assert_eq!(r.encode(), q.encode());
    }
}

#[test]
fn coordinator_run_dyn_with_checkpoints_matches_plain_run() {
    // the dynamic (CLI) path: every method through run_dyn with a
    // checkpoint policy produces the same sample as without one, and the
    // multi-pass method snapshots each pass in its own subdirectory
    let n = 300;
    let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 19);
    let src = VecSource(elems);
    let builder = worp::Worp::p(1.0)
        .k(8)
        .seed(3)
        .domain(n)
        .sketch_shape(5, 512);
    for method in [worp::Method::OnePass, worp::Method::TwoPass, worp::Method::Exact] {
        let dir = tmp(&format!("dyn_{}", method.name()));
        let plain = Coordinator::new(
            builder.sampler_config().unwrap(),
            PipelineOpts::new(3, 64).unwrap(),
        );
        let ck = Coordinator::new(
            builder.sampler_config().unwrap(),
            PipelineOpts::new(3, 64).unwrap(),
        )
        .with_checkpoints(CheckpointPolicy::new(2, &dir).unwrap());
        let proto = builder.clone().method(method).build().unwrap();
        let (s_plain, _) = plain.run_dyn(&src, proto.clone()).unwrap();
        let (s_ck, m) = ck.run_dyn(&src, proto).unwrap();
        assert_eq!(s_plain.keys(), s_ck.keys(), "{}", method.name());
        assert!(m.snapshots() > 0, "{}: no snapshots", method.name());
        if method == worp::Method::TwoPass {
            assert!(dir.join("pass-0").is_dir());
            assert!(dir.join("pass-1").is_dir());
        }
    }
}

#[test]
fn topology_invariance_holds_with_checkpointing_on() {
    // the end_to_end guarantee, now through the checkpointed path: worker
    // count / batch size / channel depth never change the merged output
    // (each topology checkpoints into its own directory)
    let elems = zipf_exact_stream(300, 1.3, 1e4, 2, 0xF1C);
    let proto = || {
        worp::Worp::p(1.0)
            .k(10)
            .seed(0xABC)
            .domain(300)
            .sketch_shape(5, 512)
            .two_pass()
            .build()
            .unwrap()
    };
    let reference: Vec<u64> = {
        let c = Coordinator::new(cfg(0xABC), PipelineOpts::new(1, 64).unwrap());
        c.run_dyn(&VecSource(elems.clone()), proto()).unwrap().0.keys()
    };
    // batch sizes kept well under the per-shard element count: snapshots
    // only fire on full-batch edges, and this test wants to prove the
    // output is invariant *while* checkpointing is actually active
    for (workers, batch) in [(2usize, 32usize), (3, 61), (4, 32)] {
        let dir = tmp(&format!("topo_{workers}_{batch}"));
        let c = Coordinator::new(cfg(0xABC), PipelineOpts::new(workers, batch).unwrap())
            .with_checkpoints(CheckpointPolicy::new(2, &dir).unwrap());
        let (s, m) = c.run_dyn(&VecSource(elems.clone()), proto()).unwrap();
        assert_eq!(s.keys(), reference, "workers={workers} batch={batch}");
        assert!(m.snapshots() > 0, "workers={workers} batch={batch}");
    }
}

#[test]
fn run_summary_checkpointed_resumes_through_the_coordinator() {
    let elems = zipf_exact_stream(300, 1.2, 1e4, 2, 29);
    let dir = tmp("run_summary");
    let make_coord = || {
        Coordinator::new(cfg(5), PipelineOpts::new(2, 32).unwrap())
            .with_checkpoints(CheckpointPolicy::new(2, &dir).unwrap())
    };
    let cut = elems.len() / 2;
    make_coord()
        .run_summary_checkpointed(&elems[..cut], ExactWor::new(cfg(5)))
        .unwrap();
    let (resumed, m) = make_coord()
        .run_summary_checkpointed(&elems, ExactWor::new(cfg(5)))
        .unwrap();
    assert!(m.restores() > 0);
    let plain = Coordinator::new(cfg(5), PipelineOpts::new(2, 32).unwrap());
    let (reference, _) = plain.run_summary(&elems, ExactWor::new(cfg(5))).unwrap();
    assert_eq!(resumed.encode(), reference.encode());
    assert_eq!(
        Mergeable::fingerprint(&resumed),
        Mergeable::fingerprint(&reference)
    );
}
