//! Generic persistence contract: for **every** [`Persist`] summary,
//! `decode(encode(s))` preserves the fingerprint, the final output and
//! merge-compatibility; encoding is canonical (logically-equal states
//! encode to identical bytes); and the key composability law
//!
//! ```text
//! merge(decode(encode(a)), decode(encode(b))) ≡ merge(a, b)   bit-for-bit
//! ```
//!
//! holds — the property the cross-process `worp shard` / `worp
//! merge-files` workflow and the checkpointed pipeline both rest on.

use worp::api::{Finalize, Mergeable, Persist, StreamSummary, WorSampler};
use worp::data::zipf::zipf_exact_stream;
use worp::data::Element;
use worp::sampler::exact::ExactWor;
use worp::sampler::perfect_lp::{OracleSampler, PrecisionSampler, SingleLpSampler};
use worp::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use worp::sampler::windowed::WindowedWorp;
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::worp2::{TwoPassWorp, TwoPassWorpPass1};
use worp::sampler::SamplerConfig;
use worp::sketch::countmin::CountMin;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::spacesaving::SpaceSaving;
use worp::sketch::topk::TopK;
use worp::sketch::window::WindowedCountSketch;
use worp::sketch::{AnyRhh, RhhSketch, SketchParams};
use worp::util::rng::Rng;

fn cfg(p: f64, k: usize, seed: u64) -> SamplerConfig {
    SamplerConfig::new(p, k)
        .with_seed(seed)
        .with_domain(400)
        .with_sketch_shape(5, 256)
}

/// Two deterministic disjoint-ish element streams (signed).
fn streams(seed: u64, len: usize) -> (Vec<Element>, Vec<Element>) {
    let elems = zipf_exact_stream(400, 1.2, 1e4, 2, seed);
    let (mut a, mut b) = (Vec::new(), Vec::new());
    for (i, e) in elems.into_iter().enumerate().take(len) {
        if i % 2 == 0 {
            a.push(e);
        } else {
            b.push(e);
        }
    }
    (a, b)
}

fn positive(elems: &[Element]) -> Vec<Element> {
    elems
        .iter()
        .map(|e| Element::new(e.key, e.val.abs()))
        .collect()
}

/// The generic contract for a Mergeable summary: round-trip preserves
/// fingerprint + bytes, the decoded state stays merge-compatible, and
/// merging decoded copies is bit-identical to merging the originals.
fn check_persist_mergeable<T: Persist + Mergeable + Clone>(a: &T, b: &T, what: &str) {
    let enc_a = a.encode();
    let da = T::decode(&enc_a).unwrap_or_else(|e| panic!("{what}: decode failed: {e}"));
    assert_eq!(
        Mergeable::fingerprint(&da),
        Mergeable::fingerprint(a),
        "{what}: fingerprint changed across the round-trip"
    );
    assert_eq!(
        da.encode(),
        enc_a,
        "{what}: re-encoding the decoded state produced different bytes"
    );
    // decoded states remain merge-compatible with live siblings
    let mut dm = T::decode(&enc_a).unwrap();
    dm.merge(b).unwrap_or_else(|e| panic!("{what}: decoded state refused a merge: {e}"));
    // the key law, bit-for-bit via canonical encodings
    let db = T::decode(&b.encode()).unwrap();
    let mut lhs = T::decode(&enc_a).unwrap();
    lhs.merge(&db).unwrap();
    let mut rhs = a.clone();
    rhs.merge(b).unwrap();
    assert_eq!(
        lhs.encode(),
        rhs.encode(),
        "{what}: merge(decode(enc(a)), decode(enc(b))) != merge(a, b)"
    );
}

#[test]
fn countsketch_contract() {
    let params = SketchParams::new(5, 128, 11);
    let (ea, eb) = streams(1, 2000);
    let mut a = CountSketch::new(params);
    let mut b = CountSketch::new(params);
    for e in &ea {
        RhhSketch::process(&mut a, e);
    }
    for e in &eb {
        RhhSketch::process(&mut b, e);
    }
    check_persist_mergeable(&a, &b, "countsketch");
    // estimates survive the round-trip exactly
    let d = CountSketch::decode(&a.encode()).unwrap();
    for key in 0..50u64 {
        assert_eq!(d.est(key).to_bits(), a.est(key).to_bits(), "key {key}");
    }
    assert_eq!(d.processed(), a.processed());
    assert_eq!(d.table(), a.table());
}

#[test]
fn countmin_contract() {
    let params = SketchParams::new(3, 64, 7);
    let (ea, eb) = streams(2, 1500);
    let mut a = CountMin::new(params);
    let mut b = CountMin::new(params);
    for e in &positive(&ea) {
        RhhSketch::process(&mut a, e);
    }
    for e in &positive(&eb) {
        RhhSketch::process(&mut b, e);
    }
    check_persist_mergeable(&a, &b, "countmin");
    let d = CountMin::decode(&a.encode()).unwrap();
    for key in 0..50u64 {
        assert_eq!(d.est(key).to_bits(), a.est(key).to_bits());
    }
}

#[test]
fn anyrhh_contract_both_variants() {
    let params = SketchParams::new(5, 64, 13);
    let (ea, eb) = streams(3, 1000);
    for q in [1.0, 2.0] {
        let mut a = AnyRhh::for_q(q, params);
        let mut b = AnyRhh::for_q(q, params);
        let (fa, fb) = if q < 2.0 {
            (positive(&ea), positive(&eb))
        } else {
            (ea.clone(), eb.clone())
        };
        for e in &fa {
            RhhSketch::process(&mut a, e);
        }
        for e in &fb {
            RhhSketch::process(&mut b, e);
        }
        check_persist_mergeable(&a, &b, &format!("anyrhh q={q}"));
        let d = AnyRhh::decode(&a.encode()).unwrap();
        assert_eq!(d.q(), a.q());
        assert_eq!(d.est(5).to_bits(), a.est(5).to_bits());
    }
}

#[test]
fn spacesaving_contract() {
    let (ea, eb) = streams(4, 1200);
    let mut a: SpaceSaving<u64> = SpaceSaving::new(16);
    let mut b: SpaceSaving<u64> = SpaceSaving::new(16);
    for e in &positive(&ea) {
        a.process(e.key, e.val);
    }
    for e in &positive(&eb) {
        b.process(e.key, e.val);
    }
    check_persist_mergeable(&a, &b, "spacesaving");
    // the decoded summary keeps streaming correctly (heap was rebuilt):
    // drive both far past capacity and compare the deterministic top()
    let mut d = SpaceSaving::<u64>::decode(&a.encode()).unwrap();
    let mut live = a.clone();
    for t in 0..2000u64 {
        d.process((t * 13) % 97, 1.0);
        live.process((t * 13) % 97, 1.0);
    }
    let (dt, lt) = (d.top(), live.top());
    assert_eq!(dt.len(), lt.len());
    for (x, y) in dt.iter().zip(&lt) {
        assert_eq!(x.key, y.key);
        assert_eq!(x.count.to_bits(), y.count.to_bits());
    }
}

#[test]
fn topk_contract() {
    // TopK merges through its own inherent merge (it is the composable
    // pass-II structure, not an api::Mergeable)
    let mut a = TopK::new(8, 12);
    let mut b = TopK::new(8, 12);
    let mut rng = Rng::new(5);
    for _ in 0..300 {
        let k = rng.below(60);
        a.process(k, 1.0, (k % 17) as f64);
        let k = rng.below(60);
        b.process(k, 2.0, (k % 17) as f64);
    }
    let enc_a = a.encode();
    let da = TopK::decode(&enc_a).unwrap();
    assert_eq!(da.encode(), enc_a, "topk canonical re-encode differs");
    assert_eq!(da.by_priority(), a.by_priority());
    // merge law, bit-for-bit
    let db = TopK::decode(&b.encode()).unwrap();
    let mut lhs = TopK::decode(&enc_a).unwrap();
    lhs.merge(&db).unwrap();
    let mut rhs = a.clone();
    rhs.merge(&b).unwrap();
    assert_eq!(lhs.encode(), rhs.encode(), "topk merge law violated");
}

#[test]
fn window_sketch_contract() {
    let params = SketchParams::new(5, 128, 21);
    let mut a = WindowedCountSketch::new(params, 100, 10);
    let mut b = WindowedCountSketch::new(params, 100, 10);
    let mut rng = Rng::new(9);
    for t in 0..400u64 {
        let e = Element::new(rng.below(50), rng.normal());
        if e.key % 2 == 0 {
            a.process_at(&e, t);
        } else {
            b.process_at(&e, t);
        }
    }
    let enc_a = a.encode();
    let da = WindowedCountSketch::decode(&enc_a).unwrap();
    assert_eq!(da.encode(), enc_a);
    assert_eq!(da.now(), a.now());
    assert_eq!(da.live_buckets(), a.live_buckets());
    for key in 0..50u64 {
        assert_eq!(da.est(key).to_bits(), a.est(key).to_bits(), "key {key}");
    }
    // merge law through the inherent merge
    let db = WindowedCountSketch::decode(&b.encode()).unwrap();
    let mut lhs = WindowedCountSketch::decode(&enc_a).unwrap();
    lhs.merge(&db).unwrap();
    let mut rhs = a.clone();
    rhs.merge(&b).unwrap();
    assert_eq!(lhs.encode(), rhs.encode(), "windowed sketch merge law violated");
}

#[test]
fn exact_wor_contract() {
    let (ea, eb) = streams(6, 2000);
    let c = cfg(1.0, 12, 31);
    let mut a = ExactWor::new(c.clone());
    let mut b = ExactWor::new(c);
    for e in &ea {
        a.process(e);
    }
    for e in &eb {
        b.process(e);
    }
    check_persist_mergeable(&a, &b, "exact");
    let d = ExactWor::decode(&a.encode()).unwrap();
    let (sa, sd) = (a.sample(), d.sample());
    assert_eq!(sa.entries, sd.entries);
    assert_eq!(sa.tau.to_bits(), sd.tau.to_bits());
}

#[test]
fn worp1_contract() {
    let (ea, eb) = streams(7, 3000);
    let c = cfg(1.0, 10, 41);
    let mut a = OnePassWorp::new(c.clone());
    let mut b = OnePassWorp::new(c);
    for e in &ea {
        a.process(e);
    }
    for e in &eb {
        b.process(e);
    }
    check_persist_mergeable(&a, &b, "worp1");
    let d = OnePassWorp::decode(&a.encode()).unwrap();
    let (sa, sd) = (OnePassWorp::sample(&a), OnePassWorp::sample(&d));
    assert_eq!(sa.entries, sd.entries);
    assert_eq!(sa.tau.to_bits(), sd.tau.to_bits());
    assert_eq!(d.processed(), a.processed());
}

#[test]
fn worp2_contract_both_passes() {
    let (ea, eb) = streams(8, 2000);
    let c = cfg(1.0, 10, 51);

    // pass I state machine
    let mut a = TwoPassWorp::new(c.clone());
    let mut b = TwoPassWorp::new(c.clone());
    for e in &ea {
        StreamSummary::process(&mut a, e);
    }
    for e in &eb {
        StreamSummary::process(&mut b, e);
    }
    check_persist_mergeable(&a, &b, "worp2 pass I");
    // the decoded state machine still advances into pass II
    let mut d = TwoPassWorp::decode(&a.encode()).unwrap();
    assert_eq!(d.pass_index(), 0);
    d.advance().unwrap();
    assert_eq!(d.pass_index(), 1);

    // standalone pass-I summary
    let mut p1a = TwoPassWorpPass1::new(c.clone());
    let mut p1b = TwoPassWorpPass1::new(c.clone());
    for e in &ea {
        p1a.process(e);
    }
    for e in &eb {
        p1b.process(e);
    }
    check_persist_mergeable(&p1a, &p1b, "worp2 pass1");

    // pass II collectors seeded from the *merged* pass-I sketch
    let mut merged1 = p1a.clone();
    merged1.merge(&p1b).unwrap();
    let mut p2a = merged1.clone().into_pass2();
    let mut p2b = merged1.into_pass2();
    for e in &ea {
        p2a.process(e);
    }
    for e in &eb {
        p2b.process(e);
    }
    check_persist_mergeable(&p2a, &p2b, "worp2 pass2");
    let d2 = worp::sampler::worp2::TwoPassWorpPass2::decode(&p2a.encode()).unwrap();
    assert_eq!(d2.sample().entries, p2a.sample().entries);

    // full state machine in pass II round-trips with its sample intact
    let mut w = TwoPassWorp::new(cfg(1.0, 10, 51));
    for e in &ea {
        StreamSummary::process(&mut w, e);
    }
    w.advance().unwrap();
    for e in &ea {
        StreamSummary::process(&mut w, e);
    }
    let dw = TwoPassWorp::decode(&w.encode()).unwrap();
    assert_eq!(dw.pass_index(), 1);
    assert_eq!(
        dw.sample().unwrap().entries,
        w.sample().unwrap().entries
    );
    // cross-pass merge of decoded states is still incompatible
    let d0 = TwoPassWorp::decode(&a.encode()).unwrap();
    let mut d1 = TwoPassWorp::decode(&w.encode()).unwrap();
    assert!(Mergeable::merge(&mut d1, &d0).is_err());
}

#[test]
fn tv_contract_both_substrates() {
    let (ea, eb) = streams(9, 800);
    for kind in [SamplerKind::Oracle, SamplerKind::Precision] {
        let c = TvSamplerConfig::new(1.0, 4, 400, 61, kind).with_r(10);
        let mut a = TvSampler::new(c.clone());
        let mut b = TvSampler::new(c);
        for e in &ea {
            a.process(e);
        }
        for e in &eb {
            b.process(e);
        }
        check_persist_mergeable(&a, &b, &format!("tv {kind:?}"));
        // the decoded sampler draws the *same* WOR tuple (the private rng
        // state of every inner sampler round-trips)
        let d = TvSampler::decode(&a.encode()).unwrap();
        assert_eq!(d.produce_keys(), a.produce_keys(), "{kind:?}");
    }
}

#[test]
fn windowed_sampler_contract() {
    let (ea, eb) = streams(10, 1500);
    let c = cfg(1.0, 8, 71);
    let mut a = WindowedWorp::new(c.clone(), 200, 10);
    let mut b = WindowedWorp::new(c, 200, 10);
    for (t, e) in ea.iter().enumerate() {
        a.process_at(e, t as u64);
    }
    for (t, e) in eb.iter().enumerate() {
        b.process_at(e, t as u64);
    }
    check_persist_mergeable(&a, &b, "windowed");
    let d = WindowedWorp::decode(&a.encode()).unwrap();
    let (sa, sd) = (WindowedWorp::sample(&a), WindowedWorp::sample(&d));
    assert_eq!(sa.entries, sd.entries);
}

#[test]
fn single_lp_samplers_contract() {
    let (ea, eb) = streams(11, 600);
    // oracle
    let mut a = OracleSampler::new(1.0, 81);
    let mut b = OracleSampler::new(1.0, 81);
    for e in &ea {
        SingleLpSampler::process(&mut a, e);
    }
    for e in &eb {
        SingleLpSampler::process(&mut b, e);
    }
    check_persist_mergeable(&a, &b, "oracle-lp");
    let d = OracleSampler::decode(&a.encode()).unwrap();
    // private randomness round-trips: identical draw sequences
    assert_eq!(Finalize::finalize(&d), Finalize::finalize(&a));
    // precision
    let mut a = PrecisionSampler::new(1.0, 91, 5, 128);
    let mut b = PrecisionSampler::new(1.0, 91, 5, 128);
    for e in &ea {
        SingleLpSampler::process(&mut a, e);
    }
    for e in &eb {
        SingleLpSampler::process(&mut b, e);
    }
    check_persist_mergeable(&a, &b, "precision-lp");
    let d = PrecisionSampler::decode(&a.encode()).unwrap();
    assert_eq!(Finalize::finalize(&d), Finalize::finalize(&a));
}

#[test]
fn boxed_dyn_sampler_roundtrips_for_every_method() {
    let elems = zipf_exact_stream(300, 1.2, 1e4, 2, 5);
    let build = |method: &str| -> Box<dyn WorSampler> {
        let b = worp::Worp::p(1.0)
            .k(8)
            .seed(17)
            .domain(300)
            .sketch_shape(5, 512)
            .method(worp::Method::parse(method).unwrap());
        let b = if method == "windowed" { b.windowed(100, 10) } else { b };
        let b = if method == "tv" { b.tv_r(20) } else { b };
        b.build().unwrap()
    };
    for method in ["1pass", "2pass", "tv", "windowed", "exact"] {
        let mut s = build(method);
        for e in &elems {
            StreamSummary::process(&mut s, e);
        }
        let bytes = Persist::encode(&s);
        let d: Box<dyn WorSampler> = Persist::decode(&bytes).unwrap();
        assert_eq!(d.name(), s.name(), "{method}");
        assert_eq!(d.fingerprint(), s.fingerprint(), "{method}");
        assert_eq!(d.processed(), s.processed(), "{method}");
        // canonical re-encode
        assert_eq!(Persist::encode(&d), bytes, "{method}");
        // decoded summaries merge through the dynamic path
        let mut m: Box<dyn WorSampler> = Persist::decode(&bytes).unwrap();
        m.merge_dyn(&*d).unwrap();
        match (s.sample(), d.sample()) {
            (Ok(ss), Ok(ds)) => {
                assert_eq!(ss.entries, ds.entries, "{method}");
                assert_eq!(ss.tau.to_bits(), ds.tau.to_bits(), "{method}");
            }
            (Err(_), Err(_)) => {} // 2pass mid-pass: both refuse identically
            (a, b) => panic!("{method}: sample() disagreed: {a:?} vs {b:?}"),
        }
    }
}

#[test]
fn decode_as_wrong_type_is_a_codec_error() {
    let mut cs = CountSketch::with_shape(3, 32, 1);
    RhhSketch::process(&mut cs, &Element::new(4, 2.0));
    let bytes = cs.encode();
    assert!(matches!(
        CountMin::decode(&bytes),
        Err(worp::Error::Codec(_))
    ));
    assert!(matches!(TopK::decode(&bytes), Err(worp::Error::Codec(_))));
    // a sketch envelope is not a sampler
    assert!(matches!(
        worp::codec::decode_sampler(&bytes),
        Err(worp::Error::Codec(_))
    ));
}
