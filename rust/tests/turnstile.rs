//! Turnstile regression guard: keys whose signed updates cancel to a net
//! frequency of zero must never be sampled — for p ∈ {0.5, 1, 2}, through
//! both the scalar and the columnar batch ingestion paths, across every
//! sampling method that supports signed streams.
//!
//! A cancelled key that leaks into a sample is exactly the "speedup
//! silently corrupts sampling semantics" failure mode this suite guards
//! against: a batch path that reorders or drops signed updates would
//! surface here immediately.

use worp::api::{MultiPass, StreamSummary, WorSampler};
use worp::data::Element;
use worp::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use worp::sampler::SamplerConfig;
use worp::util::rng::Rng;
use worp::{Method, Worp};

// 24 live + 10 cancelled = 34 distinct keys, below the 2-pass collector
// capacity 4·(K+1) = 36: every key is admitted at its *first* element, so
// collected pass-II values are exact and cancellation is exact (±v/2
// halves are lossless in binary floating point)
const LIVE_KEYS: u64 = 24;
const CANCELLED_KEYS: std::ops::Range<u64> = 100..110;
const K: usize = 8;

/// Seeded stream: live keys with positive net mass, plus keys whose
/// updates cancel exactly (each gets +v, −v/2, −v/2 interleaved).
fn turnstile_stream(seed: u64) -> Vec<Element> {
    let mut elems = Vec::new();
    for key in 0..LIVE_KEYS {
        let f = 100.0 / (key + 1) as f64;
        for _ in 0..3 {
            elems.push(Element::new(key, f / 3.0));
        }
    }
    for key in CANCELLED_KEYS {
        let v = 500.0 + key as f64; // heavy before cancellation
        elems.push(Element::new(key, v));
        elems.push(Element::new(key, -v / 2.0));
        elems.push(Element::new(key, -v / 2.0));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut elems);
    elems
}

fn assert_no_cancelled_keys(method: &str, p: f64, mode: &str, keys: &[u64]) {
    for k in keys {
        assert!(
            !CANCELLED_KEYS.contains(k),
            "{method} (p={p}, {mode}): cancelled key {k} leaked into the sample; keys={keys:?}"
        );
    }
    assert!(!keys.is_empty(), "{method} (p={p}, {mode}): empty sample");
}

/// Drive a boxed sampler through all passes, scalar or batched.
fn drive(mut s: Box<dyn WorSampler>, elems: &[Element], batch: Option<usize>) -> Vec<u64> {
    for pass in 0..s.passes() {
        if pass > 0 {
            s.advance().unwrap();
        }
        match batch {
            None => {
                for e in elems {
                    s.process(e);
                }
            }
            Some(c) => {
                for chunk in elems.chunks(c) {
                    s.process_batch(chunk);
                }
            }
        }
    }
    s.sample().unwrap().keys()
}

#[test]
fn cancelled_keys_never_sampled_scalar_and_batch() {
    let elems = turnstile_stream(0xCA9CE1);
    for &p in &[0.5, 1.0, 2.0] {
        // all signed-capable methods go through the CountSketch (q=2) path
        for method in [Method::OnePass, Method::TwoPass, Method::Exact] {
            let b = Worp::p(p)
                .k(K)
                .seed(7)
                .domain(200)
                .sketch_shape(7, 1024)
                .method(method);
            for (mode, batch) in [("scalar", None), ("batch", Some(17)), ("batch", Some(4096))] {
                let keys = drive(b.build().unwrap(), &elems, batch);
                assert_no_cancelled_keys(method.name(), p, mode, &keys);
            }
        }
    }
}

#[test]
fn cancelled_keys_never_sampled_windowed() {
    // cancellation happens *within* the window, so the windowed estimate
    // of a cancelled key is exactly zero
    let elems = turnstile_stream(0x57ED);
    for &p in &[0.5, 1.0, 2.0] {
        let b = Worp::p(p)
            .k(K)
            .seed(7)
            .domain(200)
            .sketch_shape(7, 1024)
            .windowed(1 << 30, 4);
        for (mode, batch) in [("scalar", None), ("batch", Some(23))] {
            let keys = drive(b.build().unwrap(), &elems, batch);
            assert_no_cancelled_keys("windowed", p, mode, &keys);
        }
    }
}

#[test]
fn cancelled_keys_never_sampled_tv() {
    // Algorithm 1 (oracle substrate): the oracle drops zero-net keys and
    // the rHH estimates of cancelled keys vanish by linearity
    let elems = turnstile_stream(0x7F1E);
    for &p in &[0.5, 1.0, 2.0] {
        let cfg = TvSamplerConfig::new(p, K, 200, 13, SamplerKind::Oracle).with_r(64);
        let mut scalar = TvSampler::new(cfg.clone());
        let mut batched = TvSampler::new(cfg);
        for e in &elems {
            StreamSummary::process(&mut scalar, e);
        }
        for chunk in elems.chunks(19) {
            StreamSummary::process_batch(&mut batched, chunk);
        }
        for (mode, s) in [("scalar", &scalar), ("batch", &batched)] {
            let keys = s.produce_keys();
            assert_no_cancelled_keys("tv", p, mode, &keys);
        }
        // the two paths must also agree exactly
        assert_eq!(scalar.produce_keys(), batched.produce_keys(), "p={p}");
    }
}
