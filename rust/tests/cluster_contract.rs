//! Cluster contract suite (ISSUE 6 acceptance):
//!
//! 1. **3-node cluster ≡ 1 process** — a `ClusterClient` driving three
//!    real TCP `worp serve` members produces a merged sampler whose
//!    encoded state is **bit-for-bit identical** to a single-process
//!    engine that ingested the whole stream (the merge law across
//!    machines; the ascending-slice fold order makes the non-associative
//!    f64 merges associate identically).
//! 2. **Kill → snapshot-restore → continue ≡ never stopping** — a member
//!    dies mid-stream (with pending rows), a replacement restores its
//!    snapshot, ingest continues, and the final merge is unchanged.
//! 3. **Live add-node rebalance mid-ingest** — growing 2 → 3 members
//!    moves exactly the rendezvous-reassigned slices (install before
//!    drop) and the final merge is unchanged.
//! 4. **Duplicate-ownership windows dedupe** toward the spec-assigned
//!    owner, and **stale cluster stamps / incompatible slices are
//!    refused with typed errors** over the wire.
//! 5. **Multi-pass methods are refused at cluster create** — the
//!    inter-pass handoff cannot span nodes.
//! 6. **The connection cap answers with a typed error frame**, not a
//!    silent drop.

use std::sync::Arc;
use std::time::Duration;
use worp::cluster::{ClusterClient, ClusterSpec, Member};
use worp::data::zipf::zipf_exact_stream;
use worp::data::{Element, ElementBlock};
use worp::engine::client::Client;
use worp::engine::proto::{self, InstanceSpec};
use worp::engine::server::{ServeOpts, Server};
use worp::engine::{Engine, EngineOpts};
use worp::{Error, WorSampler};

const SLICES: usize = 24;
const BATCH: usize = 128;
const CHUNK: usize = 97; // deliberately coprime-ish with BATCH

fn proto_spec(method: &str, seed: u64) -> InstanceSpec {
    let mut cfg = worp::config::PipelineConfig::default();
    cfg.method = method.into();
    cfg.k = 16;
    cfg.seed = seed;
    cfg.n = 600;
    cfg.rows = 7;
    cfg.width = 1024;
    InstanceSpec::from_config(&cfg)
}

fn stream() -> Vec<Element> {
    zipf_exact_stream(600, 1.2, 1e4, 3, 21) // 1800 elements
}

fn blocks_of(elems: &[Element], chunk: usize) -> Vec<ElementBlock> {
    elems.chunks(chunk).map(ElementBlock::from_elements).collect()
}

/// A spec over the given member names with addresses to be filled in
/// after each server binds its port (HRW placement only reads names and
/// the slice count, so ownership is known before any socket exists).
fn spec_of(names: &[&str]) -> ClusterSpec {
    ClusterSpec {
        name: "ct".into(),
        slices: SLICES,
        members: names
            .iter()
            .map(|n| Member { name: n.to_string(), addr: String::new() })
            .collect(),
    }
}

struct Node {
    engine: Arc<Engine>,
    server: Server,
}

/// Start one cluster member owning its HRW slices, on a free port.
fn start_member(spec: &ClusterSpec, name: &str) -> Node {
    let owned = spec.owned_slices(name).unwrap();
    let engine = Arc::new(
        Engine::with_ownership(EngineOpts::new(1, BATCH).unwrap(), SLICES, &owned, spec.stamp())
            .unwrap(),
    );
    let server = Server::start(Arc::clone(&engine), "127.0.0.1:0", ServeOpts::default()).unwrap();
    Node { engine, server }
}

fn start_cluster(names: &[&str]) -> (ClusterSpec, Vec<Node>) {
    let mut spec = spec_of(names);
    let mut nodes = Vec::new();
    for i in 0..names.len() {
        let node = start_member(&spec, names[i]);
        spec.members[i].addr = node.server.local_addr().to_string();
        nodes.push(node);
    }
    (spec, nodes)
}

/// The single-process reference: one engine partitioned into SLICES
/// shards sees the whole stream with the same chunking; its merged
/// encode is the byte string every cluster topology must reproduce.
fn single_process_reference(method: &str, seed: u64, elems: &[Element]) -> Vec<u8> {
    let engine = Engine::new(EngineOpts::new(SLICES, BATCH).unwrap());
    let proto = proto_spec(method, seed).to_worp().unwrap().build().unwrap();
    engine.create_from_proto("ref", proto).unwrap();
    for b in blocks_of(elems, CHUNK) {
        engine.ingest("ref", &b).unwrap();
    }
    engine.flush("ref").unwrap();
    let mut out = Vec::new();
    engine.instance("ref").unwrap().merged().unwrap().encode_state(&mut out);
    out
}

fn cluster_merged_encode(cc: &mut ClusterClient, name: &str) -> Vec<u8> {
    let merged = cc.merged(name).unwrap();
    let mut out = Vec::new();
    merged.encode_state(&mut out);
    out
}

// ---------------------------------------------------------------------------
// 1. three real TCP nodes ≡ one process, bit for bit

#[test]
fn three_node_cluster_equals_single_process_bit_for_bit() {
    let elems = stream();
    let (spec, nodes) = start_cluster(&["alpha", "beta", "gamma"]);
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    let mut sent = 0;
    for b in blocks_of(&elems, CHUNK) {
        sent += cc.ingest("t/keys", &b).unwrap();
    }
    assert_eq!(sent as usize, elems.len());
    cc.flush("t/keys").unwrap();

    let reference = single_process_reference("1pass", 7, &elems);
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        reference,
        "3 TCP nodes must merge to the single-process summary bit-for-bit"
    );
    // the finalized sample agrees down to the tau bits
    let cluster_sample = cc.sample("t/keys").unwrap();
    let ref_sample = worp::codec::decode_sampler(&reference).unwrap().sample().unwrap();
    assert_eq!(cluster_sample.keys(), ref_sample.keys());
    assert_eq!(cluster_sample.tau.to_bits(), ref_sample.tau.to_bits());

    // every row landed on the member owning its slice: per-node accepted
    // counts sum to the stream and every member reports the full topology
    let statuses = cc.status().unwrap();
    assert_eq!(statuses.len(), 3);
    let mut accepted = 0;
    for (member, s) in &statuses {
        assert_eq!(s.instances.len(), 1, "{member} should hold one instance");
        assert_eq!(s.instances[0].total_slices as usize, SLICES);
        let owned = spec.owned_slices(member).unwrap().len();
        assert_eq!(s.instances[0].shards as usize, owned, "{member} owned-slice count");
        accepted += s.instances[0].accepted;
    }
    assert_eq!(accepted as usize, elems.len());
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 2. kill a node, restore its snapshot, continue — as if it never died

#[test]
fn killed_node_restores_from_snapshot_and_the_cluster_continues() {
    let elems = stream();
    let (first, rest) = elems.split_at(elems.len() / 2);
    let (mut spec, mut nodes) = start_cluster(&["alpha", "beta", "gamma"]);
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    for b in blocks_of(first, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    // deliberately NO flush: beta's snapshot must carry its pending rows

    // snapshot beta over the wire, then kill it
    let snapshot = {
        let mut c = Client::connect(&spec.members[1].addr).unwrap();
        c.snapshot("t/keys").unwrap()
    };
    let mut beta = nodes.remove(1);
    beta.server.stop();
    drop(beta);

    // a replacement with the same identity restores the snapshot
    let replacement = start_member(&spec, "beta");
    let mut c = Client::connect(&replacement.server.local_addr().to_string()).unwrap();
    assert_eq!(c.restore(&snapshot).unwrap(), "t/keys");
    spec.members[1].addr = replacement.server.local_addr().to_string();
    nodes.insert(1, replacement);

    // reconnect (the old client holds a dead socket) and finish the stream
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    for b in blocks_of(rest, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    cc.flush("t/keys").unwrap();
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems),
        "kill → snapshot-restore → continue must be invisible in the merged state"
    );
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 3. grow 2 → 3 members mid-ingest

#[test]
fn adding_a_node_mid_ingest_rebalances_and_preserves_the_merge() {
    let elems = stream();
    let (first, rest) = elems.split_at(elems.len() / 2);
    let (spec, nodes) = start_cluster(&["alpha", "beta"]);
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    for b in blocks_of(first, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    // no flush: moved slices must carry their pending rows too

    // the new member set; gamma's server starts with its NEW ownership
    let mut new_spec = spec_of(&["alpha", "beta", "gamma"]);
    new_spec.members[0].addr = spec.members[0].addr.clone();
    new_spec.members[1].addr = spec.members[1].addr.clone();
    let gamma_owned = new_spec.owned_slices("gamma").unwrap();
    assert!(
        !gamma_owned.is_empty(),
        "rendezvous must hand the new member some of {SLICES} slices"
    );
    let gamma = start_member(&new_spec, "gamma");
    new_spec.members[2].addr = gamma.server.local_addr().to_string();

    let moves = cc.rebalance_to(new_spec.clone()).unwrap();
    assert_eq!(moves, gamma_owned.len(), "exactly the reassigned slices move");

    // ingest continues against the grown cluster, routed by the new spec
    for b in blocks_of(rest, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    cc.flush("t/keys").unwrap();
    assert_eq!(
        cluster_merged_encode(&mut cc, "t/keys"),
        single_process_reference("1pass", 7, &elems),
        "a live 2→3 rebalance must not change the merged state"
    );
    // the donors no longer answer for the moved slices
    let statuses = cc.status().unwrap();
    let gamma_stats = &statuses[2].1.instances[0];
    assert_eq!(gamma_stats.shards as usize, gamma_owned.len());
    drop((nodes, gamma));
}

// ---------------------------------------------------------------------------
// 4. duplicate-ownership windows + stale stamps, over the wire

#[test]
fn duplicate_ownership_dedupes_and_stale_stamps_are_refused() {
    let elems = stream();
    let (spec, nodes) = start_cluster(&["alpha", "beta"]);
    let mut cc = ClusterClient::connect(spec.clone()).unwrap();
    cc.create("t/keys", &proto_spec("1pass", 7)).unwrap();
    for b in blocks_of(&elems, CHUNK) {
        cc.ingest("t/keys", &b).unwrap();
    }
    cc.flush("t/keys").unwrap();
    let before = cluster_merged_encode(&mut cc, "t/keys");

    // copy one alpha-owned slice onto beta WITHOUT dropping it from
    // alpha — the mid-rebalance double-ownership window, frozen
    let slice = spec.owned_slices("alpha").unwrap()[0];
    let mut ca = Client::connect(&spec.members[0].addr).unwrap();
    let mut cb = Client::connect(&spec.members[1].addr).unwrap();
    let slice_bytes = ca.slice_snapshot("t/keys", slice as u64).unwrap();

    // a stale stamp (different membership epoch id) is refused typed
    let err = cb.slice_install(spec.stamp() ^ 1, &slice_bytes).unwrap_err();
    assert!(
        matches!(err, Error::Incompatible(_)),
        "stale stamp must be Incompatible, got {err}"
    );

    cb.slice_install(spec.stamp(), &slice_bytes).unwrap();
    // both members now answer for `slice`; the query dedupes toward the
    // spec-assigned owner and the merge is unchanged
    assert_eq!(cluster_merged_encode(&mut cc, "t/keys"), before);

    // finishing the move (drop from the donor) is equally invisible
    ca.slice_drop("t/keys", slice as u64).unwrap();
    // the client still routes ingest by the spec, which says alpha owns
    // the slice — so from here queries must dedupe toward beta's copy
    let after_spec = {
        // rebuild coverage expectations: alpha no longer holds the slice
        cluster_merged_encode(&mut cc, "t/keys")
    };
    assert_eq!(after_spec, before);
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 5. multi-pass methods cannot span nodes

#[test]
fn cluster_client_refuses_multi_pass_and_clock_methods() {
    let (spec, nodes) = start_cluster(&["alpha", "beta"]);
    let mut cc = ClusterClient::connect(spec).unwrap();
    let err = cc.create("t/two", &proto_spec("2pass", 7)).unwrap_err();
    assert!(
        matches!(&err, Error::Config(m) if m.contains("pass")),
        "2pass create must be refused client-side, got {err}"
    );
    let err = cc.create("t/win", &proto_spec("windowed", 7)).unwrap_err();
    assert!(
        matches!(&err, Error::Config(m) if m.contains("clock")),
        "windowed create must be refused client-side, got {err}"
    );
    // nothing leaked onto the members
    assert!(cc.instances().unwrap().is_empty());
    drop(nodes);
}

// ---------------------------------------------------------------------------
// 6. the connection cap is a typed refusal, not a hang or a drop

#[test]
fn connection_cap_answers_with_a_typed_error() {
    let engine = Arc::new(Engine::new(EngineOpts::new(2, 64).unwrap()));
    let opts = ServeOpts {
        max_frame: proto::DEFAULT_MAX_FRAME,
        checkpoint: None,
        max_connections: 1,
        ..ServeOpts::default()
    };
    let mut srv = Server::start(Arc::clone(&engine), "127.0.0.1:0", opts).unwrap();
    let addr = srv.local_addr().to_string();
    let mut first = Client::connect(&addr)
        .unwrap()
        .with_timeout(Duration::from_secs(10))
        .unwrap();
    first.ping().unwrap(); // occupies the only slot

    let mut second = Client::connect(&addr)
        .unwrap()
        .with_timeout(Duration::from_secs(10))
        .unwrap();
    // give the server a beat to emit the refusal frame
    std::thread::sleep(Duration::from_millis(100));
    match second.ping() {
        Err(Error::State(m)) => assert!(m.contains("cap"), "unexpected message: {m}"),
        // the refused socket may already be closed by the time we write
        Err(Error::Io(_)) | Err(Error::Pipeline(_)) => {}
        other => panic!("over-cap connection must fail, got {other:?}"),
    }
    // the occupied slot keeps working, and freeing it admits new clients
    first.ping().unwrap();
    drop(first);
    std::thread::sleep(Duration::from_millis(200));
    let mut third = Client::connect(&addr)
        .unwrap()
        .with_timeout(Duration::from_secs(10))
        .unwrap();
    third.ping().unwrap();
    srv.stop();
}
