//! Scenario-engine contract suite (the release gate CI runs before the
//! `worp scenario` smoke commands).
//!
//! Three layers, matching what the scenario engine promises:
//!
//! 1. **WR reservoir primitives** — ingest-mode bit-identity, persist
//!    round-trip that resumes identically, slot-wise merge winners, and
//!    a frequency check of the draws against the closed-form WR
//!    probabilities the `wr-vs-wor` estimator divides by.
//! 2. **Served ≡ offline** — a decayed instance driven over the wire in
//!    engine-chosen chunks must sample bit-identically to a scalar
//!    offline replay, and two engines created with a shared seed must
//!    produce identical coordinated key sets.
//! 3. **The `scenario::run` surface itself** — the same entry point the
//!    CLI calls must pass its own gates in local and served modes.

use std::collections::HashSet;

use worp::api::{Mergeable, Persist, StreamSummary};
use worp::data::{Element, ElementBlock};
use worp::engine::proto::InstanceSpec;
use worp::engine::{Engine, EngineOpts};
use worp::estimate::wr_inclusion_prob;
use worp::sampler::decayed::DecayedWorp;
use worp::sampler::wr_reservoir::WrReservoir;
use worp::sampler::{Sample, SamplerConfig};
use worp::scenario::{self, Host, Mode, ScenarioOpts};
use worp::transform::DecaySpec;

fn wr_cfg(k: usize, seed: u64) -> SamplerConfig {
    SamplerConfig::new(1.0, k)
        .with_seed(seed)
        .with_domain(1_000)
        .with_sketch_shape(3, 64)
}

/// An unaggregated stream with repeated keys and mixed weights.
fn stream(n: u64) -> Vec<Element> {
    (0..n)
        .map(|i| Element::new(i % 97, 1.0 + (i % 5) as f64))
        .collect()
}

fn spec(method: &str, p: f64, k: usize, seed: u64, n: usize) -> InstanceSpec {
    InstanceSpec {
        method: method.to_string(),
        dist: "ppswor".to_string(),
        p,
        k,
        q: 2.0,
        seed,
        n,
        delta: 0.01,
        eps: 1.0 / 3.0,
        rows: 0,
        width: 0,
        window: 0,
        buckets: 0,
        decay: String::new(),
        decay_rate: 0.0,
        coordinate: String::new(),
    }
}

fn assert_samples_bit_identical(a: &Sample, b: &Sample, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: sample sizes differ");
    assert_eq!(a.tau.to_bits(), b.tau.to_bits(), "{what}: tau differs");
    for (x, y) in a.entries.iter().zip(&b.entries) {
        assert_eq!(x.key, y.key, "{what}: keys differ");
        assert_eq!(x.freq.to_bits(), y.freq.to_bits(), "{what}: freqs differ");
        assert_eq!(
            x.transformed.to_bits(),
            y.transformed.to_bits(),
            "{what}: transformed values differ"
        );
    }
}

// --- 1. WR reservoir primitives ------------------------------------------

#[test]
fn wr_ingest_modes_are_bit_identical() {
    let elems = stream(5_000);
    let mut scalar = WrReservoir::new(wr_cfg(16, 42));
    let mut batched = WrReservoir::new(wr_cfg(16, 42));
    let mut blocked = WrReservoir::new(wr_cfg(16, 42));
    for e in &elems {
        StreamSummary::process(&mut scalar, e);
    }
    // uneven chunk boundaries, so batch/block state can't luck into
    // agreement by mirroring the scalar loop's cadence
    for chunk in elems.chunks(613) {
        batched.process_batch(chunk);
        blocked.process_block(&ElementBlock::from_elements(chunk));
    }
    let want = scalar.encode();
    assert_eq!(batched.encode(), want, "batch drifted from scalar");
    assert_eq!(blocked.encode(), want, "block drifted from scalar");
}

#[test]
fn wr_persist_roundtrip_resumes_identically() {
    let elems = stream(4_000);
    let (head, tail) = elems.split_at(2_500);
    let mut live = WrReservoir::new(wr_cfg(12, 7));
    for e in head {
        StreamSummary::process(&mut live, e);
    }
    let snapshot = live.encode();
    let mut resumed = WrReservoir::decode(&snapshot).expect("decode own snapshot");
    assert_eq!(resumed.encode(), snapshot, "canonical re-encode");
    // the decoded reservoir re-arms its jump points from the persisted
    // RNG, exactly as the live one will from its identical state
    for e in tail {
        StreamSummary::process(&mut live, e);
        StreamSummary::process(&mut resumed, e);
    }
    assert_eq!(
        resumed.encode(),
        live.encode(),
        "resumed run diverged from the uninterrupted one"
    );
}

#[test]
fn wr_merge_takes_slotwise_winners() {
    let elems = stream(6_000);
    let (left, right) = elems.split_at(3_000);
    let mut a = WrReservoir::new(wr_cfg(16, 9));
    let mut b = WrReservoir::new(wr_cfg(16, 9));
    for e in left {
        StreamSummary::process(&mut a, e);
    }
    for e in right {
        StreamSummary::process(&mut b, e);
    }
    let (sa, sb) = (a.sample(), b.sample());
    let mut merged = a.clone();
    Mergeable::merge(&mut merged, &b).unwrap();
    let sm = merged.sample();
    assert_eq!(sm.len(), 16, "every slot stays occupied through a merge");
    // a sample entry's `transformed` carries the slot's E–S exponent:
    // slot-wise the smaller exponent must win, key riding along
    for (i, ((ea, eb), em)) in
        sa.entries.iter().zip(&sb.entries).zip(&sm.entries).enumerate()
    {
        let want = if ea.transformed <= eb.transformed { ea } else { eb };
        assert_eq!(em.key, want.key, "slot {i}: wrong winner");
        assert_eq!(
            em.transformed.to_bits(),
            want.transformed.to_bits(),
            "slot {i}: winner exponent not preserved"
        );
    }
    assert!(
        (merged.total_weight() - (a.total_weight() + b.total_weight())).abs() < 1e-9,
        "merged weight must be the sum of the parts"
    );
}

#[test]
fn wr_draws_track_the_closed_form_probabilities() {
    // 6 keys with geometric weights; each slot draws its winner with
    // probability w_x / W, independently across slots — so over many
    // seeds the draw counts are multinomial(S·k, q) and the distinct-key
    // inclusion rate is exactly the 1 − (1 − q)^k the scenario's WR
    // estimator divides by.
    let weights = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
    let total: f64 = weights.iter().sum();
    let k = 8usize;
    let runs = 600u64;
    let mut draw_counts = [0u64; 6];
    let mut incl_counts = [0u64; 6];
    for s in 0..runs {
        let mut r = WrReservoir::new(wr_cfg(k, 0x5EED ^ (s * 0x9E37)));
        for (i, &w) in weights.iter().enumerate() {
            StreamSummary::process(&mut r, &Element::new(i as u64, w));
        }
        let draws = r.draws();
        assert_eq!(draws.len(), k);
        let mut seen = HashSet::new();
        for d in draws {
            draw_counts[d as usize] += 1;
            if seen.insert(d) {
                incl_counts[d as usize] += 1;
            }
        }
    }
    // chi-square of the draw counts against multinomial expectations
    // (5 dof, E[χ²] = 5): 50 is a far-out bound, and the run is
    // deterministic, so this cannot flake
    let n = (runs as usize * k) as f64;
    let chi2: f64 = weights
        .iter()
        .zip(&draw_counts)
        .map(|(&w, &c)| {
            let expect = n * w / total;
            (c as f64 - expect).powi(2) / expect
        })
        .sum();
    assert!(chi2 < 50.0, "draw counts off the WR law: chi2 = {chi2:.1}");
    // per-key inclusion rate within 6σ of the closed form
    for (i, &w) in weights.iter().enumerate() {
        let pi = wr_inclusion_prob(w / total, k);
        let expect = runs as f64 * pi;
        let sigma = (runs as f64 * pi * (1.0 - pi)).sqrt().max(1.0);
        let obs = incl_counts[i] as f64;
        assert!(
            (obs - expect).abs() < 6.0 * sigma,
            "key {i}: inclusion {obs} vs expected {expect:.1} (σ = {sigma:.1})"
        );
    }
}

// --- 2. served ≡ offline --------------------------------------------------

#[test]
fn served_decayed_sample_is_bit_identical_to_offline_replay() {
    const RATE: f64 = 0.05;
    let elems: Vec<Element> =
        (0..3_000u64).map(|i| Element::new(i % 37, 1.0)).collect();
    let mut dspec = spec("decayed", 1.0, 12, 77, 37);
    dspec.decay = "exp".to_string();
    dspec.decay_rate = RATE;

    // over the wire, in server-chosen chunks
    let mut host = Host::start(Mode::Served).unwrap();
    host.create("contract/decay", &dspec).unwrap();
    host.ingest("contract/decay", &elems).unwrap();
    host.flush("contract/decay").unwrap();
    let served = host.sample("contract/decay").unwrap();
    host.shutdown();

    // offline scalar replay through the same builder path
    let cfg = dspec.to_worp().unwrap().sampler_config().unwrap();
    let mut offline = DecayedWorp::new(cfg, DecaySpec::exponential(RATE).unwrap());
    for e in &elems {
        StreamSummary::process(&mut offline, e);
    }
    assert_samples_bit_identical(&served, &offline.sample(), "decayed served vs offline");
}

#[test]
fn shared_seed_engines_sample_identical_key_sets() {
    let elems: Vec<Element> =
        (0..2_000u64).map(|i| Element::new(i % 211, 1.0 + (i % 3) as f64)).collect();
    let keys_of = |seed: u64| -> Vec<u64> {
        let engine = Engine::new(EngineOpts::new(2, 1024).unwrap());
        engine
            .create("contract/coord", &spec("1pass", 1.0, 32, seed, 211).to_worp().unwrap())
            .unwrap();
        for chunk in elems.chunks(512) {
            engine
                .ingest("contract/coord", &ElementBlock::from_elements(chunk))
                .unwrap();
        }
        engine.flush("contract/coord").unwrap();
        let mut keys: Vec<u64> =
            engine.sample("contract/coord").unwrap().entries.iter().map(|e| e.key).collect();
        keys.sort_unstable();
        keys
    };
    // the randomization is a pure function of the creation seed: two
    // independent engines with a shared seed agree key-for-key (the
    // coordination contract behind the SIMILARITY op) …
    assert_eq!(keys_of(0xC0DE), keys_of(0xC0DE), "shared seed must coordinate");
    // … and an uncoordinated seed does not
    assert_ne!(keys_of(0xC0DE), keys_of(0xBEEF), "distinct seeds must decorrelate");
}

// --- 3. the scenario surface the CLI calls --------------------------------

#[test]
fn wr_vs_wor_scenario_passes_locally() {
    let opts = ScenarioOpts { runs: 12, ..ScenarioOpts::default() };
    let report = scenario::run("wr-vs-wor", &opts).unwrap();
    report.check().unwrap_or_else(|e| panic!("{report}\n{e}"));
}

#[test]
fn coordinated_scenario_passes_over_the_wire() {
    let opts = ScenarioOpts { mode: Mode::Served, ..ScenarioOpts::default() };
    let report = scenario::run("coordinated", &opts).unwrap();
    report.check().unwrap_or_else(|e| panic!("{report}\n{e}"));
    assert_eq!(report.mode, Mode::Served);
}

#[test]
fn decay_scenario_passes_over_the_wire() {
    let opts = ScenarioOpts { mode: Mode::Served, ..ScenarioOpts::default() };
    let report = scenario::run("decay", &opts).unwrap();
    report.check().unwrap_or_else(|e| panic!("{report}\n{e}"));
}
