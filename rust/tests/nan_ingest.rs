//! Adversarial non-finite ingest suite (ISSUE 8 acceptance):
//!
//! Before PR 8, `Engine::ingest_records` accepted raw f64 bits straight
//! off the wire — one crafted NaN poisoned a sketch table (NaN
//! propagates through every `+=` it touches) and, pre-`total_cmp`, made
//! every later median panic. The contract now is **whole-block
//! rejection at the boundary**:
//!
//! 1. a block containing any NaN/±inf value is refused with a typed
//!    [`Error::Codec`] before *any* shard state is touched;
//! 2. over TCP, a crafted non-finite INGEST frame is answered with a
//!    typed error frame and the connection stays usable — no panic, no
//!    poisoned sketch, no close;
//! 3. the instance remains fully serviceable afterwards: good ingest,
//!    flush, sample and snapshot → restore all still round-trip;
//! 4. the offline pipeline entry ([`run_sharded`]) rejects non-finite
//!    stream elements the same way.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use worp::codec::{self, wire};
use worp::data::{Element, ElementBlock};
use worp::engine::client::Client;
use worp::engine::proto::{self, op};
use worp::engine::server::{ServeOpts, Server};
use worp::engine::{Engine, EngineOpts};
use worp::pipeline::{run_sharded, FnSink, PipelineOpts};
use worp::{Error, Worp};

const SHARDS: usize = 3;
const BATCH: usize = 64;

fn spec(seed: u64) -> Worp {
    Worp::p(1.0).k(16).seed(seed).domain(600).sketch_shape(5, 256)
}

fn proto_spec(seed: u64) -> proto::InstanceSpec {
    let mut cfg = worp::config::PipelineConfig::default();
    cfg.method = "1pass".into();
    cfg.k = 16;
    cfg.seed = seed;
    cfg.n = 600;
    cfg.rows = 5;
    cfg.width = 256;
    proto::InstanceSpec::from_config(&cfg)
}

fn good_block(lo: u64, n: u64) -> ElementBlock {
    let elems: Vec<Element> =
        (lo..lo + n).map(|i| Element::new(i % 97, (i % 7) as f64 + 0.5)).collect();
    ElementBlock::from_elements(&elems)
}

fn merged_encode(engine: &Engine, name: &str) -> Vec<u8> {
    let mut out = Vec::new();
    engine.instance(name).unwrap().merged().unwrap().encode_state(&mut out);
    out
}

/// Every non-finite f64 the wire can carry, including a payload NaN
/// whose bit pattern a naive `!= f64::NAN` style check would miss.
fn nonfinite_values() -> Vec<f64> {
    vec![
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::from_bits(0x7FF0_0000_0000_0001), // signaling-style NaN
        f64::from_bits(0xFFF8_DEAD_BEEF_0001), // negative quiet NaN, junk payload
    ]
}

// ---------------------------------------------------------------------------
// 1. library boundary: whole-block rejection, state untouched

#[test]
fn nonfinite_block_rejected_with_typed_error_and_state_intact() {
    let engine = Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap());
    engine.create("t", &spec(5).exact()).unwrap();
    engine.ingest("t", &good_block(0, 500)).unwrap();
    engine.flush("t").unwrap();
    let before = merged_encode(&engine, "t");

    for bad in nonfinite_values() {
        let mut block = good_block(500, 3);
        block.push(11, bad); // poison row *after* valid rows
        block.push(12, 1.0); // and a valid row after the poison
        let err = engine.ingest("t", &block).unwrap_err();
        assert!(matches!(err, Error::Codec(_)), "value {bad:?}: got {err:?}");
        assert!(
            err.to_string().contains("non-finite"),
            "error must name the contract, got: {err}"
        );
    }

    // whole-block rejection: not even the valid rows before the poison
    // may have landed — the merged state is bit-identical to before
    engine.flush("t").unwrap();
    assert_eq!(
        before,
        merged_encode(&engine, "t"),
        "rejected blocks must leave no trace in any shard"
    );

    // and the instance still works
    engine.ingest("t", &good_block(500, 100)).unwrap();
    engine.flush("t").unwrap();
    assert_ne!(before, merged_encode(&engine, "t"));
}

#[test]
fn nonfinite_raw_records_rejected_before_any_shard_state() {
    let engine = Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap());
    engine.create("t", &spec(7).exact()).unwrap();
    engine.ingest("t", &good_block(0, 200)).unwrap();
    engine.flush("t").unwrap();
    let before = merged_encode(&engine, "t");

    // the zero-copy wire path: raw little-endian (key u64, val f64)
    // records, poisoned via raw bit patterns — exactly what a hostile
    // client would put in an INGEST payload
    for bits in [
        f64::NAN.to_bits(),
        f64::INFINITY.to_bits(),
        0x7FF0_0000_0000_0001u64,
    ] {
        let mut recs = Vec::new();
        wire::put_u64(&mut recs, 1);
        wire::put_f64(&mut recs, 2.0);
        wire::put_u64(&mut recs, 2);
        recs.extend_from_slice(&bits.to_le_bytes());
        wire::put_u64(&mut recs, 3);
        wire::put_f64(&mut recs, 3.0);
        let err = engine.ingest_records("t", &recs).unwrap_err();
        assert!(matches!(err, Error::Codec(_)), "bits {bits:#x}: got {err:?}");
    }

    engine.flush("t").unwrap();
    assert_eq!(before, merged_encode(&engine, "t"));
}

// ---------------------------------------------------------------------------
// 2+3. wire boundary: crafted frame, surviving connection, full recovery

fn read_resp(stream: &mut TcpStream) -> worp::Result<Option<proto::Frame>> {
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    proto::read_frame(stream, proto::DEFAULT_MAX_FRAME)
}

#[test]
fn crafted_nan_frame_gets_typed_error_and_connection_survives() {
    let engine = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv = Server::start(Arc::clone(&engine), "127.0.0.1:0", ServeOpts::default()).unwrap();
    let addr = srv.local_addr().to_string();

    let mut c = Client::connect(&addr)
        .unwrap()
        .with_timeout(Duration::from_secs(20))
        .unwrap();
    c.create("wire/t", &proto_spec(5)).unwrap();
    c.ingest("wire/t", &good_block(0, 300)).unwrap();

    // hand-crafted v1 INGEST frame: well-formed framing, NaN payload —
    // the framing layer cannot catch this, only the engine boundary can
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut payload = Vec::new();
        codec::put_str(&mut payload, "wire/t");
        wire::put_usize(&mut payload, 2);
        wire::put_u64(&mut payload, 40);
        wire::put_f64(&mut payload, 1.0);
        wire::put_u64(&mut payload, 41);
        payload.extend_from_slice(&f64::NAN.to_bits().to_le_bytes());
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::INGEST, &payload);
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("a typed error frame, not a close");
        assert_eq!(f.opcode, proto::RESP_ERR);
        assert!(matches!(proto::decode_error(&f.payload), Error::Codec(_)));
        // the framing was valid, so the connection MUST stay open
        let mut buf = Vec::new();
        proto::put_frame(&mut buf, op::PING, b"");
        s.write_all(&buf).unwrap();
        let f = read_resp(&mut s).unwrap().expect("ping still answered");
        assert_eq!(f.opcode, proto::resp_ok(op::PING));
    }

    // the rust client path: a typed engine error must surface as
    // Error::Codec and must NOT poison the connection
    let mut bad = good_block(300, 2);
    bad.push(42, f64::INFINITY);
    let err = c.ingest("wire/t", &bad).unwrap_err();
    assert!(matches!(err, Error::Codec(_)), "got {err:?}");
    c.ping().expect("typed engine errors must not poison the client");

    // full recovery: good ingest, flush, sample, snapshot -> restore
    c.ingest("wire/t", &good_block(300, 100)).unwrap();
    c.flush("wire/t").unwrap();
    let sample = c.sample("wire/t").unwrap();
    assert!(!sample.entries.is_empty());
    for e in &sample.entries {
        assert!(e.freq.is_finite(), "a NaN leaked into the sample: {e:?}");
    }
    let snap = c.snapshot("wire/t").unwrap();

    let engine_b = Arc::new(Engine::new(EngineOpts::new(SHARDS, BATCH).unwrap()));
    let srv_b = Server::start(Arc::clone(&engine_b), "127.0.0.1:0", ServeOpts::default()).unwrap();
    let mut cb = Client::connect(&srv_b.local_addr().to_string()).unwrap();
    assert_eq!(cb.restore(&snap).unwrap(), "wire/t");
    assert_eq!(
        merged_encode(&engine, "wire/t"),
        merged_encode(&engine_b, "wire/t"),
        "snapshot -> restore must still round-trip bit-identically after the attack"
    );
}

// ---------------------------------------------------------------------------
// 4. offline pipeline entry

#[test]
fn offline_pipeline_rejects_nonfinite_stream_elements() {
    let mut stream: Vec<Element> = (0..1_000u64).map(|i| Element::new(i % 50, 1.0)).collect();
    stream[617] = Element::new(9, f64::NAN);
    let opts = PipelineOpts::new(4, 128).unwrap();
    let err = run_sharded(&stream, opts, |_| FnSink::new(|_e: &Element| {})).unwrap_err();
    assert!(matches!(err, Error::Codec(_)), "got {err:?}");
    assert!(
        err.to_string().contains("617"),
        "error should name the offending stream position, got: {err}"
    );

    // a finite stream still runs clean through the same call
    stream[617] = Element::new(9, 1.0);
    let (states, metrics) = run_sharded(&stream, opts, |_| FnSink::new(|_e: &Element| {})).unwrap();
    assert_eq!(states.len(), 4);
    assert_eq!(metrics.elements(), 1_000);
}
