//! Cross-module integration tests: pipeline ∘ samplers ∘ estimators over
//! realistic workloads, coordinator invariants as properties, and failure
//! injection.
//!
//! Determinism: every assertion here is a function of explicit seeds only.
//! The historical seed-red flakes came from `HashMap`-iteration-order
//! leaks inside the samplers (TopK/SpaceSaving eviction ties, the oracle
//! sampler's draw walk, candidate-truncation sorts) — those are fixed at
//! the source with total-order tie-breaks and a `BTreeMap`, and guarded
//! by `topology_and_batching_never_change_output` below, which re-runs an
//! identical seeded pipeline and demands *identical* samples.

use worp::coordinator::{Coordinator, FnSource, VecSource};
use worp::data::stream::{unaggregate, GradientStream};
use worp::data::zipf::{zipf_exact_stream, zipf_frequencies, ZipfStream};
use worp::data::Element;
use worp::estimate::moment_estimate;
use worp::pipeline::PipelineOpts;
use worp::sampler::ppswor::perfect_ppswor;
use worp::sampler::SamplerConfig;
use worp::util::proptest::{run, Gen};
use worp::util::stats::mean;

fn cfg(p: f64, k: usize, n: usize, seed: u64) -> SamplerConfig {
    SamplerConfig::new(p, k)
        .with_seed(seed)
        .with_domain(n)
        .with_sketch_shape(9, 2048)
}

#[test]
fn moment_estimates_from_pipeline_are_consistent() {
    // estimates from the sharded 2-pass pipeline average to the truth
    let n = 1_000;
    let freqs = zipf_frequencies(n, 1.3, 1e4);
    let truth: f64 = freqs.iter().sum();
    let elems = unaggregate(&freqs, 3, false, 3);
    let src = VecSource(elems);
    let ests: Vec<f64> = (0..40)
        .map(|seed| {
            let c = Coordinator::new(cfg(1.0, 60, n, seed), PipelineOpts::new(3, 256).unwrap());
            let (s, _) = c.two_pass(&src).unwrap();
            moment_estimate(&s, 1.0)
        })
        .collect();
    let m = mean(&ests);
    assert!((m - truth).abs() / truth < 0.05, "mean {m} truth {truth}");
}

#[test]
fn generator_source_streams_without_materializing() {
    // FnSource feeds the two-pass pipeline twice from a generator
    let n = 500;
    let src = FnSource(move || ZipfStream::new(n, 1.5, 200_000, 11));
    let c = Coordinator::new(cfg(1.0, 20, n, 5), PipelineOpts::new(2, 1024).unwrap());
    let (sample, metrics) = c.two_pass(&src).unwrap();
    assert_eq!(sample.len(), 20);
    assert_eq!(metrics.elements(), 200_000); // pass-II element count
}

#[test]
fn property_two_pass_invariant_to_topology() {
    // coordinator invariant: worker count and batch size never change
    // the 2-pass output (composability end-to-end)
    run("two-pass topology invariance", 6, |g: &mut Gen| {
        let n = 300;
        let k = 8;
        let seed = g.u64_below(1 << 40);
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, seed ^ 1);
        let src = VecSource(elems);
        let reference: Vec<u64> = {
            let c = Coordinator::new(cfg(1.0, k, n, seed), PipelineOpts::new(1, 64).unwrap());
            c.two_pass(&src).unwrap().0.keys()
        };
        let workers = g.usize_range(2, 6);
        let batch = *g.choose(&[16usize, 128, 1024]);
        let c = Coordinator::new(
            cfg(1.0, k, n, seed),
            PipelineOpts::new(workers, batch).unwrap(),
        );
        let got = c.two_pass(&src).unwrap().0.keys();
        assert_eq!(got, reference, "workers={workers} batch={batch}");
    });
}

#[test]
fn property_one_pass_merge_associative_across_shardings() {
    // routing invariance of the merged sketch: any partition of the
    // stream yields the same merged estimates
    run("one-pass sharding invariance", 5, |g: &mut Gen| {
        let n = 200;
        let seed = g.u64_below(1 << 40);
        let elems = zipf_exact_stream(n, 1.0, 1e3, 2, seed ^ 9);
        let c1 = Coordinator::new(cfg(1.0, 10, n, seed), PipelineOpts::new(1, 32).unwrap());
        let cn = Coordinator::new(
            cfg(1.0, 10, n, seed),
            PipelineOpts::new(g.usize_range(2, 8), 32).unwrap(),
        );
        let (s1, _) = c1.one_pass(&elems).unwrap();
        let (sn, _) = cn.one_pass(&elems).unwrap();
        assert_eq!(s1.keys(), sn.keys());
        for (a, b) in s1.entries.iter().zip(&sn.entries) {
            assert!((a.freq - b.freq).abs() < 1e-6 * a.freq.abs().max(1.0));
        }
    });
}

#[test]
fn topology_and_batching_never_change_output() {
    // seeded fixture: the same configuration must yield the *same* sample
    // run-to-run (catches HashMap-order nondeterminism anywhere in the
    // path) and across router batch sizes (catches batch-path divergence
    // and buffer-recycling bugs)
    let n = 400;
    let k = 12;
    let elems = zipf_exact_stream(n, 1.3, 1e4, 3, 0xF1C);
    let src = VecSource(elems);
    let mut outputs: Vec<Vec<u64>> = Vec::new();
    for (workers, batch) in [(1usize, 32usize), (3, 32), (3, 257), (2, 4096), (3, 32)] {
        let c = Coordinator::new(
            cfg(1.0, k, n, 0xABC),
            PipelineOpts::new(workers, batch).unwrap(),
        );
        let (s, metrics) = c.two_pass(&src).unwrap();
        assert_eq!(metrics.elements() as usize, src.0.len());
        outputs.push(s.keys());
    }
    for o in &outputs[1..] {
        assert_eq!(o, &outputs[0], "topology/batching changed the sample");
    }
}

#[test]
fn signed_gradient_pipeline_end_to_end() {
    // turnstile workload through the full sharded path, l2 sampling
    let n = 5_000;
    let elems: Vec<Element> = GradientStream::new(n, 1.0, 300_000, 7).collect();
    let c = Coordinator::new(cfg(2.0, 50, n, 13), PipelineOpts::new(4, 2048).unwrap());
    let (sample, metrics) = c.one_pass(&elems).unwrap();
    assert_eq!(metrics.elements(), 300_000);
    assert_eq!(sample.len(), 50);
    // heavy parameters (small indices) dominate the l2 sample
    let heavy_hits = sample.keys().iter().filter(|&&k| k < 100).count();
    assert!(heavy_hits > 25, "heavy_hits={heavy_hits}");
}

#[test]
fn failure_injection_worker_panic_is_reported() {
    struct Bomb;
    // StreamSummary is the only impl a sink needs — ShardSink is blanket
    impl worp::api::StreamSummary for Bomb {
        fn process(&mut self, e: &Element) {
            if e.key == 13 {
                panic!("injected worker failure");
            }
        }

        fn size_words(&self) -> usize {
            0
        }

        fn processed(&self) -> u64 {
            0
        }
    }
    let elems: Vec<Element> = (0..1000u64).map(|i| Element::new(i % 50, 1.0)).collect();
    let r = worp::pipeline::run_sharded(&elems, PipelineOpts::new(2, 64).unwrap(), |_| Bomb);
    match r {
        Err(e) => assert!(e.to_string().contains("pipeline")),
        Ok(_) => panic!("worker panic must surface as a pipeline error"),
    }
}

#[test]
fn degenerate_streams_handled() {
    // empty stream
    let c = Coordinator::new(cfg(1.0, 5, 100, 1), PipelineOpts::new(2, 16).unwrap());
    let (s, m) = c.one_pass(&Vec::<Element>::new()).unwrap();
    assert_eq!(m.elements(), 0);
    assert!(s.is_empty());
    // single-key stream
    let elems = vec![Element::new(7, 1.0); 100];
    let (s, _) = c.one_pass(&elems).unwrap();
    assert_eq!(s.len(), 1);
    assert_eq!(s.entries[0].key, 7);
    assert_eq!(s.tau, 0.0);
}

#[test]
fn coordinated_samples_share_randomization() {
    // samples of two *different* datasets built with the same seed are
    // coordinated (paper Conclusion): keys rank by the same r_x, so
    // overlapping heavy keys coincide
    let n = 400;
    let f1 = zipf_frequencies(n, 1.5, 1e4);
    let mut f2 = f1.clone();
    for i in 0..20 {
        f2[i] *= 1.05; // small perturbation
    }
    let s1 = perfect_ppswor(&f1, 1.0, 40, 99);
    let s2 = perfect_ppswor(&f2, 1.0, 40, 99);
    let overlap = s1.keys().iter().filter(|k| s2.keys().contains(k)).count();
    assert!(overlap >= 35, "coordinated samples should barely change: {overlap}/40");
    // different seed -> far less coordination in the random tail
    let s3 = perfect_ppswor(&f2, 1.0, 40, 100);
    let overlap3 = s1.keys().iter().filter(|k| s3.keys().contains(k)).count();
    assert!(overlap3 < overlap, "{overlap3} vs {overlap}");
}
