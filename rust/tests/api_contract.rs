//! Contract tests for the unified summary API: one generic driver covers
//! every WOR sampler through `Box<dyn WorSampler>` (the same path the
//! CLI/pipeline uses), checking the paper's composability property
//! `merge(split stream) ≡ process(whole stream)` and the loud-failure
//! contract for incompatible merges.

use worp::api::{Mergeable, MultiPass, StreamSummary, WorSampler};
use worp::data::zipf::zipf_exact_stream;
use worp::data::Element;
use worp::sampler::Sample;
use worp::{Error, Worp};

fn stream(n: usize, seed: u64) -> Vec<Element> {
    zipf_exact_stream(n, 1.2, 1e4, 2, seed)
}

/// Drive a boxed sampler through all its passes, single stream.
fn drive_seq(proto: &dyn WorSampler, elems: &[Element]) -> Sample {
    let mut c = proto.clone_box();
    for pass in 0..c.passes() {
        if pass > 0 {
            c.advance().unwrap();
        }
        for e in elems {
            c.process(e);
        }
    }
    c.sample().unwrap()
}

/// Drive a boxed sampler through all its passes with the stream split
/// across two "shards" that are merged per pass.
fn drive_split(proto: &dyn WorSampler, elems: &[Element]) -> Sample {
    let mut current = proto.clone_box();
    for pass in 0..current.passes() {
        if pass > 0 {
            current.advance().unwrap();
        }
        let mut a = current.clone();
        let mut b = current.clone();
        for (i, e) in elems.iter().enumerate() {
            if i % 2 == 0 {
                a.process(e);
            } else {
                b.process(e);
            }
        }
        a.merge_dyn(&*b).unwrap();
        current = a;
    }
    assert_eq!(current.processed(), elems.len() as u64);
    current.sample().unwrap()
}

fn assert_samples_agree(method: &str, split: &Sample, whole: &Sample) {
    assert_eq!(split.keys(), whole.keys(), "{method}: key sets differ");
    assert!(
        (split.tau - whole.tau).abs() <= 1e-9 * whole.tau.abs().max(1.0),
        "{method}: tau {} vs {}",
        split.tau,
        whole.tau
    );
    for (s, w) in split.entries.iter().zip(&whole.entries) {
        assert!(
            (s.freq - w.freq).abs() <= 1e-6 * w.freq.abs().max(1.0),
            "{method}: freq {} vs {} for key {}",
            s.freq,
            w.freq,
            s.key
        );
    }
}

/// The satellite property, generically: for every WOR sampler the
/// builder can produce, merging shard summaries equals summarizing the
/// whole stream — same sample keys and threshold τ.
#[test]
fn merge_split_stream_equals_whole_stream_for_every_sampler() {
    // n is kept below the 1-pass candidate capacity so candidate-set
    // truncation (timing-dependent by design) cannot perturb the check
    let n = 200;
    let elems = stream(n, 5);
    let base = Worp::p(1.0)
        .k(16)
        .seed(77)
        .domain(n)
        .sketch_shape(7, 1024);
    let builders = [
        base.clone().one_pass(),
        base.clone().two_pass(),
        base.clone().exact(),
        // effectively-unbounded window: trait ticks stay inside it
        base.clone().windowed(1 << 40, 4),
        base.clone().k(6).tv().tv_r(64),
    ];
    for b in builders {
        let proto = b.build().unwrap();
        let method = proto.name();
        let whole = drive_seq(&*proto, &elems);
        let split = drive_split(&*proto, &elems);
        assert_samples_agree(method, &split, &whole);
        assert!(!whole.entries.is_empty(), "{method}: empty sample");
    }
}

/// Same property through static dispatch, for call sites that keep
/// concrete types (the generic constraint is the whole test: any
/// `WorSampler + Mergeable + Clone` passes through unchanged).
fn split_merge_static<S>(proto: S, elems: &[Element]) -> (Sample, Sample)
where
    S: WorSampler + Mergeable + Clone,
{
    let mut whole = proto.clone();
    for e in elems {
        whole.process(e);
    }
    let mut a = proto.clone();
    let mut b = proto;
    for (i, e) in elems.iter().enumerate() {
        if i % 2 == 0 {
            a.process(e);
        } else {
            b.process(e);
        }
    }
    Mergeable::merge(&mut a, &b).unwrap();
    assert_eq!(
        StreamSummary::processed(&a),
        StreamSummary::processed(&whole)
    );
    (
        WorSampler::sample(&a).unwrap(),
        WorSampler::sample(&whole).unwrap(),
    )
}

#[test]
fn static_dispatch_merge_property() {
    let n = 200;
    let elems = stream(n, 9);
    let base = Worp::p(2.0).k(12).seed(3).domain(n).sketch_shape(7, 1024);
    let (s, w) = split_merge_static(base.build_one_pass().unwrap(), &elems);
    assert_samples_agree("1pass-static", &s, &w);
    let (s, w) = split_merge_static(base.build_exact().unwrap(), &elems);
    assert_samples_agree("exact-static", &s, &w);
}

/// Satellite: merging summaries built from different seeds or sketch
/// shapes returns `Error::Incompatible` — never a panic, never silent
/// corruption.
#[test]
fn incompatible_merges_fail_loudly() {
    let base = Worp::p(1.0).k(8).domain(100).sketch_shape(5, 256);
    let elems = stream(100, 1);

    // different seeds
    for method in ["1pass", "2pass", "exact", "windowed", "tv"] {
        let m = worp::Method::parse(method).unwrap();
        let mk = |seed: u64| {
            let mut b = base.clone().seed(seed).method(m);
            if m == worp::Method::Windowed {
                b = b.windowed(1 << 20, 4);
            }
            let mut s = b.build().unwrap();
            for e in &elems {
                s.process(e);
            }
            s
        };
        let mut a = mk(1);
        let b2 = mk(2);
        let err = a.merge_dyn(&*b2).unwrap_err();
        assert!(
            matches!(err, Error::Incompatible(_)),
            "{method} seed mismatch: {err}"
        );
    }

    // different sketch shapes
    let mut a = base.clone().one_pass().build().unwrap();
    let b2 = base.clone().sketch_shape(5, 512).one_pass().build().unwrap();
    let err = a.merge_dyn(&*b2).unwrap_err();
    assert!(matches!(err, Error::Incompatible(_)), "shape mismatch: {err}");

    // different concrete samplers
    let mut a = base.clone().one_pass().build().unwrap();
    let b2 = base.clone().exact().build().unwrap();
    let err = a.merge_dyn(&*b2).unwrap_err();
    assert!(matches!(err, Error::Incompatible(_)), "cross-method: {err}");

    // different k
    let mut a = base.clone().exact().build().unwrap();
    let b2 = base.clone().k(9).exact().build().unwrap();
    let err = a.merge_dyn(&*b2).unwrap_err();
    assert!(matches!(err, Error::Incompatible(_)), "k mismatch: {err}");
}

#[test]
fn multipass_surface_is_consistent() {
    let one = Worp::p(1.0).k(4).one_pass().build().unwrap();
    assert_eq!(one.passes(), 1);
    assert_eq!(one.pass(), 0);
    let mut one = one;
    assert!(matches!(one.advance(), Err(Error::State(_))));

    let mut two = Worp::p(1.0).k(4).two_pass().build().unwrap();
    assert_eq!(two.passes(), 2);
    assert_eq!(two.pass(), 0);
    assert!(matches!(two.sample(), Err(Error::State(_))));
    two.advance().unwrap();
    assert_eq!(two.pass(), 1);
    assert!(two.sample().is_ok());
    assert!(matches!(two.advance(), Err(Error::State(_))));
}

#[test]
fn batch_and_element_paths_agree() {
    let n = 300;
    let elems = stream(n, 11);
    let b = Worp::p(1.0).k(10).seed(5).domain(n).sketch_shape(7, 1024);
    let mut by_elem = b.clone().one_pass().build().unwrap();
    let mut by_batch = b.one_pass().build().unwrap();
    for e in &elems {
        by_elem.process(e);
    }
    for chunk in elems.chunks(64) {
        by_batch.process_batch(chunk);
    }
    assert_eq!(by_elem.processed(), by_batch.processed());
    assert_eq!(
        by_elem.sample().unwrap().keys(),
        by_batch.sample().unwrap().keys()
    );
}
