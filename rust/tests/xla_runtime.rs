//! Integration: the AOT-compiled JAX/Pallas artifacts executed from rust
//! must agree with the native CountSketch bit-for-bit (up to f32).
//!
//! Requires the `xla` cargo feature (PJRT bindings) and `make artifacts`;
//! tests skip (with a notice) when the artifacts directory is absent so
//! `cargo test` stays runnable standalone.
#![cfg(feature = "xla")]

use worp::data::Element;
use worp::runtime::artifact::ArtifactDir;
use worp::runtime::executor::{XlaCountSketch, XlaEstimator};
use worp::runtime::XlaRuntime;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::RhhSketch;
use worp::util::rng::Rng;

fn artifacts() -> Option<ArtifactDir> {
    for dir in ["artifacts", "../artifacts"] {
        if ArtifactDir::exists(dir) {
            return ArtifactDir::open(dir).ok();
        }
    }
    eprintln!("SKIP: no artifacts (run `make artifacts`)");
    None
}

#[test]
fn xla_update_matches_native_countsketch() {
    let Some(dir) = artifacts() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    let seed = 0xBEEF;
    let mut xs = XlaCountSketch::load(&rt, &dir, seed).unwrap();
    let (rows, width) = xs.shape();
    let mut native = CountSketch::with_shape(rows, width, seed);

    let mut rng = Rng::new(42);
    let elems: Vec<Element> = (0..10_000)
        .map(|_| Element::new(rng.below(5_000), (rng.below(200) as f64 - 100.0) / 4.0))
        .collect();
    for e in &elems {
        xs.process(e).unwrap();
        native.process(e);
    }
    xs.flush().unwrap();
    assert!(xs.kernel_calls >= 2, "batched execution expected");

    // tables agree to f32 precision
    for (i, (&x, &n)) in xs.table().iter().zip(native.table().iter()).enumerate() {
        assert!(
            (x as f64 - n).abs() < 1e-2 + 1e-5 * n.abs(),
            "cell {i}: xla={x} native={n}"
        );
    }
    // estimates agree on hot keys
    for key in 0..64u64 {
        let a = xs.est(key);
        let b = native.est(key);
        assert!((a - b).abs() < 1e-2 + 1e-4 * b.abs(), "key {key}: {a} vs {b}");
    }
}

#[test]
fn xla_estimator_matches_native_estimates() {
    let Some(dir) = artifacts() else { return };
    let rt = XlaRuntime::cpu().unwrap();
    let seed = 0xF00D;
    let mut xs = XlaCountSketch::load(&rt, &dir, seed).unwrap();
    let (rows, width) = xs.shape();
    let mut native = CountSketch::with_shape(rows, width, seed);
    let mut rng = Rng::new(7);
    for _ in 0..5_000 {
        let e = Element::new(rng.below(1_000), rng.normal() * 10.0);
        xs.process(&e).unwrap();
        native.process(&e);
    }
    xs.flush().unwrap();

    let est = XlaEstimator::load(&rt, &dir, seed).unwrap();
    let keys: Vec<u64> = (0..est.batch_size().min(256) as u64).collect();
    let got = est.estimate(xs.table(), &keys).unwrap();
    for (i, &k) in keys.iter().enumerate() {
        let want = native.est(k);
        assert!(
            (got[i] - want).abs() < 1e-2 + 1e-4 * want.abs(),
            "key {k}: xla={} native={want}",
            got[i]
        );
    }
}

#[test]
fn xla_one_pass_coordinator_end_to_end() {
    let Some(_) = artifacts() else { return };
    use worp::coordinator::Coordinator;
    use worp::data::zipf::zipf_exact_stream;
    use worp::pipeline::PipelineOpts;
    use worp::sampler::SamplerConfig;

    let n = 500;
    let k = 10;
    // shape must match the artifact (rows=5, width=1024)
    let cfg = SamplerConfig::new(1.0, k)
        .with_seed(33)
        .with_domain(n)
        .with_sketch_shape(5, 1024);
    let c = Coordinator::new(cfg.clone(), PipelineOpts::default());
    let elems = zipf_exact_stream(n, 1.5, 1e4, 2, 3);
    let dir = if ArtifactDir::exists("artifacts") { "artifacts" } else { "../artifacts" };
    let (xla_sample, _) = c.one_pass_xla(elems.clone(), dir).unwrap();
    assert_eq!(xla_sample.len(), k);

    // the native 1-pass sampler with the same seed must agree on the keys
    let mut native = worp::sampler::worp1::OnePassWorp::new(cfg);
    for e in &elems {
        native.process(e);
    }
    let native_sample = native.sample();
    let overlap = xla_sample
        .keys()
        .iter()
        .filter(|k| native_sample.keys().contains(k))
        .count();
    assert!(overlap >= k - 1, "xla vs native overlap {overlap}/{k}");
}
