//! Golden-vector suite: the committed `tests/golden/*.worp` fixtures
//! (generated independently by `tests/golden/gen_goldens.py`) pin the
//! wire format. Today's encoder must reproduce each fixture
//! **byte-for-byte**, and today's decoder must accept it — any layout,
//! hashing, fingerprint or checksum drift fails loudly here instead of
//! silently orphaning previously persisted summaries.
//!
//! Every fixture is constructed so its payload involves only integer
//! arithmetic and exact IEEE-754 sums, so the bytes are reproducible
//! from first principles on any platform.

use worp::api::Persist;
use worp::data::Element;
use worp::sampler::decayed::DecayedWorp;
use worp::sampler::exact::ExactWor;
use worp::sampler::perfect_lp::{OracleSampler, PrecisionSampler, SingleLpSampler};
use worp::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use worp::sampler::windowed::WindowedWorp;
use worp::sampler::worp1::OnePassWorp;
use worp::sampler::worp2::{TwoPassWorp, TwoPassWorpPass1};
use worp::sampler::wr_reservoir::WrReservoir;
use worp::sampler::SamplerConfig;
use worp::sketch::countmin::CountMin;
use worp::sketch::countsketch::CountSketch;
use worp::sketch::spacesaving::SpaceSaving;
use worp::sketch::topk::TopK;
use worp::sketch::window::WindowedCountSketch;
use worp::sketch::{AnyRhh, RhhSketch, SketchParams};
use worp::transform::DecaySpec;

fn golden_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn first_diff(a: &[u8], b: &[u8]) -> String {
    let n = a.len().min(b.len());
    for i in 0..n {
        if a[i] != b[i] {
            let lo = i.saturating_sub(8);
            return format!(
                "first difference at byte {i}: encoder {:02x?} vs golden {:02x?} (context from {lo})",
                &a[lo..(i + 8).min(a.len())],
                &b[lo..(i + 8).min(b.len())]
            );
        }
    }
    format!("lengths differ: encoder {} vs golden {}", a.len(), b.len())
}

/// Assert today's encoder reproduces the fixture and today's decoder
/// accepts it (with a canonical re-encode back to the same bytes).
fn check_golden<T: Persist>(name: &str, live: &T) {
    let path = golden_dir().join(name);
    let golden = std::fs::read(&path)
        .unwrap_or_else(|e| panic!("{name}: missing golden fixture {}: {e}", path.display()));
    let encoded = live.encode();
    assert!(
        encoded == golden,
        "{name}: encoder drifted from the committed format — {}",
        first_diff(&encoded, &golden)
    );
    let decoded = T::decode(&golden)
        .unwrap_or_else(|e| panic!("{name}: decoder rejects the committed fixture: {e}"));
    assert!(
        decoded.encode() == golden,
        "{name}: decode∘encode is not the identity on the fixture — {}",
        first_diff(&decoded.encode(), &golden)
    );
}

fn cfg8() -> SamplerConfig {
    SamplerConfig::new(1.0, 4)
        .with_seed(42)
        .with_domain(100)
        .with_sketch_shape(3, 16)
}

#[test]
fn golden_countsketch() {
    let mut s = CountSketch::with_shape(3, 8, 42);
    for (k, v) in [(1u64, 2.0), (2, -3.0), (1, 1.0)] {
        RhhSketch::process(&mut s, &Element::new(k, v));
    }
    check_golden("countsketch.worp", &s);
}

#[test]
fn golden_countmin() {
    let mut s = CountMin::with_shape(3, 8, 42);
    for (k, v) in [(1u64, 2.0), (2, 3.0)] {
        RhhSketch::process(&mut s, &Element::new(k, v));
    }
    check_golden("countmin.worp", &s);
}

#[test]
fn golden_anyrhh() {
    let s = AnyRhh::for_q(1.0, SketchParams::new(3, 8, 42));
    check_golden("anyrhh.worp", &s);
}

#[test]
fn golden_spacesaving() {
    let mut s: SpaceSaving<u64> = SpaceSaving::new(4);
    s.process(5, 1.0);
    s.process(5, 1.0);
    s.process(7, 2.5);
    check_golden("spacesaving.worp", &s);
}

#[test]
fn golden_topk() {
    let mut s = TopK::new(3, 4);
    s.process(1, 2.0, 10.0);
    s.process(2, 1.0, 5.0);
    s.process(1, 3.0, 10.0);
    check_golden("topk.worp", &s);
}

#[test]
fn golden_windowsketch() {
    let s = WindowedCountSketch::new(SketchParams::new(3, 8, 42), 100, 10);
    check_golden("windowsketch.worp", &s);
}

#[test]
fn golden_exact() {
    let mut s = ExactWor::new(SamplerConfig::new(1.0, 8).with_seed(42).with_domain(100));
    for (k, v) in [(1u64, 2.0), (2, 3.0), (1, 1.0)] {
        s.process(&Element::new(k, v));
    }
    check_golden("exact.worp", &s);
}

#[test]
fn golden_worp1() {
    check_golden("worp1.worp", &OnePassWorp::new(cfg8()));
}

#[test]
fn golden_worp2() {
    check_golden("worp2.worp", &TwoPassWorp::new(cfg8()));
}

#[test]
fn golden_worp2pass2() {
    check_golden("worp2pass2.worp", &TwoPassWorpPass1::new(cfg8()).into_pass2());
}

#[test]
fn golden_tv() {
    let cfg = TvSamplerConfig::new(1.0, 2, 16, 42, SamplerKind::Oracle).with_r(3);
    check_golden("tv.worp", &TvSampler::new(cfg));
}

#[test]
fn golden_windowed() {
    check_golden("windowed.worp", &WindowedWorp::new(cfg8(), 50, 5));
}

#[test]
fn golden_wr() {
    check_golden("wr.worp", &WrReservoir::new(cfg8()));
}

#[test]
fn golden_decayed() {
    use worp::api::StreamSummary;
    let cfg = SamplerConfig::new(1.0, 8).with_seed(42).with_domain(100);
    let mut s = DecayedWorp::new(cfg, DecaySpec::exponential(0.5).unwrap());
    // three scalar ticks on distinct keys: every stored sum is the raw
    // value itself (0.0 * carry + val), so the payload is integer-exact
    for (k, v) in [(1u64, 2.0), (5, -3.0), (9, 4.0)] {
        s.process(&Element::new(k, v));
    }
    check_golden("decayed.worp", &s);
}

#[test]
fn golden_oracle() {
    let mut s = OracleSampler::new(1.0, 42);
    SingleLpSampler::process(&mut s, &Element::new(1, 2.0));
    check_golden("oracle.worp", &s);
}

#[test]
fn golden_precision() {
    check_golden("precision.worp", &PrecisionSampler::new(1.0, 42, 3, 8));
}

#[test]
fn golden_fixtures_decode_through_the_dynamic_path() {
    // sampler fixtures also decode behind Box<dyn WorSampler> via the
    // type-tagged envelope, with the right method name
    use worp::api::WorSampler;
    for (file, name) in [
        ("worp1.worp", "1pass"),
        ("worp2.worp", "2pass"),
        ("tv.worp", "tv"),
        ("windowed.worp", "windowed"),
        ("exact.worp", "exact"),
        ("wr.worp", "wr"),
        ("decayed.worp", "decayed"),
    ] {
        let bytes = std::fs::read(golden_dir().join(file)).unwrap();
        let s: Box<dyn WorSampler> = worp::codec::decode_sampler(&bytes)
            .unwrap_or_else(|e| panic!("{file}: dynamic decode failed: {e}"));
        assert_eq!(s.name(), name, "{file}");
    }
}
