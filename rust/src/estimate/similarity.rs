//! Multi-set estimators over **coordinated** samples (paper Conclusion:
//! "coordinated samples facilitate powerful estimators for multi-set
//! statistics and similarity measures such as weighted Jaccard
//! similarity, min or max sums, ...").
//!
//! Two bottom-k samples built with the *same* randomization `r_x` are
//! coordinated: key `x` is in sample `i` iff `r_x ≤ (ν_x^{(i)}/τ_i)^p`,
//! with the same draw `r_x` on both sides. Hence
//!
//! - `x ∈ S₁ ∩ S₂  ⇔  r_x ≤ min_i (ν_x^{(i)}/τ_i)^p`
//!
//! which yields rigorous inverse-probability estimators for min-sums over
//! the intersection, and plug-in ratio estimators for weighted Jaccard.

use crate::error::{Error, Result};
use crate::sampler::Sample;
use crate::util::hashing::BottomKDist;
use std::collections::HashMap;

fn incl_prob(dist: BottomKDist, ratio_p: f64) -> f64 {
    match dist {
        BottomKDist::Exp => 1.0 - (-ratio_p).exp(),
        BottomKDist::Uniform => ratio_p.min(1.0),
    }
}

/// Check two samples are coordinated-compatible (same p and D; the caller
/// is responsible for having used the same seed).
fn check_pair(a: &Sample, b: &Sample) {
    assert_eq!(a.p, b.p, "coordinated samples need equal p");
    assert_eq!(a.dist, b.dist, "coordinated samples need equal D");
}

/// The fallible twin of the internal pair check — what served query
/// paths use, so a mismatched pair is a typed [`Error::Incompatible`]
/// over the wire rather than a panic in the server.
pub fn check_compatible(a: &Sample, b: &Sample) -> Result<()> {
    if a.p != b.p {
        return Err(Error::Incompatible(format!(
            "coordinated samples need equal p (got {} and {})",
            a.p, b.p
        )));
    }
    if a.dist != b.dist {
        return Err(Error::Incompatible(
            "coordinated samples need the same bottom-k distribution".into(),
        ));
    }
    Ok(())
}

/// Every similarity statistic the coordinated estimators produce for one
/// pair of samples — what the WRPC `SIMILARITY` query returns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimilarityReport {
    /// Estimated `Σ_x min(ν_x^{(1)}, ν_x^{(2)})` (see [`min_sum`]).
    pub min_sum: f64,
    /// Estimated `Σ_x max(ν_x^{(1)}, ν_x^{(2)})` (see [`max_sum`]).
    pub max_sum: f64,
    /// Estimated weighted Jaccard `Σmin / Σmax ∈ [0, 1]`.
    pub jaccard: f64,
    /// Key-overlap diagnostic `|S₁ ∩ S₂| / min(|S₁|, |S₂|)`.
    pub overlap: f64,
}

/// Compute the full [`SimilarityReport`] for two coordinated samples
/// (typed error on a mismatched pair, never a panic).
pub fn report(a: &Sample, b: &Sample) -> Result<SimilarityReport> {
    check_compatible(a, b)?;
    let mn = min_sum(a, b);
    let mx = max_sum(a, b);
    let jaccard = if mx > 0.0 { (mn / mx).clamp(0.0, 1.0) } else { 0.0 };
    Ok(SimilarityReport { min_sum: mn, max_sum: mx, jaccard, overlap: key_overlap(a, b) })
}

/// Unbiased estimate of the min-sum `Σ_x min(ν_x^{(1)}, ν_x^{(2)})` from
/// two coordinated samples (frequencies taken by magnitude). Keys outside
/// `S₁ ∩ S₂` contribute through inverse-probability weighting of the
/// intersection membership condition.
pub fn min_sum(a: &Sample, b: &Sample) -> f64 {
    check_pair(a, b);
    let fb: HashMap<u64, f64> = b.entries.iter().map(|e| (e.key, e.freq)).collect();
    let mut total = 0.0;
    for e in &a.entries {
        let Some(&f2) = fb.get(&e.key) else { continue };
        let f1 = e.freq.abs();
        let f2 = f2.abs();
        let m = f1.min(f2);
        if m <= 0.0 {
            continue;
        }
        // Pr[x in S1 ∩ S2] under shared r_x:
        // r_x <= min((f1/tau1)^p, (f2/tau2)^p)
        let r1 = if a.tau > 0.0 { (f1 / a.tau).powf(a.p) } else { f64::INFINITY };
        let r2 = if b.tau > 0.0 { (f2 / b.tau).powf(b.p) } else { f64::INFINITY };
        let ratio = r1.min(r2);
        let p_inc = if ratio.is_finite() { incl_prob(a.dist, ratio) } else { 1.0 };
        total += m / p_inc.max(1e-300);
    }
    total
}

/// Plug-in estimate of the max-sum `Σ_x max(ν_x^{(1)}, ν_x^{(2)})` via
/// `sum₁ + sum₂ − min_sum` (each `sum_i` estimated from its own sample).
pub fn max_sum(a: &Sample, b: &Sample) -> f64 {
    let s1 = crate::estimate::moment_estimate(a, 1.0);
    let s2 = crate::estimate::moment_estimate(b, 1.0);
    (s1 + s2 - min_sum(a, b)).max(0.0)
}

/// Plug-in estimate of the weighted Jaccard similarity
/// `J = Σ min / Σ max ∈ [0, 1]`. Slightly biased (ratio of estimates) but
/// consistent; coordination makes the numerator estimable at all.
pub fn weighted_jaccard(a: &Sample, b: &Sample) -> f64 {
    let mn = min_sum(a, b);
    let mx = max_sum(a, b);
    if mx <= 0.0 {
        return 0.0;
    }
    (mn / mx).clamp(0.0, 1.0)
}

/// Sample-overlap diagnostic: |S₁ ∩ S₂| / k — with coordination this is
/// itself an estimator of sample stability (paper's LSH property).
pub fn key_overlap(a: &Sample, b: &Sample) -> f64 {
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let kb: std::collections::HashSet<u64> = b.keys().into_iter().collect();
    let inter = a.keys().iter().filter(|k| kb.contains(k)).count();
    inter as f64 / a.len().min(b.len()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::zipf_frequencies;
    use crate::sampler::ppswor::perfect_ppswor;
    use crate::util::stats::mean;

    fn true_min_max_jaccard(f1: &[f64], f2: &[f64]) -> (f64, f64, f64) {
        let mut mn = 0.0;
        let mut mx = 0.0;
        for i in 0..f1.len().max(f2.len()) {
            let a = f1.get(i).copied().unwrap_or(0.0).abs();
            let b = f2.get(i).copied().unwrap_or(0.0).abs();
            mn += a.min(b);
            mx += a.max(b);
        }
        (mn, mx, mn / mx)
    }

    fn perturbed(f: &[f64], factor: f64, stride: usize) -> Vec<f64> {
        f.iter()
            .enumerate()
            .map(|(i, &v)| if i % stride == 0 { v * factor } else { v })
            .collect()
    }

    #[test]
    fn min_sum_unbiased_on_coordinated_samples() {
        let n = 500;
        let f1 = zipf_frequencies(n, 1.2, 1e4);
        let f2 = perturbed(&f1, 0.5, 3);
        let (true_min, _, _) = true_min_max_jaccard(&f1, &f2);
        let ests: Vec<f64> = (0..300)
            .map(|seed| {
                let a = perfect_ppswor(&f1, 1.0, 80, seed);
                let b = perfect_ppswor(&f2, 1.0, 80, seed); // same seed!
                min_sum(&a, &b)
            })
            .collect();
        let m = mean(&ests);
        assert!(
            (m - true_min).abs() / true_min < 0.08,
            "min-sum mean {m} vs truth {true_min}"
        );
    }

    #[test]
    fn jaccard_accurate_on_similar_sets() {
        let n = 500;
        let f1 = zipf_frequencies(n, 1.5, 1e4);
        let f2 = perturbed(&f1, 0.8, 2);
        let (_, _, true_j) = true_min_max_jaccard(&f1, &f2);
        let ests: Vec<f64> = (0..200)
            .map(|seed| {
                let a = perfect_ppswor(&f1, 1.0, 100, seed);
                let b = perfect_ppswor(&f2, 1.0, 100, seed);
                weighted_jaccard(&a, &b)
            })
            .collect();
        let m = mean(&ests);
        assert!((m - true_j).abs() < 0.08, "J est {m} vs truth {true_j}");
    }

    #[test]
    fn identical_datasets_give_jaccard_one() {
        let f = zipf_frequencies(300, 1.0, 1e3);
        let a = perfect_ppswor(&f, 1.0, 50, 7);
        let b = perfect_ppswor(&f, 1.0, 50, 7);
        assert_eq!(a.keys(), b.keys());
        assert!((weighted_jaccard(&a, &b) - 1.0).abs() < 1e-6);
        assert_eq!(key_overlap(&a, &b), 1.0);
    }

    #[test]
    fn disjoint_datasets_give_jaccard_zero() {
        let n = 200;
        let mut f1 = vec![0.0; n];
        let mut f2 = vec![0.0; n];
        for i in 0..100 {
            f1[i] = 10.0;
            f2[i + 100] = 10.0;
        }
        let a = perfect_ppswor(&f1, 1.0, 30, 3);
        let b = perfect_ppswor(&f2, 1.0, 30, 3);
        assert_eq!(min_sum(&a, &b), 0.0);
        assert_eq!(weighted_jaccard(&a, &b), 0.0);
    }

    #[test]
    #[should_panic(expected = "equal p")]
    fn mismatched_p_rejected() {
        let f = zipf_frequencies(100, 1.0, 1e3);
        let a = perfect_ppswor(&f, 1.0, 10, 3);
        let b = perfect_ppswor(&f, 2.0, 10, 3);
        min_sum(&a, &b);
    }

    #[test]
    fn report_bundles_the_estimators_and_types_mismatches() {
        let f = zipf_frequencies(400, 1.2, 1e4);
        let f2 = perturbed(&f, 0.5, 2);
        let a = perfect_ppswor(&f, 1.0, 60, 5);
        let b = perfect_ppswor(&f2, 1.0, 60, 5);
        let r = report(&a, &b).unwrap();
        assert_eq!(r.min_sum, min_sum(&a, &b));
        assert_eq!(r.max_sum, max_sum(&a, &b));
        assert!((r.jaccard - weighted_jaccard(&a, &b)).abs() < 1e-12);
        assert_eq!(r.overlap, key_overlap(&a, &b));
        // mismatched p is a typed error on the fallible path
        let c = perfect_ppswor(&f, 2.0, 60, 5);
        assert!(matches!(
            report(&a, &c),
            Err(crate::error::Error::Incompatible(_))
        ));
    }

    #[test]
    fn uncoordinated_samples_lose_overlap() {
        let f = zipf_frequencies(2000, 1.0, 1e4);
        let a = perfect_ppswor(&f, 1.0, 50, 7);
        let b_coord = perfect_ppswor(&f, 1.0, 50, 7);
        let b_indep = perfect_ppswor(&f, 1.0, 50, 8);
        assert!(key_overlap(&a, &b_coord) > key_overlap(&a, &b_indep));
    }
}
