//! Rank-frequency distribution estimation (paper Fig 1 right, Fig 2).
//!
//! From a WOR sample: sort sampled keys by decreasing frequency; the
//! estimated rank of the i-th sampled key is the running sum of inverse
//! inclusion probabilities `Σ_{j ≤ i} 1/p_j` — an unbiased estimate of
//! `|{y : ν_y ≥ ν_x}|`. Plotting (estimated rank, frequency) reproduces
//! the paper's rank-frequency series. The WR variant weights distinct
//! draws by `1/(1 − (1−q)^k)`.

use super::wr_inclusion_prob;
use crate::sampler::wr::WrSample;
use crate::sampler::Sample;

/// One point of the estimated rank-frequency curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankFreqPoint {
    /// Estimated rank (number of keys with frequency ≥ this one).
    pub rank: f64,
    /// The key's (estimated or exact) frequency.
    pub freq: f64,
}

/// Estimate the rank-frequency curve from a WOR bottom-k sample.
pub fn rank_frequency_wor(sample: &Sample) -> Vec<RankFreqPoint> {
    let mut entries: Vec<(f64, f64)> = sample
        .entries
        .iter()
        .map(|e| {
            let p = if sample.tau > 0.0 {
                sample.inclusion_prob(e.freq)
            } else {
                1.0
            };
            (e.freq.abs(), p.max(1e-300))
        })
        .collect();
    entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut acc = 0.0;
    entries
        .into_iter()
        .map(|(freq, p)| {
            acc += 1.0 / p;
            RankFreqPoint { rank: acc, freq }
        })
        .collect()
}

/// Estimate the rank-frequency curve from a WR sample (distinct draws,
/// inverse per-key inclusion over k draws).
pub fn rank_frequency_wr(sample: &WrSample) -> Vec<RankFreqPoint> {
    let mut entries: Vec<(f64, f64)> = sample
        .distinct()
        .into_iter()
        .map(|(_, freq, q)| (freq.abs(), wr_inclusion_prob(q, sample.k).max(1e-300)))
        .collect();
    entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut acc = 0.0;
    entries
        .into_iter()
        .map(|(freq, p)| {
            acc += 1.0 / p;
            RankFreqPoint { rank: acc, freq }
        })
        .collect()
}

/// Mean relative error between an estimated curve and the true
/// rank-frequency vector (`true_rf[r]` = frequency of rank r+1), evaluated
/// at the estimated ranks; splits head (ranks ≤ `head`) and tail. Used by
/// the Fig 2 bench to quantify "WOR approximates the tail much better".
pub fn curve_error(
    points: &[RankFreqPoint],
    true_rf: &[f64],
    head: usize,
) -> (f64, f64) {
    let (mut eh, mut nh, mut et, mut nt) = (0.0, 0u32, 0.0, 0u32);
    for pt in points {
        let r = (pt.rank.round().max(1.0) as usize - 1).min(true_rf.len() - 1);
        let truth = true_rf[r];
        if truth <= 0.0 {
            continue;
        }
        let rel = (pt.freq - truth).abs() / truth;
        if r < head {
            eh += rel;
            nh += 1;
        } else {
            et += rel;
            nt += 1;
        }
    }
    (
        if nh > 0 { eh / nh as f64 } else { 0.0 },
        if nt > 0 { et / nt as f64 } else { 0.0 },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::zipf_frequencies;
    use crate::data::FreqVector;
    use crate::sampler::ppswor::perfect_ppswor;
    use crate::sampler::wr::perfect_wr;

    #[test]
    fn wor_curve_monotone_and_anchored() {
        let freqs = zipf_frequencies(1000, 1.0, 100.0);
        let s = perfect_ppswor(&freqs, 1.0, 50, 3);
        let pts = rank_frequency_wor(&s);
        assert_eq!(pts.len(), 50);
        for w in pts.windows(2) {
            assert!(w[0].rank < w[1].rank);
            assert!(w[0].freq >= w[1].freq);
        }
        // the top key is nearly always sampled with p ~ 1 -> rank ~ 1
        assert!(pts[0].rank < 2.0, "top rank {}", pts[0].rank);
    }

    #[test]
    fn wor_ranks_track_truth_on_zipf() {
        let n = 2000;
        let freqs = zipf_frequencies(n, 1.0, 1000.0);
        let true_rf = FreqVector::new(freqs.clone()).rank_frequency();
        // average the estimated freq at mid ranks over seeds
        let mut rel_errs = Vec::new();
        for seed in 0..30 {
            let s = perfect_ppswor(&freqs, 1.0, 100, seed);
            let pts = rank_frequency_wor(&s);
            let (_, tail) = curve_error(&pts, &true_rf, 10);
            rel_errs.push(tail);
        }
        let avg = crate::util::stats::mean(&rel_errs);
        assert!(avg < 0.6, "avg tail rel err {avg}");
    }

    #[test]
    fn wr_curve_tail_worse_than_wor_on_skew() {
        // Fig 1 right: WR's tail estimates are much worse on Zipf[2]
        let n = 2000;
        let freqs = zipf_frequencies(n, 2.0, 1000.0);
        let true_rf = FreqVector::new(freqs.clone()).rank_frequency();
        let k = 100;
        let (mut wor_tail, mut wr_tail) = (0.0, 0.0);
        let runs = 30;
        for seed in 0..runs {
            let sw = perfect_ppswor(&freqs, 2.0, k, seed);
            let (_, t1) = curve_error(&rank_frequency_wor(&sw), &true_rf, 10);
            wor_tail += t1;
            let sr = perfect_wr(&freqs, 2.0, k, seed);
            let (_, t2) = curve_error(&rank_frequency_wr(&sr), &true_rf, 10);
            wr_tail += t2;
        }
        wor_tail /= runs as f64;
        wr_tail /= runs as f64;
        assert!(
            wor_tail < wr_tail,
            "wor tail {wor_tail} should beat wr tail {wr_tail}"
        );
    }

    #[test]
    fn wr_effective_size_small_on_skew() {
        // Fig 1 left/middle: WR effective sample size collapses
        let freqs = zipf_frequencies(10_000, 2.0, 1.0);
        let s = perfect_wr(&freqs, 2.0, 100, 7);
        assert!(s.effective_size() < 40, "eff={}", s.effective_size());
    }
}
