//! Estimators over samples (paper §2.1 Eqs. 1–3, §5 Eq. 17).
//!
//! Bottom-k samples give conditioned inverse-probability
//! (Horvitz–Thompson) per-key estimates:
//!
//! ```text
//! f̂(ν_x) = f(ν_x) / Pr_{r~D}[ r ≤ (|ν_x|/τ)^p ]   for x ∈ S, else 0
//! ```
//!
//! which are unbiased for perfect samples and `O(ε)`-biased for 1-pass
//! WORp (Theorem 5.1). Sum statistics `Σ_x f(ν_x) L_x` are estimated by
//! summing per-key estimates over the sample. WR samples use the
//! Hansen–Hurwitz estimator. [`rankfreq`] estimates the rank-frequency
//! distribution (Figs 1–2).

pub mod rankfreq;
pub mod similarity;

use crate::sampler::{Sample, SampleEntry};
use crate::sampler::wr::WrSample;

/// Per-key inverse-probability estimate of `f(ν_x)` for a sampled entry
/// (0 for keys outside the sample — simply don't call it for those).
pub fn per_key_estimate<F: Fn(f64) -> f64>(sample: &Sample, entry: &SampleEntry, f: &F) -> f64 {
    let p_inc = sample.inclusion_prob(entry.freq);
    if p_inc <= 0.0 {
        return 0.0;
    }
    f(entry.freq) / p_inc
}

/// Estimate the sum statistic `Σ_x f(ν_x) · L(x)` from a WOR sample
/// (paper Eq. 2); `l` is the per-key multiplier (selector) function.
pub fn sum_statistic<F, L>(sample: &Sample, f: &F, l: &L) -> f64
where
    F: Fn(f64) -> f64,
    L: Fn(u64) -> f64,
{
    if sample.tau <= 0.0 {
        // degenerate sample (fewer keys than k): the sample *is* the data
        return sample.entries.iter().map(|e| f(e.freq) * l(e.key)).sum();
    }
    sample
        .entries
        .iter()
        .map(|e| per_key_estimate(sample, e, f) * l(e.key))
        .sum()
}

/// Estimate the frequency moment `‖ν‖_{p'}^{p'} = Σ |ν_x|^{p'}` from a
/// WOR sample (the statistic of the paper's Table 3).
pub fn moment_estimate(sample: &Sample, p_prime: f64) -> f64 {
    sum_statistic(sample, &|v: f64| v.abs().powf(p_prime), &|_| 1.0)
}

/// Hansen–Hurwitz estimate of `Σ_x f(ν_x)` from a WR ℓp sample:
/// `(1/k) Σ_draws f(ν_i)/q_i`. Note: degenerate (zero-variance) when
/// `f(ν) ∝ ν^p`; the sample-based estimator below is what a WR *sample*
/// (the sparse summary) actually supports and what the paper reports.
pub fn wr_sum_estimate_hh<F: Fn(f64) -> f64>(sample: &WrSample, f: &F) -> f64 {
    let k = sample.k as f64;
    sample
        .draws
        .iter()
        .enumerate()
        .map(|(i, _)| f(sample.freqs[i]) / sample.probs[i])
        .sum::<f64>()
        / k
}

/// Distinct-key inverse-inclusion (Horvitz–Thompson) estimate of
/// `Σ_x f(ν_x)` from a WR sample: each distinct key is weighted by
/// `1/(1 − (1−q_x)^k)`. This treats the WR draw as a *sample of keys* —
/// the comparison the paper's Table 3 makes.
pub fn wr_sum_estimate<F: Fn(f64) -> f64>(sample: &WrSample, f: &F) -> f64 {
    sample
        .distinct()
        .into_iter()
        .map(|(_, freq, q)| f(freq) / wr_inclusion_prob(q, sample.k).max(1e-300))
        .sum()
}

/// WR moment estimate `‖ν‖_{p'}^{p'}` (Table 3 "perfect WR" column).
pub fn wr_moment_estimate(sample: &WrSample, p_prime: f64) -> f64 {
    wr_sum_estimate(sample, &|v: f64| v.abs().powf(p_prime))
}

/// Per-key WR inclusion probability over k draws: `1 − (1 − q_x)^k`
/// (used by the WR distinct-key rank-frequency estimator).
pub fn wr_inclusion_prob(q: f64, k: usize) -> f64 {
    1.0 - (1.0 - q).powi(k as i32)
}

/// Sparse vector representation: the sample as `(key, f̂(ν_x))` pairs —
/// the "sparse summary" use-case of the introduction (e.g. sparsified
/// gradients).
pub fn sparsify<F: Fn(f64) -> f64>(sample: &Sample, f: &F) -> Vec<(u64, f64)> {
    sample
        .entries
        .iter()
        .map(|e| (e.key, per_key_estimate(sample, e, f)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::zipf_frequencies;
    use crate::sampler::ppswor::perfect_ppswor;
    use crate::sampler::wr::perfect_wr;
    use crate::util::stats::{mean, nrmse};

    #[test]
    fn moment_estimate_unbiased_over_seeds() {
        // perfect ppswor estimates of ||nu||_1 should average to the truth
        let freqs = zipf_frequencies(500, 1.0, 100.0);
        let truth: f64 = freqs.iter().sum();
        let ests: Vec<f64> = (0..400)
            .map(|seed| moment_estimate(&perfect_ppswor(&freqs, 1.0, 50, seed), 1.0))
            .collect();
        let m = mean(&ests);
        assert!(
            (m - truth).abs() / truth < 0.02,
            "mean {m} vs truth {truth}"
        );
    }

    #[test]
    fn wor_beats_wr_on_skewed_second_moment() {
        // the paper's headline comparison: l2 sampling of Zipf[2],
        // estimating ||nu||_2^2 — WOR must have much smaller NRMSE
        let freqs = zipf_frequencies(2_000, 2.0, 1.0);
        let truth: f64 = freqs.iter().map(|f| f * f).sum();
        let k = 50;
        let runs = 150;
        let wor: Vec<f64> = (0..runs)
            .map(|s| moment_estimate(&perfect_ppswor(&freqs, 2.0, k, s), 2.0))
            .collect();
        let wr: Vec<f64> = (0..runs)
            .map(|s| wr_moment_estimate(&perfect_wr(&freqs, 2.0, k, s), 2.0))
            .collect();
        let e_wor = nrmse(&wor, truth);
        let e_wr = nrmse(&wr, truth);
        assert!(
            e_wor < 0.5 * e_wr,
            "NRMSE wor={e_wor:.2e} wr={e_wr:.2e} — WOR should win clearly"
        );
    }

    #[test]
    fn subset_sum_statistic() {
        // estimate the total frequency of even keys only
        let freqs = zipf_frequencies(300, 1.0, 10.0);
        let truth: f64 = freqs.iter().enumerate().filter(|(i, _)| i % 2 == 0).map(|(_, f)| f).sum();
        let ests: Vec<f64> = (0..300)
            .map(|seed| {
                let s = perfect_ppswor(&freqs, 1.0, 60, seed);
                sum_statistic(&s, &|v| v, &|k| if k % 2 == 0 { 1.0 } else { 0.0 })
            })
            .collect();
        let m = mean(&ests);
        assert!((m - truth).abs() / truth < 0.05, "mean {m} truth {truth}");
    }

    #[test]
    fn degenerate_sample_returns_exact_sums() {
        // domain smaller than k: tau = 0, estimates are exact sums
        let freqs = vec![3.0, 4.0];
        let s = perfect_ppswor(&freqs, 1.0, 10, 1);
        assert_eq!(s.tau, 0.0);
        let est = moment_estimate(&s, 1.0);
        assert!((est - 7.0).abs() < 1e-12);
    }

    #[test]
    fn wr_estimator_unbiased() {
        let freqs = zipf_frequencies(200, 1.0, 5.0);
        let truth: f64 = freqs.iter().map(|f| f * f).sum();
        let ests: Vec<f64> = (0..500)
            .map(|s| wr_moment_estimate(&perfect_wr(&freqs, 1.0, 40, s), 2.0))
            .collect();
        let m = mean(&ests);
        assert!((m - truth).abs() / truth < 0.05, "mean {m} truth {truth}");
    }

    #[test]
    fn wr_inclusion_prob_sane() {
        assert!((wr_inclusion_prob(0.5, 1) - 0.5).abs() < 1e-12);
        assert!((wr_inclusion_prob(0.5, 2) - 0.75).abs() < 1e-12);
        assert!(wr_inclusion_prob(1.0, 3) == 1.0);
    }

    #[test]
    fn sparsify_shape() {
        let freqs = zipf_frequencies(100, 1.0, 10.0);
        let s = perfect_ppswor(&freqs, 1.0, 10, 3);
        let sparse = sparsify(&s, &|v| v);
        assert_eq!(sparse.len(), 10);
        // estimates upper-bound the raw frequency (inverse prob >= 1)
        for (k, est) in &sparse {
            assert!(*est >= freqs[*k as usize] - 1e-9);
        }
    }
}
