//! Machine-readable performance suite — the data source for the perf
//! trajectory (`BENCH_PR2.json` → `BENCH_PR8.json` → `BENCH_PR10.json`).
//!
//! One suite, two drivers: the `worp bench` CLI subcommand (smoke mode in
//! CI — fails on panics, never on numbers) and `cargo bench --bench
//! throughput` (full mode). Each summary is measured **three** times over
//! the same seeded Zipf stream: the scalar [`StreamSummary::process`]
//! loop, the AoS micro-batched [`StreamSummary::process_batch`] path, and
//! the SoA [`StreamSummary::process_block`] path (§Perf L3-7) — so every
//! record triple quantifies first what columnar sweeps buy over scalar,
//! then what the structure-of-arrays layout buys on top. PR 8 adds the
//! read side ([`run_query_suite`] — batched `est_many` throughput) and a
//! row-major vs d-interleaved table-layout ablation
//! ([`run_layout_suite`]); `python/bench_check.py` turns any two of these
//! artifacts into a regression verdict, and CI runs it as a gate.

use crate::api::StreamSummary;
use crate::data::zipf::ZipfStream;
use crate::data::{Element, ElementBlock};
use crate::sampler::exact::ExactWor;
use crate::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use crate::sampler::windowed::WindowedWorp;
use crate::sampler::worp1::OnePassWorp;
use crate::sampler::worp2::TwoPassWorp;
use crate::sampler::wr_reservoir::WrReservoir;
use crate::sampler::SamplerConfig;
use crate::sketch::countmin::CountMin;
use crate::sketch::countsketch::CountSketch;
use crate::util::bench::Bencher;
use std::io::Write;

/// Suite configuration.
#[derive(Clone, Debug)]
pub struct PerfOpts {
    /// Elements in the generated Zipf stream.
    pub stream_len: u64,
    /// Key-domain size.
    pub n_keys: usize,
    /// Micro-batch size for the batched runs.
    pub batch: usize,
    /// Measured iterations per benchmark.
    pub iters: u32,
    /// Warmup iterations per benchmark.
    pub warmup: u32,
    /// Sample size k for the samplers.
    pub k: usize,
    /// Smoke mode (recorded in the JSON meta).
    pub smoke: bool,
}

impl PerfOpts {
    /// CI smoke profile: small stream, one measured iteration — exists to
    /// catch panics and emit a well-formed JSON artifact, not to produce
    /// stable numbers.
    pub fn smoke() -> Self {
        PerfOpts {
            stream_len: 50_000,
            n_keys: 5_000,
            batch: 4096,
            iters: 2,
            warmup: 1,
            k: 32,
            smoke: true,
        }
    }

    /// Full profile (the `cargo bench` path).
    pub fn full() -> Self {
        PerfOpts {
            stream_len: 1_000_000,
            n_keys: 100_000,
            batch: 4096,
            iters: 8,
            warmup: 2,
            k: 100,
            smoke: false,
        }
    }
}

/// One measurement: a (summary, mode) pair with its throughput.
#[derive(Clone, Debug)]
pub struct PerfRecord {
    /// Summary under test ("countsketch", "worp1", "ppswor", ...).
    pub summary: String,
    /// "scalar" (per-element `process`), "batch" (AoS `process_batch`)
    /// or "block" (SoA `process_block`).
    pub mode: String,
    /// Items per second (mean over iterations).
    pub items_per_sec: f64,
    /// Mean iteration wall-clock in nanoseconds.
    pub mean_ns: u128,
    /// Median iteration wall-clock in nanoseconds.
    pub p50_ns: u128,
    /// 95th-percentile iteration wall-clock in nanoseconds.
    pub p95_ns: u128,
}

fn bench_triple<S, F>(
    b: &mut Bencher,
    out: &mut Vec<PerfRecord>,
    name: &str,
    stream: &[Element],
    blocks: &[ElementBlock],
    batch: usize,
    make: F,
) where
    S: StreamSummary,
    F: Fn() -> S,
{
    let m = stream.len() as u64;
    let scalar = b.bench_throughput(&format!("{name} scalar"), m, || {
        let mut s = make();
        for e in stream {
            s.process(e);
        }
        s.processed()
    });
    out.push(record(name, "scalar", scalar));
    let batched = b.bench_throughput(&format!("{name} batch({batch})"), m, || {
        let mut s = make();
        for chunk in stream.chunks(batch) {
            s.process_batch(chunk);
        }
        s.processed()
    });
    out.push(record(name, "batch", batched));
    let blocked = b.bench_throughput(&format!("{name} block({batch})"), m, || {
        let mut s = make();
        for blk in blocks {
            s.process_block(blk);
        }
        s.processed()
    });
    out.push(record(name, "block", blocked));
}

/// Pre-chunk a stream into SoA blocks of `batch` elements (done once per
/// suite so the block benches measure ingestion, not conversion).
fn blocks_of(stream: &[Element], batch: usize) -> Vec<ElementBlock> {
    stream.chunks(batch).map(ElementBlock::from_elements).collect()
}

fn record(name: &str, mode: &str, r: &crate::util::bench::BenchResult) -> PerfRecord {
    PerfRecord {
        summary: name.to_string(),
        mode: mode.to_string(),
        items_per_sec: r.throughput().unwrap_or(0.0),
        mean_ns: r.mean.as_nanos(),
        p50_ns: r.p50.as_nanos(),
        p95_ns: r.p95.as_nanos(),
    }
}

/// Run the scalar/batch/block suite over every summary family.
pub fn run_suite(opts: &PerfOpts) -> Vec<PerfRecord> {
    let stream: Vec<Element> = ZipfStream::new(opts.n_keys, 1.2, opts.stream_len, 1).collect();
    let blocks = blocks_of(&stream, opts.batch);
    let k = opts.k;
    let cfg = SamplerConfig::new(1.0, k)
        .with_seed(3)
        .with_domain(opts.n_keys)
        .with_sketch_shape(5, 1024);

    Bencher::header();
    let mut b = Bencher::new().with_iters(opts.warmup, opts.iters);
    let mut out = Vec::new();

    bench_triple(&mut b, &mut out, "countsketch", &stream, &blocks, opts.batch, || {
        CountSketch::with_shape(5, 1024, 7)
    });
    bench_triple(&mut b, &mut out, "countmin", &stream, &blocks, opts.batch, || {
        CountMin::with_shape(5, 1024, 7)
    });
    bench_triple(&mut b, &mut out, "worp1", &stream, &blocks, opts.batch, {
        let cfg = cfg.clone();
        move || OnePassWorp::new(cfg.clone())
    });
    bench_triple(&mut b, &mut out, "worp2-pass1", &stream, &blocks, opts.batch, {
        let cfg = cfg.clone();
        move || TwoPassWorp::new(cfg.clone())
    });
    // "ppswor": the exact streaming p-ppswor baseline (linear memory)
    bench_triple(&mut b, &mut out, "ppswor", &stream, &blocks, opts.batch, {
        let cfg = cfg.clone();
        move || ExactWor::new(cfg.clone())
    });
    // "wr": the with-replacement reservoir the scenario gate compares
    // against — k exponential-jump single-item reservoirs + sketch
    bench_triple(&mut b, &mut out, "wr", &stream, &blocks, opts.batch, {
        let cfg = cfg.clone();
        move || WrReservoir::new(cfg.clone())
    });
    bench_triple(&mut b, &mut out, "windowed", &stream, &blocks, opts.batch, {
        let cfg = cfg.clone();
        let window = (opts.stream_len / 2).max(16);
        move || WindowedWorp::new(cfg.clone(), window, 8)
    });
    // the TV sampler runs r parallel single samplers; keep its stream
    // slice small so the suite stays minutes, not hours
    let tv_stream = &stream[..stream.len().min(opts.stream_len as usize / 16).max(1)];
    let tv_blocks = blocks_of(tv_stream, opts.batch);
    bench_triple(&mut b, &mut out, "tv1pass", tv_stream, &tv_blocks, opts.batch, {
        let n = opts.n_keys;
        move || TvSampler::new(TvSamplerConfig::new(1.0, 8, n, 3, SamplerKind::Oracle).with_r(32))
    });

    out
}

/// Served-ingest suite: the same Zipf stream pushed through the engine's
/// in-process block path ("offline_block") and through a real pipelined
/// TCP session against a loopback reactor server ("served_ingest") — the
/// pair quantifies what the wire adds on top of raw ingestion. Both
/// paths drive the very same engine topology, so the numbers are
/// apples-to-apples.
pub fn run_served_suite(opts: &PerfOpts) -> Vec<PerfRecord> {
    use crate::api::builder::Worp;
    use crate::engine::client::Client;
    use crate::engine::server::{ServeOpts, Server};
    use crate::engine::{Engine, EngineOpts};
    use std::sync::Arc;

    let stream: Vec<Element> = ZipfStream::new(opts.n_keys, 1.2, opts.stream_len, 1).collect();
    let blocks = blocks_of(&stream, opts.batch);
    let m = stream.len() as u64;

    let engine_opts = EngineOpts::new(4, opts.batch.max(1)).expect("bench engine opts");
    let engine = Arc::new(Engine::new(engine_opts));
    let spec = Worp::p(1.0).k(opts.k).seed(3).exact();
    engine.create("bench/offline", &spec).expect("create bench/offline");
    engine.create("bench/served", &spec).expect("create bench/served");
    let server_opts = ServeOpts { max_frame: 256 << 20, ..ServeOpts::default() };
    let mut srv =
        Server::start(Arc::clone(&engine), "127.0.0.1:0", server_opts).expect("bench server");
    let addr = srv.local_addr().to_string();

    let mut b = Bencher::new().with_iters(opts.warmup, opts.iters);
    let mut out = Vec::new();

    let offline = b.bench_throughput("engine offline block", m, || {
        let mut accepted = 0;
        for blk in &blocks {
            accepted = engine.ingest("bench/offline", blk).expect("offline ingest");
        }
        engine.flush("bench/offline").expect("offline flush");
        accepted
    });
    out.push(record("engine", "offline_block", offline));

    let mut client = Client::connect(&addr).expect("bench client");
    let served = b.bench_throughput("engine served ingest (pipelined)", m, || {
        let mut pipe = client.ingest_pipe("bench/served").expect("ingest pipe");
        for blk in &blocks {
            pipe.send(blk).expect("pipelined send");
        }
        let accepted = pipe.finish().expect("pipelined finish");
        client.flush("bench/served").expect("served flush");
        accepted
    });
    out.push(record("engine", "served_ingest", served));

    srv.stop();
    out
}

/// Query-side suite: batched point queries ([`CountSketch::est_many`] /
/// [`CountMin::est_many`]) against a sketch built once from the stream.
/// The probe set is the stream's own key column (hot-key-skewed, so the
/// mix matches what the serving read path actually sees); throughput is
/// estimates per second. These records make read-path regressions
/// first-class in the trajectory — PR 8's lane-batched gather lands here.
pub fn run_query_suite(opts: &PerfOpts) -> Vec<PerfRecord> {
    let stream: Vec<Element> = ZipfStream::new(opts.n_keys, 1.2, opts.stream_len, 1).collect();
    let blocks = blocks_of(&stream, opts.batch);
    let probe: Vec<u64> = stream.iter().map(|e| e.key).collect();
    let m = probe.len() as u64;

    let mut cs = CountSketch::with_shape(5, 1024, 7);
    let mut cm = CountMin::with_shape(5, 1024, 7);
    for blk in &blocks {
        cs.process_cols(&blk.keys, &blk.vals);
        cm.process_cols(&blk.keys, &blk.vals);
    }

    let mut b = Bencher::new().with_iters(opts.warmup, opts.iters);
    let mut out = Vec::new();
    let mut ests = vec![0.0f64; probe.len()];

    let r = b.bench_throughput("countsketch est_many", m, || {
        cs.est_many(&probe, &mut ests);
        ests[0]
    });
    out.push(record("countsketch", "est_many", r));
    let r = b.bench_throughput("countmin est_many", m, || {
        cm.est_many(&probe, &mut ests);
        ests[0]
    });
    out.push(record("countmin", "est_many", r));
    out
}

// ---------------------------------------------------------------------------
// Table-layout ablation

/// Bench-only CountSketch variant with a **d-interleaved** table layout:
/// cell `(r, b)` lives at `b * rows + r` (row-major puts it at
/// `r * width + b`). Interleaving clusters the `rows` counters of one
/// bucket *column*, which looks attractive for element-major updates —
/// but a key's per-row buckets differ, so its counters still straddle
/// `rows` distinct cache neighborhoods, and the layout forfeits the
/// contiguous row slices the lane-unrolled row sweeps and the batched
/// est_many gather stride through. The ablation records quantify that
/// trade; the shipped sketches stay row-major.
struct InterleavedCountSketch {
    hasher: crate::util::hashing::SketchHasher,
    rows: usize,
    table: Vec<f64>,
    coords: Vec<crate::util::hashing::KeyCoords>,
}

impl InterleavedCountSketch {
    fn with_shape(rows: usize, width: usize, seed: u64) -> Self {
        InterleavedCountSketch {
            hasher: crate::util::hashing::SketchHasher::new(seed, width),
            rows,
            table: vec![0.0; rows * width],
            coords: Vec::new(),
        }
    }

    /// Element-major columnar update (the natural sweep for this layout:
    /// per element, its `rows` cells are walked at stride 1 in `r`
    /// *within* each bucket column).
    fn process_cols(&mut self, keys: &[u64], vals: &[f64]) {
        let mut coords = std::mem::take(&mut self.coords);
        self.hasher.fill_coords_slice(keys, &mut coords);
        let rows = self.rows;
        for (c, &v) in coords.iter().zip(vals) {
            for r in 0..rows {
                let (b, s) = self.hasher.bucket_sign_from(c, r);
                self.table[b * rows + r] += s * v;
            }
        }
        self.coords = coords;
    }
}

/// Layout ablation: the identical Zipf block sweep through the shipped
/// row-major [`CountSketch`] and the d-interleaved variant above. Both
/// records carry summary `countsketch_layout` so the regression gate and
/// the trajectory table keep them side by side.
pub fn run_layout_suite(opts: &PerfOpts) -> Vec<PerfRecord> {
    let stream: Vec<Element> = ZipfStream::new(opts.n_keys, 1.2, opts.stream_len, 1).collect();
    let blocks = blocks_of(&stream, opts.batch);
    let m = stream.len() as u64;

    let mut b = Bencher::new().with_iters(opts.warmup, opts.iters);
    let mut out = Vec::new();

    let r = b.bench_throughput("countsketch_layout row_major", m, || {
        let mut s = CountSketch::with_shape(5, 1024, 7);
        for blk in &blocks {
            s.process_cols(&blk.keys, &blk.vals);
        }
        crate::sketch::RhhSketch::est(&s, blocks[0].keys[0])
    });
    out.push(record("countsketch_layout", "row_major", r));
    let r = b.bench_throughput("countsketch_layout interleaved", m, || {
        let mut s = InterleavedCountSketch::with_shape(5, 1024, 7);
        for blk in &blocks {
            s.process_cols(&blk.keys, &blk.vals);
        }
        s.table[0]
    });
    out.push(record("countsketch_layout", "interleaved", r));
    out
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render the suite result as a JSON document (hand-rolled — no serde in
/// the offline image).
pub fn to_json(opts: &PerfOpts, records: &[PerfRecord]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"meta\": {");
    s.push_str(&format!(
        "\"stream_len\": {}, \"n_keys\": {}, \"batch\": {}, \"iters\": {}, \"k\": {}, \"smoke\": {}",
        opts.stream_len, opts.n_keys, opts.batch, opts.iters, opts.k, opts.smoke
    ));
    s.push_str("},\n  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"summary\": \"{}\", \"mode\": \"{}\", \"items_per_sec\": {:.1}, \"mean_ns\": {}, \"p50_ns\": {}, \"p95_ns\": {}}}{}\n",
            json_escape(&r.summary),
            json_escape(&r.mode),
            r.items_per_sec,
            r.mean_ns,
            r.p50_ns,
            r.p95_ns,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write the suite result to `path` as JSON.
pub fn write_json(path: &str, opts: &PerfOpts, records: &[PerfRecord]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_json(opts, records).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_suite_runs_and_serializes() {
        // minimal opts: existence/shape test, not a measurement
        let opts = PerfOpts {
            stream_len: 500,
            n_keys: 100,
            batch: 64,
            iters: 1,
            warmup: 0,
            k: 4,
            smoke: true,
        };
        let records = run_suite(&opts);
        // every summary contributes a scalar + batch + block triple
        assert_eq!(records.len() % 3, 0);
        let names = [
            "countsketch",
            "countmin",
            "worp1",
            "worp2-pass1",
            "ppswor",
            "wr",
            "windowed",
            "tv1pass",
        ];
        for name in names {
            for mode in ["scalar", "batch", "block"] {
                assert!(
                    records
                        .iter()
                        .any(|r| r.summary == name && r.mode == mode && r.items_per_sec > 0.0),
                    "missing {name}/{mode}"
                );
            }
        }
        let json = to_json(&opts, &records);
        assert!(json.contains("\"items_per_sec\""));
        assert!(json.contains("\"smoke\": true"));
        // crude balance check so the artifact is parseable downstream
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count()
        );
    }

    #[test]
    fn query_suite_emits_est_many_records() {
        let opts = PerfOpts {
            stream_len: 500,
            n_keys: 100,
            batch: 64,
            iters: 1,
            warmup: 0,
            k: 4,
            smoke: true,
        };
        let records = run_query_suite(&opts);
        assert_eq!(records.len(), 2);
        for name in ["countsketch", "countmin"] {
            assert!(
                records
                    .iter()
                    .any(|r| r.summary == name && r.mode == "est_many" && r.items_per_sec > 0.0),
                "missing {name}/est_many"
            );
        }
    }

    #[test]
    fn layout_suite_emits_both_layout_records() {
        let opts = PerfOpts {
            stream_len: 500,
            n_keys: 100,
            batch: 64,
            iters: 1,
            warmup: 0,
            k: 4,
            smoke: true,
        };
        let records = run_layout_suite(&opts);
        assert_eq!(records.len(), 2);
        for mode in ["row_major", "interleaved"] {
            assert!(
                records.iter().any(|r| r.summary == "countsketch_layout"
                    && r.mode == mode
                    && r.items_per_sec > 0.0),
                "missing countsketch_layout/{mode}"
            );
        }
    }

    #[test]
    fn interleaved_layout_estimates_match_row_major() {
        // the ablation variant must be a faithful CountSketch: same
        // hasher, same updates, only the cell addressing differs — so a
        // direct cell-by-cell transpose comparison must hold
        let stream: Vec<Element> = ZipfStream::new(50, 1.2, 2_000, 9).collect();
        let blocks = blocks_of(&stream, 128);
        let (rows, width) = (5usize, 256usize);
        let mut rm = CountSketch::with_shape(rows, width, 7);
        let mut il = InterleavedCountSketch::with_shape(rows, width, 7);
        for blk in &blocks {
            rm.process_cols(&blk.keys, &blk.vals);
            il.process_cols(&blk.keys, &blk.vals);
        }
        for r in 0..rows {
            for bkt in 0..width {
                assert_eq!(
                    rm.table()[r * width + bkt].to_bits(),
                    il.table[bkt * rows + r].to_bits(),
                    "cell ({r},{bkt}) differs between layouts"
                );
            }
        }
    }

    #[test]
    fn json_escaping_handles_quotes() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
    }

    #[test]
    fn served_suite_emits_offline_and_served_records() {
        // loopback smoke of the wire bench: shape test, not a measurement
        let opts = PerfOpts {
            stream_len: 400,
            n_keys: 100,
            batch: 64,
            iters: 1,
            warmup: 0,
            k: 4,
            smoke: true,
        };
        let records = run_served_suite(&opts);
        assert_eq!(records.len(), 2);
        for mode in ["offline_block", "served_ingest"] {
            assert!(
                records
                    .iter()
                    .any(|r| r.summary == "engine" && r.mode == mode && r.items_per_sec > 0.0),
                "missing engine/{mode}"
            );
        }
        // both suites render into one artifact downstream
        let json = to_json(&opts, &records);
        assert!(json.contains("\"served_ingest\""));
    }
}
