//! The coordinator (leader): ties the sharded pipeline to the WORp
//! samplers — routing, per-shard sampler state, merge tree, two-pass
//! orchestration, and the XLA-offloaded backend.
//!
//! This is the public entry point a downstream user drives (and what the
//! `worp` binary launches): hand it a stream (replayable for two-pass)
//! and a config, get back a [`Sample`] plus run metrics.

use crate::config::PipelineConfig;
use crate::data::Element;
use crate::error::{Error, Result};
use crate::pipeline::merge::tree_merge;
use crate::pipeline::metrics::Metrics;
use crate::pipeline::{run_sharded, PipelineOpts, ShardSink};
use crate::sampler::worp1::OnePassWorp;
use crate::sampler::worp2::{TwoPassWorpPass1, TwoPassWorpPass2};
use crate::sampler::{Sample, SamplerConfig};
use std::sync::Arc;

/// A replayable element source (two-pass methods read it twice).
/// Implementations must produce the *same multiset of elements* on every
/// call — e.g. a deterministic generator or an in-memory/spooled buffer.
pub trait StreamSource {
    /// A fresh iterator over the stream.
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_>;
}

/// In-memory stream (owns the elements; trivially replayable).
pub struct VecSource(pub Vec<Element>);

impl StreamSource for VecSource {
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        Box::new(self.0.iter().copied())
    }
}

/// A replayable deterministic generator: any `Fn() -> Iterator`.
pub struct FnSource<F>(pub F);

impl<F, I> StreamSource for FnSource<F>
where
    F: Fn() -> I,
    I: Iterator<Item = Element> + Send + 'static,
{
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        Box::new((self.0)())
    }
}

impl ShardSink for OnePassWorp {
    fn process(&mut self, e: &Element) {
        OnePassWorp::process(self, e)
    }
}

impl ShardSink for TwoPassWorpPass1 {
    fn process(&mut self, e: &Element) {
        TwoPassWorpPass1::process(self, e)
    }
}

impl ShardSink for TwoPassWorpPass2 {
    fn process(&mut self, e: &Element) {
        TwoPassWorpPass2::process(self, e)
    }
}

/// The leader/coordinator.
pub struct Coordinator {
    sampler_cfg: SamplerConfig,
    opts: PipelineOpts,
}

impl Coordinator {
    /// From the launcher config.
    pub fn from_config(cfg: &PipelineConfig) -> Result<Self> {
        cfg.validate()?;
        let mut scfg = SamplerConfig::new(cfg.p, cfg.k)
            .with_seed(cfg.seed)
            .with_domain(cfg.n);
        scfg.q = cfg.q;
        scfg.delta = cfg.delta;
        if cfg.width > 0 {
            scfg = scfg.with_sketch_shape(cfg.rows, cfg.width);
        } else {
            scfg.rows = cfg.rows;
        }
        let opts = PipelineOpts::new(cfg.workers, cfg.batch, cfg.channel_cap)?;
        Ok(Coordinator { sampler_cfg: scfg, opts })
    }

    /// Direct construction.
    pub fn new(sampler_cfg: SamplerConfig, opts: PipelineOpts) -> Self {
        Coordinator { sampler_cfg, opts }
    }

    /// Sampler configuration in use.
    pub fn sampler_config(&self) -> &SamplerConfig {
        &self.sampler_cfg
    }

    /// 1-pass WORp over a sharded pipeline: each worker owns a sibling
    /// `OnePassWorp` (same seed → same randomization), the leader
    /// tree-merges them and extracts the sample.
    pub fn one_pass<I>(&self, stream: I) -> Result<(Sample, Arc<Metrics>)>
    where
        I: IntoIterator<Item = Element>,
    {
        let cfg = self.sampler_cfg.clone();
        let (states, metrics) =
            run_sharded(stream, self.opts, move |_| OnePassWorp::new(cfg.clone()))?;
        let merged = tree_merge(states, &metrics, |a, b| a.merge(b))?
            .ok_or_else(|| Error::Pipeline("no workers".into()))?;
        Ok((merged.sample(), metrics))
    }

    /// 2-pass WORp: pass I shards the stream into sibling rHH sketches and
    /// merges them; pass II replays the stream into sharded top-k′
    /// collectors seeded with the *merged* pass-I sketch; the leader
    /// merges collectors and cuts the exact sample.
    pub fn two_pass<S: StreamSource>(&self, source: &S) -> Result<(Sample, Arc<Metrics>)> {
        let cfg = self.sampler_cfg.clone();

        // ---- pass I
        let mk = cfg.clone();
        let (p1s, metrics1) = run_sharded(source.stream(), self.opts, move |_| {
            TwoPassWorpPass1::new(mk.clone())
        })?;
        let merged_p1 = tree_merge(p1s, &metrics1, |a, b| a.merge(b))?
            .ok_or_else(|| Error::Pipeline("no workers".into()))?;

        // ---- pass II (every worker gets a clone of the merged sketch)
        let template = merged_p1.into_pass2();
        let (p2s, metrics2) = run_sharded(source.stream(), self.opts, move |_| template.clone())?;
        let merged_p2: TwoPassWorpPass2 = tree_merge(p2s, &metrics2, |a, b| a.merge(b))?
            .ok_or_else(|| Error::Pipeline("no workers".into()))?;

        // fold pass-I counters into the returned metrics
        metrics2.note_batch(0);
        Ok((merged_p2.sample(), metrics2))
    }

    /// 1-pass WORp with the **XLA backend**: the transformed-element
    /// CountSketch update executes on the PJRT client via the AOT
    /// `countsketch_update` artifact (single-threaded — the PJRT client is
    /// not `Send` in the published crate; the benches compare this against
    /// the native sharded path).
    pub fn one_pass_xla<I>(
        &self,
        stream: I,
        artifacts_dir: &str,
    ) -> Result<(Sample, Arc<Metrics>)>
    where
        I: IntoIterator<Item = Element>,
    {
        use crate::runtime::artifact::ArtifactDir;
        use crate::runtime::executor::XlaCountSketch;
        use crate::runtime::XlaRuntime;

        let rt = XlaRuntime::cpu()?;
        let dir = ArtifactDir::open(artifacts_dir)?;
        let cfg = &self.sampler_cfg;
        let mut xs = XlaCountSketch::load(&rt, &dir, cfg.seed ^ 0x1AB5)?;
        let transform = cfg.transform();
        let metrics = Arc::new(Metrics::default());

        let mut candidates: std::collections::HashMap<u64, ()> = Default::default();
        let cand_cap = 8 * (cfg.k + 1);
        let mut count = 0u64;
        for e in stream {
            let te = transform.apply(&e);
            xs.process(&te)?;
            candidates.insert(e.key, ());
            count += 1;
            if candidates.len() > 4 * cand_cap {
                // shrink by current estimates
                xs.flush()?;
                let mut scored: Vec<(u64, f64)> = candidates
                    .keys()
                    .map(|&k| (k, xs.est(k).abs()))
                    .collect();
                scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                scored.truncate(cand_cap);
                candidates = scored.into_iter().map(|(k, _)| (k, ())).collect();
            }
        }
        xs.flush()?;
        metrics.note_batch(count);

        let mut scored: Vec<(u64, f64)> = candidates
            .keys()
            .map(|&k| (k, xs.est(k)))
            .filter(|(_, v)| *v != 0.0)
            .collect();
        scored.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
        let k = cfg.k;
        let tau = if scored.len() > k { scored[k].1.abs() } else { 0.0 };
        let entries = scored
            .into_iter()
            .take(k)
            .map(|(key, est)| crate::sampler::SampleEntry {
                key,
                freq: transform.invert(key, est),
                transformed: est,
            })
            .collect();
        Ok((
            Sample { entries, tau, p: cfg.p, dist: transform.dist() },
            metrics,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::{zipf_exact_stream, zipf_frequencies};
    use crate::sampler::ppswor::perfect_ppswor;

    fn cfg(n: usize, k: usize) -> SamplerConfig {
        SamplerConfig::new(1.0, k)
            .with_seed(77)
            .with_domain(n)
            .with_sketch_shape(9, 2048)
    }

    #[test]
    fn sharded_one_pass_matches_perfect_on_skew() {
        let n = 800;
        let k = 16;
        let c = Coordinator::new(cfg(n, k), PipelineOpts::new(4, 256, 4).unwrap());
        let elems = zipf_exact_stream(n, 1.5, 1e4, 3, 7);
        let (sample, metrics) = c.one_pass(elems.clone()).unwrap();
        assert_eq!(metrics.elements() as usize, elems.len());
        assert_eq!(sample.len(), k);
        let want = perfect_ppswor(&zipf_frequencies(n, 1.5, 1e4), 1.0, k, 77);
        let overlap = sample
            .keys()
            .iter()
            .filter(|x| want.keys().contains(x))
            .count();
        assert!(overlap >= k - 1, "overlap {overlap}/{k}");
    }

    #[test]
    fn sharded_two_pass_equals_perfect_sample() {
        let n = 600;
        let k = 12;
        let c = Coordinator::new(cfg(n, k), PipelineOpts::new(3, 128, 4).unwrap());
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 9);
        let (sample, _) = c.two_pass(&VecSource(elems)).unwrap();
        let want = perfect_ppswor(&zipf_frequencies(n, 1.2, 1e4), 1.0, k, 77);
        assert_eq!(sample.keys(), want.keys());
        for (g, w) in sample.entries.iter().zip(&want.entries) {
            assert!((g.freq - w.freq).abs() < 1e-6 * w.freq.abs().max(1.0));
        }
    }

    #[test]
    fn worker_count_does_not_change_two_pass_output() {
        let n = 400;
        let k = 10;
        let elems = zipf_exact_stream(n, 1.0, 1e4, 2, 3);
        let src = VecSource(elems);
        let mut outputs = Vec::new();
        for workers in [1usize, 2, 5] {
            let c = Coordinator::new(cfg(n, k), PipelineOpts::new(workers, 64, 4).unwrap());
            let (s, _) = c.two_pass(&src).unwrap();
            outputs.push(s.keys());
        }
        assert_eq!(outputs[0], outputs[1]);
        assert_eq!(outputs[1], outputs[2]);
    }

    #[test]
    fn fn_source_replays_deterministically() {
        let src = FnSource(|| crate::data::zipf::ZipfStream::new(100, 1.0, 1000, 5));
        let a: Vec<Element> = src.stream().collect();
        let b: Vec<Element> = src.stream().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn from_config_wires_parameters() {
        let mut pc = crate::config::PipelineConfig::default();
        pc.p = 2.0;
        pc.k = 32;
        pc.rows = 5;
        pc.width = 777;
        let c = Coordinator::from_config(&pc).unwrap();
        assert_eq!(c.sampler_config().p, 2.0);
        assert_eq!(c.sampler_config().resolved_width_two_pass(), 777);
    }
}
