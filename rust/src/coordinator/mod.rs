//! The coordinator (leader): ties the sharded pipeline to the unified
//! summary API — routing, per-shard summary state, merge tree, and the
//! multi-pass loop — plus the XLA-offloaded backend.
//!
//! Everything is driven through the [`crate::api`] traits:
//!
//! - [`Coordinator::run_summary`] shards *any* [`Mergeable`] summary and
//!   folds the shards back with the fingerprint-checked merge tree;
//! - [`Coordinator::run_dyn`] drives *any* `Box<dyn `[`WorSampler`]`>`
//!   (from the [`crate::Worp`] builder) through all of its passes — one
//!   generic loop, no per-sampler match arms anywhere;
//! - [`Coordinator::one_pass`] / [`Coordinator::two_pass`] are the
//!   statically-typed conveniences built on the same primitives.

use crate::api::{Finalize, Mergeable, MultiPass, Persist, WorSampler};
use crate::config::PipelineConfig;
use crate::data::Element;
use crate::engine::{Engine, EngineOpts};
use crate::error::{Error, Result};
use crate::pipeline::merge::{merge_all, tree_merge};
use crate::pipeline::metrics::Metrics;
use crate::pipeline::{
    run_sharded, run_sharded_checkpointed, CheckpointPolicy, ParallelSource, PipelineOpts,
};
use crate::sampler::worp1::OnePassWorp;
use crate::sampler::worp2::TwoPassWorp;
use crate::sampler::{Sample, SamplerConfig};
use std::sync::Arc;

/// A replayable element source (two-pass methods read it twice; the
/// parallel-partitioning pipeline scans it once *per worker*).
/// Implementations must produce the *same sequence of elements* on every
/// call — e.g. a deterministic generator or an in-memory/spooled buffer.
/// `Sync` because the pipeline workers all stream through one shared
/// reference, concurrently.
pub trait StreamSource: Sync {
    /// A fresh iterator over the stream.
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_>;
}

/// Bridge a [`StreamSource`] (dynamically-dispatched, what
/// [`Coordinator::run_dyn`] holds) into the pipeline's
/// [`ParallelSource`]: each worker's scan is one `stream()` call.
pub struct SourceScan<'a, S: StreamSource + ?Sized>(pub &'a S);

impl<'a, S: StreamSource + ?Sized> ParallelSource for SourceScan<'a, S> {
    type Iter<'b> = Box<dyn Iterator<Item = Element> + Send + 'a>
    where
        Self: 'b;

    fn scan(&self) -> Self::Iter<'_> {
        // copy the `&'a S` out so the returned iterator borrows the
        // source for 'a, not merely for this `&self` borrow
        let source: &'a S = self.0;
        source.stream()
    }
}

/// In-memory stream (owns the elements; trivially replayable).
pub struct VecSource(pub Vec<Element>);

impl StreamSource for VecSource {
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        Box::new(self.0.iter().copied())
    }
}

/// Monomorphic scan for the typed pipeline entry points — no per-element
/// dynamic dispatch when a `VecSource` is used directly as a
/// [`ParallelSource`].
impl ParallelSource for VecSource {
    type Iter<'a> = std::iter::Copied<std::slice::Iter<'a, Element>>
    where
        Self: 'a;

    fn scan(&self) -> Self::Iter<'_> {
        self.0.iter().copied()
    }
}

/// A replayable deterministic generator: any `Fn() -> Iterator`.
pub struct FnSource<F>(pub F);

impl<F, I> StreamSource for FnSource<F>
where
    F: Fn() -> I + Sync,
    I: Iterator<Item = Element> + Send + 'static,
{
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        Box::new((self.0)())
    }
}

/// The leader/coordinator.
pub struct Coordinator {
    sampler_cfg: SamplerConfig,
    opts: PipelineOpts,
    checkpoint: Option<CheckpointPolicy>,
}

impl Coordinator {
    /// From the launcher config (including the checkpoint policy when
    /// `checkpoint_dir` is set).
    pub fn from_config(cfg: &PipelineConfig) -> Result<Self> {
        cfg.validate()?;
        let mut scfg = SamplerConfig::new(cfg.p, cfg.k)
            .with_seed(cfg.seed)
            .with_domain(cfg.n);
        scfg.q = cfg.q;
        scfg.delta = cfg.delta;
        scfg.eps = cfg.eps;
        if cfg.width > 0 {
            scfg = scfg.with_sketch_shape(cfg.rows, cfg.width);
        } else {
            scfg.rows = cfg.rows;
        }
        let opts = PipelineOpts::new(cfg.workers, cfg.batch)?;
        let mut c = Coordinator { sampler_cfg: scfg, opts, checkpoint: None };
        if !cfg.checkpoint_dir.is_empty() {
            c.checkpoint = Some(CheckpointPolicy::new(
                cfg.checkpoint_every,
                cfg.checkpoint_dir.clone(),
            )?);
        }
        Ok(c)
    }

    /// Direct construction.
    pub fn new(sampler_cfg: SamplerConfig, opts: PipelineOpts) -> Self {
        Coordinator { sampler_cfg, opts, checkpoint: None }
    }

    /// Enable checkpointing: every pass of [`Coordinator::run_dyn`] (and
    /// [`Coordinator::run_summary_checkpointed`]) snapshots shard states
    /// under the policy's directory and resumes from whatever snapshots
    /// already exist there.
    ///
    /// Only those two entry points honor the policy — the statically
    /// typed conveniences ([`Coordinator::run_summary`],
    /// [`Coordinator::one_pass`], [`Coordinator::two_pass`]) and the XLA
    /// path run without snapshots; use `run_summary_checkpointed` where
    /// typed crash recovery is needed.
    pub fn with_checkpoints(mut self, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some(policy);
        self
    }

    /// Sampler configuration in use.
    pub fn sampler_config(&self) -> &SamplerConfig {
        &self.sampler_cfg
    }

    /// Shard `source` across the workers (each scans it in parallel and
    /// keeps its own hash-partition), each owning a clone of `proto`, and
    /// fold the per-shard summaries back through the fingerprint-checked
    /// merge tree. Works for any [`Mergeable`] summary: samplers,
    /// sketches, pass states.
    pub fn run_summary<S, Src>(&self, source: &Src, proto: S) -> Result<(S, Arc<Metrics>)>
    where
        S: Mergeable + Clone + Send + 'static,
        Src: ParallelSource + ?Sized,
    {
        let (states, metrics) = run_sharded(source, self.opts, move |_| proto.clone())?;
        let merged = merge_all(states, &metrics)?
            .ok_or_else(|| Error::Pipeline("no workers".into()))?;
        Ok((merged, metrics))
    }

    /// [`Coordinator::run_summary`] with crash recovery for statically
    /// typed summaries: shard states snapshot to (and resume from) the
    /// coordinator's checkpoint directory. Falls back to the plain path
    /// when no policy is configured.
    pub fn run_summary_checkpointed<S, Src>(
        &self,
        source: &Src,
        proto: S,
    ) -> Result<(S, Arc<Metrics>)>
    where
        S: Mergeable + Persist + Clone + Send + 'static,
        Src: ParallelSource + ?Sized,
    {
        let Some(policy) = &self.checkpoint else {
            return self.run_summary(source, proto);
        };
        let (states, metrics) =
            run_sharded_checkpointed(source, self.opts, policy, move |_| proto.clone())?;
        let merged = merge_all(states, &metrics)?
            .ok_or_else(|| Error::Pipeline("no workers".into()))?;
        Ok((merged, metrics))
    }

    /// Drive a boxed WOR sampler (from the [`crate::Worp`] builder)
    /// through *all* of its passes over `source`, sharding every pass
    /// across the workers, and extract the final sample. The multi-pass
    /// handoff, sharding and merging are method-agnostic — this is the
    /// single driver behind the CLI.
    ///
    /// This is a thin offline front-end over the
    /// [`crate::engine::Engine`] ingest path: the coordinator registers
    /// one anonymous instance (`workers` shards, the pipeline batch
    /// size), drives each pass through
    /// [`crate::engine::Instance::ingest_source`] (the same per-shard
    /// scan / block-boundary discipline a live served instance keeps),
    /// and uses the engine's merge + advance handoff between passes — so
    /// a batch run and a served run of the same stream are bit-identical
    /// (`tests/engine_contract.rs` holds both paths to that).
    ///
    /// With a checkpoint policy the passes run on the checkpointed
    /// pipeline instead (per-pass crash-recovery snapshots; same
    /// boundaries, same outputs).
    pub fn run_dyn(
        &self,
        source: &dyn StreamSource,
        proto: Box<dyn WorSampler>,
    ) -> Result<(Sample, Arc<Metrics>)> {
        let passes = proto.passes().max(1);
        if let Some(policy) = &self.checkpoint {
            // crash recovery stays on the checkpointed pipeline: every
            // pass snapshots (and resumes) its shard states in its own
            // pass-<i>/ subdirectory — the Box<dyn WorSampler> persists
            // through the codec's type-tagged envelope
            let opts = if proto.parallel_safe() {
                self.opts
            } else {
                PipelineOpts { workers: 1, ..self.opts }
            };
            let mut current = proto;
            let mut metrics = Arc::new(Metrics::default());
            for pass in 0..passes {
                if pass > 0 {
                    current.advance()?;
                }
                let template = current;
                let (states, m) = run_sharded_checkpointed(
                    &SourceScan(source),
                    opts,
                    &policy.for_pass(pass),
                    move |_| template.clone(),
                )?;
                current = tree_merge(states, &m, |a, b| a.merge_dyn(&**b))?
                    .ok_or_else(|| Error::Pipeline("no workers".into()))?;
                metrics = m;
            }
            let sample = current.sample()?;
            return Ok((sample, metrics));
        }
        let engine = Engine::new(EngineOpts::from_pipeline(self.opts));
        const NAME: &str = "coordinator/run";
        engine.create_from_proto(NAME, proto)?;
        let instance = engine.instance(NAME)?;
        let mut metrics = Arc::new(Metrics::default());
        for pass in 0..passes {
            if pass > 0 {
                instance.advance()?;
            }
            metrics = instance.ingest_source(&SourceScan(source))?;
        }
        let sample = instance.merged_with(&metrics)?.sample()?;
        Ok((sample, metrics))
    }

    /// 1-pass WORp over a sharded pipeline: each worker owns a sibling
    /// `OnePassWorp` (same seed → same randomization), the leader
    /// tree-merges them and extracts the sample.
    pub fn one_pass<Src>(&self, source: &Src) -> Result<(Sample, Arc<Metrics>)>
    where
        Src: ParallelSource + ?Sized,
    {
        let proto = OnePassWorp::new(self.sampler_cfg.clone());
        let (merged, metrics) = self.run_summary(source, proto)?;
        Ok((merged.finalize(), metrics))
    }

    /// 2-pass WORp: pass I shards the stream into sibling rHH sketches
    /// and merges them; [`MultiPass::advance`] arms pass II; the replayed
    /// stream fills sharded collectors seeded with the *merged* pass-I
    /// sketch; the leader merges collectors and cuts the exact sample.
    pub fn two_pass<S: StreamSource + ?Sized>(&self, source: &S) -> Result<(Sample, Arc<Metrics>)> {
        let proto = TwoPassWorp::new(self.sampler_cfg.clone());
        let (mut w, _m1) = self.run_summary(&SourceScan(source), proto)?;
        w.advance()?;
        let (w, metrics) = self.run_summary(&SourceScan(source), w)?;
        // fold pass-I counters into the returned metrics
        metrics.note_batch(0);
        Ok((w.sample()?, metrics))
    }

    /// 1-pass WORp with the **XLA backend**: the transformed-element
    /// CountSketch update executes on the PJRT client via the AOT
    /// `countsketch_update` artifact (single-threaded — the PJRT client is
    /// not `Send` in the published crate; the benches compare this against
    /// the native sharded path). Without the `xla` cargo feature this
    /// returns a clean runtime error.
    pub fn one_pass_xla<I>(
        &self,
        stream: I,
        artifacts_dir: &str,
    ) -> Result<(Sample, Arc<Metrics>)>
    where
        I: IntoIterator<Item = Element>,
    {
        use crate::runtime::artifact::ArtifactDir;
        use crate::runtime::executor::XlaCountSketch;
        use crate::runtime::XlaRuntime;

        let rt = XlaRuntime::cpu()?;
        let dir = ArtifactDir::open(artifacts_dir)?;
        let cfg = &self.sampler_cfg;
        let mut xs = XlaCountSketch::load(&rt, &dir, cfg.seed ^ 0x1AB5)?;
        let transform = cfg.transform();
        let metrics = Arc::new(Metrics::default());

        let mut candidates: std::collections::HashMap<u64, ()> = Default::default();
        let cand_cap = 8 * (cfg.k + 1);
        let mut count = 0u64;
        for e in stream {
            let te = transform.apply(&e);
            xs.process(&te)?;
            candidates.insert(e.key, ());
            count += 1;
            if candidates.len() > 4 * cand_cap {
                // shrink by current estimates
                xs.flush()?;
                let mut scored: Vec<(u64, f64)> = candidates
                    .keys()
                    .map(|&k| (k, xs.est(k).abs()))
                    .collect();
                // rank_desc: deterministic truncation (see worp1)
                scored.sort_by(crate::util::stats::rank_desc);
                scored.truncate(cand_cap);
                candidates = scored.into_iter().map(|(k, _)| (k, ())).collect();
            }
        }
        xs.flush()?;
        metrics.note_batch(count);

        let mut scored: Vec<(u64, f64)> = candidates
            .keys()
            .map(|&k| (k, xs.est(k)))
            .filter(|(_, v)| *v != 0.0)
            .collect();
        // total_cmp: a NaN that slips past the ingest boundary ranks
        // deterministically instead of panicking the coordinator
        // mid-query (identical order on finite estimates)
        scored.sort_by(|a, b| b.1.abs().total_cmp(&a.1.abs()));
        let k = cfg.k;
        let tau = if scored.len() > k { scored[k].1.abs() } else { 0.0 };
        let entries = scored
            .into_iter()
            .take(k)
            .map(|(key, est)| crate::sampler::SampleEntry {
                key,
                freq: transform.invert(key, est),
                transformed: est,
            })
            .collect();
        Ok((
            Sample { entries, tau, p: cfg.p, dist: transform.dist(), names: None },
            metrics,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::{zipf_exact_stream, zipf_frequencies, ZipfStream};
    use crate::sampler::ppswor::perfect_ppswor;
    use crate::Worp;

    fn cfg(n: usize, k: usize) -> SamplerConfig {
        SamplerConfig::new(1.0, k)
            .with_seed(77)
            .with_domain(n)
            .with_sketch_shape(9, 2048)
    }

    #[test]
    fn sharded_one_pass_matches_perfect_on_skew() {
        let n = 800;
        let k = 16;
        let c = Coordinator::new(cfg(n, k), PipelineOpts::new(4, 256).unwrap());
        let elems = zipf_exact_stream(n, 1.5, 1e4, 3, 7);
        let (sample, metrics) = c.one_pass(&elems).unwrap();
        assert_eq!(metrics.elements() as usize, elems.len());
        assert_eq!(sample.len(), k);
        let want = perfect_ppswor(&zipf_frequencies(n, 1.5, 1e4), 1.0, k, 77);
        let overlap = sample
            .keys()
            .iter()
            .filter(|x| want.keys().contains(x))
            .count();
        assert!(overlap >= k - 1, "overlap {overlap}/{k}");
    }

    #[test]
    fn sharded_two_pass_equals_perfect_sample() {
        let n = 600;
        let k = 12;
        let c = Coordinator::new(cfg(n, k), PipelineOpts::new(3, 128).unwrap());
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 9);
        let (sample, _) = c.two_pass(&VecSource(elems)).unwrap();
        let want = perfect_ppswor(&zipf_frequencies(n, 1.2, 1e4), 1.0, k, 77);
        assert_eq!(sample.keys(), want.keys());
        for (g, w) in sample.entries.iter().zip(&want.entries) {
            assert!((g.freq - w.freq).abs() < 1e-6 * w.freq.abs().max(1.0));
        }
    }

    #[test]
    fn worker_count_does_not_change_two_pass_output() {
        let n = 400;
        let k = 10;
        let elems = zipf_exact_stream(n, 1.0, 1e4, 2, 3);
        let src = VecSource(elems);
        let mut outputs = Vec::new();
        for workers in [1usize, 2, 5] {
            let c = Coordinator::new(cfg(n, k), PipelineOpts::new(workers, 64).unwrap());
            let (s, _) = c.two_pass(&src).unwrap();
            outputs.push(s.keys());
        }
        assert_eq!(outputs[0], outputs[1]);
        assert_eq!(outputs[1], outputs[2]);
    }

    #[test]
    fn run_dyn_matches_typed_paths_for_every_method() {
        // one generic driver: the dynamic pipeline output must equal the
        // statically-typed convenience wrappers
        let n = 400;
        let k = 10;
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 5);
        let src = VecSource(elems.clone());
        let c = Coordinator::new(cfg(n, k), PipelineOpts::new(3, 128).unwrap());

        let builder = Worp::p(1.0)
            .k(k)
            .seed(77)
            .domain(n)
            .sketch_shape(9, 2048);

        let (dyn1, _) = c
            .run_dyn(&src, builder.clone().one_pass().build().unwrap())
            .unwrap();
        let (typed1, _) = c.one_pass(&elems).unwrap();
        assert_eq!(dyn1.keys(), typed1.keys());

        let (dyn2, m2) = c
            .run_dyn(&src, builder.clone().two_pass().build().unwrap())
            .unwrap();
        let (typed2, _) = c.two_pass(&src).unwrap();
        assert_eq!(dyn2.keys(), typed2.keys());
        assert_eq!(m2.elements() as usize, elems.len()); // pass-II count

        // the exact baseline through the same driver equals perfect ppswor
        let (dyn_exact, _) = c
            .run_dyn(&src, builder.clone().exact().build().unwrap())
            .unwrap();
        let want = perfect_ppswor(&zipf_frequencies(n, 1.2, 1e4), 1.0, k, 77);
        assert_eq!(dyn_exact.keys(), want.keys());
    }

    #[test]
    fn run_dyn_serializes_clock_dependent_samplers() {
        // the windowed sampler's implicit clock is stream-global; run_dyn
        // must force one worker so the worker count cannot change output
        let n = 300;
        let k = 8;
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 7);
        let src = VecSource(elems);
        let b = Worp::p(1.0)
            .k(k)
            .seed(5)
            .domain(n)
            .sketch_shape(7, 1024)
            .windowed(100, 10); // small window: sharded clocks would skew it
        let c1 = Coordinator::new(
            b.sampler_config().unwrap(),
            PipelineOpts::new(1, 64).unwrap(),
        );
        let c4 = Coordinator::new(
            b.sampler_config().unwrap(),
            PipelineOpts::new(4, 64).unwrap(),
        );
        let (s1, _) = c1.run_dyn(&src, b.build().unwrap()).unwrap();
        let (s4, _) = c4.run_dyn(&src, b.build().unwrap()).unwrap();
        assert_eq!(s1.keys(), s4.keys());
    }

    #[test]
    fn run_summary_rejects_incompatible_shards() {
        // a worker construction bug (different seeds per shard) must fail
        // loudly in the merge tree, not silently corrupt the sample
        use crate::sketch::countsketch::CountSketch;
        use crate::sketch::SketchParams;
        let c = Coordinator::new(cfg(100, 5), PipelineOpts::new(2, 64).unwrap());
        let stream: Vec<Element> = ZipfStream::new(100, 1.0, 1000, 3).collect();
        let (states, metrics) =
            run_sharded(&stream, PipelineOpts::new(2, 64).unwrap(), |shard| {
                CountSketch::new(SketchParams::new(3, 64, shard as u64))
            })
            .unwrap();
        let err = merge_all(states, &metrics).unwrap_err();
        assert!(matches!(err, Error::Incompatible(_)), "{err}");
        let _ = c;
    }

    #[test]
    fn fn_source_replays_deterministically() {
        let src = FnSource(|| crate::data::zipf::ZipfStream::new(100, 1.0, 1000, 5));
        let a: Vec<Element> = src.stream().collect();
        let b: Vec<Element> = src.stream().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn from_config_wires_parameters() {
        let mut pc = crate::config::PipelineConfig::default();
        pc.p = 2.0;
        pc.k = 32;
        pc.rows = 5;
        pc.width = 777;
        let c = Coordinator::from_config(&pc).unwrap();
        assert_eq!(c.sampler_config().p, 2.0);
        assert_eq!(c.sampler_config().resolved_width_two_pass(), 777);
    }
}
