//! 2-pass WORp (paper §4, Algorithm 2): an **exact** p-ppswor sample in
//! two passes.
//!
//! - **Pass I** computes an `ℓq(k+1, ψ)` rHH sketch `R` of the transformed
//!   elements, `ψ ← Ψ_{n,k,ρ}(δ)/(3q)`.
//! - **Pass II** runs the composable top structure `T` ([`crate::sketch::topk`]):
//!   keys with top pass-I estimates collect *exact* frequencies. Capacity
//!   follows the §4.1 practical optimization (≈2(k+1) keys, merge cap
//!   3(k+1)) instead of the worst-case `B(k+1)` with `B = 63`
//!   (Corollary D.2).
//! - **Output**: re-rank stored keys by exact `ν*_x = ν_x · r_x^{-1/p}`;
//!   the top-k with threshold `τ = |ν*|_(k+1)` form an exact p-ppswor
//!   sample whenever property (15) held — w.p. ≥ (1−δ)(1−3e^{−k}).

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint, WorSampler};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::sketch::topk::TopK;
use crate::sketch::{AnyRhh, RhhSketch, SketchParams};
use crate::transform::BottomKTransform;

/// Pass-I composable sketch.
#[derive(Clone, Debug)]
pub struct TwoPassWorpPass1 {
    cfg: SamplerConfig,
    transform: BottomKTransform,
    sketch: AnyRhh,
    processed: u64,
    /// Reusable transformed-element buffer for the batch path (§Perf L3-6).
    tbuf: Vec<Element>,
    /// Reusable transformed-value column for the SoA block path (§Perf L3-7).
    vbuf: Vec<f64>,
}

impl TwoPassWorpPass1 {
    /// Build from a sampler config.
    pub fn new(cfg: SamplerConfig) -> Self {
        let rows = cfg.resolved_rows();
        let width = cfg.resolved_width_two_pass();
        let params = SketchParams::new(rows, width, cfg.seed ^ 0x2AB5);
        let sketch = AnyRhh::for_q(cfg.q, params);
        let transform = cfg.transform();
        TwoPassWorpPass1 {
            cfg,
            transform,
            sketch,
            processed: 0,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        }
    }

    /// Process one raw element.
    #[inline]
    pub fn process(&mut self, e: &Element) {
        let te = self.transform.apply(e);
        self.sketch.process(&te);
        self.processed += 1;
    }

    /// Micro-batch path (§Perf L3-6): transform into the reusable buffer,
    /// then one columnar sketch update for the whole batch.
    pub fn process_batch(&mut self, batch: &[Element]) {
        let mut tbuf = std::mem::take(&mut self.tbuf);
        tbuf.clear();
        tbuf.extend(batch.iter().map(|e| self.transform.apply(e)));
        self.sketch.process_batch(&tbuf);
        self.tbuf = tbuf;
        self.processed += batch.len() as u64;
    }

    /// SoA block path (§Perf L3-7): the transform rewrites only the value
    /// column (reusable `vbuf`); the sketch hashes straight off the
    /// block's key column. Bit-identical to `process_batch`.
    pub fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let mut vbuf = std::mem::take(&mut self.vbuf);
        self.transform.apply_cols(&block.keys, &block.vals, &mut vbuf);
        self.sketch.process_cols(&block.keys, &vbuf);
        self.vbuf = vbuf;
        self.processed += block.len() as u64;
    }

    /// Merge a sibling pass-I sketch.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        self.sketch.merge(&other.sketch)?;
        self.processed += other.processed;
        Ok(())
    }

    /// Estimate a key's transformed frequency `ν̂*_x`.
    pub fn est(&self, key: u64) -> f64 {
        self.sketch.est(key)
    }

    /// Elements processed in pass I.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Sketch size in words.
    pub fn size_words(&self) -> usize {
        self.sketch.size_words()
    }

    /// Finish pass I: freeze into the pass-II collector.
    ///
    /// Capacity 4(k+1) (merge cap 6(k+1)): the §4.1 threshold condition
    /// (16) stores *every* key with `ν̂* ≥ ½ ν̂*_(k+1)` — unbounded but
    /// ~O(k) in practice; a fixed 4(k+1) slots subsumes it in all our
    /// workloads while staying far below the worst-case B(k+1) = 63(k+1)
    /// of Corollary D.2.
    pub fn into_pass2(self) -> TwoPassWorpPass2 {
        let cap = 4 * (self.cfg.k + 1);
        let merge_cap = 6 * (self.cfg.k + 1);
        TwoPassWorpPass2 {
            cfg: self.cfg,
            transform: self.transform,
            sketch: self.sketch,
            topk: TopK::new(cap, merge_cap),
            processed: 0,
        }
    }
}

/// Pass-II composable collector.
#[derive(Clone, Debug)]
pub struct TwoPassWorpPass2 {
    cfg: SamplerConfig,
    transform: BottomKTransform,
    sketch: AnyRhh,
    topk: TopK,
    processed: u64,
}

impl TwoPassWorpPass2 {
    /// Process one raw element in pass II (same stream, replayed).
    ///
    /// §Perf L3-6: membership is checked *before* the pass-I estimate —
    /// repeat elements of stored keys (the common case on skewed streams)
    /// accumulate in O(1) without touching the rHH sketch at all; only
    /// first sightings pay the rows-wide `est`.
    #[inline]
    pub fn process(&mut self, e: &Element) {
        if !self.topk.accumulate(e.key, e.val) {
            let priority = self.sketch.est(e.key).abs();
            self.topk.process(e.key, e.val, priority);
        }
        self.processed += 1;
    }

    /// Micro-batch path: same accumulate-first fast path with the
    /// per-element bookkeeping hoisted; sub-threshold unseen keys reject
    /// in O(1) against the collector's cached minimum.
    pub fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            if !self.topk.accumulate(e.key, e.val) {
                let priority = self.sketch.est(e.key).abs();
                self.topk.process(e.key, e.val, priority);
            }
        }
        self.processed += batch.len() as u64;
    }

    /// SoA block path (§Perf L3-7): the collector's columnar sweep over
    /// the key/value columns, with pass-I estimates computed only for
    /// first sightings. Identical update order to the scalar loop.
    pub fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let sketch = &self.sketch;
        self.topk
            .process_cols(&block.keys, &block.vals, |k| sketch.est(k).abs());
        self.processed += block.len() as u64;
    }

    /// Merge a sibling pass-II collector (disjoint shards of the stream).
    /// Only the collectors merge — every sibling holds the *same* merged
    /// pass-I sketch, which must not be double-counted.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        self.topk.merge(&other.topk)?;
        self.processed += other.processed;
        Ok(())
    }

    /// Elements processed in pass II.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of keys currently stored in `T`.
    pub fn stored_keys(&self) -> usize {
        self.topk.len()
    }

    /// Total summary size in words (rHH sketch + T slots).
    pub fn size_words(&self) -> usize {
        self.sketch.size_words() + self.topk.size_words()
    }

    /// Produce the exact p-ppswor sample: re-rank stored keys by exact
    /// transformed frequency and cut at k (paper "Producing a p-ppswor
    /// sample from T").
    pub fn sample(&self) -> Sample {
        let t = &self.transform;
        let ranked = self.topk.by_score(|e| (e.value * t.scale(e.key)).abs());
        let k = self.cfg.k;
        let tau = if ranked.len() > k { ranked[k].1 } else { 0.0 };
        let entries: Vec<SampleEntry> = ranked
            .into_iter()
            .take(k)
            .map(|(e, _)| SampleEntry {
                key: e.key,
                freq: e.value,
                transformed: e.value * t.scale(e.key),
            })
            .collect();
        Sample { entries, tau, p: self.cfg.p, dist: t.dist(), names: None }
    }

    /// The §4.1 "larger effective sample" extraction: every stored key
    /// whose exact `|ν*_x|` clears the certification threshold
    /// `L + |ν*|_(k+1)/3` is returned (≥ k keys), with `τ` the smallest
    /// retained `|ν*|`. Estimation quality can only improve.
    pub fn extended_sample(&self) -> Sample {
        let t = &self.transform;
        let ranked = self.topk.by_score(|e| (e.value * t.scale(e.key)).abs());
        let k = self.cfg.k;
        if ranked.len() <= k + 1 {
            return self.sample();
        }
        // uniform error bound |nu*_(k+1)|/3 (paper Eq. 14);
        // L = min estimated |nu*| over stored keys — scored in one
        // est_many sweep (shared scratch, §Perf L3-7)
        let nu_k1 = ranked[k].1;
        let keys: Vec<u64> = ranked.iter().map(|(e, _)| e.key).collect();
        let mut ests = vec![0.0f64; keys.len()];
        self.sketch.est_many(&keys, &mut ests);
        let l = ests.iter().map(|e| e.abs()).fold(f64::INFINITY, f64::min);
        let cut = l + nu_k1 / 3.0;
        let mut kept: Vec<(crate::sketch::topk::TopKEntry, f64)> = ranked
            .into_iter()
            .filter(|(_, s)| *s >= cut)
            .collect();
        if kept.len() <= k {
            return self.sample();
        }
        // threshold = smallest retained |nu*|; that key is excluded
        let tau = kept.last().unwrap().1;
        kept.pop();
        let entries = kept
            .into_iter()
            .map(|(e, s)| SampleEntry { key: e.key, freq: e.value, transformed: s })
            .collect();
        Sample { entries, tau, p: self.cfg.p, dist: t.dist(), names: None }
    }
}

/// 2-pass WORp as a first-class state machine: one summary that is in
/// pass I (rHH sketching) or pass II (exact collection), with the
/// handoff modeled by [`api::MultiPass::advance`] instead of two
/// loosely-coupled structs. This is what the [`crate::Worp`] builder
/// returns for `.two_pass()` and what the coordinator's generic pass
/// loop drives.
#[derive(Clone, Debug)]
pub struct TwoPassWorp {
    state: TwoPassState,
}

#[derive(Clone, Debug)]
enum TwoPassState {
    One(TwoPassWorpPass1),
    Two(TwoPassWorpPass2),
    /// Transient marker held only inside `advance`.
    Poisoned,
}

impl TwoPassWorp {
    /// Start in pass I.
    pub fn new(cfg: SamplerConfig) -> Self {
        TwoPassWorp { state: TwoPassState::One(TwoPassWorpPass1::new(cfg)) }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        match &self.state {
            TwoPassState::One(p) => &p.cfg,
            TwoPassState::Two(p) => &p.cfg,
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Current pass index (0 = pass I, 1 = pass II).
    pub fn pass_index(&self) -> usize {
        match &self.state {
            TwoPassState::One(_) => 0,
            TwoPassState::Two(_) => 1,
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Process one element of the current pass.
    #[inline]
    pub fn process(&mut self, e: &Element) {
        match &mut self.state {
            TwoPassState::One(p) => p.process(e),
            TwoPassState::Two(p) => p.process(e),
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Process a micro-batch of the current pass (§Perf L3-6).
    pub fn process_batch(&mut self, batch: &[Element]) {
        match &mut self.state {
            TwoPassState::One(p) => p.process_batch(batch),
            TwoPassState::Two(p) => p.process_batch(batch),
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Process an SoA block of the current pass (§Perf L3-7).
    pub fn process_block(&mut self, block: &crate::data::ElementBlock) {
        match &mut self.state {
            TwoPassState::One(p) => p.process_block(block),
            TwoPassState::Two(p) => p.process_block(block),
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Seal pass I and arm pass II; errors when already in pass II.
    pub fn advance(&mut self) -> Result<()> {
        match std::mem::replace(&mut self.state, TwoPassState::Poisoned) {
            TwoPassState::One(p1) => {
                self.state = TwoPassState::Two(p1.into_pass2());
                Ok(())
            }
            s @ TwoPassState::Two(_) => {
                self.state = s;
                Err(Error::State("2-pass WORp is already in pass II".into()))
            }
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
    }

    /// Merge a sibling in the *same pass*; merging across passes is an
    /// incompatibility (the fingerprint encodes the pass index).
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        match (&mut self.state, &other.state) {
            (TwoPassState::One(a), TwoPassState::One(b)) => a.merge(b),
            (TwoPassState::Two(a), TwoPassState::Two(b)) => a.merge(b),
            _ => Err(Error::Incompatible(
                "cannot merge 2-pass summaries in different passes".into(),
            )),
        }
    }

    /// The exact sample; errors until pass II has been armed.
    pub fn sample(&self) -> Result<Sample> {
        match &self.state {
            TwoPassState::Two(p) => Ok(p.sample()),
            _ => Err(Error::State(
                "2-pass WORp has not finished pass I — call advance() and replay the stream"
                    .into(),
            )),
        }
    }

    /// The §4.1 larger effective sample; errors until pass II.
    pub fn extended_sample(&self) -> Result<Sample> {
        match &self.state {
            TwoPassState::Two(p) => Ok(p.extended_sample()),
            _ => Err(Error::State("2-pass WORp has not finished pass I".into())),
        }
    }

    /// Summary size in words for the current state.
    pub fn size_words(&self) -> usize {
        match &self.state {
            TwoPassState::One(p) => p.size_words(),
            TwoPassState::Two(p) => p.size_words(),
            TwoPassState::Poisoned => 0,
        }
    }

    /// Elements processed in the current pass.
    pub fn processed(&self) -> u64 {
        match &self.state {
            TwoPassState::One(p) => p.processed(),
            TwoPassState::Two(p) => p.processed(),
            TwoPassState::Poisoned => 0,
        }
    }
}

impl api::StreamSummary for TwoPassWorp {
    fn process(&mut self, e: &Element) {
        TwoPassWorp::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        TwoPassWorp::process_batch(self, batch)
    }

    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        TwoPassWorp::process_block(self, block)
    }

    fn size_words(&self) -> usize {
        TwoPassWorp::size_words(self)
    }

    fn processed(&self) -> u64 {
        TwoPassWorp::processed(self)
    }
}

impl api::Mergeable for TwoPassWorp {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("worp2", self.config()).with(self.pass_index() as u64)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        TwoPassWorp::merge(self, other)
    }
}

impl api::Finalize for TwoPassWorp {
    type Output = Result<Sample>;

    fn finalize(&self) -> Result<Sample> {
        self.sample()
    }
}

impl api::MultiPass for TwoPassWorp {
    fn passes(&self) -> usize {
        2
    }

    fn pass(&self) -> usize {
        self.pass_index()
    }

    fn advance(&mut self) -> Result<()> {
        TwoPassWorp::advance(self)
    }
}

impl WorSampler for TwoPassWorp {
    fn sample(&self) -> Result<Sample> {
        TwoPassWorp::sample(self)
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(Error::Incompatible(format!(
                "cannot merge 2-pass WORp with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "2pass"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }
}

/// Wire payload: the shared [`SamplerConfig`] fragment, `processed u64`,
/// and the pass-I rHH sketch as a nested envelope.
impl crate::api::Persist for TwoPassWorpPass1 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.sketch);
        crate::codec::write_envelope(
            crate::codec::tag::WORP2_PASS1,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WORP2_PASS1))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let processed = r.u64()?;
        let sketch: AnyRhh = crate::codec::read_nested(&mut r)?;
        r.finish("2pass-pass1")?;
        let transform = cfg.transform();
        let s = TwoPassWorpPass1 {
            cfg,
            transform,
            sketch,
            processed,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

/// Wire payload: the shared [`SamplerConfig`] fragment, `processed u64`,
/// the (frozen) pass-I rHH sketch and the pass-II collector `T`, both as
/// nested envelopes.
impl crate::api::Persist for TwoPassWorpPass2 {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.sketch);
        crate::codec::put_nested(&mut p, &self.topk);
        crate::codec::write_envelope(
            crate::codec::tag::WORP2_PASS2,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WORP2_PASS2))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let processed = r.u64()?;
        let sketch: AnyRhh = crate::codec::read_nested(&mut r)?;
        let topk: TopK = crate::codec::read_nested(&mut r)?;
        r.finish("2pass-pass2")?;
        let transform = cfg.transform();
        let s = TwoPassWorpPass2 { cfg, transform, sketch, topk, processed };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

/// Wire payload: `pass u8 (0 | 1)` followed by the corresponding pass
/// state as a nested envelope — the state machine round-trips in
/// whichever pass it was saved.
impl crate::api::Persist for TwoPassWorp {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        match &self.state {
            TwoPassState::One(s) => {
                crate::codec::wire::put_u8(&mut p, 0);
                crate::codec::put_nested(&mut p, s);
            }
            TwoPassState::Two(s) => {
                crate::codec::wire::put_u8(&mut p, 1);
                crate::codec::put_nested(&mut p, s);
            }
            TwoPassState::Poisoned => unreachable!("poisoned two-pass state"),
        }
        crate::codec::write_envelope(
            crate::codec::tag::WORP2,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WORP2))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let state = match r.u8()? {
            0 => TwoPassState::One(crate::codec::read_nested(&mut r)?),
            1 => TwoPassState::Two(crate::codec::read_nested(&mut r)?),
            v => {
                return Err(Error::Codec(format!(
                    "unknown 2-pass state byte {v} (expected 0 or 1)"
                )))
            }
        };
        r.finish("2pass")?;
        let s = TwoPassWorp { state };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

impl api::StreamSummary for TwoPassWorpPass1 {
    fn process(&mut self, e: &Element) {
        TwoPassWorpPass1::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        TwoPassWorpPass1::process_batch(self, batch)
    }

    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        TwoPassWorpPass1::process_block(self, block)
    }

    fn size_words(&self) -> usize {
        TwoPassWorpPass1::size_words(self)
    }

    fn processed(&self) -> u64 {
        TwoPassWorpPass1::processed(self)
    }
}

impl api::Mergeable for TwoPassWorpPass1 {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("worp2-pass1", &self.cfg)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        TwoPassWorpPass1::merge(self, other)
    }
}

impl api::StreamSummary for TwoPassWorpPass2 {
    fn process(&mut self, e: &Element) {
        TwoPassWorpPass2::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        TwoPassWorpPass2::process_batch(self, batch)
    }

    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        TwoPassWorpPass2::process_block(self, block)
    }

    fn size_words(&self) -> usize {
        TwoPassWorpPass2::size_words(self)
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for TwoPassWorpPass2 {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("worp2-pass2", &self.cfg)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        TwoPassWorpPass2::merge(self, other)
    }
}

impl api::Finalize for TwoPassWorpPass2 {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

/// Convenience driver: run both passes over an in-memory stream.
pub fn two_pass_sample(elems: &[Element], cfg: SamplerConfig) -> Sample {
    let mut w = TwoPassWorp::new(cfg);
    for e in elems {
        w.process(e);
    }
    w.advance().expect("pass I -> pass II");
    for e in elems {
        w.process(e);
    }
    w.sample().expect("pass II complete")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::{zipf_exact_stream, zipf_frequencies};
    use crate::sampler::ppswor::perfect_ppswor;
    use std::collections::HashSet;

    #[test]
    fn recovers_exact_ppswor_sample_on_zipf() {
        // the headline guarantee: 2-pass output == perfect p-ppswor with
        // the same randomization, including *exact* frequencies
        for &(p, alpha) in &[(1.0, 1.0), (2.0, 2.0), (0.5, 1.0)] {
            let n = 1000;
            let k = 25;
            let cfg = SamplerConfig::new(p, k)
                .with_seed(21)
                .with_domain(n)
                .with_sketch_shape(9, 2048);
            let elems = zipf_exact_stream(n, alpha, 1e4, 3, 5);
            let got = two_pass_sample(&elems, cfg);
            let freqs = zipf_frequencies(n, alpha, 1e4);
            let want = perfect_ppswor(&freqs, p, k, 21);
            assert_eq!(got.keys(), want.keys(), "p={p} alpha={alpha}");
            for (g, w) in got.entries.iter().zip(&want.entries) {
                assert!((g.freq - w.freq).abs() < 1e-6 * w.freq.abs().max(1.0));
            }
            assert!((got.tau - want.tau).abs() < 1e-6 * want.tau);
        }
    }

    #[test]
    fn pass1_merge_then_pass2_merge_matches_single() {
        let n = 500;
        let cfg = SamplerConfig::new(1.0, 10)
            .with_seed(31)
            .with_domain(n)
            .with_sketch_shape(7, 1024);
        let elems = zipf_exact_stream(n, 1.5, 1e4, 2, 9);

        // single-node reference
        let whole = two_pass_sample(&elems, cfg.clone());

        // two shards
        let (ea, eb): (Vec<(usize, Element)>, Vec<(usize, Element)>) = elems
            .iter()
            .copied()
            .enumerate()
            .partition(|(i, _)| i % 2 == 0);
        let ea: Vec<Element> = ea.into_iter().map(|(_, e)| e).collect();
        let eb: Vec<Element> = eb.into_iter().map(|(_, e)| e).collect();

        let mut a1 = TwoPassWorpPass1::new(cfg.clone());
        let mut b1 = TwoPassWorpPass1::new(cfg);
        for e in &ea {
            a1.process(e);
        }
        for e in &eb {
            b1.process(e);
        }
        a1.merge(&b1).unwrap();
        let mut a2 = a1.clone().into_pass2();
        let mut b2 = a1.into_pass2();
        for e in &ea {
            a2.process(e);
        }
        for e in &eb {
            b2.process(e);
        }
        a2.merge(&b2).unwrap();
        let merged = a2.sample();
        assert_eq!(merged.keys(), whole.keys());
        for (g, w) in merged.entries.iter().zip(&whole.entries) {
            assert!((g.freq - w.freq).abs() < 1e-9);
        }
    }

    #[test]
    fn signed_turnstile_sample_follows_net_frequencies() {
        let n = 200;
        let k = 8;
        let mut freqs: Vec<f64> = vec![1.0; n];
        for i in 0..10 {
            freqs[i] = 100.0 * (i + 1) as f64;
        }
        let elems = crate::data::stream::unaggregate(&freqs, 4, true, 3);
        let cfg = SamplerConfig::new(2.0, k)
            .with_seed(41)
            .with_domain(n)
            .with_sketch_shape(9, 1024);
        let got = two_pass_sample(&elems, cfg);
        let want = perfect_ppswor(&freqs, 2.0, k, 41);
        assert_eq!(got.keys(), want.keys());
    }

    #[test]
    fn extended_sample_supersets_base_sample() {
        let n = 800;
        let cfg = SamplerConfig::new(1.0, 20)
            .with_seed(51)
            .with_domain(n)
            .with_sketch_shape(9, 2048);
        let elems = zipf_exact_stream(n, 1.2, 1e4, 2, 7);
        let mut p1 = TwoPassWorpPass1::new(cfg);
        for e in &elems {
            p1.process(e);
        }
        let mut p2 = p1.into_pass2();
        for e in &elems {
            p2.process(e);
        }
        let base = p2.sample();
        let ext = p2.extended_sample();
        assert!(ext.len() >= base.len());
        let base_keys: HashSet<u64> = base.keys().into_iter().collect();
        let ext_keys: HashSet<u64> = ext.keys().into_iter().collect();
        assert!(base_keys.is_subset(&ext_keys));
        assert!(ext.tau <= base.tau + 1e-12);
    }

    #[test]
    fn state_machine_enforces_pass_order() {
        let cfg = SamplerConfig::new(1.0, 5)
            .with_seed(3)
            .with_domain(100)
            .with_sketch_shape(5, 256);
        let mut w = TwoPassWorp::new(cfg);
        assert_eq!(w.pass_index(), 0);
        // sampling before pass II is an invalid state
        let err = w.sample().unwrap_err();
        assert!(matches!(err, crate::error::Error::State(_)), "{err}");
        w.process(&Element::new(1, 2.0));
        assert_eq!(w.processed(), 1);
        w.advance().unwrap();
        assert_eq!(w.pass_index(), 1);
        assert_eq!(w.processed(), 0); // per-pass counter
        w.process(&Element::new(1, 2.0));
        assert!(w.sample().is_ok());
        // advancing past the last pass is an invalid state
        let err = w.advance().unwrap_err();
        assert!(matches!(err, crate::error::Error::State(_)), "{err}");
    }

    #[test]
    fn cross_pass_merge_is_incompatible() {
        let cfg = SamplerConfig::new(1.0, 5)
            .with_seed(3)
            .with_domain(100)
            .with_sketch_shape(5, 256);
        let mut a = TwoPassWorp::new(cfg.clone());
        let mut b = TwoPassWorp::new(cfg);
        b.advance().unwrap();
        let err = api::Mergeable::merge(&mut a, &b).unwrap_err();
        assert!(matches!(err, crate::error::Error::Incompatible(_)), "{err}");
    }

    #[test]
    fn stored_keys_bounded_by_capacity() {
        let n = 2000;
        let cfg = SamplerConfig::new(1.0, 10)
            .with_seed(61)
            .with_domain(n)
            .with_sketch_shape(7, 512);
        let elems = zipf_exact_stream(n, 1.0, 1e4, 1, 3);
        let mut p1 = TwoPassWorpPass1::new(cfg);
        for e in &elems {
            p1.process(e);
        }
        let mut p2 = p1.into_pass2();
        for e in &elems {
            p2.process(e);
        }
        assert!(p2.stored_keys() <= 4 * 11, "stored={}", p2.stored_keys());
    }
}
