//! `DecayedWorp` — exact bottom-k WOR sampling over *time-decayed*
//! frequencies, served as a first-class method (the scenario subsystem's
//! decay workload).
//!
//! The decayed frequency of key `x` at query tick `T` is
//! `ν_x(T) = Σ_i v_i · decay(t_i, T)` over the key's updates, with
//! `decay` from [`crate::transform::decay::DecaySpec`] (exponential or
//! polynomial-forward). Because both families satisfy the carry law
//! `carry(a, b)·carry(b, c) = carry(a, c)`, each key needs only one
//! `(last_tick, acc)` pair, where `acc` is the decayed sum *as of* the
//! key's last update — every stored multiplier is in `[0, 1]`, so the
//! state never overflows regardless of stream length or rate.
//!
//! Ticks mirror the windowed sampler's run-chunked clock: the implicit
//! `process` stamps `now + 1`, and the batch/block paths stamp
//! `t0 + 1 + i` arithmetically — so served runs (any batch slicing) are
//! bit-identical to offline runs, which `tests/scenario_contract.rs`
//! locks in. [`DecayedWorp::process_at`] is the explicit-tick surface.
//!
//! Sampling is the exact bottom-k transform over the decayed
//! frequencies (same hash-defined randomization as [`super::exact`],
//! so equal seeds give coordinated decayed samples). Like every
//! clock-driven sampler, `parallel_safe()` is `false`.

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::transform::decay::{DecayKind, DecaySpec};
use crate::transform::BottomKTransform;
use std::collections::HashMap;

/// Exact streaming WOR sampler over exponentially / polynomially decayed
/// frequencies (linear memory in live distinct keys).
#[derive(Clone, Debug)]
pub struct DecayedWorp {
    cfg: SamplerConfig,
    decay: DecaySpec,
    transform: BottomKTransform,
    /// key → (tick of last update, decayed sum as of that tick).
    entries: HashMap<u64, (u64, f64)>,
    now: u64,
    processed: u64,
}

impl DecayedWorp {
    /// Build from a sampler config plus a decay spec (only `p`, `k`,
    /// `seed`, `dist` of the config matter; sketch parameters are
    /// ignored).
    pub fn new(cfg: SamplerConfig, decay: DecaySpec) -> Self {
        let transform = cfg.transform();
        DecayedWorp {
            cfg,
            decay,
            transform,
            entries: HashMap::new(),
            now: 0,
            processed: 0,
        }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    /// The decay specification.
    pub fn decay(&self) -> DecaySpec {
        self.decay
    }

    /// Current tick.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Number of distinct keys currently tracked.
    pub fn distinct_keys(&self) -> usize {
        self.entries.len()
    }

    /// Process one element at explicit tick `t` (the clock never runs
    /// backwards: `now` is the max tick seen).
    #[inline]
    pub fn process_at(&mut self, e: &Element, t: u64) {
        self.touch(e.key, e.val, t);
        if t > self.now {
            self.now = t;
        }
        self.processed += 1;
    }

    #[inline]
    fn touch(&mut self, key: u64, val: f64, t: u64) {
        let slot = self.entries.entry(key).or_insert((t, 0.0));
        if t >= slot.0 {
            // bring the stored sum forward, then add this update
            slot.1 = slot.1 * self.decay.carry(slot.0, t) + val;
            slot.0 = t;
        } else {
            // out-of-order tick: decay the *contribution* forward to the
            // stored coordinate instead (exact, and never > 1 factors)
            slot.1 += val * self.decay.carry(t, slot.0);
        }
    }

    /// Decayed frequency of one key at the current tick (0 if untracked).
    pub fn decayed_freq(&self, key: u64) -> f64 {
        match self.entries.get(&key) {
            Some(&(last, acc)) => acc * self.decay.carry(last, self.now),
            None => 0.0,
        }
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Merge a sibling summary: clocks take the max, and each key's two
    /// decayed sums are aligned to the later of the two last-update
    /// ticks before adding (addition of f64 is commutative, so merge
    /// order cannot change the bits of a two-way combine).
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        for (&key, &(lo, vo)) in &other.entries {
            match self.entries.get_mut(&key) {
                None => {
                    self.entries.insert(key, (lo, vo));
                }
                Some(slot) => {
                    let m = slot.0.max(lo);
                    let mine = slot.1 * self.decay.carry(slot.0, m);
                    let theirs = vo * self.decay.carry(lo, m);
                    *slot = (m, mine + theirs);
                }
            }
        }
        self.entries.retain(|_, &mut (_, v)| v != 0.0);
        self.now = self.now.max(other.now);
        self.processed += other.processed;
        Ok(())
    }

    /// The exact bottom-k sample of the decayed frequencies at the
    /// current tick.
    pub fn sample(&self) -> Sample {
        let t = &self.transform;
        let mut scored: Vec<SampleEntry> = self
            .entries
            .iter()
            .map(|(&key, &(last, acc))| {
                let freq = acc * self.decay.carry(last, self.now);
                SampleEntry { key, freq, transformed: freq * t.scale(key) }
            })
            .filter(|e| e.freq.abs() > 1e-12)
            .collect();
        scored.sort_by(|a, b| {
            b.transformed
                .abs()
                .total_cmp(&a.transformed.abs())
                .then_with(|| a.key.cmp(&b.key))
        });
        let k = self.cfg.k;
        let tau = if scored.len() > k {
            scored[k].transformed.abs()
        } else {
            0.0
        };
        scored.truncate(k);
        Sample { entries: scored, tau, p: self.cfg.p, dist: t.dist(), names: None }
    }
}

impl api::StreamSummary for DecayedWorp {
    /// Implicit clock: each element advances the tick by one (the same
    /// run-chunked convention as the windowed sampler).
    fn process(&mut self, e: &Element) {
        let t = self.now + 1;
        self.process_at(e, t);
    }

    /// Micro-batch path: ticks are stamped arithmetically (`t0 + 1 + i`),
    /// exactly what the scalar loop would have produced.
    fn process_batch(&mut self, batch: &[Element]) {
        let t0 = self.now;
        self.entries.reserve(batch.len().min(4096));
        for (i, e) in batch.iter().enumerate() {
            self.touch(e.key, e.val, t0 + 1 + i as u64);
        }
        self.now = t0 + batch.len() as u64;
        self.processed += batch.len() as u64;
    }

    /// SoA block path: same arithmetic ticks off the dense columns.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let t0 = self.now;
        self.entries.reserve(block.len().min(4096));
        for (i, (&k, &v)) in block.keys.iter().zip(&block.vals).enumerate() {
            self.touch(k, v, t0 + 1 + i as u64);
        }
        self.now = t0 + block.len() as u64;
        self.processed += block.len() as u64;
    }

    fn size_words(&self) -> usize {
        3 * self.entries.len() + 4
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for DecayedWorp {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("decayed", &self.cfg)
            .with(self.decay.kind().to_byte() as u64)
            .with_f64(self.decay.rate())
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        DecayedWorp::merge(self, other)
    }
}

impl api::Finalize for DecayedWorp {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

impl api::MultiPass for DecayedWorp {}

impl api::WorSampler for DecayedWorp {
    fn sample(&self) -> Result<Sample> {
        Ok(DecayedWorp::sample(self))
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn api::WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(Error::Incompatible(format!(
                "cannot merge decayed sampler with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn api::WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "decayed"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }

    /// The implicit per-element clock must tick over the whole stream in
    /// order — sharding would skew per-shard clocks (the windowed rule).
    fn parallel_safe(&self) -> bool {
        false
    }
}

/// Wire payload (canonical — entries sorted by key): the shared
/// [`SamplerConfig`] fragment, `kind u8, rate f64, now u64,
/// processed u64, n u64, n × (key u64, last_tick u64, acc f64)`.
impl crate::api::Persist for DecayedWorp {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(104 + 24 * self.entries.len());
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u8(&mut p, self.decay.kind().to_byte());
        crate::codec::wire::put_f64(&mut p, self.decay.rate());
        crate::codec::wire::put_u64(&mut p, self.now);
        crate::codec::wire::put_u64(&mut p, self.processed);
        let mut keys: Vec<u64> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            let (last, acc) = self.entries[&k];
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_u64(&mut p, last);
            crate::codec::wire::put_f64(&mut p, acc);
        }
        crate::codec::write_envelope(
            crate::codec::tag::DECAYED_WORP,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::DECAYED_WORP))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let kind = DecayKind::from_byte(r.u8()?)?;
        let rate = r.finite_f64("decay rate")?;
        let decay = match kind {
            DecayKind::Exponential => DecaySpec::exponential(rate),
            DecayKind::Polynomial => DecaySpec::polynomial(rate),
        }
        .map_err(|e| crate::error::Error::Codec(format!("decayed sampler: {e}")))?;
        let now = r.u64()?;
        let processed = r.u64()?;
        let n = r.seq_len(24)?;
        let mut entries = HashMap::with_capacity(n);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|p| p >= key) {
                return Err(Error::Codec(
                    "DecayedWorp entries are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            let last = r.u64()?;
            if last > now {
                return Err(Error::Codec(format!(
                    "DecayedWorp entry tick {last} is ahead of the clock {now}"
                )));
            }
            entries.insert(key, (last, r.finite_f64("DecayedWorp decayed sum")?));
        }
        r.finish("decayed")?;
        let transform = cfg.transform();
        let s = DecayedWorp { cfg, decay, transform, entries, now, processed };
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Persist, StreamSummary};

    fn spec() -> DecaySpec {
        DecaySpec::exponential(0.01).unwrap()
    }

    fn cfg(k: usize) -> SamplerConfig {
        SamplerConfig::new(1.0, k).with_seed(5)
    }

    #[test]
    fn decayed_freq_matches_closed_form() {
        let mut s = DecayedWorp::new(cfg(4), spec());
        s.process_at(&Element::new(1, 10.0), 1);
        s.process_at(&Element::new(1, 5.0), 11);
        s.process_at(&Element::new(2, 1.0), 21);
        let d = spec();
        let want = 10.0 * d.weight(1, 21) + 5.0 * d.weight(11, 21);
        assert!((s.decayed_freq(1) - want).abs() < 1e-12 * want);
        assert_eq!(s.decayed_freq(2), 1.0);
        assert_eq!(s.decayed_freq(99), 0.0);
    }

    #[test]
    fn batch_and_block_tick_like_the_scalar_loop() {
        let elems: Vec<Element> = (0..257u64)
            .map(|i| Element::new(i % 19, 1.0 + (i % 3) as f64))
            .collect();
        let mut scalar = DecayedWorp::new(cfg(8), spec());
        for e in &elems {
            StreamSummary::process(&mut scalar, e);
        }
        let mut batched = DecayedWorp::new(cfg(8), spec());
        for chunk in elems.chunks(64) {
            batched.process_batch(chunk);
        }
        let mut blocked = DecayedWorp::new(cfg(8), spec());
        for chunk in elems.chunks(50) {
            blocked.process_block(&crate::data::ElementBlock::from_elements(chunk));
        }
        assert_eq!(scalar.encode(), batched.encode());
        assert_eq!(scalar.encode(), blocked.encode());
    }

    #[test]
    fn recent_keys_dominate_the_sample() {
        // era shift: keys 0..10 hot early, keys 100..110 hot late, with a
        // strong decay rate — the sample must be the late era
        let mut s = DecayedWorp::new(cfg(10), DecaySpec::exponential(0.05).unwrap());
        for round in 0..200u64 {
            for k in 0..10u64 {
                StreamSummary::process(&mut s, &Element::new(k, 1.0));
            }
            let _ = round;
        }
        for _ in 0..200u64 {
            for k in 100..110u64 {
                StreamSummary::process(&mut s, &Element::new(k, 1.0));
            }
        }
        let sample = s.sample();
        assert!(!sample.is_empty());
        for key in sample.keys() {
            assert!(key >= 100, "stale key {key} survived the decay");
        }
    }

    #[test]
    fn merge_aligns_clocks_and_matches_closed_form() {
        let d = spec();
        let mut a = DecayedWorp::new(cfg(4), d);
        let mut b = DecayedWorp::new(cfg(4), d);
        a.process_at(&Element::new(1, 4.0), 10);
        b.process_at(&Element::new(1, 2.0), 30);
        b.process_at(&Element::new(2, 1.0), 5);
        a.merge(&b).unwrap();
        assert_eq!(a.now(), 30);
        let want1 = 4.0 * d.weight(10, 30) + 2.0;
        assert!((a.decayed_freq(1) - want1).abs() < 1e-12 * want1);
        let want2 = 1.0 * d.weight(5, 30);
        assert!((a.decayed_freq(2) - want2).abs() < 1e-12 * want2);
    }

    #[test]
    fn persist_roundtrip_is_canonical() {
        let mut s = DecayedWorp::new(cfg(6), DecaySpec::polynomial(1.25).unwrap());
        for i in 0..300u64 {
            StreamSummary::process(&mut s, &Element::new(i % 41, (i % 7) as f64 - 2.0));
        }
        let buf = s.encode();
        let back = DecayedWorp::decode(&buf).unwrap();
        assert_eq!(back.encode(), buf);
        assert_eq!(back.now(), s.now());
        for cut in 0..buf.len() {
            assert!(DecayedWorp::decode(&buf[..cut]).is_err());
        }
    }

    #[test]
    fn same_seed_decayed_samplers_are_coordinated() {
        // equal seeds => identical hash randomization => identical key
        // sets when fed the same stream
        let mut a = DecayedWorp::new(cfg(5), spec());
        let mut b = DecayedWorp::new(cfg(5), spec());
        for i in 0..500u64 {
            let e = Element::new(i % 67, 1.0);
            StreamSummary::process(&mut a, &e);
            StreamSummary::process(&mut b, &e);
        }
        assert_eq!(a.sample().keys(), b.sample().keys());
    }
}
