//! Algorithm 1 (paper §6 / Appendix F): 1-pass WOR sampling with
//! polynomially-small total-variation distance to a true p-ppswor k-tuple.
//!
//! `r` independent perfect ℓp single samplers run alongside an rHH sketch
//! `R`. Producing the sample walks the samplers in order; every fresh
//! index `Out_i` is added to `S` and **subtracted** from all later
//! samplers via the linear update `(Out_i, −R(Out_i))`, uncovering fresh
//! WOR picks. With `r = Θ(k log n)` the procedure fails (returns fewer
//! than k keys) with probability `1/poly(n)`.

use super::perfect_lp::{OracleSampler, PrecisionSampler, SingleLpSampler};
use super::{Sample, SampleEntry};
use crate::api::{self, Fingerprint, WorSampler};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::sketch::countsketch::CountSketch;
use crate::sketch::{RhhSketch, SketchParams};
use crate::util::hashing::BottomKDist;

/// Which single-sampler substrate to use (DESIGN.md §6 substitution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// Exact per-draw distribution (TV 0 per draw) — isolates the
    /// subtraction machinery; linear memory.
    Oracle,
    /// Sketch-based precision sampler — honest 1-pass memory profile.
    Precision,
}

/// Configuration for the low-TV sampler.
#[derive(Clone, Debug)]
pub struct TvSamplerConfig {
    /// Power `p ∈ (0, 2]`.
    pub p: f64,
    /// Sample size `k`.
    pub k: usize,
    /// Number of single samplers `r` (paper: `C·k·log n`).
    pub r: usize,
    /// Seed.
    pub seed: u64,
    /// Substrate choice.
    pub kind: SamplerKind,
    /// rHH sketch shape for the subtraction estimates.
    pub rhh_rows: usize,
    /// rHH sketch width.
    pub rhh_width: usize,
    /// Precision-sampler sketch shape (ignored for Oracle).
    pub inner_rows: usize,
    /// Precision-sampler sketch width.
    pub inner_width: usize,
}

impl TvSamplerConfig {
    /// Paper-faithful defaults: `r = ceil(C k ln n)` with C=4.
    pub fn new(p: f64, k: usize, n: usize, seed: u64, kind: SamplerKind) -> Self {
        let r = ((4.0 * k as f64 * (n.max(2) as f64).ln()).ceil() as usize).max(2 * k);
        TvSamplerConfig {
            p,
            k,
            r,
            seed,
            kind,
            rhh_rows: 7,
            rhh_width: (8 * k).max(64),
            inner_rows: 5,
            inner_width: (4 * k).max(128),
        }
    }

    /// Override the sampler count `r`.
    pub fn with_r(mut self, r: usize) -> Self {
        self.r = r;
        self
    }
}

#[derive(Clone)]
enum Samplers {
    Oracle(Vec<OracleSampler>),
    Precision(Vec<PrecisionSampler>),
}

/// The 1-pass low-TV WOR sampler (Algorithm 1).
#[derive(Clone)]
pub struct TvSampler {
    cfg: TvSamplerConfig,
    samplers: Samplers,
    rhh: CountSketch,
    processed: u64,
    /// Reusable AoS bridge buffer for the SoA block path (§Perf L3-7):
    /// the `r` single samplers consume element slices, so one shared
    /// materialization serves all of them per block.
    ebuf: Vec<Element>,
}

impl TvSampler {
    /// Build all `r` samplers plus the rHH sketch.
    pub fn new(cfg: TvSamplerConfig) -> Self {
        let samplers = match cfg.kind {
            SamplerKind::Oracle => Samplers::Oracle(
                (0..cfg.r)
                    .map(|i| OracleSampler::new(cfg.p, cfg.seed ^ (i as u64).wrapping_mul(0xD1E5)))
                    .collect(),
            ),
            SamplerKind::Precision => Samplers::Precision(
                (0..cfg.r)
                    .map(|i| {
                        PrecisionSampler::new(
                            cfg.p,
                            cfg.seed ^ (i as u64).wrapping_mul(0xD1E5),
                            cfg.inner_rows,
                            cfg.inner_width,
                        )
                    })
                    .collect(),
            ),
        };
        let rhh = CountSketch::new(SketchParams::new(
            cfg.rhh_rows,
            cfg.rhh_width,
            cfg.seed ^ 0x0FF5E7,
        ));
        TvSampler { cfg, samplers, rhh, processed: 0, ebuf: Vec::new() }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &TvSamplerConfig {
        &self.cfg
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pass 1: feed a stream update into every sampler and the rHH sketch.
    pub fn process(&mut self, e: &Element) {
        match &mut self.samplers {
            Samplers::Oracle(v) => {
                for s in v.iter_mut() {
                    s.process(e);
                }
            }
            Samplers::Precision(v) => {
                for s in v.iter_mut() {
                    s.process(e);
                }
            }
        }
        self.rhh.process(e);
        self.processed += 1;
    }

    /// Micro-batch path (§Perf L3-6): the loop nest is swapped to
    /// sampler-major — each of the `r` single samplers streams the whole
    /// batch (through its own specialized batch path) while its private
    /// state is hot, instead of all `r` states being touched per element —
    /// and the rHH sketch takes the batch through its columnar path.
    /// Samplers are mutually independent during pass 1, so the reordering
    /// is semantically identical.
    pub fn process_batch(&mut self, batch: &[Element]) {
        match &mut self.samplers {
            Samplers::Oracle(v) => {
                for s in v.iter_mut() {
                    api::StreamSummary::process_batch(s, batch);
                }
            }
            Samplers::Precision(v) => {
                for s in v.iter_mut() {
                    api::StreamSummary::process_batch(s, batch);
                }
            }
        }
        self.rhh.process_batch(batch);
        self.processed += batch.len() as u64;
    }

    /// SoA block path (§Perf L3-7): the rHH sketch hashes straight off
    /// the key column via its columnar `process_cols`; the `r` single
    /// samplers (whose interface is element slices) share ONE reusable
    /// AoS materialization of the block instead of each paying the
    /// default bridge's per-sampler allocation. Sampler-major order as in
    /// `process_batch`, so the state is identical.
    pub fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let mut ebuf = std::mem::take(&mut self.ebuf);
        ebuf.clear();
        ebuf.extend(block.iter());
        match &mut self.samplers {
            Samplers::Oracle(v) => {
                for s in v.iter_mut() {
                    api::StreamSummary::process_batch(s, &ebuf);
                }
            }
            Samplers::Precision(v) => {
                for s in v.iter_mut() {
                    api::StreamSummary::process_batch(s, &ebuf);
                }
            }
        }
        self.ebuf = ebuf;
        self.rhh.process_cols(&block.keys, &block.vals);
        self.processed += block.len() as u64;
    }

    /// Merge a sibling sampler built with the same config and seed. All
    /// substrates are linear, so merging is sampler-by-sampler merging
    /// plus an rHH sketch merge — the WOR k-tuple of the merged state
    /// equals the single-stream one (the samplers' private randomness is
    /// untouched by processing).
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        match (&mut self.samplers, &other.samplers) {
            (Samplers::Oracle(a), Samplers::Oracle(b)) if a.len() == b.len() => {
                for (x, y) in a.iter_mut().zip(b.iter()) {
                    x.merge(y);
                }
            }
            (Samplers::Precision(a), Samplers::Precision(b)) if a.len() == b.len() => {
                for (x, y) in a.iter_mut().zip(b.iter()) {
                    x.merge(y)?;
                }
            }
            _ => {
                return Err(Error::Incompatible(
                    "TV samplers differ in substrate kind or sampler count".into(),
                ))
            }
        }
        RhhSketch::merge(&mut self.rhh, &other.rhh)?;
        self.processed += other.processed;
        Ok(())
    }

    /// Produce the WOR k-tuple (paper Algorithm 1 "Produce sample").
    /// Returns fewer than `k` keys only on FAIL (probability 1/poly(n)).
    pub fn produce(mut self) -> Vec<u64> {
        let mut selected: Vec<u64> = Vec::with_capacity(self.cfg.k);
        let r = self.cfg.r;
        for i in 0..r {
            let out = match &mut self.samplers {
                Samplers::Oracle(v) => v[i].output(),
                Samplers::Precision(v) => v[i].output(),
            };
            let Some(out) = out else { continue };
            if selected.contains(&out) {
                continue;
            }
            selected.push(out);
            if selected.len() == self.cfg.k {
                return selected;
            }
            // subtract the selection from all later samplers using the
            // rHH estimate of its frequency
            let est = self.rhh.est(out);
            if est != 0.0 {
                let update = Element::new(out, -est);
                match &mut self.samplers {
                    Samplers::Oracle(v) => {
                        for s in v.iter_mut().skip(i + 1) {
                            s.process(&update);
                        }
                    }
                    Samplers::Precision(v) => {
                        for s in v.iter_mut().skip(i + 1) {
                            s.process(&update);
                        }
                    }
                }
            }
        }
        selected
    }

    /// Non-consuming variant of [`TvSampler::produce`]: walks a clone so
    /// the summary can keep streaming afterwards.
    pub fn produce_keys(&self) -> Vec<u64> {
        self.clone().produce()
    }

    /// Total memory words across samplers and the rHH sketch
    /// (Oracle excluded — it is an oracle, not a sketch).
    pub fn size_words(&self) -> usize {
        let inner = match &self.samplers {
            Samplers::Oracle(_) => 0,
            Samplers::Precision(v) => v.iter().map(|s| s.size_words()).sum(),
        };
        inner + self.rhh.size_words()
    }
}

impl api::StreamSummary for TvSampler {
    fn process(&mut self, e: &Element) {
        TvSampler::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        TvSampler::process_batch(self, batch)
    }

    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        TvSampler::process_block(self, block)
    }

    fn size_words(&self) -> usize {
        TvSampler::size_words(self)
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for TvSampler {
    fn fingerprint(&self) -> Fingerprint {
        let kind = match self.cfg.kind {
            SamplerKind::Oracle => 1u64,
            SamplerKind::Precision => 2u64,
        };
        Fingerprint::new("tv1pass")
            .with_f64(self.cfg.p)
            .with(self.cfg.k as u64)
            .with(self.cfg.r as u64)
            .with(self.cfg.seed)
            .with(kind)
            .with(self.cfg.rhh_rows as u64)
            .with(self.cfg.rhh_width as u64)
            .with(self.cfg.inner_rows as u64)
            .with(self.cfg.inner_width as u64)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        TvSampler::merge(self, other)
    }
}

impl api::Finalize for TvSampler {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        WorSampler::sample(self).expect("tv sample is infallible")
    }
}

impl api::MultiPass for TvSampler {}

impl WorSampler for TvSampler {
    /// The WOR k-tuple as a [`Sample`]: keys from Algorithm 1's produce
    /// step, frequencies estimated from the rHH sketch. `τ = 0` marks the
    /// sample as threshold-free (Algorithm 1 yields a tuple, not a
    /// bottom-k threshold).
    fn sample(&self) -> Result<Sample> {
        let entries = self
            .produce_keys()
            .into_iter()
            .map(|key| {
                let freq = self.rhh.est(key);
                SampleEntry { key, freq, transformed: freq }
            })
            .collect();
        Ok(Sample {
            entries,
            tau: 0.0,
            p: self.cfg.p,
            dist: BottomKDist::Exp,
            names: None,
        })
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(Error::Incompatible(format!(
                "cannot merge TV sampler with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "tv"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }
}

/// Wire payload: the full [`TvSamplerConfig`] (`p f64, k u64, r u64,
/// seed u64, kind u8 (1 = Oracle, 2 = Precision), rhh_rows u64,
/// rhh_width u64, inner_rows u64, inner_width u64`), `processed u64`,
/// the subtraction rHH sketch as a nested envelope, then the `r` single
/// samplers in order, each a nested envelope of the kind's type.
impl crate::api::Persist for TvSampler {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::wire::put_f64(&mut p, self.cfg.p);
        crate::codec::wire::put_usize(&mut p, self.cfg.k);
        crate::codec::wire::put_usize(&mut p, self.cfg.r);
        crate::codec::wire::put_u64(&mut p, self.cfg.seed);
        crate::codec::wire::put_u8(
            &mut p,
            match self.cfg.kind {
                SamplerKind::Oracle => 1,
                SamplerKind::Precision => 2,
            },
        );
        crate::codec::wire::put_usize(&mut p, self.cfg.rhh_rows);
        crate::codec::wire::put_usize(&mut p, self.cfg.rhh_width);
        crate::codec::wire::put_usize(&mut p, self.cfg.inner_rows);
        crate::codec::wire::put_usize(&mut p, self.cfg.inner_width);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.rhh);
        match &self.samplers {
            Samplers::Oracle(v) => {
                crate::codec::wire::put_usize(&mut p, v.len());
                for s in v {
                    crate::codec::put_nested(&mut p, s);
                }
            }
            Samplers::Precision(v) => {
                crate::codec::wire::put_usize(&mut p, v.len());
                for s in v {
                    crate::codec::put_nested(&mut p, s);
                }
            }
        }
        crate::codec::write_envelope(
            crate::codec::tag::TV,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        const SIZE_CAP: u64 = u32::MAX as u64;
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::TV))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let p = r.finite_f64("tv p")?;
        crate::codec::validate_p(p, "tv")?;
        let k = r.u64()?;
        let count = r.u64()?;
        let seed = r.u64()?;
        let kind = match r.u8()? {
            1 => SamplerKind::Oracle,
            2 => SamplerKind::Precision,
            v => return Err(Error::Codec(format!("unknown tv substrate byte {v}"))),
        };
        let rhh_rows = r.u64()?;
        let rhh_width = r.u64()?;
        let inner_rows = r.u64()?;
        let inner_width = r.u64()?;
        if k == 0
            || k > SIZE_CAP
            || count > SIZE_CAP
            || rhh_rows > SIZE_CAP
            || rhh_width > SIZE_CAP
            || inner_rows > SIZE_CAP
            || inner_width > SIZE_CAP
        {
            return Err(Error::Codec(format!(
                "tv config sizes out of range: k={k} r={count}"
            )));
        }
        let processed = r.u64()?;
        let rhh: CountSketch = crate::codec::read_nested(&mut r)?;
        let n = r.seq_len(8)?;
        if n as u64 != count {
            return Err(Error::Codec(format!(
                "tv sampler count {n} does not match configured r={count}"
            )));
        }
        let samplers = match kind {
            SamplerKind::Oracle => {
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(crate::codec::read_nested::<OracleSampler>(&mut r)?);
                }
                Samplers::Oracle(v)
            }
            SamplerKind::Precision => {
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(crate::codec::read_nested::<PrecisionSampler>(&mut r)?);
                }
                Samplers::Precision(v)
            }
        };
        r.finish("tv")?;
        let cfg = TvSamplerConfig {
            p,
            k: k as usize,
            r: count as usize,
            seed,
            kind,
            rhh_rows: rhh_rows as usize,
            rhh_width: rhh_width as usize,
            inner_rows: inner_rows as usize,
            inner_width: inner_width as usize,
        };
        let s = TvSampler { cfg, samplers, rhh, processed, ebuf: Vec::new() };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

/// Exact k-tuple *set* probabilities of perfect p-ppswor over a small
/// domain, by enumeration (used by the TV-distance bench): returns the
/// probability of each k-subset under successive WOR `|ν|^p` sampling.
pub fn ppswor_subset_probs(freqs: &[f64], p: f64, k: usize) -> std::collections::HashMap<Vec<u64>, f64> {
    let n = freqs.len();
    assert!(k <= n && n <= 12, "enumeration is exponential; keep n small");
    let weights: Vec<f64> = freqs.iter().map(|f| f.abs().powf(p)).collect();
    let mut probs: std::collections::HashMap<Vec<u64>, f64> = std::collections::HashMap::new();
    // DFS over ordered prefixes
    fn dfs(
        weights: &[f64],
        chosen: &mut Vec<u64>,
        used: u64,
        prob: f64,
        k: usize,
        probs: &mut std::collections::HashMap<Vec<u64>, f64>,
    ) {
        if chosen.len() == k {
            let mut key = chosen.clone();
            key.sort_unstable();
            *probs.entry(key).or_insert(0.0) += prob;
            return;
        }
        let total: f64 = weights
            .iter()
            .enumerate()
            .filter(|(i, _)| used & (1 << i) == 0)
            .map(|(_, w)| w)
            .sum();
        if total <= 0.0 {
            return;
        }
        for i in 0..weights.len() {
            if used & (1 << i) == 0 && weights[i] > 0.0 {
                chosen.push(i as u64);
                dfs(weights, chosen, used | (1 << i), prob * weights[i] / total, k, probs);
                chosen.pop();
            }
        }
    }
    let mut chosen = Vec::new();
    dfs(&weights, &mut chosen, 0, 1.0, k, &mut probs);
    probs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::unaggregate;

    fn run(freqs: &[f64], p: f64, k: usize, seed: u64, kind: SamplerKind) -> Vec<u64> {
        let cfg = TvSamplerConfig::new(p, k, freqs.len(), seed, kind).with_r(8 * k + 16);
        let mut tv = TvSampler::new(cfg);
        for e in unaggregate(freqs, 2, false, seed ^ 7) {
            tv.process(&e);
        }
        tv.produce()
    }

    #[test]
    fn produces_k_distinct_keys() {
        let freqs: Vec<f64> = (0..50).map(|i| 1.0 / (1.0 + i as f64)).collect();
        for kind in [SamplerKind::Oracle, SamplerKind::Precision] {
            let s = run(&freqs, 1.0, 8, 3, kind);
            assert_eq!(s.len(), 8, "kind={kind:?}");
            let set: std::collections::HashSet<u64> = s.iter().copied().collect();
            assert_eq!(set.len(), 8);
        }
    }

    #[test]
    fn oracle_tuple_distribution_close_to_ppswor() {
        // small domain: compare empirical subset frequencies with exact
        // successive-WOR probabilities
        let freqs = vec![4.0, 2.0, 1.0, 1.0];
        let p = 1.0;
        let k = 2;
        let exact = ppswor_subset_probs(&freqs, p, k);
        let trials = 4000;
        let mut counts: std::collections::HashMap<Vec<u64>, f64> = Default::default();
        for seed in 0..trials {
            let mut s = run(&freqs, p, k, seed as u64 ^ 0x7117, SamplerKind::Oracle);
            s.sort_unstable();
            *counts.entry(s).or_insert(0.0) += 1.0 / trials as f64;
        }
        let mut tv = 0.0;
        for (subset, &pr) in &exact {
            let emp = counts.get(subset).copied().unwrap_or(0.0);
            tv += (pr - emp).abs();
        }
        tv /= 2.0;
        assert!(tv < 0.05, "empirical TV distance {tv}");
    }

    #[test]
    fn subtraction_prevents_heavy_key_repeat() {
        // one huge key: without subtraction every sampler would return it;
        // with subtraction we still get k distinct keys
        let mut freqs = vec![1.0; 30];
        freqs[0] = 1000.0;
        let s = run(&freqs, 1.0, 10, 11, SamplerKind::Oracle);
        assert_eq!(s.len(), 10);
        assert_eq!(s[0], 0); // heavy key first
    }

    #[test]
    fn subset_probs_sum_to_one() {
        let freqs = vec![3.0, 2.0, 1.0];
        let probs = ppswor_subset_probs(&freqs, 1.0, 2);
        let sum: f64 = probs.values().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert_eq!(probs.len(), 3);
        // P({0,1}) should be the largest
        let p01 = probs[&vec![0u64, 1]];
        assert!(probs.values().all(|&v| v <= p01 + 1e-12));
    }

    #[test]
    fn fails_gracefully_when_domain_smaller_than_k() {
        let freqs = vec![1.0, 2.0];
        let s = run(&freqs, 1.0, 5, 3, SamplerKind::Oracle);
        assert_eq!(s.len(), 2); // all available keys, no panic
    }
}
