//! Perfect priority (sequential Poisson) sampling over aggregated data —
//! bottom-k with `D = U[0,1]` (paper §2.1). Mimics probability-
//! proportional-to-size with probabilities truncated at 1.

use super::Sample;
use crate::transform::BottomKTransform;

/// Perfect p-priority sample of `k` keys from the dense frequency vector.
pub fn perfect_priority(freqs: &[f64], p: f64, k: usize, seed: u64) -> Sample {
    let t = BottomKTransform::priority(seed, p);
    super::ppswor::sample_with_transform(freqs, k, &t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hashing::BottomKDist;
    use std::collections::HashSet;

    #[test]
    fn returns_k_distinct_keys() {
        let freqs: Vec<f64> = (0..50).map(|i| 1.0 / (i + 1) as f64).collect();
        let s = perfect_priority(&freqs, 1.0, 8, 3);
        assert_eq!(s.len(), 8);
        assert_eq!(s.dist, BottomKDist::Uniform);
        let keys: HashSet<u64> = s.keys().into_iter().collect();
        assert_eq!(keys.len(), 8);
    }

    #[test]
    fn inclusion_prob_is_truncated_pps() {
        // key with nu/tau >= 1 has inclusion probability exactly 1
        let s = perfect_priority(&[5.0, 1.0, 1.0, 1.0], 1.0, 2, 9);
        assert!(s.inclusion_prob(10.0 * s.tau) == 1.0);
        assert!((s.inclusion_prob(0.5 * s.tau) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn heavy_key_nearly_always_included() {
        let freqs = vec![1000.0, 1.0, 1.0, 1.0, 1.0];
        let mut hits = 0;
        for seed in 0..300 {
            let s = perfect_priority(&freqs, 1.0, 2, seed);
            if s.keys().contains(&0) {
                hits += 1;
            }
        }
        assert!(hits >= 299);
    }

    #[test]
    fn priority_and_ppswor_differ_in_randomization() {
        let freqs: Vec<f64> = (0..100).map(|i| (i + 1) as f64).collect();
        let a = perfect_priority(&freqs, 1.0, 10, 4);
        let b = super::super::ppswor::perfect_ppswor(&freqs, 1.0, 10, 4);
        // same seed, different D -> generally different samples
        assert_ne!(a.keys(), b.keys());
    }
}
