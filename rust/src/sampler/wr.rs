//! Perfect with-replacement (WR) ℓp sampling over aggregated data — the
//! baseline the paper contrasts WOR against (Fig 1, Table 3 "perfect WR").
//!
//! Draws `k` i.i.d. keys with `Pr[x] = |ν_x|^p / ‖ν‖_p^p`. Repetitions are
//! retained (that is the point: heavy keys eat the sample), and the
//! Hansen–Hurwitz / distinct-key estimators live in [`crate::estimate`].

use crate::util::rng::{sample_cumulative, Rng};

/// A with-replacement ℓp sample: `k` draws (with repetition) plus the
/// drawing probabilities needed for estimation.
#[derive(Clone, Debug)]
pub struct WrSample {
    /// The `k` drawn keys, in draw order (repeats possible).
    pub draws: Vec<u64>,
    /// Frequency of each drawn key.
    pub freqs: Vec<f64>,
    /// Drawing probability `q_x = |ν_x|^p / ‖ν‖_p^p` of each draw.
    pub probs: Vec<f64>,
    /// Number of draws `k`.
    pub k: usize,
    /// The power `p`.
    pub p: f64,
}

impl WrSample {
    /// Distinct keys with their (freq, prob), keeping first occurrence.
    pub fn distinct(&self) -> Vec<(u64, f64, f64)> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for i in 0..self.draws.len() {
            if seen.insert(self.draws[i]) {
                out.push((self.draws[i], self.freqs[i], self.probs[i]));
            }
        }
        out
    }

    /// Effective sample size: number of distinct keys (Fig 1 left/middle).
    pub fn effective_size(&self) -> usize {
        self.draws.iter().collect::<std::collections::HashSet<_>>().len()
    }
}

/// Draw a perfect WR ℓp sample of size `k` from the dense frequency
/// vector (zero frequencies are never drawn).
pub fn perfect_wr(freqs: &[f64], p: f64, k: usize, seed: u64) -> WrSample {
    let weights: Vec<f64> = freqs.iter().map(|f| f.abs().powf(p)).collect();
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "cannot sample from all-zero frequencies");
    let mut cum = Vec::with_capacity(weights.len());
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cum.push(acc);
    }
    let mut rng = Rng::new(seed ^ 0x3141_5926);
    let mut draws = Vec::with_capacity(k);
    let mut fs = Vec::with_capacity(k);
    let mut probs = Vec::with_capacity(k);
    for _ in 0..k {
        let x = sample_cumulative(&mut rng, &cum);
        draws.push(x as u64);
        fs.push(freqs[x]);
        probs.push(weights[x] / total);
    }
    WrSample { draws, freqs: fs, probs, k, p }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_k_with_correct_probs() {
        let freqs = vec![3.0, 1.0];
        let s = perfect_wr(&freqs, 2.0, 100, 1);
        assert_eq!(s.draws.len(), 100);
        for (i, &d) in s.draws.iter().enumerate() {
            let want = if d == 0 { 0.9 } else { 0.1 };
            assert!((s.probs[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn heavy_key_repeats_shrink_effective_size() {
        // Zipf[2]-like: the heavy key should appear many times
        let freqs: Vec<f64> = (0..1000).map(|i| ((i + 1) as f64).powf(-2.0)).collect();
        let s = perfect_wr(&freqs, 1.0, 100, 5);
        assert!(s.effective_size() < 80, "eff={}", s.effective_size());
        let zero_draws = s.draws.iter().filter(|&&d| d == 0).count();
        assert!(zero_draws > 30, "zero_draws={zero_draws}");
    }

    #[test]
    fn frequency_of_draws_matches_lp_weights() {
        let freqs = vec![2.0, 1.0, 1.0];
        let trials = 30_000;
        let s = perfect_wr(&freqs, 1.0, trials, 9);
        let frac0 = s.draws.iter().filter(|&&d| d == 0).count() as f64 / trials as f64;
        assert!((frac0 - 0.5).abs() < 0.01, "frac0={frac0}");
    }

    #[test]
    fn distinct_keeps_first_occurrence() {
        let freqs = vec![1.0, 1.0];
        let s = perfect_wr(&freqs, 1.0, 50, 3);
        let d = s.distinct();
        assert!(d.len() <= 2);
        assert!(!d.is_empty());
    }

    #[test]
    fn signed_frequencies_use_magnitudes() {
        let freqs = vec![-5.0, 1.0];
        let s = perfect_wr(&freqs, 2.0, 200, 7);
        let neg_draws = s.draws.iter().filter(|&&d| d == 0).count();
        assert!(neg_draws > 170); // 25/26 of the mass
    }
}
