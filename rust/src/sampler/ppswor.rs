//! Perfect p-ppswor sampling over **aggregated** data — the gold-standard
//! WOR baseline the paper compares against ("perfect WOR", Figs 1–2,
//! Table 3).
//!
//! Given the exact frequency vector, apply the bottom-k transform with the
//! shared hash-defined randomness and take the exact top-k of
//! `ν* = ν · r^{-1/p}` plus the exact threshold `τ = |ν*_(k+1)|`.
//! By §2.2 this is precisely a ppswor (successive WOR) sample by `ν^p`.

use super::{Sample, SampleEntry};
use crate::transform::BottomKTransform;

/// Perfect p-ppswor sample of `k` keys from the dense frequency vector
/// `freqs` (key `i` has frequency `freqs[i]`; zero entries never sampled).
pub fn perfect_ppswor(freqs: &[f64], p: f64, k: usize, seed: u64) -> Sample {
    let t = BottomKTransform::ppswor(seed, p);
    sample_with_transform(freqs, k, &t)
}

/// Perfect bottom-k sample under an arbitrary transform (shared by the
/// priority variant and by tests that need a fixed randomization).
pub fn sample_with_transform(freqs: &[f64], k: usize, t: &BottomKTransform) -> Sample {
    let mut scored: Vec<SampleEntry> = freqs
        .iter()
        .enumerate()
        .filter(|(_, &f)| f != 0.0)
        .map(|(x, &f)| {
            let key = x as u64;
            SampleEntry { key, freq: f, transformed: f * t.scale(key) }
        })
        .collect();
    scored.sort_by(|a, b| {
        b.transformed
            .abs()
            .partial_cmp(&a.transformed.abs())
            .unwrap()
    });
    let tau = if scored.len() > k {
        scored[k].transformed.abs()
    } else {
        0.0
    };
    scored.truncate(k);
    Sample { entries: scored, tau, p: t.p(), dist: t.dist(), names: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};
    use std::collections::HashSet;

    #[test]
    fn returns_k_distinct_keys_and_positive_tau() {
        let freqs: Vec<f64> = (0..100).map(|i| (i + 1) as f64).collect();
        let s = perfect_ppswor(&freqs, 1.0, 10, 7);
        assert_eq!(s.len(), 10);
        let keys: HashSet<u64> = s.keys().into_iter().collect();
        assert_eq!(keys.len(), 10);
        assert!(s.tau > 0.0);
        // entries sorted by decreasing transformed magnitude, all >= tau
        for w in s.entries.windows(2) {
            assert!(w[0].transformed.abs() >= w[1].transformed.abs());
        }
        assert!(s.entries.last().unwrap().transformed.abs() >= s.tau);
    }

    #[test]
    fn skips_zero_frequencies() {
        let freqs = vec![0.0, 5.0, 0.0, 3.0];
        let s = perfect_ppswor(&freqs, 1.0, 4, 3);
        let keys: HashSet<u64> = s.keys().into_iter().collect();
        assert_eq!(keys, HashSet::from([1, 3]));
        assert_eq!(s.tau, 0.0); // fewer than k+1 keys
    }

    #[test]
    fn first_key_marginal_is_pps() {
        // Pr[key 0 is top-1] = w0^p / sum(w^p) for ppswor
        let freqs = vec![3.0, 1.0, 1.0, 1.0];
        let p = 2.0;
        let want = 9.0 / 12.0;
        let trials = 5000;
        let mut hits = 0;
        for seed in 0..trials {
            let s = perfect_ppswor(&freqs, p, 1, seed as u64 ^ 0xFEED);
            if s.entries[0].key == 0 {
                hits += 1;
            }
        }
        let frac = hits as f64 / trials as f64;
        assert!((frac - want).abs() < 0.02, "frac={frac} want={want}");
    }

    #[test]
    fn without_replacement_second_draw_renormalizes() {
        // with weights (2,1,1) and p=1: Pr[sample = {0,1}] =
        // 2/4*1/2 + 1/4*2/3 = 5/12 (order-summed)
        let freqs = vec![2.0, 1.0, 1.0];
        let trials = 8000;
        let mut hits = 0;
        for seed in 0..trials {
            let s = perfect_ppswor(&freqs, 1.0, 2, seed as u64 ^ 0xABC);
            let keys: HashSet<u64> = s.keys().into_iter().collect();
            if keys == HashSet::from([0, 1]) {
                hits += 1;
            }
        }
        let frac = hits as f64 / trials as f64;
        assert!((frac - 5.0 / 12.0).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn negative_frequencies_sampled_by_magnitude() {
        let freqs = vec![-100.0, 1.0, 1.0];
        let mut hits = 0;
        for seed in 0..500 {
            let s = perfect_ppswor(&freqs, 2.0, 1, seed);
            if s.entries[0].key == 0 {
                hits += 1;
            }
        }
        assert!(hits > 480); // |−100|² dominates overwhelmingly
        let s = perfect_ppswor(&freqs, 2.0, 1, 0);
        assert_eq!(s.entries[0].freq, -100.0); // original sign preserved
    }

    #[test]
    fn property_sample_is_exact_topk_of_transformed() {
        run("ppswor = top-k of nu*", 25, |g: &mut Gen| {
            let n = g.usize_range(5, 200);
            let k = g.usize_range(1, n.min(20));
            let p = *g.choose(&[0.5, 1.0, 2.0]);
            let seed = g.u64_below(1 << 48);
            let freqs = g.freq_vector(n, 1.0, true);
            let t = BottomKTransform::ppswor(seed, p);
            let s = sample_with_transform(&freqs, k, &t);
            // brute-force top-k
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                let ta = (freqs[a] * t.scale(a as u64)).abs();
                let tb = (freqs[b] * t.scale(b as u64)).abs();
                tb.partial_cmp(&ta).unwrap()
            });
            let want: Vec<u64> = idx[..k].iter().map(|&i| i as u64).collect();
            assert_eq!(s.keys(), want);
        });
    }
}
