//! Perfect ℓp **single** samplers — the substrate Algorithm 1
//! ([`crate::sampler::tv1pass`]) consumes. The paper uses the
//! Jayaram–Woodruff sketch [50]; per DESIGN.md §6 we provide two linear
//! implementations with the interface Algorithm 1 needs
//! (process / subtraction-update / output):
//!
//! - [`OracleSampler`] — maintains the exact (linear) frequency vector and
//!   draws from `|x_i|^p / ‖x‖_p^p` with sampler-private randomness. Its
//!   per-draw TV distance is **0**, so measured k-tuple TV isolates the
//!   paper's subtraction machinery (the contribution under test).
//! - [`PrecisionSampler`] — an honest sketch-based sampler in the
//!   precision-sampling tradition [6]: sampler-private uniform scaling
//!   `x_i / u_i^{1/p}`, a CountSketch of the scaled stream, candidate
//!   tracking, and max-recovery. Memory `O(polylog)`; per-draw
//!   distribution approaches `μ` as the sketch grows.
//!
//! Both are *linear*: feeding the update `(i, -R(i))` subtracts key `i`'s
//! (estimated) mass, exactly what Algorithm 1's "subtract prior
//! selections" step requires.

use crate::api::{self, Fingerprint};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::sketch::countsketch::CountSketch;
use crate::sketch::{RhhSketch, SketchParams};
use crate::util::hashing::hash_unit_open;
use crate::util::rng::Rng;
use std::collections::{BTreeMap, HashMap};

/// Common interface of perfect ℓp single samplers (one WR draw each).
pub trait SingleLpSampler {
    /// Feed a stream update.
    fn process(&mut self, e: &Element);

    /// Draw/return the sampler's output index, or `None` (FAIL).
    fn output(&mut self) -> Option<u64>;
}

/// Exact-frequency oracle sampler (TV distance 0 per draw).
///
/// Frequencies live in a `BTreeMap` so [`SingleLpSampler::output`] walks
/// keys in a deterministic order: with a `HashMap`, the per-instance
/// random iteration order made the drawn key depend on which *instance*
/// held the (identical) frequencies — a seed-red flake in every test that
/// compares two samplers fed the same stream.
#[derive(Clone, Debug)]
pub struct OracleSampler {
    p: f64,
    seed: u64,
    freqs: BTreeMap<u64, f64>,
    rng: Rng,
    processed: u64,
}

impl OracleSampler {
    /// Sampler with private randomness `seed`.
    pub fn new(p: f64, seed: u64) -> Self {
        OracleSampler {
            p,
            seed,
            freqs: BTreeMap::new(),
            rng: Rng::new(seed ^ 0x0AC1E),
            processed: 0,
        }
    }

    /// Merge a sibling sampler (exact frequency maps add; the private
    /// draw randomness is untouched by processing, so the merged sampler
    /// draws exactly as a single-stream one would).
    pub fn merge(&mut self, other: &Self) {
        for (&k, &v) in &other.freqs {
            *self.freqs.entry(k).or_insert(0.0) += v;
        }
        self.freqs.retain(|_, f| f.abs() >= 1e-12);
        self.processed += other.processed;
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }
}

impl SingleLpSampler for OracleSampler {
    fn process(&mut self, e: &Element) {
        let f = self.freqs.entry(e.key).or_insert(0.0);
        *f += e.val;
        if f.abs() < 1e-12 {
            self.freqs.remove(&e.key);
        }
        self.processed += 1;
    }

    fn output(&mut self) -> Option<u64> {
        let total: f64 = self.freqs.values().map(|f| f.abs().powf(self.p)).sum();
        if total <= 0.0 {
            return None;
        }
        let mut t = self.rng.uniform() * total;
        for (&k, &f) in &self.freqs {
            t -= f.abs().powf(self.p);
            if t <= 0.0 {
                return Some(k);
            }
        }
        self.freqs.keys().next().copied()
    }
}

/// Sketch-based precision sampler (Andoni–Krauthgamer–Onak style).
#[derive(Clone, Debug)]
pub struct PrecisionSampler {
    p: f64,
    seed: u64,
    sketch: CountSketch,
    /// keys seen (candidate recovery set; bounded)
    candidates: HashMap<u64, ()>,
    cand_cap: usize,
    processed: u64,
    /// Reusable scaled-element buffer for the batch path (§Perf L3-6).
    tbuf: Vec<Element>,
}

impl PrecisionSampler {
    /// Sampler with private scaling seed and sketch shape.
    pub fn new(p: f64, seed: u64, rows: usize, width: usize) -> Self {
        PrecisionSampler {
            p,
            seed,
            sketch: CountSketch::new(SketchParams::new(rows, width, seed ^ 0x9C13)),
            candidates: HashMap::new(),
            cand_cap: 4 * width,
            processed: 0,
            tbuf: Vec::new(),
        }
    }

    /// Merge a sibling sampler sharing seed and sketch shape: the scaled
    /// sketches add (linearity) and the candidate sets union.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        if self.seed != other.seed || self.p != other.p {
            return Err(Error::Incompatible(
                "precision samplers have different private seeds".into(),
            ));
        }
        RhhSketch::merge(&mut self.sketch, &other.sketch)?;
        for &k in other.candidates.keys() {
            self.candidates.insert(k, ());
        }
        if self.candidates.len() > 2 * self.cand_cap {
            let mut scored: Vec<(u64, f64)> = self
                .candidates
                .keys()
                .map(|&k| (k, self.sketch.est(k).abs()))
                .collect();
            // rank_desc: truncation must not inherit HashMap order
            scored.sort_by(crate::util::stats::rank_desc);
            scored.truncate(self.cand_cap);
            self.candidates = scored.into_iter().map(|(k, _)| (k, ())).collect();
        }
        self.processed += other.processed;
        Ok(())
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Private per-key scale `u_i^{-1/p}` with `u_i ~ U(0,1]`.
    #[inline]
    fn scale(&self, key: u64) -> f64 {
        hash_unit_open(self.seed ^ 0x5CA1E, key).powf(-1.0 / self.p)
    }

    /// Memory words.
    pub fn size_words(&self) -> usize {
        self.sketch.size_words() + self.cand_cap
    }
}

impl SingleLpSampler for PrecisionSampler {
    fn process(&mut self, e: &Element) {
        self.processed += 1;
        let scaled = Element::new(e.key, e.val * self.scale(e.key));
        self.sketch.process(&scaled);
        if self.candidates.len() < self.cand_cap {
            self.candidates.insert(e.key, ());
        } else if !self.candidates.contains_key(&e.key) {
            // reservoir-ish: replace only when the key's scaled estimate
            // beats the weakest candidate (cheap heuristic refresh)
            self.candidates.insert(e.key, ());
            if self.candidates.len() > 2 * self.cand_cap {
                let mut scored: Vec<(u64, f64)> = self
                    .candidates
                    .keys()
                    .map(|&k| (k, self.sketch.est(k).abs()))
                    .collect();
                // rank_desc: truncation must not inherit HashMap order
                scored.sort_by(crate::util::stats::rank_desc);
                scored.truncate(self.cand_cap);
                self.candidates = scored.into_iter().map(|(k, _)| (k, ())).collect();
            }
        }
    }

    fn output(&mut self) -> Option<u64> {
        // the max of the scaled vector is the sample (precision sampling);
        // recover it as the candidate with the largest estimate. The
        // comparator is a total order over (estimate, key) so estimate
        // ties cannot leak the candidate map's iteration order.
        self.candidates
            .keys()
            .map(|&k| (k, self.sketch.est(k).abs()))
            .filter(|(_, v)| *v > 0.0)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then_with(|| a.0.cmp(&b.0)))
            .map(|(k, _)| k)
    }
}

impl api::StreamSummary for OracleSampler {
    fn process(&mut self, e: &Element) {
        SingleLpSampler::process(self, e)
    }

    /// Batch path (§Perf L3-6): identical per-element aggregation with the
    /// processed counter hoisted to once per batch.
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            let f = self.freqs.entry(e.key).or_insert(0.0);
            *f += e.val;
            if f.abs() < 1e-12 {
                self.freqs.remove(&e.key);
            }
        }
        self.processed += batch.len() as u64;
    }

    /// SoA block path (§Perf L3-7): the same aggregation straight off the
    /// dense columns, skipping the default bridge's AoS materialization.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        for (&k, &v) in block.keys.iter().zip(&block.vals) {
            let f = self.freqs.entry(k).or_insert(0.0);
            *f += v;
            if f.abs() < 1e-12 {
                self.freqs.remove(&k);
            }
        }
        self.processed += block.len() as u64;
    }

    fn size_words(&self) -> usize {
        2 * self.freqs.len()
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for OracleSampler {
    fn fingerprint(&self) -> Fingerprint {
        Fingerprint::new("oracle-lp").with_f64(self.p).with(self.seed)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        OracleSampler::merge(self, other);
        Ok(())
    }
}

impl api::Finalize for OracleSampler {
    type Output = Option<u64>;

    /// The sampler's output index (drawn on a clone — finalization does
    /// not advance the private randomness of the live summary).
    fn finalize(&self) -> Option<u64> {
        self.clone().output()
    }
}

impl api::StreamSummary for PrecisionSampler {
    fn process(&mut self, e: &Element) {
        SingleLpSampler::process(self, e)
    }

    /// Batch path (§Perf L3-6). When candidate truncation cannot fire
    /// within this batch, the privately-scaled elements go through the
    /// CountSketch columnar update in one call (bit-identical tables) and
    /// candidate bookkeeping reduces to plain inserts (the scalar branch
    /// structure is insert in every reachable case). Otherwise fall back
    /// to the literal scalar loop, so mid-batch truncation scores never
    /// see sketch updates from *future* elements — batch ≡ scalar always.
    fn process_batch(&mut self, batch: &[Element]) {
        if self.candidates.len() + batch.len() <= 2 * self.cand_cap {
            let mut scaled = std::mem::take(&mut self.tbuf);
            scaled.clear();
            scaled.extend(
                batch
                    .iter()
                    .map(|e| Element::new(e.key, e.val * self.scale(e.key))),
            );
            self.sketch.process_batch(&scaled);
            self.tbuf = scaled;
            for e in batch {
                self.candidates.insert(e.key, ());
            }
            self.processed += batch.len() as u64;
        } else {
            for e in batch {
                SingleLpSampler::process(self, e);
            }
        }
    }

    fn size_words(&self) -> usize {
        PrecisionSampler::size_words(self)
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for PrecisionSampler {
    fn fingerprint(&self) -> Fingerprint {
        let params = *self.sketch.params();
        Fingerprint::new("precision-lp")
            .with_f64(self.p)
            .with(self.seed)
            .with(params.rows as u64)
            .with(params.width as u64)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        PrecisionSampler::merge(self, other)
    }
}

impl api::Finalize for PrecisionSampler {
    type Output = Option<u64>;

    fn finalize(&self) -> Option<u64> {
        self.clone().output()
    }
}

/// Wire payload: `p f64, seed u64, processed u64, rng u64×4` (the
/// private draw state — a restored sampler continues the same random
/// sequence), then the exact frequency map (canonical — `BTreeMap`
/// iteration is already key-sorted) as `n u64, n × (key u64, freq f64)`.
impl crate::api::Persist for OracleSampler {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(64 + 16 * self.freqs.len());
        crate::codec::wire::put_f64(&mut p, self.p);
        crate::codec::wire::put_u64(&mut p, self.seed);
        crate::codec::wire::put_u64(&mut p, self.processed);
        for s in self.rng.state() {
            crate::codec::wire::put_u64(&mut p, s);
        }
        crate::codec::wire::put_usize(&mut p, self.freqs.len());
        for (&k, &f) in &self.freqs {
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_f64(&mut p, f);
        }
        crate::codec::write_envelope(
            crate::codec::tag::ORACLE_LP,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::ORACLE_LP))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let p = r.finite_f64("oracle p")?;
        crate::codec::validate_p(p, "oracle-lp")?;
        let seed = r.u64()?;
        let processed = r.u64()?;
        let rng = Rng::from_state([r.u64()?, r.u64()?, r.u64()?, r.u64()?]);
        let n = r.seq_len(16)?;
        let mut freqs = BTreeMap::new();
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|q| q >= key) {
                return Err(Error::Codec(
                    "oracle frequencies are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            // non-finite frequencies would poison later comparators
            freqs.insert(key, r.finite_f64("oracle frequency")?);
        }
        r.finish("oracle-lp")?;
        let s = OracleSampler { p, seed, freqs, rng, processed };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

/// Wire payload: `p f64, seed u64, cand_cap u64, processed u64`, the
/// privately-scaled CountSketch as a nested envelope, then the candidate
/// key set (canonical — sorted) as `n u64, n × key u64`.
impl crate::api::Persist for PrecisionSampler {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::wire::put_f64(&mut p, self.p);
        crate::codec::wire::put_u64(&mut p, self.seed);
        crate::codec::wire::put_usize(&mut p, self.cand_cap);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.sketch);
        let mut keys: Vec<u64> = self.candidates.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            crate::codec::wire::put_u64(&mut p, k);
        }
        crate::codec::write_envelope(
            crate::codec::tag::PRECISION_LP,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::PRECISION_LP))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let p = r.finite_f64("precision p")?;
        crate::codec::validate_p(p, "precision-lp")?;
        let seed = r.u64()?;
        let cand_cap = r.u64()?;
        if cand_cap > u32::MAX as u64 {
            return Err(Error::Codec(format!(
                "precision candidate capacity out of range: {cand_cap}"
            )));
        }
        let processed = r.u64()?;
        let sketch: CountSketch = crate::codec::read_nested(&mut r)?;
        // the constructor invariant is cand_cap == 4 × sketch width; a
        // payload claiming otherwise describes no constructible sampler
        if cand_cap != 4 * sketch.params().width as u64 {
            return Err(Error::Codec(format!(
                "precision candidate capacity {cand_cap} does not match 4 x sketch width {}",
                sketch.params().width
            )));
        }
        let n = r.seq_len(8)?;
        if n as u64 > 2 * cand_cap {
            return Err(Error::Codec(format!(
                "precision candidate set of {n} exceeds twice the capacity {cand_cap}"
            )));
        }
        let mut candidates = HashMap::with_capacity(n);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|q| q >= key) {
                return Err(Error::Codec(
                    "precision candidates are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            candidates.insert(key, ());
        }
        r.finish("precision-lp")?;
        let s = PrecisionSampler {
            p,
            seed,
            sketch,
            candidates,
            cand_cap: cand_cap as usize,
            processed,
            tbuf: Vec::new(),
        };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed<S: SingleLpSampler>(s: &mut S, freqs: &[f64]) {
        for (i, &f) in freqs.iter().enumerate() {
            if f != 0.0 {
                s.process(&Element::new(i as u64, f));
            }
        }
    }

    #[test]
    fn oracle_draws_proportional_to_lp() {
        let freqs = vec![2.0, 1.0, 1.0];
        let p = 2.0; // weights 4:1:1
        let mut hits = 0;
        for seed in 0..6000 {
            let mut s = OracleSampler::new(p, seed);
            feed(&mut s, &freqs);
            if s.output() == Some(0) {
                hits += 1;
            }
        }
        let frac = hits as f64 / 6000.0;
        assert!((frac - 4.0 / 6.0).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn oracle_subtraction_removes_key() {
        let mut s = OracleSampler::new(1.0, 3);
        feed(&mut s, &[100.0, 1.0]);
        // subtract key 0's mass entirely
        s.process(&Element::new(0, -100.0));
        for _ in 0..20 {
            assert_eq!(s.output(), Some(1));
        }
    }

    #[test]
    fn oracle_fails_on_empty_vector() {
        let mut s = OracleSampler::new(1.0, 5);
        assert_eq!(s.output(), None);
        s.process(&Element::new(7, 2.0));
        s.process(&Element::new(7, -2.0));
        assert_eq!(s.output(), None);
    }

    #[test]
    fn precision_sampler_heavy_key_usually_wins_overall() {
        // marginal over seeds should favor heavy keys roughly by lp weight
        let freqs = vec![8.0, 1.0, 1.0, 1.0, 1.0]; // p=1: 8/12 for key 0
        let mut hits = 0;
        let trials = 600;
        for seed in 0..trials {
            let mut s = PrecisionSampler::new(1.0, seed as u64 ^ 0xF00D, 5, 256);
            feed(&mut s, &freqs);
            if s.output() == Some(0) {
                hits += 1;
            }
        }
        let frac = hits as f64 / trials as f64;
        assert!(
            (frac - 8.0 / 12.0).abs() < 0.12,
            "frac={frac}, want ~0.67"
        );
    }

    #[test]
    fn precision_sampler_linear_subtraction() {
        let mut s = PrecisionSampler::new(1.0, 99, 5, 256);
        feed(&mut s, &[50.0, 3.0, 2.0]);
        let first = s.output();
        assert!(first.is_some());
        if first == Some(0) {
            s.process(&Element::new(0, -50.0));
            let second = s.output();
            assert!(second == Some(1) || second == Some(2), "second={second:?}");
        }
    }

    #[test]
    fn independent_seeds_decorrelate_outputs() {
        // near-uniform vector: different sampler seeds pick different keys
        let freqs = vec![1.0; 64];
        let mut outputs = std::collections::HashSet::new();
        for seed in 0..40 {
            let mut s = PrecisionSampler::new(1.0, seed, 5, 512);
            feed(&mut s, &freqs);
            if let Some(o) = s.output() {
                outputs.insert(o);
            }
        }
        assert!(outputs.len() > 15, "only {} distinct outputs", outputs.len());
    }
}
