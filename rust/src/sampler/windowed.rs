//! Sliding-window WORp: WOR ℓp sampling over the **recent** stream — the
//! time-decay variant of 1-pass WORp built on
//! [`crate::sketch::window::WindowedCountSketch`] (paper Conclusion).
//!
//! The bottom-k transform randomization `r_x` is time-invariant (the same
//! hash), so windowed samples taken at different times are *coordinated*:
//! a key's rank moves only when its windowed frequency moves — the LSH
//! property the paper highlights for sample stability.

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint, WorSampler};
use crate::data::Element;
use crate::error::Result;
use crate::sketch::window::WindowedCountSketch;
use crate::sketch::SketchParams;
use crate::transform::BottomKTransform;
use std::collections::HashMap;

/// Windowed 1-pass WORp sampler.
#[derive(Clone, Debug)]
pub struct WindowedWorp {
    cfg: SamplerConfig,
    transform: BottomKTransform,
    sketch: WindowedCountSketch,
    /// Candidate keys → last touch time.
    candidates: HashMap<u64, u64>,
    cand_cap: usize,
    window: u64,
    processed: u64,
    /// Reusable transformed-element buffer for the batch path (§Perf L3-6).
    tbuf: Vec<Element>,
    /// Reusable transformed-value column for the SoA block path (§Perf L3-7).
    vbuf: Vec<f64>,
}

impl WindowedWorp {
    /// Sampler over a sliding window of `window` time units split into
    /// `buckets` sub-sketches. Only the CountSketch (q = 2) path supports
    /// windows (subtraction on expiry needs linearity).
    pub fn new(cfg: SamplerConfig, window: u64, buckets: usize) -> Self {
        assert!(cfg.q >= 2.0, "windowed WORp requires the CountSketch (q=2) path");
        let params = SketchParams::new(
            cfg.resolved_rows(),
            cfg.resolved_width_one_pass(),
            cfg.seed ^ 0x3AB5,
        );
        let transform = cfg.transform();
        let cand_cap = 16 * (cfg.k + 1);
        WindowedWorp {
            cfg,
            transform,
            sketch: WindowedCountSketch::new(params, window, buckets),
            candidates: HashMap::new(),
            cand_cap,
            window,
            processed: 0,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    /// Elements processed (all time, not only the current window).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Process an element stamped with non-decreasing time `t`.
    pub fn process_at(&mut self, e: &Element, t: u64) {
        let te = self.transform.apply(e);
        self.sketch.process_at(&te, t);
        self.candidates.insert(e.key, t);
        self.processed += 1;
        if self.candidates.len() > 2 * self.cand_cap {
            self.prune(t);
        }
    }

    /// Merge a sibling windowed sampler whose timestamps come from the
    /// same clock (same seed / shape / window).
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        self.sketch.merge(&other.sketch)?;
        for (&k, &t) in &other.candidates {
            let slot = self.candidates.entry(k).or_insert(t);
            *slot = (*slot).max(t);
        }
        self.processed += other.processed;
        let now = self.sketch.now();
        if self.candidates.len() > 2 * self.cand_cap {
            self.prune(now);
        }
        Ok(())
    }

    /// Drop candidates last touched outside the window; if still over
    /// capacity keep the most recently touched.
    fn prune(&mut self, now: u64) {
        let cutoff = now.saturating_sub(self.window);
        self.candidates.retain(|_, &mut t| t >= cutoff);
        if self.candidates.len() > 2 * self.cand_cap {
            let mut v: Vec<(u64, u64)> = self.candidates.iter().map(|(&k, &t)| (k, t)).collect();
            // key-tiebroken: many keys share a touch time, and truncation
            // must not depend on HashMap iteration order
            v.sort_by_key(|&(k, t)| (std::cmp::Reverse(t), k));
            v.truncate(self.cand_cap);
            self.candidates = v.into_iter().collect();
        }
    }

    /// The sample over the current window.
    pub fn sample(&self) -> Sample {
        let cutoff = self.sketch.now().saturating_sub(self.window);
        let mut scored: Vec<(u64, f64)> = self
            .candidates
            .iter()
            .filter(|(_, &t)| t >= cutoff)
            .map(|(&key, _)| (key, self.sketch.est(key)))
            .filter(|(_, e)| e.abs() > 1e-12)
            .collect();
        scored.sort_by(|a, b| {
            crate::util::stats::rank_desc(&(a.0, a.1.abs()), &(b.0, b.1.abs()))
        });
        let k = self.cfg.k;
        let tau = if scored.len() > k { scored[k].1.abs() } else { 0.0 };
        let entries = scored
            .into_iter()
            .take(k)
            .map(|(key, est)| SampleEntry {
                key,
                freq: self.transform.invert(key, est),
                transformed: est,
            })
            .collect();
        Sample { entries, tau, p: self.cfg.p, dist: self.transform.dist(), names: None }
    }
}

impl api::StreamSummary for WindowedWorp {
    /// Untimestamped path: each element advances an implicit clock by one
    /// tick, so "window" means "the last `window` elements". Use
    /// [`WindowedWorp::process_at`] for real event time.
    fn process(&mut self, e: &Element) {
        let t = self.sketch.now().saturating_add(1);
        self.process_at(e, t);
    }

    /// Micro-batch path for the implicit clock (§Perf L3-6): transform
    /// into the reusable buffer, one run-chunked columnar pass through the
    /// windowed sketch (bit-identical tables), candidate touch-times
    /// stamped arithmetically, and the candidate-prune check amortized to
    /// once per batch. Deferred pruning uses the end-of-batch clock, so
    /// when the tracker overflows mid-batch the retained *candidate set*
    /// can differ from the per-element path (later cutoff, different
    /// truncation population) — expired keys are filtered out of
    /// [`WindowedWorp::sample`] by timestamp either way, so only the
    /// over-capacity truncation choice is timing-dependent, the same
    /// deliberate trade the 1-pass sampler's deferred shrink makes.
    fn process_batch(&mut self, batch: &[Element]) {
        let t0 = self.sketch.now();
        let mut tbuf = std::mem::take(&mut self.tbuf);
        tbuf.clear();
        tbuf.extend(batch.iter().map(|e| self.transform.apply(e)));
        self.sketch.process_batch_ticks(&tbuf);
        self.tbuf = tbuf;
        for (i, e) in batch.iter().enumerate() {
            self.candidates.insert(e.key, t0 + 1 + i as u64);
        }
        self.processed += batch.len() as u64;
        if self.candidates.len() > 2 * self.cand_cap {
            let now = self.sketch.now();
            self.prune(now);
        }
    }

    /// SoA block path for the implicit clock (§Perf L3-7): the transform
    /// rewrites only the value column (reusable `vbuf`), the windowed
    /// sketch takes `(keys, vbuf)` through its run-chunked columnar
    /// `process_cols_ticks` (bit-identical tables), and candidate
    /// touch-times stamp straight off the key column — same deferred
    /// prune semantics as `process_batch`.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let t0 = self.sketch.now();
        let mut vbuf = std::mem::take(&mut self.vbuf);
        self.transform.apply_cols(&block.keys, &block.vals, &mut vbuf);
        self.sketch.process_cols_ticks(&block.keys, &vbuf);
        self.vbuf = vbuf;
        for (i, &k) in block.keys.iter().enumerate() {
            self.candidates.insert(k, t0 + 1 + i as u64);
        }
        self.processed += block.len() as u64;
        if self.candidates.len() > 2 * self.cand_cap {
            let now = self.sketch.now();
            self.prune(now);
        }
    }

    fn size_words(&self) -> usize {
        self.sketch.size_words() + 2 * self.candidates.len()
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for WindowedWorp {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("windowed", &self.cfg)
            .with(self.window)
            .with(self.sketch.span())
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        WindowedWorp::merge(self, other)
    }
}

impl api::Finalize for WindowedWorp {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

impl api::MultiPass for WindowedWorp {}

impl WorSampler for WindowedWorp {
    fn sample(&self) -> Result<Sample> {
        Ok(WindowedWorp::sample(self))
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(crate::error::Error::Incompatible(format!(
                "cannot merge windowed WORp with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "windowed"
    }

    /// The untimestamped [`api::StreamSummary::process`] path ticks a
    /// clock per processed element; sharding would give every worker its
    /// own clock and make the merged window cover skewed spans of the
    /// stream, so the coordinator must run this sampler on one worker.
    fn parallel_safe(&self) -> bool {
        false
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }
}

/// Wire payload: the shared [`SamplerConfig`] fragment, `window u64,
/// processed u64`, the windowed sketch as a nested envelope, then the
/// candidate tracker (canonical — sorted by key) as `n u64,
/// n × (key u64, last_touch u64)`.
impl crate::api::Persist for WindowedWorp {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u64(&mut p, self.window);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.sketch);
        let mut keys: Vec<u64> = self.candidates.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_u64(&mut p, self.candidates[&k]);
        }
        crate::codec::write_envelope(
            crate::codec::tag::WINDOWED_WORP,
            api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WINDOWED_WORP))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        if cfg.q < 2.0 {
            return Err(crate::error::Error::Codec(
                "windowed WORp requires the CountSketch (q=2) path".into(),
            ));
        }
        let window = r.u64()?;
        let processed = r.u64()?;
        let sketch: WindowedCountSketch = crate::codec::read_nested(&mut r)?;
        if sketch.window() != window {
            return Err(crate::error::Error::Codec(format!(
                "windowed sampler window {window} disagrees with its sketch ({})",
                sketch.window()
            )));
        }
        let cand_cap = 16 * (cfg.k + 1);
        let n = r.seq_len(16)?;
        if n > 2 * cand_cap {
            return Err(crate::error::Error::Codec(format!(
                "windowed candidate set of {n} exceeds twice the capacity {cand_cap}"
            )));
        }
        let mut candidates = HashMap::with_capacity(n);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|p| p >= key) {
                return Err(crate::error::Error::Codec(
                    "windowed candidates are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            candidates.insert(key, r.u64()?);
        }
        r.finish("windowed")?;
        let transform = cfg.transform();
        let s = WindowedWorp {
            cfg,
            transform,
            sketch,
            candidates,
            cand_cap,
            window,
            processed,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        };
        crate::codec::check_fingerprint(
            env.fingerprint,
            api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn cfg(k: usize) -> SamplerConfig {
        SamplerConfig::new(1.0, k)
            .with_seed(5)
            .with_domain(1000)
            .with_sketch_shape(7, 1024)
    }

    #[test]
    fn sample_tracks_the_window() {
        let mut w = WindowedWorp::new(cfg(5), 100, 10);
        // era 1: keys 0..10 heavy
        for t in 0..100u64 {
            for key in 0..10u64 {
                w.process_at(&Element::new(key, 10.0), t);
            }
        }
        let s1: HashSet<u64> = w.sample().keys().into_iter().collect();
        assert!(s1.iter().all(|&k| k < 10));
        // era 2: keys 100..110 heavy; era-1 mass expires
        for t in 300..400u64 {
            for key in 100..110u64 {
                w.process_at(&Element::new(key, 10.0), t);
            }
        }
        let s2: HashSet<u64> = w.sample().keys().into_iter().collect();
        assert!(s2.iter().all(|&k| (100..110).contains(&k)), "{s2:?}");
    }

    #[test]
    fn windowed_samples_are_coordinated_over_time() {
        // stationary stream: consecutive window samples barely change
        let mut w = WindowedWorp::new(cfg(10), 200, 10);
        let mut rng = crate::util::rng::Rng::new(9);
        let mut prev: Option<HashSet<u64>> = None;
        let mut min_overlap = usize::MAX;
        for t in 0..2000u64 {
            // zipf-ish stationary keys
            let bound = 1 + rng.below(100);
            let key = rng.below(bound);
            w.process_at(&Element::new(key, 1.0), t);
            if t >= 400 && t % 200 == 0 {
                let s: HashSet<u64> = w.sample().keys().into_iter().collect();
                if let Some(p) = &prev {
                    min_overlap = min_overlap.min(s.intersection(p).count());
                }
                prev = Some(s);
            }
        }
        assert!(min_overlap >= 6, "coordinated windows: overlap {min_overlap}/10");
    }

    #[test]
    fn freq_estimates_reflect_windowed_counts() {
        let mut w = WindowedWorp::new(cfg(3), 50, 5);
        for t in 0..40u64 {
            w.process_at(&Element::new(1, 2.0), t);
        }
        let s = w.sample();
        let e = s.entries.iter().find(|e| e.key == 1).expect("key 1 sampled");
        assert!((e.freq - 80.0).abs() < 1.0, "freq {}", e.freq);
    }

    #[test]
    #[should_panic(expected = "q=2")]
    fn countmin_path_rejected() {
        let mut c = cfg(3);
        c.q = 1.0;
        WindowedWorp::new(c, 10, 2);
    }
}
