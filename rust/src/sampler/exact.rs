//! `ExactWor` — the exact streaming WOR baseline: aggregate the stream
//! into the exact frequency map, then take the perfect bottom-k sample
//! under the shared hash-defined randomization (paper §2.2, the "perfect
//! WOR" of Figs 1–2 / Table 3 — here as a composable `StreamSummary`
//! rather than a free function over a dense vector).
//!
//! Memory is linear in the number of distinct keys — this is the
//! gold-standard precision baseline the sketched samplers are compared
//! against, not a small-space method.

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint, WorSampler};
use crate::data::Element;
use crate::error::Result;
use crate::transform::BottomKTransform;
use std::collections::HashMap;

/// Exact streaming p-ppswor / p-priority sampler (linear memory).
#[derive(Clone, Debug)]
pub struct ExactWor {
    cfg: SamplerConfig,
    transform: BottomKTransform,
    freqs: HashMap<u64, f64>,
    processed: u64,
}

impl ExactWor {
    /// Build from a sampler config (only `p`, `k`, `seed` and `dist`
    /// matter; sketch parameters are ignored).
    pub fn new(cfg: SamplerConfig) -> Self {
        let transform = cfg.transform();
        ExactWor { cfg, transform, freqs: HashMap::new(), processed: 0 }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    /// Number of distinct keys currently tracked.
    pub fn distinct_keys(&self) -> usize {
        self.freqs.len()
    }

    /// Process one element (exact aggregation).
    #[inline]
    pub fn process(&mut self, e: &Element) {
        *self.freqs.entry(e.key).or_insert(0.0) += e.val;
        self.processed += 1;
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Summary size in words (2 per tracked key).
    pub fn size_words(&self) -> usize {
        2 * self.freqs.len()
    }

    /// Merge a sibling summary (same seed / config): exact frequency maps
    /// add; keys whose net frequency cancels to ~0 are dropped.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        for (&k, &v) in &other.freqs {
            *self.freqs.entry(k).or_insert(0.0) += v;
        }
        self.freqs.retain(|_, f| f.abs() > 1e-12);
        self.processed += other.processed;
        Ok(())
    }

    /// The exact bottom-k sample of the aggregated frequencies.
    pub fn sample(&self) -> Sample {
        let t = &self.transform;
        let mut scored: Vec<SampleEntry> = self
            .freqs
            .iter()
            .filter(|(_, &f)| f.abs() > 1e-12)
            .map(|(&key, &freq)| SampleEntry {
                key,
                freq,
                transformed: freq * t.scale(key),
            })
            .collect();
        scored.sort_by(|a, b| {
            b.transformed
                .abs()
                .partial_cmp(&a.transformed.abs())
                .unwrap()
                .then_with(|| a.key.cmp(&b.key))
        });
        let k = self.cfg.k;
        let tau = if scored.len() > k {
            scored[k].transformed.abs()
        } else {
            0.0
        };
        scored.truncate(k);
        Sample { entries: scored, tau, p: self.cfg.p, dist: t.dist(), names: None }
    }
}

impl api::StreamSummary for ExactWor {
    fn process(&mut self, e: &Element) {
        ExactWor::process(self, e)
    }

    /// Micro-batch path (§Perf L3-6): the per-element processed counter is
    /// hoisted and the map grows at most once per batch (aggregation is
    /// order-free, so this is exactly the scalar loop's result).
    fn process_batch(&mut self, batch: &[Element]) {
        self.freqs.reserve(batch.len().min(4096));
        for e in batch {
            *self.freqs.entry(e.key).or_insert(0.0) += e.val;
        }
        self.processed += batch.len() as u64;
    }

    /// SoA block path (§Perf L3-7): aggregation streams off the dense
    /// key/value columns — same per-key addition order as the scalar
    /// loop, so the map is bit-identical.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        self.freqs.reserve(block.len().min(4096));
        for (&k, &v) in block.keys.iter().zip(&block.vals) {
            *self.freqs.entry(k).or_insert(0.0) += v;
        }
        self.processed += block.len() as u64;
    }

    fn size_words(&self) -> usize {
        ExactWor::size_words(self)
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for ExactWor {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("exact", &self.cfg)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        ExactWor::merge(self, other)
    }
}

impl api::Finalize for ExactWor {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

impl api::MultiPass for ExactWor {}

impl api::WorSampler for ExactWor {
    fn sample(&self) -> Result<Sample> {
        Ok(ExactWor::sample(self))
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn api::WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(crate::error::Error::Incompatible(format!(
                "cannot merge exact baseline with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn api::WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "exact"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }
}

/// Wire payload (canonical — frequencies sorted by key): the shared
/// [`SamplerConfig`] fragment, `processed u64, n u64,
/// n × (key u64, freq f64)`. The transform is hash-defined by the config
/// and rebuilt on decode.
impl crate::api::Persist for ExactWor {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(80 + 16 * self.freqs.len());
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u64(&mut p, self.processed);
        let mut keys: Vec<u64> = self.freqs.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_f64(&mut p, self.freqs[&k]);
        }
        crate::codec::write_envelope(
            crate::codec::tag::EXACT_WOR,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::EXACT_WOR))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let processed = r.u64()?;
        let n = r.seq_len(16)?;
        let mut freqs = HashMap::with_capacity(n);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|p| p >= key) {
                return Err(crate::error::Error::Codec(
                    "ExactWor frequencies are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            // non-finite frequencies would poison the sample-sort
            // comparators (which unwrap partial_cmp)
            freqs.insert(key, r.finite_f64("ExactWor frequency")?);
        }
        r.finish("exact")?;
        let transform = cfg.transform();
        let s = ExactWor { cfg, transform, freqs, processed };
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ppswor::perfect_ppswor;

    #[test]
    fn matches_perfect_ppswor_over_dense_vector() {
        let n = 300;
        let freqs: Vec<f64> = (0..n).map(|i| 1000.0 / (i + 1) as f64).collect();
        let cfg = SamplerConfig::new(1.0, 20).with_seed(17).with_domain(n);
        let mut s = ExactWor::new(cfg);
        // unaggregated: split each frequency into 3 parts
        for (i, &f) in freqs.iter().enumerate() {
            for _ in 0..3 {
                s.process(&Element::new(i as u64, f / 3.0));
            }
        }
        let got = s.sample();
        let want = perfect_ppswor(&freqs, 1.0, 20, 17);
        assert_eq!(got.keys(), want.keys());
        assert!((got.tau - want.tau).abs() < 1e-9 * want.tau.max(1.0));
        for (g, w) in got.entries.iter().zip(&want.entries) {
            assert!((g.freq - w.freq).abs() < 1e-9);
        }
    }

    #[test]
    fn merge_equals_whole_stream_exactly() {
        let cfg = SamplerConfig::new(2.0, 8).with_seed(3);
        let elems: Vec<Element> = (0..500u64)
            .map(|i| Element::new(i % 97, (i % 5) as f64 - 1.5))
            .collect();
        let mut whole = ExactWor::new(cfg.clone());
        let mut a = ExactWor::new(cfg.clone());
        let mut b = ExactWor::new(cfg);
        for (i, e) in elems.iter().enumerate() {
            whole.process(e);
            if i % 2 == 0 {
                a.process(e);
            } else {
                b.process(e);
            }
        }
        a.merge(&b).unwrap();
        assert_eq!(a.processed(), whole.processed());
        let (sa, sw) = (a.sample(), whole.sample());
        assert_eq!(sa.keys(), sw.keys());
        assert_eq!(sa.tau, sw.tau);
    }

    #[test]
    fn cancelled_keys_leave_the_sample() {
        let cfg = SamplerConfig::new(2.0, 5).with_seed(1);
        let mut s = ExactWor::new(cfg);
        s.process(&Element::new(1, 5.0));
        s.process(&Element::new(2, 3.0));
        s.process(&Element::new(1, -5.0));
        let keys = s.sample().keys();
        assert_eq!(keys, vec![2]);
    }
}
