//! 1-pass WORp (paper §5): a composable sketch whose output approximates
//! a p-ppswor sample of size `k`.
//!
//! - **Sketch**: an `ℓq(k+1, ψ)` rHH sketch of the transformed elements
//!   `(x, v·r_x^{-1/p})` with `ψ ← ε^q Ψ_{n,k+1,ρ}`.
//! - **Candidates**: streaming sketches cannot enumerate the key domain,
//!   so (as the paper prescribes for streaming, Appendix A) we maintain an
//!   auxiliary structure of keys with the currently-largest estimates; it
//!   holds `O(k)` keys and is composable (merge = union + re-estimate +
//!   truncate against the *merged* sketch).
//! - **Sample**: the top-k candidates by `|ν̂*_x|`, with approximate input
//!   frequencies `ν'_x = ν̂*_x · r_x^{1/p}` (Eq. 6) and threshold
//!   `τ = |ν̂*|_(k+1)`.

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint, WorSampler};
use crate::data::Element;
use crate::error::Result;
use crate::sketch::{AnyRhh, RhhSketch, SketchParams};
use crate::transform::BottomKTransform;
use crate::util::fastset::FastSet;

/// Composable 1-pass WORp sampler.
#[derive(Clone, Debug)]
pub struct OnePassWorp {
    cfg: SamplerConfig,
    transform: BottomKTransform,
    sketch: AnyRhh,
    /// Candidate keys (scored lazily against the sketch — §Perf L3-1/5).
    candidates: FastSet,
    /// Candidate capacity (a small multiple of k).
    cand_cap: usize,
    processed: u64,
    /// Reusable transformed-element buffer for the batch path (§Perf L3-6).
    tbuf: Vec<Element>,
    /// Reusable transformed-value column for the SoA block path (§Perf
    /// L3-7) — the key column passes through untransformed.
    vbuf: Vec<f64>,
}

impl OnePassWorp {
    /// Build from a sampler config.
    pub fn new(cfg: SamplerConfig) -> Self {
        let rows = cfg.resolved_rows();
        let width = cfg.resolved_width_one_pass();
        let params = SketchParams::new(rows, width, cfg.seed ^ 0x1AB5);
        let sketch = AnyRhh::for_q(cfg.q, params);
        let transform = cfg.transform();
        let cand_cap = 8 * (cfg.k + 1);
        OnePassWorp {
            cfg,
            transform,
            sketch,
            candidates: FastSet::with_capacity(2 * cand_cap),
            cand_cap,
            processed: 0,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    /// The shared bottom-k transform (exposed for coordinated samples).
    pub fn transform(&self) -> &BottomKTransform {
        &self.transform
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Sketch size in memory words (excluding candidates).
    pub fn sketch_words(&self) -> usize {
        self.sketch.size_words()
    }

    /// Total summary size in words (sketch + candidate slots).
    pub fn size_words(&self) -> usize {
        self.sketch.size_words() + 2 * self.cand_cap
    }

    /// Process one raw element (untransformed).
    ///
    /// §Perf L3-1: the hot loop does *not* estimate the key — it only
    /// records it as a candidate. Estimates are computed lazily, in bulk,
    /// when the candidate set overflows (amortized `O(est/elem · cap/N)`)
    /// and at sample time. Before this change every element paid a full
    /// `rows`-row estimate (hashing + median), which dominated the
    /// profile at ~2× the sketch-update cost.
    pub fn process(&mut self, e: &Element) {
        let te = self.transform.apply(e);
        self.sketch.process(&te);
        self.processed += 1;
        self.candidates.insert(e.key);
        if self.candidates.len() > 2 * self.cand_cap {
            self.shrink_candidates();
        }
    }

    fn shrink_candidates(&mut self) {
        // score all candidates against the sketch in one est_many sweep
        // (one shared scratch for the whole set — §Perf L3-7), keep the
        // top cand_cap (rank_desc: deterministic on score ties)
        let keys: Vec<u64> = self.candidates.iter().collect();
        let mut ests = vec![0.0f64; keys.len()];
        self.sketch.est_many(&keys, &mut ests);
        let mut v: Vec<(u64, f64)> = keys
            .into_iter()
            .zip(ests)
            .map(|(k, e)| (k, e.abs()))
            .collect();
        v.sort_by(crate::util::stats::rank_desc);
        v.truncate(self.cand_cap);
        self.candidates.clear();
        for (k, _) in v {
            self.candidates.insert(k);
        }
    }

    /// Merge a sibling sampler (same config & seed). The merged candidate
    /// set is re-scored against the merged sketch.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        self.sketch.merge(&other.sketch)?;
        self.processed += other.processed;
        for k in other.candidates.iter() {
            self.candidates.insert(k);
        }
        // candidates are re-scored lazily (shrink / sample time) against
        // the now-merged sketch
        if self.candidates.len() > self.cand_cap {
            self.shrink_candidates();
        }
        Ok(())
    }

    /// Produce the approximate p-ppswor sample (paper §5) from the
    /// tracked candidate set.
    ///
    /// Candidate tracking can lose a key whose *relative* standing rises
    /// only because other keys shrink (pure-deletion phases). When the key
    /// domain is a known `[n]`, use [`Self::sample_enumerating`] — the
    /// paper's recovery prescription for CountSketch (Appendix A).
    pub fn sample(&self) -> Sample {
        self.sample_from_keys(self.candidates.iter())
    }

    /// Produce the sample by scoring an explicit key universe (paper
    /// Appendix A: "the rHH keys can be recovered by enumerating over
    /// [n] and retaining the keys with largest estimates").
    pub fn sample_enumerating(&self, n: u64) -> Sample {
        self.sample_from_keys(0..n)
    }

    /// The rHH failure test (paper Appendix A, "Testing for failure"):
    /// the dataset may simply not have `(k, ψ)` residual heavy hitters
    /// after the transform. Declare failure when the k-th largest
    /// estimated transformed frequency falls below the sketch's own error
    /// scale `sqrt(ψ/k · ‖tail_k(ν̂*)‖₂²)` (q = 2 path), with the tail
    /// mass estimated from the sketch table itself.
    pub fn certify(&self, sample: &Sample) -> crate::error::Result<()> {
        let AnyRhh::CountSketch(cs) = &self.sketch else {
            return Ok(()); // counter sketches are deterministic: no test
        };
        if sample.entries.len() < self.cfg.k || sample.tau <= 0.0 {
            return Err(crate::error::Error::RhhFailure(format!(
                "sample has {} of {} keys",
                sample.entries.len(),
                self.cfg.k
            )));
        }
        // E[sum of row squares] = ||nu*||_2^2; median over rows is robust
        let params = cs.params();
        let mut row_mass: Vec<f64> = (0..params.rows)
            .map(|r| {
                cs.table()[r * params.width..(r + 1) * params.width]
                    .iter()
                    .map(|c| c * c)
                    .sum()
            })
            .collect();
        row_mass.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total_sq = row_mass[row_mass.len() / 2];
        let topk_sq: f64 = sample.entries.iter().map(|e| e.transformed * e.transformed).sum();
        let tail_sq = (total_sq - topk_sq).max(0.0);
        let psi = crate::psi::worp_psi_one_pass(
            self.cfg.n,
            self.cfg.k,
            self.cfg.p,
            self.cfg.q,
            self.cfg.delta,
            self.cfg.eps,
        );
        let noise_scale = (psi / self.cfg.k as f64 * tail_sq).sqrt();
        let kth = sample.entries.last().unwrap().transformed.abs();
        if kth < noise_scale {
            return Err(crate::error::Error::RhhFailure(format!(
                "k-th transformed estimate {kth:.3e} below error scale {noise_scale:.3e} — \
                 dataset lacks (k, ψ) rHH; enlarge the sketch or reduce k"
            )));
        }
        Ok(())
    }

    fn sample_from_keys<I: IntoIterator<Item = u64>>(&self, keys: I) -> Sample {
        // candidate scoring goes through est_many: one scratch for the
        // whole key universe instead of one per est call (§Perf L3-7)
        let keys: Vec<u64> = keys.into_iter().collect();
        let mut ests = vec![0.0f64; keys.len()];
        self.sketch.est_many(&keys, &mut ests);
        let mut scored: Vec<(u64, f64)> = keys
            .into_iter()
            .zip(ests)
            .filter(|(_, e)| *e != 0.0)
            .collect();
        scored.sort_by(|a, b| {
            crate::util::stats::rank_desc(&(a.0, a.1.abs()), &(b.0, b.1.abs()))
        });
        let k = self.cfg.k;
        // fewer than k+1 scored keys: the "sample" is the whole dataset
        // and tau = 0 marks estimates as exact (paper Eq. 1 degenerates)
        let tau = if scored.len() > k { scored[k].1.abs() } else { 0.0 };
        let entries: Vec<SampleEntry> = scored
            .into_iter()
            .take(k)
            .map(|(key, est)| SampleEntry {
                key,
                freq: self.transform.invert(key, est),
                transformed: est,
            })
            .collect();
        Sample { entries, tau, p: self.cfg.p, dist: self.transform.dist(), names: None }
    }
}

impl api::StreamSummary for OnePassWorp {
    fn process(&mut self, e: &Element) {
        OnePassWorp::process(self, e)
    }

    /// Vectorized batch path (§Perf L3-6): the whole batch is transformed
    /// into a reusable buffer, the sketch ingests it through its columnar
    /// `process_batch`, and the candidate-overflow check is amortized to
    /// once per batch.
    fn process_batch(&mut self, batch: &[Element]) {
        let mut tbuf = std::mem::take(&mut self.tbuf);
        tbuf.clear();
        tbuf.extend(batch.iter().map(|e| self.transform.apply(e)));
        self.sketch.process_batch(&tbuf);
        self.tbuf = tbuf;
        for e in batch {
            self.candidates.insert(e.key);
        }
        self.processed += batch.len() as u64;
        if self.candidates.len() > 2 * self.cand_cap {
            self.shrink_candidates();
        }
    }

    /// SoA block path (§Perf L3-7): the transform rewrites only the value
    /// column into the reusable `vbuf` (the key column passes through),
    /// the sketch ingests `(keys, vbuf)` through its columnar
    /// `process_cols`, and candidates insert straight off the key slice —
    /// no `Element` structs anywhere. Bit-identical to `process_batch`.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        let mut vbuf = std::mem::take(&mut self.vbuf);
        self.transform.apply_cols(&block.keys, &block.vals, &mut vbuf);
        self.sketch.process_cols(&block.keys, &vbuf);
        self.vbuf = vbuf;
        for &k in &block.keys {
            self.candidates.insert(k);
        }
        self.processed += block.len() as u64;
        if self.candidates.len() > 2 * self.cand_cap {
            self.shrink_candidates();
        }
    }

    fn size_words(&self) -> usize {
        OnePassWorp::size_words(self)
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for OnePassWorp {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("worp1", &self.cfg)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        OnePassWorp::merge(self, other)
    }
}

impl api::Finalize for OnePassWorp {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

impl api::MultiPass for OnePassWorp {}

impl WorSampler for OnePassWorp {
    fn sample(&self) -> Result<Sample> {
        Ok(OnePassWorp::sample(self))
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(crate::error::Error::Incompatible(format!(
                "cannot merge 1-pass WORp with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "1pass"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }
}

/// Wire payload: the shared [`SamplerConfig`] fragment, `processed u64`,
/// the rHH sketch as a nested envelope, then the candidate key set
/// (canonical — sorted) as `n u64, n × key u64`. The candidate capacity
/// and transform are derived from the config; the transform buffer is
/// transient and not persisted.
impl crate::api::Persist for OnePassWorp {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_u64(&mut p, self.processed);
        crate::codec::put_nested(&mut p, &self.sketch);
        let mut keys: Vec<u64> = self.candidates.iter().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            crate::codec::wire::put_u64(&mut p, k);
        }
        crate::codec::write_envelope(
            crate::codec::tag::WORP1,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WORP1))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let cand_cap = 8 * (cfg.k + 1);
        let processed = r.u64()?;
        let sketch: AnyRhh = crate::codec::read_nested(&mut r)?;
        let n = r.seq_len(8)?;
        if n > 2 * cand_cap {
            return Err(crate::error::Error::Codec(format!(
                "1-pass candidate set of {n} exceeds twice the capacity {cand_cap}"
            )));
        }
        // allocation from the *actual* candidate count (bounded by the
        // payload size), never from the untrusted config-derived cand_cap
        let mut candidates = FastSet::with_capacity(n.max(8));
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|q| q >= key) {
                return Err(crate::error::Error::Codec(
                    "1-pass candidates are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            candidates.insert(key);
        }
        r.finish("1pass")?;
        let transform = cfg.transform();
        let s = OnePassWorp {
            cfg,
            transform,
            sketch,
            candidates,
            cand_cap,
            processed,
            tbuf: Vec::new(),
            vbuf: Vec::new(),
        };
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::{zipf_exact_stream, zipf_frequencies};
    use crate::sampler::ppswor::perfect_ppswor;
    use std::collections::HashSet;

    fn run_stream(s: &mut OnePassWorp, elems: &[Element]) {
        for e in elems {
            s.process(e);
        }
    }

    #[test]
    fn returns_k_keys_on_zipf() {
        let cfg = SamplerConfig::new(1.0, 20)
            .with_seed(3)
            .with_domain(500)
            .with_sketch_shape(7, 512);
        let mut s = OnePassWorp::new(cfg);
        let elems = zipf_exact_stream(500, 1.0, 1e4, 3, 1);
        run_stream(&mut s, &elems);
        let sample = s.sample();
        assert_eq!(sample.len(), 20);
        assert!(sample.tau > 0.0);
        let distinct: HashSet<u64> = sample.keys().into_iter().collect();
        assert_eq!(distinct.len(), 20);
    }

    #[test]
    fn matches_perfect_ppswor_on_skewed_data() {
        // with a generous sketch, the 1-pass sample should equal the
        // perfect p-ppswor sample that shares its randomization
        let n = 1000;
        let k = 10;
        let cfg = SamplerConfig::new(2.0, k)
            .with_seed(11)
            .with_domain(n)
            .with_sketch_shape(9, 4096);
        let mut s = OnePassWorp::new(cfg);
        let elems = zipf_exact_stream(n, 2.0, 1e4, 2, 7);
        run_stream(&mut s, &elems);
        let got: HashSet<u64> = s.sample().keys().into_iter().collect();
        let freqs = zipf_frequencies(n, 2.0, 1e4);
        let want: HashSet<u64> = perfect_ppswor(&freqs, 2.0, k, 11).keys().into_iter().collect();
        let overlap = got.intersection(&want).count();
        assert!(overlap >= k - 1, "overlap {overlap}/{k}");
    }

    #[test]
    fn approximate_freqs_close_to_truth() {
        let n = 500;
        let cfg = SamplerConfig::new(1.0, 10)
            .with_seed(5)
            .with_domain(n)
            .with_sketch_shape(9, 2048);
        let mut s = OnePassWorp::new(cfg);
        let elems = zipf_exact_stream(n, 1.5, 1e4, 2, 9);
        run_stream(&mut s, &elems);
        let freqs = zipf_frequencies(n, 1.5, 1e4);
        for e in &s.sample().entries {
            let truth = freqs[e.key as usize];
            let rel = (e.freq - truth).abs() / truth;
            assert!(rel < 0.2, "key {}: est {} truth {truth}", e.key, e.freq);
        }
    }

    #[test]
    fn merge_two_shards_equals_single_stream_sample() {
        let n = 400;
        let cfg = SamplerConfig::new(1.0, 15)
            .with_seed(13)
            .with_domain(n)
            .with_sketch_shape(7, 1024);
        let elems = zipf_exact_stream(n, 1.0, 1e4, 2, 5);
        let mut whole = OnePassWorp::new(cfg.clone());
        run_stream(&mut whole, &elems);
        let mut a = OnePassWorp::new(cfg.clone());
        let mut b = OnePassWorp::new(cfg);
        for (i, e) in elems.iter().enumerate() {
            if i % 2 == 0 {
                a.process(e);
            } else {
                b.process(e);
            }
        }
        a.merge(&b).unwrap();
        assert_eq!(a.processed(), whole.processed());
        let ka: Vec<u64> = a.sample().keys();
        let kw: Vec<u64> = whole.sample().keys();
        // sketches are identical post-merge; candidate sets may differ
        // slightly, but the top keys must agree
        let overlap = ka.iter().filter(|k| kw.contains(k)).count();
        assert!(overlap >= 14, "overlap {overlap}");
    }

    #[test]
    fn certify_accepts_skewed_rejects_degenerate() {
        // skewed data with a roomy sketch: certification passes
        let n = 1000;
        let cfg = SamplerConfig::new(1.0, 10)
            .with_seed(3)
            .with_domain(n)
            .with_sketch_shape(9, 2048);
        let mut s = OnePassWorp::new(cfg.clone());
        for e in zipf_exact_stream(n, 2.0, 1e4, 2, 3) {
            s.process(&e);
        }
        let sample = s.sample();
        assert!(s.certify(&sample).is_ok());

        // fewer distinct keys than k: must fail certification
        let mut s = OnePassWorp::new(cfg);
        for i in 0..5u64 {
            s.process(&Element::new(i, 1.0));
        }
        let sample = s.sample();
        let err = s.certify(&sample).unwrap_err();
        assert!(err.to_string().contains("rHH"), "{err}");
    }

    #[test]
    fn signed_stream_supported() {
        // turnstile: insert then partially delete; sampling follows |nu|
        let cfg = SamplerConfig::new(2.0, 5)
            .with_seed(17)
            .with_domain(100)
            .with_sketch_shape(7, 512);
        let mut s = OnePassWorp::new(cfg);
        for i in 0..100u64 {
            s.process(&Element::new(i, 10.0));
        }
        // delete most of every key except 0..5
        for i in 5..100u64 {
            s.process(&Element::new(i, -9.9));
        }
        // candidate tracking may lose un-retouched keys under heavy
        // deletion; domain enumeration (paper Appendix A) recovers them
        let sample = s.sample_enumerating(100);
        let keys: HashSet<u64> = sample.keys().into_iter().collect();
        // the five surviving heavy keys should dominate the l2 sample
        let heavy_in = (0..5u64).filter(|k| keys.contains(k)).count();
        assert!(heavy_in >= 4, "heavy_in={heavy_in}");
    }
}
