//! Samplers: perfect baselines (ppswor / priority / WR over aggregated
//! data) and the paper's streaming contributions (1-pass WORp, 2-pass
//! WORp, and the low-TV-distance Algorithm 1).
//!
//! All WOR samplers produce a [`Sample`]: up to `k` keys with (exact or
//! approximate) input frequencies, the transformed frequencies used for
//! ranking, and the threshold `τ` — everything the inverse-probability
//! estimators of [`crate::estimate`] need.

pub mod decayed;
pub mod exact;
pub mod perfect_lp;
pub mod ppswor;
pub mod priority;
pub mod tv1pass;
pub mod windowed;
pub mod worp1;
pub mod worp2;
pub mod worp_strings;
pub mod wr;
pub mod wr_reservoir;

use crate::util::hashing::BottomKDist;
use std::collections::BTreeMap;

/// Key dictionary: hashed key id → original string key. String-keyed
/// samplers ([`worp_strings`]) carry one alongside their entries so
/// string results flow through the same [`Sample`] query / estimate /
/// encode surface as numeric ones. A `BTreeMap` so iteration (and hence
/// the canonical wire encoding) is key-sorted.
pub type KeyDict = BTreeMap<u64, String>;

/// One sampled key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleEntry {
    /// Key id.
    pub key: u64,
    /// Input-domain frequency `ν_x` (exact for 2-pass / perfect samplers,
    /// approximate `ν'_x` for 1-pass WORp).
    pub freq: f64,
    /// Transformed frequency `ν*_x = ν_x · r_x^{-1/p}` used for ranking.
    pub transformed: f64,
}

/// A without-replacement bottom-k sample with its threshold.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Sampled entries, sorted by decreasing `|transformed|`.
    pub entries: Vec<SampleEntry>,
    /// Threshold `τ`: the (k+1)-st largest `|ν*|` (exact or estimated).
    pub tau: f64,
    /// The power `p` the sample is weighted by (`ν^p`).
    pub p: f64,
    /// The bottom-k distribution (`Exp` = ppswor, `Uniform` = priority).
    pub dist: BottomKDist,
    /// Optional key dictionary mapping hashed key ids back to their
    /// original string form (populated by string-keyed samplers; `None`
    /// for numeric streams).
    pub names: Option<KeyDict>,
}

impl Sample {
    /// Number of sampled keys.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The sampled key set.
    pub fn keys(&self) -> Vec<u64> {
        self.entries.iter().map(|e| e.key).collect()
    }

    /// The original string form of a sampled key id, when this sample
    /// carries a key dictionary (see [`KeyDict`]).
    pub fn name_of(&self, key: u64) -> Option<&str> {
        self.names.as_ref()?.get(&key).map(String::as_str)
    }

    /// Display label of a sampled key: the dictionary string when
    /// present, the numeric id otherwise (what the CLI tables print).
    pub fn label_of(&self, key: u64) -> String {
        match self.name_of(key) {
            Some(s) => s.to_string(),
            None => key.to_string(),
        }
    }

    /// Inclusion probability of a key with frequency `freq`, conditioned
    /// on the out-of-sample threshold `τ` (paper Eq. 1 denominator).
    pub fn inclusion_prob(&self, freq: f64) -> f64 {
        debug_assert!(self.tau > 0.0);
        let ratio = (freq.abs() / self.tau).powf(self.p);
        match self.dist {
            BottomKDist::Exp => 1.0 - (-ratio).exp(),
            BottomKDist::Uniform => ratio.min(1.0),
        }
    }
}

/// Shared configuration for the WORp samplers.
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    /// Power `p ∈ (0, 2]` — sampling is weighted by `ν^p`.
    pub p: f64,
    /// Sample size `k`.
    pub k: usize,
    /// rHH norm `q ∈ {1, 2}` (2 = CountSketch; requires `q ≥ p`).
    pub q: f64,
    /// Shared randomization seed (transform + sketch hashes).
    pub seed: u64,
    /// Key-domain size `n` used for Ψ calibration.
    pub n: usize,
    /// Target failure probability δ.
    pub delta: f64,
    /// 1-pass accuracy parameter ε ∈ (0, 1/3].
    pub eps: f64,
    /// Sketch rows (odd). 0 = default (paper uses a k×31 CountSketch).
    pub rows: usize,
    /// Sketch width override; 0 = derive from Ψ calibration.
    pub width: usize,
    /// Bottom-k distribution: `Exp` = p-ppswor (paper default),
    /// `Uniform` = p-priority (sequential Poisson).
    pub dist: BottomKDist,
}

impl SamplerConfig {
    /// Defaults matching the paper's experiments (§7): CountSketch,
    /// δ=0.01, ε=1/3, n=10^4.
    pub fn new(p: f64, k: usize) -> Self {
        assert!(p > 0.0 && p <= 2.0, "p must be in (0,2]");
        assert!(k >= 1);
        SamplerConfig {
            p,
            k,
            q: 2.0,
            seed: 1,
            n: 10_000,
            delta: 0.01,
            eps: 1.0 / 3.0,
            rows: 0,
            width: 0,
            dist: BottomKDist::Exp,
        }
    }

    /// Switch to priority (sequential Poisson) sampling, `D = U[0,1]`.
    pub fn with_priority(mut self) -> Self {
        self.dist = BottomKDist::Uniform;
        self
    }

    /// Build the bottom-k transform this config prescribes.
    pub fn transform(&self) -> crate::transform::BottomKTransform {
        match self.dist {
            BottomKDist::Exp => crate::transform::BottomKTransform::ppswor(self.seed, self.p),
            BottomKDist::Uniform => {
                crate::transform::BottomKTransform::priority(self.seed, self.p)
            }
        }
    }

    /// Set the shared randomization seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the key-domain size.
    pub fn with_domain(mut self, n: usize) -> Self {
        self.n = n;
        self
    }

    /// Set sketch shape explicitly (rows must be odd).
    pub fn with_sketch_shape(mut self, rows: usize, width: usize) -> Self {
        assert!(rows % 2 == 1, "rows must be odd");
        self.rows = rows;
        self.width = width;
        self
    }

    /// Set the 1-pass accuracy parameter ε.
    pub fn with_eps(mut self, eps: f64) -> Self {
        assert!(eps > 0.0 && eps <= 1.0 / 3.0 + 1e-12);
        self.eps = eps;
        self
    }

    /// Resolved sketch rows: explicit, else the paper's default 31
    /// (Table 3 / Fig 2 use a k×31 CountSketch).
    pub fn resolved_rows(&self) -> usize {
        if self.rows > 0 {
            self.rows
        } else {
            31
        }
    }

    /// Resolved sketch width for the two-pass method: explicit override,
    /// else `O(k/ψ)` with ψ from the Ψ calibration (paper §4), capped to
    /// stay sample-sized. The paper's experiments simply use width = k.
    pub fn resolved_width_two_pass(&self) -> usize {
        if self.width > 0 {
            return self.width;
        }
        let psi = crate::psi::worp_psi_two_pass(self.n, self.k, self.p, self.q, self.delta);
        ((self.k as f64 / psi).ceil() as usize).clamp(self.k, 64 * self.k)
    }

    /// Resolved width for the 1-pass method (`ψ ← ε^q Ψ`).
    pub fn resolved_width_one_pass(&self) -> usize {
        if self.width > 0 {
            return self.width;
        }
        let psi =
            crate::psi::worp_psi_one_pass(self.n, self.k, self.p, self.q, self.delta, self.eps);
        ((self.k as f64 / psi).ceil() as usize).clamp(self.k, 256 * self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_match_paper() {
        let c = SamplerConfig::new(1.0, 100);
        assert_eq!(c.resolved_rows(), 31);
        assert_eq!(c.q, 2.0);
        assert_eq!(c.n, 10_000);
    }

    #[test]
    fn one_pass_width_at_least_two_pass() {
        let c = SamplerConfig::new(1.0, 50).with_domain(5_000);
        assert!(c.resolved_width_one_pass() >= c.resolved_width_two_pass());
    }

    #[test]
    fn explicit_shape_wins() {
        let c = SamplerConfig::new(2.0, 10).with_sketch_shape(5, 333);
        assert_eq!(c.resolved_rows(), 5);
        assert_eq!(c.resolved_width_two_pass(), 333);
        assert_eq!(c.resolved_width_one_pass(), 333);
    }

    #[test]
    fn sample_inclusion_prob_matches_transform() {
        let s = Sample {
            entries: vec![],
            tau: 2.0,
            p: 1.0,
            dist: BottomKDist::Exp,
            names: None,
        };
        let want = 1.0 - (-0.5f64).exp();
        assert!((s.inclusion_prob(1.0) - want).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn p_out_of_range_rejected() {
        SamplerConfig::new(2.5, 10);
    }
}
