//! `WrReservoir` — a streaming *with-replacement* weighted reservoir:
//! `k` independent single-item Efraimidis–Spirakis reservoirs sharing one
//! pass, each skipped forward with the exponential-jump (A-ExpJ) trick,
//! which is exactly the with-replacement extension of weighted reservoir
//! sampling (Efraimidis–Spirakis 2006; Meligrana–Fazzone 2024).
//!
//! Each stream element `(x, v)` is one *item* of weight `w = |v|^p`. Per
//! slot, the E–S key of an item is `Exp[1]/w` and the slot keeps the
//! minimum — so the slot's final winner is item `i` with probability
//! `w_i / Σw`, and key `x` is drawn with probability proportional to its
//! per-occurrence weight sum `Σ_{i: x_i = x} |v_i|^p`. For `p = 1` on a
//! positive stream this is an exact WR ℓ1 sample of the aggregated
//! frequencies `ν`; the `k` slots are independent, so the reservoir is a
//! WR sample of `k` draws — the honest streaming counterpart of the
//! aggregated [`super::wr::perfect_wr`] baseline, in `O(k + sketch)`
//! memory.
//!
//! The A-ExpJ skip: a slot holding exponent threshold `T` is next
//! replaced after `Exp[1]/T` further *weight* (memorylessness), so the
//! hot path is one `f64` compare against the cached minimum jump point;
//! per-item randomness is consumed only when a jump actually fires
//! (`O(k log n)` firings over the stream, not `O(k·n)` draws).
//!
//! Frequencies of the drawn keys are estimated from a CountSketch rHH
//! carried alongside (the same sketch substrate as 1-pass WORp), so
//! [`WrSampler::sample`] can report `freq` without aggregating the
//! stream. `τ` is reported as 0: a WR sample has no bottom-k threshold,
//! and estimators must use the WR inclusion probabilities
//! ([`crate::estimate::wr_inclusion_prob`]) instead.
//!
//! Like the windowed sampler, the reservoir draws from a single
//! sequential RNG stream, so `parallel_safe()` is `false`: engine/
//! pipeline runs are forced onto one shard (sharding would replay the
//! same RNG stream per shard and correlate the slots). Cross-process
//! merge is still sound — slot-wise, the smaller exponent wins, which is
//! precisely the single-pass fold over the concatenated stream.

use super::{Sample, SampleEntry, SamplerConfig};
use crate::api::{self, config_fingerprint, Fingerprint};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::sketch::countsketch::CountSketch;
use crate::sketch::{RhhSketch, SketchParams};
use crate::util::rng::Rng;

/// One independent single-draw reservoir.
#[derive(Clone, Copy, Debug)]
struct Slot {
    /// E–S exponent of the current winner (`Exp[1]/w`; `+∞` = empty).
    exponent: f64,
    /// Winning key.
    key: u64,
    /// Cumulative-weight coordinate at which this slot next fires.
    next_jump: f64,
}

impl Slot {
    fn empty() -> Slot {
        Slot { exponent: f64::INFINITY, key: 0, next_jump: 0.0 }
    }

    fn occupied(&self) -> bool {
        self.exponent.is_finite()
    }

    /// `true` when `self`'s winner beats `other`'s (smaller exponent;
    /// ties break on the smaller key so merges are order-independent).
    fn beats(&self, other: &Slot) -> bool {
        match self.exponent.total_cmp(&other.exponent) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.key < other.key,
        }
    }
}

/// Streaming with-replacement weighted reservoir (`k` draws ∝ `|v|^p`).
#[derive(Clone, Debug)]
pub struct WrReservoir {
    cfg: SamplerConfig,
    slots: Vec<Slot>,
    sketch: CountSketch,
    rng: Rng,
    /// Cumulative item weight `Σ |v|^p` seen so far.
    total_weight: f64,
    /// Cached `min(next_jump)` over all slots — the hot-path gate.
    min_jump: f64,
    processed: u64,
}

impl WrReservoir {
    /// Build from a sampler config: `k` slots, the shared seed (salted so
    /// the reservoir RNG is independent of the transform/sketch hashes),
    /// and the config's CountSketch shape for frequency estimates.
    pub fn new(cfg: SamplerConfig) -> Self {
        let params = SketchParams::new(
            cfg.resolved_rows(),
            cfg.resolved_width_one_pass(),
            cfg.seed ^ 0x5EED_0057_5253_6B01, // "WRSk" salt
        );
        WrReservoir {
            slots: vec![Slot::empty(); cfg.k],
            sketch: CountSketch::new(params),
            rng: Rng::new(cfg.seed ^ 0x77_52_45_53), // "wRES"
            total_weight: 0.0,
            min_jump: 0.0,
            processed: 0,
            cfg,
        }
    }

    /// Sampler configuration.
    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    /// Cumulative item weight `Σ |v|^p` (the WR denominator).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// The current winning key of each occupied slot, in slot order —
    /// the `k` WR draws.
    pub fn draws(&self) -> Vec<u64> {
        self.slots.iter().filter(|s| s.occupied()).map(|s| s.key).collect()
    }

    /// Item weight of one element.
    #[inline]
    fn weight(&self, val: f64) -> f64 {
        val.abs().powf(self.cfg.p)
    }

    /// Compete one item of weight `w` against every slot whose jump
    /// point lands inside this item's weight interval.
    #[inline]
    fn step(&mut self, key: u64, w: f64) {
        if !(w > 0.0) || !w.is_finite() {
            return; // weightless items cannot win a draw
        }
        let hi = self.total_weight + w;
        // the item owns the half-open weight interval [total_weight, hi)
        if self.min_jump < hi {
            self.fire(key, w, hi);
        }
        self.total_weight = hi;
    }

    /// Rare path: at least one slot fires inside `[total_weight, hi)`.
    /// Slots fire in deterministic `(next_jump, index)` order so RNG
    /// consumption is replayable.
    #[cold]
    fn fire(&mut self, key: u64, w: f64, hi: f64) {
        loop {
            let mut j = usize::MAX;
            let mut best = f64::INFINITY;
            for (i, s) in self.slots.iter().enumerate() {
                if s.next_jump < hi && s.next_jump < best {
                    best = s.next_jump;
                    j = i;
                }
            }
            if j == usize::MAX {
                break;
            }
            let t_old = self.slots[j].exponent;
            let e_new = if t_old.is_finite() {
                // Exp[1] truncated to [0, w·T): the winner's exponent
                // conditioned on the replacement having occurred.
                // -expm1(-a) = 1 - e^{-a} and ln_1p keep this exact for
                // tiny w·T (the limit is Uniform(0, T), as it must be).
                let a = w * t_old;
                let u = self.rng.uniform_open();
                let x = -(-u * (-(-a).exp_m1())).ln_1p();
                x / w
            } else {
                self.rng.exp1() / w
            };
            self.slots[j].exponent = e_new;
            self.slots[j].key = key;
            // memoryless skip: next replacement of this slot comes after
            // Exp[1]/T' further weight, counted from the end of this item
            self.slots[j].next_jump = hi + self.rng.exp1() / e_new;
        }
        self.min_jump = self
            .slots
            .iter()
            .map(|s| s.next_jump)
            .fold(f64::INFINITY, f64::min);
    }

    /// Re-arm every slot's jump point after a merge or decode put the
    /// cumulative-weight coordinate system out of sync. Fresh `Exp[1]/T`
    /// draws are unbiased by memorylessness.
    fn rearm(&mut self) {
        let base = self.total_weight;
        for s in &mut self.slots {
            s.next_jump = if s.occupied() {
                base + self.rng.exp1() / s.exponent
            } else {
                base
            };
        }
        self.min_jump = self
            .slots
            .iter()
            .map(|s| s.next_jump)
            .fold(f64::INFINITY, f64::min);
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Merge a sibling reservoir: slot-wise the smaller exponent wins
    /// (the fold of the per-item minimum over the concatenated streams),
    /// weights and sketches add, and every jump is re-armed against the
    /// merged weight coordinate.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        RhhSketch::merge(&mut self.sketch, &other.sketch)?;
        for (a, b) in self.slots.iter_mut().zip(&other.slots) {
            if b.beats(a) {
                *a = *b;
            }
        }
        self.total_weight += other.total_weight;
        self.processed += other.processed;
        self.rearm();
        Ok(())
    }

    /// The `k` WR draws as a [`Sample`]: one entry per occupied slot in
    /// slot order (keys repeat across slots — these are draws, not a
    /// set), `freq` estimated from the carried CountSketch, `transformed`
    /// carrying the winning E–S exponent for diagnostics, and `τ = 0`
    /// (a WR sample has no bottom-k threshold).
    pub fn sample(&self) -> Sample {
        let entries: Vec<SampleEntry> = self
            .slots
            .iter()
            .filter(|s| s.occupied())
            .map(|s| SampleEntry {
                key: s.key,
                freq: self.sketch.est(s.key),
                transformed: s.exponent,
            })
            .collect();
        Sample {
            entries,
            tau: 0.0,
            p: self.cfg.p,
            dist: self.cfg.dist,
            names: None,
        }
    }
}

impl api::StreamSummary for WrReservoir {
    fn process(&mut self, e: &Element) {
        RhhSketch::process(&mut self.sketch, e);
        self.step(e.key, self.weight(e.val));
        self.processed += 1;
    }

    /// Micro-batch path: the sketch takes its lane-unrolled batch sweep;
    /// the reservoir competition is inherently sequential (one RNG
    /// stream), so it replays the scalar loop — bit-identical by
    /// construction.
    fn process_batch(&mut self, batch: &[Element]) {
        CountSketch::process_batch(&mut self.sketch, batch);
        for e in batch {
            self.step(e.key, self.weight(e.val));
        }
        self.processed += batch.len() as u64;
    }

    /// SoA block path: sketch hashes straight off the key column; the
    /// competition walks the columns in element order.
    fn process_block(&mut self, block: &crate::data::ElementBlock) {
        self.sketch.process_cols(&block.keys, &block.vals);
        for (&k, &v) in block.keys.iter().zip(&block.vals) {
            self.step(k, self.weight(v));
        }
        self.processed += block.len() as u64;
    }

    fn size_words(&self) -> usize {
        3 * self.slots.len() + RhhSketch::size_words(&self.sketch) + 8
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

impl api::Mergeable for WrReservoir {
    fn fingerprint(&self) -> Fingerprint {
        config_fingerprint("wr", &self.cfg)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        WrReservoir::merge(self, other)
    }
}

impl api::Finalize for WrReservoir {
    type Output = Sample;

    fn finalize(&self) -> Sample {
        self.sample()
    }
}

impl api::MultiPass for WrReservoir {}

impl api::WorSampler for WrReservoir {
    fn sample(&self) -> Result<Sample> {
        Ok(WrReservoir::sample(self))
    }

    fn fingerprint(&self) -> Fingerprint {
        api::Mergeable::fingerprint(self)
    }

    fn merge_dyn(&mut self, other: &dyn api::WorSampler) -> Result<()> {
        match other.as_any().downcast_ref::<Self>() {
            Some(o) => api::Mergeable::merge(self, o),
            None => Err(Error::Incompatible(format!(
                "cannot merge WR reservoir with {}",
                other.name()
            ))),
        }
    }

    fn clone_box(&self) -> Box<dyn api::WorSampler> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> &'static str {
        "wr"
    }

    fn encode_state(&self, out: &mut Vec<u8>) {
        crate::api::Persist::encode_into(self, out)
    }

    /// The reservoir draws from one sequential RNG stream — sharding
    /// would replay the same stream per shard and correlate the slots,
    /// so the coordinator/engine pin it to a single worker (the same
    /// rule as the windowed sampler's clock).
    fn parallel_safe(&self) -> bool {
        false
    }
}

/// Wire payload: the shared [`SamplerConfig`] fragment,
/// `total_weight f64, processed u64, rng u64×4, k u64,
/// k × (exponent f64, key u64, next_jump f64)`, then the nested
/// CountSketch envelope. Slot order is the canonical order (slots are
/// positional), so logically-equal reservoirs encode byte-identically.
impl crate::api::Persist for WrReservoir {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(128 + 24 * self.slots.len());
        crate::codec::put_sampler_config(&mut p, &self.cfg);
        crate::codec::wire::put_f64(&mut p, self.total_weight);
        crate::codec::wire::put_u64(&mut p, self.processed);
        for w in self.rng.state() {
            crate::codec::wire::put_u64(&mut p, w);
        }
        crate::codec::wire::put_usize(&mut p, self.slots.len());
        for s in &self.slots {
            crate::codec::wire::put_f64(&mut p, s.exponent);
            crate::codec::wire::put_u64(&mut p, s.key);
            crate::codec::wire::put_f64(&mut p, s.next_jump);
        }
        crate::codec::put_nested(&mut p, &self.sketch);
        crate::codec::write_envelope(
            crate::codec::tag::WR_RESERVOIR,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WR_RESERVOIR))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cfg = crate::codec::read_sampler_config(&mut r)?;
        let total_weight = r.finite_f64("WrReservoir total weight")?;
        if total_weight < 0.0 {
            return Err(Error::Codec(format!(
                "WrReservoir total weight must be >= 0: {total_weight}"
            )));
        }
        let processed = r.u64()?;
        let rng = Rng::from_state([r.u64()?, r.u64()?, r.u64()?, r.u64()?]);
        let n = r.seq_len(24)?;
        if n != cfg.k {
            return Err(Error::Codec(format!(
                "WrReservoir slot count {n} does not match k = {}",
                cfg.k
            )));
        }
        let mut slots = Vec::with_capacity(n);
        for _ in 0..n {
            // exponents and jump points may legitimately be +∞ (empty
            // slot / effectively-frozen slot) but never NaN or negative
            let exponent = r.f64()?;
            let key = r.u64()?;
            let next_jump = r.f64()?;
            if exponent.is_nan() || exponent < 0.0 {
                return Err(Error::Codec(format!(
                    "WrReservoir slot exponent must be >= 0: {exponent}"
                )));
            }
            if next_jump.is_nan() || next_jump < 0.0 {
                return Err(Error::Codec(format!(
                    "WrReservoir slot jump must be >= 0: {next_jump}"
                )));
            }
            slots.push(Slot { exponent, key, next_jump });
        }
        let sketch: CountSketch = crate::codec::read_nested(&mut r)?;
        r.finish("wr")?;
        let min_jump = slots
            .iter()
            .map(|s| s.next_jump)
            .fold(f64::INFINITY, f64::min);
        let s = WrReservoir {
            cfg,
            slots,
            sketch,
            rng,
            total_weight,
            min_jump,
            processed,
        };
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Persist, StreamSummary};

    fn cfg(k: usize, seed: u64) -> SamplerConfig {
        SamplerConfig::new(1.0, k)
            .with_seed(seed)
            .with_sketch_shape(5, 64)
    }

    #[test]
    fn fills_all_slots_and_draws_proportionally_to_weight() {
        // two keys, weight 9:1 — over many seeds, key 0 should win the
        // vast majority of draws
        let mut wins0 = 0usize;
        let mut total = 0usize;
        for seed in 0..40u64 {
            let mut s = WrReservoir::new(cfg(8, seed));
            s.process(&Element::new(0, 9.0));
            s.process(&Element::new(1, 1.0));
            for d in s.draws() {
                total += 1;
                if d == 0 {
                    wins0 += 1;
                }
            }
        }
        assert_eq!(total, 40 * 8, "every slot must be occupied");
        let frac = wins0 as f64 / total as f64;
        assert!(
            (frac - 0.9).abs() < 0.06,
            "key 0 won {frac} of draws, expected ~0.9"
        );
    }

    #[test]
    fn split_occurrences_weigh_like_one_item() {
        // a key's weight delivered in many unit occurrences competes like
        // its total: 10×1.0 vs 1×10.0 should draw ~evenly
        let mut wins_a = 0usize;
        let mut total = 0usize;
        for seed in 0..60u64 {
            let mut s = WrReservoir::new(cfg(4, seed));
            for _ in 0..10 {
                s.process(&Element::new(7, 1.0));
            }
            s.process(&Element::new(8, 10.0));
            for d in s.draws() {
                total += 1;
                if d == 7 {
                    wins_a += 1;
                }
            }
        }
        let frac = wins_a as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.1, "split key won {frac}, expected ~0.5");
    }

    #[test]
    fn persist_roundtrip_is_bit_identical_and_resumes() {
        let mut s = WrReservoir::new(cfg(6, 11));
        for i in 0..500u64 {
            s.process(&Element::new(i % 37, 1.0 + (i % 5) as f64));
        }
        let buf = s.encode();
        let mut back = WrReservoir::decode(&buf).unwrap();
        assert_eq!(back.encode(), buf, "canonical re-encode");
        // the restored reservoir continues the same RNG stream: more
        // elements land identically in both copies
        for i in 0..200u64 {
            let e = Element::new(i % 23, 2.0);
            s.process(&e);
            back.process(&e);
        }
        assert_eq!(s.encode(), back.encode());
    }

    #[test]
    fn decode_rejects_corrupt_slots() {
        let mut s = WrReservoir::new(cfg(2, 1));
        s.process(&Element::new(1, 1.0));
        let buf = s.encode();
        for cut in 0..buf.len() {
            assert!(WrReservoir::decode(&buf[..cut]).is_err());
        }
    }

    #[test]
    fn merge_keeps_slotwise_winners() {
        let c = cfg(5, 3);
        let mut a = WrReservoir::new(c.clone());
        let mut b = WrReservoir::new(c.clone());
        for i in 0..300u64 {
            a.process(&Element::new(i % 11, 1.0));
            b.process(&Element::new(100 + i % 13, 1.0));
        }
        let (sa, sb) = (a.clone(), b.clone());
        a.merge(&b).unwrap();
        assert_eq!(a.processed(), 600);
        assert_eq!(a.total_weight(), sa.total_weight() + sb.total_weight());
        for (m, (x, y)) in a.slots.iter().zip(sa.slots.iter().zip(&sb.slots)) {
            let want = if y.beats(x) { y } else { x };
            assert_eq!(m.key, want.key);
            assert_eq!(m.exponent.to_bits(), want.exponent.to_bits());
        }
    }

    #[test]
    fn weightless_and_zero_items_never_win() {
        let mut s = WrReservoir::new(cfg(3, 9));
        s.process(&Element::new(5, 0.0));
        assert_eq!(s.draws().len(), 0);
        s.process(&Element::new(6, 2.0));
        assert_eq!(s.draws(), vec![6, 6, 6]);
    }
}
