//! String-keyed 2-pass WORp for positive streams — the counter-based path
//! of the paper's Table 2 (`+, p ≤ 1` rows): SpaceSaving natively stores
//! key strings (Appendix A), so no KeyHash domain and no second lookup
//! structure is needed.
//!
//! Pass I runs SpaceSaving over the transformed (still positive) stream;
//! pass II collects exact frequencies for the tracked strings; output
//! re-ranks by exact `ν*` and cuts at k — exactly Algorithm 2 with the
//! deterministic ℓ1 sketch.
//!
//! The result is the crate-wide [`Sample`] type: entries are keyed by the
//! stable [`hash_str`] id of each string, and the sample carries a
//! [`KeyDict`] (`u64 → String`) so the original strings survive — the
//! same estimate ([`crate::estimate`]), query and wire-encode surface as
//! every numeric sampler, instead of a parallel string-sample struct.

use crate::sampler::{KeyDict, Sample, SampleEntry};
use crate::sketch::spacesaving::SpaceSaving;
use crate::transform::BottomKTransform;
use crate::util::hashing::hash_str;
use std::collections::HashMap;

/// Seed of the string → u64 key-id mapping (the randomizer and the
/// sample's entry keys both derive from it, so an id in the sample and
/// its dictionary entry always agree).
pub const STRING_KEY_SEED: u64 = 0x57A6;

/// The numeric key id of a string key — what a string-keyed [`Sample`]
/// stores in its entries and its [`KeyDict`].
#[inline]
pub fn string_key_id(key: &str) -> u64 {
    hash_str(STRING_KEY_SEED, key)
}

/// Pass-I state: SpaceSaving over the transformed stream.
pub struct StringWorpPass1 {
    p: f64,
    k: usize,
    transform: BottomKTransform,
    sketch: SpaceSaving<String>,
}

impl StringWorpPass1 {
    /// `capacity` counters (≥ 4k recommended); positive values only,
    /// p ≤ 1 (the counter guarantee regime of Table 2).
    pub fn new(p: f64, k: usize, capacity: usize, seed: u64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "counter-based WORp requires p <= 1");
        assert!(capacity >= 2 * k);
        StringWorpPass1 {
            p,
            k,
            transform: BottomKTransform::ppswor(seed, p),
            sketch: SpaceSaving::new(capacity),
        }
    }

    /// The per-key randomizer value for a string key.
    fn scale_of(&self, key: &str) -> f64 {
        self.transform.scale(string_key_id(key))
    }

    /// Process a positive element.
    pub fn process(&mut self, key: &str, val: f64) {
        assert!(val >= 0.0, "counter path requires positive values");
        let scaled = val * self.scale_of(key);
        self.sketch.process(key.to_string(), scaled);
    }

    /// Merge a sibling pass-I summary.
    pub fn merge(&mut self, other: &Self) -> crate::error::Result<()> {
        self.sketch.merge(&other.sketch)
    }

    /// Sketch size in words.
    pub fn size_words(&self) -> usize {
        self.sketch.size_words()
    }

    /// Freeze into pass II: the tracked strings become the candidate set.
    pub fn into_pass2(self) -> StringWorpPass2 {
        let candidates = self
            .sketch
            .top()
            .into_iter()
            .map(|c| (c.key, 0.0))
            .collect();
        StringWorpPass2 {
            p: self.p,
            k: self.k,
            transform: self.transform,
            exact: candidates,
        }
    }
}

/// Pass-II state: exact frequency collection for candidate strings.
pub struct StringWorpPass2 {
    p: f64,
    k: usize,
    transform: BottomKTransform,
    exact: HashMap<String, f64>,
}

impl StringWorpPass2 {
    /// Process an element of the replayed stream.
    pub fn process(&mut self, key: &str, val: f64) {
        if let Some(f) = self.exact.get_mut(key) {
            *f += val;
        }
    }

    /// Merge a sibling pass-II collector over a disjoint shard.
    pub fn merge(&mut self, other: &Self) {
        for (k, v) in &other.exact {
            *self.exact.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// Candidate count.
    pub fn candidates(&self) -> usize {
        self.exact.len()
    }

    /// Produce the sample: re-rank by exact `ν*`, cut at k. The returned
    /// [`Sample`] is keyed by [`string_key_id`] and carries the
    /// [`KeyDict`] for the surviving entries, so it flows through the
    /// same estimators and codecs as any numeric sample.
    pub fn sample(self) -> Sample {
        let t = &self.transform;
        let mut ranked: Vec<(String, SampleEntry)> = self
            .exact
            .into_iter()
            .filter(|(_, v)| *v > 0.0)
            .map(|(key, freq)| {
                let id = string_key_id(&key);
                let transformed = freq * t.scale(id);
                (key, SampleEntry { key: id, freq, transformed })
            })
            .collect();
        // deterministic ranking: (transformed, id) ties like the numeric
        // samplers' rank_desc ordering
        ranked.sort_by(|a, b| {
            b.1.transformed
                .partial_cmp(&a.1.transformed)
                .unwrap()
                .then(a.1.key.cmp(&b.1.key))
        });
        let tau = if ranked.len() > self.k {
            ranked[self.k].1.transformed
        } else {
            0.0
        };
        ranked.truncate(self.k);
        let mut names = KeyDict::new();
        let entries = ranked
            .into_iter()
            .map(|(key, entry)| {
                names.insert(entry.key, key);
                entry
            })
            .collect();
        Sample {
            entries,
            tau,
            p: self.p,
            dist: crate::util::hashing::BottomKDist::Exp,
            names: Some(names),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimate::sum_statistic;

    fn corpus() -> Vec<(String, f64)> {
        // 60 words with zipfian counts
        (0..60)
            .map(|i| (format!("word{i:02}"), 1000.0 / (i + 1) as f64))
            .collect()
    }

    fn run_two_pass(k: usize, seed: u64) -> Sample {
        let data = corpus();
        let mut p1 = StringWorpPass1::new(1.0, k, 8 * k, seed);
        for (w, c) in &data {
            // unaggregated: split each count into 3 parts
            for _ in 0..3 {
                p1.process(w, c / 3.0);
            }
        }
        let mut p2 = p1.into_pass2();
        for (w, c) in &data {
            for _ in 0..3 {
                p2.process(w, c / 3.0);
            }
        }
        p2.sample()
    }

    #[test]
    fn returns_k_string_keys_with_exact_counts() {
        let s = run_two_pass(10, 3);
        assert_eq!(s.entries.len(), 10);
        assert!(s.tau > 0.0);
        for e in &s.entries {
            let word = s.name_of(e.key).expect("dictionary entry for every key");
            assert_eq!(string_key_id(word), e.key);
            let i: usize = word[4..].parse().unwrap();
            let want = 1000.0 / (i + 1) as f64;
            assert!((e.freq - want).abs() < 1e-9, "{word}: {} vs {want}", e.freq);
        }
    }

    #[test]
    fn matches_perfect_ppswor_over_hashed_keys() {
        // the string sampler must agree with the numeric perfect sampler
        // run on the same hashed randomization
        let k = 8;
        let seed = 7;
        let data = corpus();
        let s = run_two_pass(k, seed);
        let t = BottomKTransform::ppswor(seed, 1.0);
        let mut want: Vec<(String, f64)> = data
            .iter()
            .map(|(w, c)| (w.clone(), c * t.scale(string_key_id(w))))
            .collect();
        want.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let want_keys: Vec<String> = want.into_iter().take(k).map(|(w, _)| w).collect();
        let got_keys: Vec<String> = s
            .entries
            .iter()
            .map(|e| s.name_of(e.key).unwrap().to_string())
            .collect();
        assert_eq!(got_keys, want_keys);
    }

    #[test]
    fn sum_estimates_reasonable_through_the_unified_surface() {
        // string samples use the SAME estimator path as numeric ones
        let data = corpus();
        let truth: f64 = data.iter().map(|(_, c)| c).sum();
        let ests: Vec<f64> = (0..200)
            .map(|seed| {
                let s = run_two_pass(20, seed);
                sum_statistic(&s, &|v| v, &|_| 1.0)
            })
            .collect();
        let m = crate::util::stats::mean(&ests);
        assert!((m - truth).abs() / truth < 0.1, "mean {m} truth {truth}");
    }

    #[test]
    fn merge_shards_equals_whole() {
        let data = corpus();
        let k = 6;
        let mut whole = StringWorpPass1::new(1.0, k, 8 * k, 5);
        let mut a = StringWorpPass1::new(1.0, k, 8 * k, 5);
        let mut b = StringWorpPass1::new(1.0, k, 8 * k, 5);
        for (i, (w, c)) in data.iter().enumerate() {
            whole.process(w, *c);
            if i % 2 == 0 {
                a.process(w, *c);
            } else {
                b.process(w, *c);
            }
        }
        a.merge(&b).unwrap();
        let mut p2a = a.into_pass2();
        let mut p2w = whole.into_pass2();
        for (w, c) in &data {
            p2a.process(w, *c);
            p2w.process(w, *c);
        }
        let sa = p2a.sample();
        let sw = p2w.sample();
        assert_eq!(sa.keys(), sw.keys());
        assert_eq!(sa.names, sw.names);
    }

    #[test]
    fn labels_fall_back_to_numeric_ids() {
        let s = run_two_pass(5, 11);
        let e = &s.entries[0];
        assert_eq!(s.label_of(e.key), s.name_of(e.key).unwrap());
        // an id outside the dictionary prints numerically
        assert_eq!(s.label_of(12345), "12345");
    }

    #[test]
    #[should_panic(expected = "p <= 1")]
    fn p_above_one_rejected() {
        StringWorpPass1::new(1.5, 5, 20, 1);
    }
}
