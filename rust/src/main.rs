//! `worp` — launcher binary for the WORp sampling pipeline.
//!
//! See `worp help` for the command surface. All logic lives in the
//! library ([`worp::cli`] wires configs, workloads and reporting
//! together); this binary only parses argv and sets the exit code.

use worp::cli::{dispatch, Args};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match Args::parse(argv).and_then(|args| dispatch(&args)) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}
