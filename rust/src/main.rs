//! `worp` — launcher binary for the WORp sampling pipeline.
//!
//! See `worp help` for the command surface. The heavy lifting lives in the
//! library ([`worp`] crate); this binary wires configs, workloads and
//! reporting together.

use worp::cli::{usage, Args};
use worp::config::PipelineConfig;
use worp::coordinator::{Coordinator, VecSource};
use worp::data::stream::GradientStream;
use worp::data::zipf::ZipfStream;
use worp::data::Element;
use worp::error::{Error, Result};
use worp::estimate::moment_estimate;
use worp::util::fmt::{sci, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "sample" => cmd_sample(&args),
        "psi" => cmd_psi(&args),
        "info" => cmd_info(&args),
        "" | "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command {other:?}; see `worp help`"
        ))),
    }
}

fn load_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => PipelineConfig::load(path)?,
        None => PipelineConfig::default(),
    };
    // CLI overrides
    cfg.p = args.parse_or("p", cfg.p)?;
    cfg.k = args.parse_or("k", cfg.k)?;
    cfg.q = args.parse_or("q", cfg.q)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    cfg.workers = args.parse_or("workers", cfg.workers)?;
    cfg.n = args.parse_or("n", cfg.n)?;
    cfg.alpha = args.parse_or("alpha", cfg.alpha)?;
    cfg.stream_len = args.parse_or("stream-len", cfg.stream_len)?;
    cfg.rows = args.parse_or("rows", cfg.rows)?;
    cfg.width = args.parse_or("width", cfg.width)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(w) = args.get("workload") {
        cfg.workload = w.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn make_stream(cfg: &PipelineConfig) -> Vec<Element> {
    match cfg.workload.as_str() {
        "gradient" => GradientStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E)
            .collect(),
        _ => ZipfStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E).collect(),
    }
}

fn cmd_sample(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let method = args.str_or("method", "1pass");
    let coord = Coordinator::from_config(&cfg)?;
    println!(
        "workload={} n={} alpha={} stream_len={} | p={} k={} method={method} backend={} workers={}",
        cfg.workload, cfg.n, cfg.alpha, cfg.stream_len, cfg.p, cfg.k, cfg.backend, cfg.workers
    );
    let elems = make_stream(&cfg);
    let (sample, metrics) = match (method.as_str(), cfg.backend.as_str()) {
        ("1pass", "native") => coord.one_pass(elems.clone())?,
        ("1pass", "xla") => coord.one_pass_xla(elems.clone(), &cfg.artifacts_dir)?,
        ("2pass", _) => coord.two_pass(&VecSource(elems.clone()))?,
        ("tv", _) => {
            use worp::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
            let tvc = TvSamplerConfig::new(cfg.p, cfg.k, cfg.n, cfg.seed, SamplerKind::Oracle)
                .with_r(8 * cfg.k);
            let mut tv = TvSampler::new(tvc);
            for e in &elems {
                tv.process(e);
            }
            let keys = tv.produce();
            println!(
                "tv sample ({} keys): {:?}",
                keys.len(),
                &keys[..keys.len().min(20)]
            );
            return Ok(());
        }
        (m, b) => {
            return Err(Error::Config(format!(
                "unsupported method/backend combination {m}/{b}"
            )))
        }
    };
    println!("pipeline: {}", metrics.report());
    let mut t = Table::new(
        &format!("top sampled keys (of {})", sample.len()),
        &["key", "freq", "transformed"],
    );
    for e in sample.entries.iter().take(15) {
        t.row(&[e.key.to_string(), sci(e.freq), sci(e.transformed)]);
    }
    t.print();
    println!("tau = {}", sci(sample.tau));
    for p_prime in [1.0, 2.0] {
        println!(
            "estimated ||nu||_{p_prime}^{p_prime} = {}",
            sci(moment_estimate(&sample, p_prime))
        );
    }
    Ok(())
}

fn cmd_psi(args: &Args) -> Result<()> {
    let n = args.parse_or("n", 10_000usize)?;
    let k = args.parse_or("k", 100usize)?;
    let rho = args.parse_or("rho", 2.0f64)?;
    let delta = args.parse_or("delta", 0.01f64)?;
    let trials = args.parse_or("trials", 2_000usize)?;
    let psi = worp::psi::psi_estimate(n, k, rho, delta, trials, 0xCA11B);
    let lb2 = worp::psi::psi_lower_bound(n, k, rho, 2.0);
    println!(
        "Psi_{{n={n},k={k},rho={rho}}}(delta={delta}) ~= {psi:.5}  (thm 3.1 bound @C=2: {lb2:.5})"
    );
    // the effective constant C the simulation implies (paper App B.1)
    let ln_nk = ((n as f64) / (k as f64)).ln().max(1.0);
    let c = if rho <= 1.0 {
        1.0 / (psi * ln_nk)
    } else {
        (rho - 1.0f64).max(1.0 / ln_nk) / psi
    };
    println!("implied constant C = {c:.3} (paper: C<2 suffices for k>=10)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    match worp::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!(
            "PJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    match worp::runtime::artifact::ArtifactDir::open(&dir) {
        Ok(a) => {
            for s in a.specs() {
                println!(
                    "artifact {}: file={:?} rows={} width={} batch={}",
                    s.name, s.file, s.rows, s.width, s.batch
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}
