//! Crate-wide error type (hand-rolled `Display`/`Error` impls — no
//! `thiserror` offline, DESIGN.md §7).

use std::fmt;

/// Errors surfaced by the WORp library.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI parameter problems.
    Config(String),

    /// A sketch or sampler was used with incompatible parameters
    /// (e.g. merging summaries with different shapes, seeds or types).
    Incompatible(String),

    /// A summary was driven through an invalid state transition (e.g.
    /// finalizing a multi-pass sampler before its last pass, or advancing
    /// a single-pass summary).
    State(String),

    /// The dataset failed the rHH test — the sample cannot be certified
    /// (Appendix A, "Testing for failure").
    RhhFailure(String),

    /// PJRT / XLA runtime errors (artifact loading, compile, execute).
    Runtime(String),

    /// Pipeline orchestration errors (worker panic, channel close, ...).
    Pipeline(String),

    /// Malformed bytes fed to the persistence codec (bad magic, version,
    /// truncation, checksum/fingerprint mismatch, length-field lies).
    /// Decoding untrusted input maps every failure here — it never panics.
    Codec(String),

    /// I/O errors.
    Io(std::io::Error),

    /// A cluster member (or served endpoint) could not be reached after
    /// the retry budget was exhausted, or is currently marked Down. The
    /// operation may succeed later; the cluster state itself is intact.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Incompatible(m) => write!(f, "incompatible sketches: {m}"),
            Error::State(m) => write!(f, "invalid state: {m}"),
            Error::RhhFailure(m) => write!(f, "rHH failure: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Unavailable(m) => write!(f, "unavailable: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Config(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("missing key 'p'".into());
        assert!(e.to_string().contains("missing key 'p'"));
        let e = Error::RhhFailure("tail too heavy".into());
        assert!(e.to_string().contains("rHH"));
        let e = Error::State("pass I not finished".into());
        assert!(e.to_string().contains("invalid state"));
        let e = Error::Codec("bad magic".into());
        assert!(e.to_string().contains("codec error: bad magic"));
        let e = Error::Unavailable("member \"beta\" down after 3 attempts".into());
        assert!(e.to_string().contains("unavailable: member"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
