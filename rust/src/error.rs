//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the WORp library.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file / CLI parameter problems.
    #[error("config error: {0}")]
    Config(String),

    /// A sketch or sampler was used with incompatible parameters
    /// (e.g. merging sketches with different shapes or randomization).
    #[error("incompatible sketches: {0}")]
    Incompatible(String),

    /// The dataset failed the rHH test — the sample cannot be certified
    /// (Appendix A, "Testing for failure").
    #[error("rHH failure: {0}")]
    RhhFailure(String),

    /// PJRT / XLA runtime errors (artifact loading, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Pipeline orchestration errors (worker panic, channel close, ...).
    #[error("pipeline error: {0}")]
    Pipeline(String),

    /// I/O errors.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Config(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("missing key 'p'".into());
        assert!(e.to_string().contains("missing key 'p'"));
        let e = Error::RhhFailure("tail too heavy".into());
        assert!(e.to_string().contains("rHH"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
