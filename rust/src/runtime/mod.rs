//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced by `make artifacts` →
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python never runs at
//! request time: artifacts are compiled once here and executed per
//! micro-batch.

pub mod artifact;
pub mod executor;

use crate::error::{Error, Result};

/// A process-wide PJRT CPU client (compilation is cached per executable,
/// the client itself is shared).
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(XlaRuntime { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it on this client.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {path:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = XlaRuntime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        let p = rt.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "platform={p}");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = XlaRuntime::cpu().unwrap();
        match rt.compile_hlo_text(std::path::Path::new("/nonexistent/x.hlo.txt")) {
            Err(err) => assert!(err.to_string().contains("runtime error")),
            Ok(_) => panic!("expected an error for a missing artifact"),
        }
    }
}
