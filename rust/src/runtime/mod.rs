//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced by `make artifacts` →
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python never runs at
//! request time: artifacts are compiled once here and executed per
//! micro-batch.
//!
//! The PJRT bindings (`xla` crate) are not available offline, so the
//! whole backend is gated behind the `xla` cargo feature. The default
//! build compiles the stub below: every entry point returns
//! [`Error::Runtime`] with an actionable message, artifact discovery
//! ([`artifact`]) stays fully functional, and the rest of the crate is
//! unaffected.

pub mod artifact;

#[cfg(feature = "xla")]
pub mod executor;

use crate::error::{Error, Result};

#[cfg(feature = "xla")]
/// A process-wide PJRT CPU client (compilation is cached per executable,
/// the client itself is shared).
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(XlaRuntime { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it on this client.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {path:?}: {e}")))
    }
}

#[cfg(not(feature = "xla"))]
fn unavailable() -> Error {
    Error::Runtime(
        "XLA backend unavailable: this binary was built without the `xla` cargo feature \
         (add the vendored xla_extension bindings as a dependency in rust/Cargo.toml, \
         then rebuild with `--features xla`)"
            .into(),
    )
}

#[cfg(not(feature = "xla"))]
/// Stub PJRT client: every constructor fails with a clear message.
pub struct XlaRuntime {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Always fails in stub builds.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }
}

#[cfg(not(feature = "xla"))]
/// Stub executors mirroring `runtime::executor` so downstream code
/// compiles unchanged; all entry points fail with [`Error::Runtime`].
pub mod executor {
    use super::{unavailable, XlaRuntime};
    use crate::data::Element;
    use crate::error::Result;
    use crate::runtime::artifact::ArtifactDir;

    /// Stub of the XLA-offloaded CountSketch.
    pub struct XlaCountSketch {
        /// Kernel invocations (always 0 in stub builds).
        pub kernel_calls: u64,
        table: Vec<f32>,
    }

    impl XlaCountSketch {
        /// Always fails in stub builds.
        pub fn load(_rt: &XlaRuntime, _dir: &ArtifactDir, _seed: u64) -> Result<Self> {
            Err(unavailable())
        }

        /// Unreachable in stub builds (`load` never succeeds).
        pub fn process(&mut self, _e: &Element) -> Result<()> {
            Err(unavailable())
        }

        /// Unreachable in stub builds.
        pub fn flush(&mut self) -> Result<()> {
            Err(unavailable())
        }

        /// Unreachable in stub builds.
        pub fn est(&self, _key: u64) -> f64 {
            0.0
        }

        /// Sketch shape `(rows, width)`.
        pub fn shape(&self) -> (usize, usize) {
            (0, 0)
        }

        /// Micro-batch size baked into the artifact.
        pub fn batch_size(&self) -> usize {
            0
        }

        /// Elements processed.
        pub fn processed(&self) -> u64 {
            0
        }

        /// Current table (row-major f32).
        pub fn table(&self) -> &[f32] {
            &self.table
        }
    }

    /// Stub of the batched estimate executor.
    pub struct XlaEstimator {
        _private: (),
    }

    impl XlaEstimator {
        /// Always fails in stub builds.
        pub fn load(_rt: &XlaRuntime, _dir: &ArtifactDir, _seed: u64) -> Result<Self> {
            Err(unavailable())
        }

        /// Micro-batch size baked into the artifact.
        pub fn batch_size(&self) -> usize {
            0
        }

        /// Unreachable in stub builds.
        pub fn estimate(&self, _table: &[f32], _keys: &[u64]) -> Result<Vec<f64>> {
            Err(unavailable())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "xla")]
    #[test]
    fn cpu_client_boots() {
        let rt = XlaRuntime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        let p = rt.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "platform={p}");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = XlaRuntime::cpu().unwrap();
        match rt.compile_hlo_text(std::path::Path::new("/nonexistent/x.hlo.txt")) {
            Err(err) => assert!(err.to_string().contains("runtime error")),
            Ok(_) => panic!("expected an error for a missing artifact"),
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_fails_with_actionable_message() {
        let err = XlaRuntime::cpu().unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
