//! Artifact discovery: locate AOT outputs and read the manifest written by
//! `python/compile/aot.py`.
//!
//! The manifest (`artifacts/manifest.txt`) uses the same TOML subset as
//! the config system: one section per kernel with its lowered shapes, e.g.
//!
//! ```toml
//! [countsketch_update]
//! file = "countsketch_update_r5_w1024_b4096.hlo.txt"
//! rows = 5
//! width = 1024
//! batch = 4096
//! ```

use crate::config::Document;
use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Description of one compiled kernel artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Kernel name (manifest section).
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: PathBuf,
    /// Sketch rows baked into the artifact.
    pub rows: usize,
    /// Sketch width baked into the artifact.
    pub width: usize,
    /// Micro-batch size baked into the artifact.
    pub batch: usize,
}

/// The artifacts directory and its manifest.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

impl ArtifactDir {
    /// Open `dir` and parse `manifest.txt`.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        if !manifest.exists() {
            return Err(Error::Runtime(format!(
                "no manifest at {manifest:?} — run `make artifacts` first"
            )));
        }
        let doc = Document::load(&manifest)?;
        let mut specs = Vec::new();
        for name in known_kernels() {
            if let Some(v) = doc.get(name, "file") {
                let file = v
                    .as_str()
                    .ok_or_else(|| Error::Runtime(format!("manifest [{name}] file not a string")))?;
                specs.push(ArtifactSpec {
                    name: name.to_string(),
                    file: PathBuf::from(file),
                    rows: doc.usize_or(name, "rows", 0),
                    width: doc.usize_or(name, "width", 0),
                    batch: doc.usize_or(name, "batch", 0),
                });
            }
        }
        Ok(ArtifactDir { dir, specs })
    }

    /// Check whether an artifacts dir looks usable without opening it.
    pub fn exists<P: AsRef<Path>>(dir: P) -> bool {
        dir.as_ref().join("manifest.txt").exists()
    }

    /// All kernels in the manifest.
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find a kernel by name.
    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| Error::Runtime(format!("kernel {name:?} not in manifest")))
    }

    /// Absolute path of a spec's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// Kernel names the runtime knows how to drive.
pub fn known_kernels() -> &'static [&'static str] {
    &[
        "countsketch_update",
        "countsketch_estimate",
        "ppswor_transform_update",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_manifest_sections() {
        let dir = std::env::temp_dir().join("worp_artifact_test1");
        write_manifest(
            &dir,
            r#"
[countsketch_update]
file = "cs_update.hlo.txt"
rows = 5
width = 256
batch = 1024

[countsketch_estimate]
file = "cs_est.hlo.txt"
rows = 5
width = 256
batch = 64
"#,
        );
        let a = ArtifactDir::open(&dir).unwrap();
        assert_eq!(a.specs().len(), 2);
        let u = a.find("countsketch_update").unwrap();
        assert_eq!(u.rows, 5);
        assert_eq!(u.batch, 1024);
        assert!(a.path_of(u).ends_with("cs_update.hlo.txt"));
        assert!(a.find("nope").is_err());
    }

    #[test]
    fn missing_manifest_is_actionable_error() {
        let dir = std::env::temp_dir().join("worp_artifact_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = ArtifactDir::open(&dir).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
        assert!(!ArtifactDir::exists(&dir));
    }
}
