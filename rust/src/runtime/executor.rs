//! Compiled-kernel executors: the XLA-offloaded CountSketch backend.
//!
//! [`XlaCountSketch`] mirrors the native [`crate::sketch::countsketch::CountSketch`]
//! but performs the table update on the PJRT client by executing the
//! AOT-lowered Pallas kernel (`countsketch_update`). Hashing stays in rust
//! (single source of randomness — DESIGN.md §4): per element we compute
//! the per-row `(bucket, sign·value)` coordinates and buffer them; a full
//! micro-batch executes one kernel call
//!
//! ```text
//! sketch[R,W] , bucket[R,B] i32 , signval[R,B] f32  ->  sketch'[R,W]
//! ```
//!
//! Partial batches are padded with `signval = 0` (a no-op contribution).

use super::artifact::{ArtifactDir, ArtifactSpec};
use super::XlaRuntime;
use crate::data::Element;
use crate::error::{Error, Result};
use crate::util::hashing::SketchHasher;

/// A compiled `countsketch_update` executable plus its staging buffers.
pub struct XlaCountSketch {
    exe: xla::PjRtLoadedExecutable,
    hasher: SketchHasher,
    rows: usize,
    width: usize,
    batch: usize,
    /// Current sketch table, row-major `rows × width` (f32 on device).
    table: Vec<f32>,
    /// Staged bucket indices, `rows × batch`.
    buckets: Vec<i32>,
    /// Staged sign·value entries, `rows × batch`.
    signvals: Vec<f32>,
    /// Number of staged elements (< batch).
    staged: usize,
    /// Elements processed (including staged).
    processed: u64,
    /// Kernel invocations so far.
    pub kernel_calls: u64,
}

impl XlaCountSketch {
    /// Load the `countsketch_update` artifact from `dir` and build an
    /// empty sketch with the artifact's baked shape. `seed` must match the
    /// native sketch it is compared against.
    pub fn load(rt: &XlaRuntime, dir: &ArtifactDir, seed: u64) -> Result<Self> {
        let spec = dir.find("countsketch_update")?.clone();
        Self::from_spec(rt, dir, &spec, seed)
    }

    /// Build from an explicit artifact spec.
    pub fn from_spec(
        rt: &XlaRuntime,
        dir: &ArtifactDir,
        spec: &ArtifactSpec,
        seed: u64,
    ) -> Result<Self> {
        if spec.rows == 0 || spec.width == 0 || spec.batch == 0 {
            return Err(Error::Runtime(format!(
                "artifact {} has incomplete shape metadata",
                spec.name
            )));
        }
        let exe = rt.compile_hlo_text(&dir.path_of(spec))?;
        Ok(XlaCountSketch {
            exe,
            hasher: SketchHasher::new(seed, spec.width),
            rows: spec.rows,
            width: spec.width,
            batch: spec.batch,
            table: vec![0.0; spec.rows * spec.width],
            buckets: vec![0; spec.rows * spec.batch],
            signvals: vec![0.0; spec.rows * spec.batch],
            staged: 0,
            processed: 0,
            kernel_calls: 0,
        })
    }

    /// Sketch shape `(rows, width)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.width)
    }

    /// Micro-batch size baked into the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Stage one element; executes the kernel when the batch fills.
    pub fn process(&mut self, e: &Element) -> Result<()> {
        let b = self.staged;
        for r in 0..self.rows {
            self.buckets[r * self.batch + b] = self.hasher.bucket(r, e.key) as i32;
            self.signvals[r * self.batch + b] =
                (self.hasher.sign(r, e.key) * e.val) as f32;
        }
        self.staged += 1;
        self.processed += 1;
        if self.staged == self.batch {
            self.flush()?;
        }
        Ok(())
    }

    /// Execute the kernel on the staged (possibly partial, zero-padded)
    /// batch and fold the result into the table.
    pub fn flush(&mut self) -> Result<()> {
        if self.staged == 0 {
            return Ok(());
        }
        // zero-pad the rest of the batch
        for r in 0..self.rows {
            for b in self.staged..self.batch {
                self.buckets[r * self.batch + b] = 0;
                self.signvals[r * self.batch + b] = 0.0;
            }
        }
        let sketch = xla::Literal::vec1(&self.table)
            .reshape(&[self.rows as i64, self.width as i64])
            .map_err(wrap)?;
        let buckets = xla::Literal::vec1(&self.buckets)
            .reshape(&[self.rows as i64, self.batch as i64])
            .map_err(wrap)?;
        let signvals = xla::Literal::vec1(&self.signvals)
            .reshape(&[self.rows as i64, self.batch as i64])
            .map_err(wrap)?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[sketch, buckets, signvals])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let out = result.to_tuple1().map_err(wrap)?;
        self.table = out.to_vec::<f32>().map_err(wrap)?;
        self.staged = 0;
        self.kernel_calls += 1;
        Ok(())
    }

    /// Median-of-rows estimate (computed natively over the table — the
    /// update is the hot path worth offloading; see also the
    /// `countsketch_estimate` artifact exercised in the benches).
    pub fn est(&self, key: u64) -> f64 {
        let mut vals: Vec<f32> = (0..self.rows)
            .map(|r| {
                let b = self.hasher.bucket(r, key);
                (self.hasher.sign(r, key) as f32) * self.table[r * self.width + b]
            })
            .collect();
        let mid = vals.len() / 2;
        // total_cmp mirrors the native CountSketch median: a NaN in a
        // device-written table degrades deterministically, never panics
        vals.select_nth_unstable_by(mid, f32::total_cmp);
        vals[mid] as f64
    }

    /// Current table (row-major f32).
    pub fn table(&self) -> &[f32] {
        &self.table
    }
}

fn wrap<E: std::fmt::Display>(e: E) -> Error {
    Error::Runtime(e.to_string())
}

/// A compiled `countsketch_estimate` executor: batched key estimates,
/// used by benches to demonstrate the full offload of the read path.
pub struct XlaEstimator {
    exe: xla::PjRtLoadedExecutable,
    hasher: SketchHasher,
    rows: usize,
    width: usize,
    batch: usize,
}

impl XlaEstimator {
    /// Load `countsketch_estimate` from `dir`.
    pub fn load(rt: &XlaRuntime, dir: &ArtifactDir, seed: u64) -> Result<Self> {
        let spec = dir.find("countsketch_estimate")?.clone();
        let exe = rt.compile_hlo_text(&dir.path_of(&spec))?;
        Ok(XlaEstimator {
            exe,
            hasher: SketchHasher::new(seed, spec.width),
            rows: spec.rows,
            width: spec.width,
            batch: spec.batch,
        })
    }

    /// Batch size baked into the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Estimate a batch of keys (≤ batch size) against a sketch table.
    pub fn estimate(&self, table: &[f32], keys: &[u64]) -> Result<Vec<f64>> {
        if keys.len() > self.batch {
            return Err(Error::Runtime(format!(
                "estimate batch {} exceeds artifact batch {}",
                keys.len(),
                self.batch
            )));
        }
        let mut buckets = vec![0i32; self.rows * self.batch];
        let mut signs = vec![0.0f32; self.rows * self.batch];
        for (i, &k) in keys.iter().enumerate() {
            for r in 0..self.rows {
                buckets[r * self.batch + i] = self.hasher.bucket(r, k) as i32;
                signs[r * self.batch + i] = self.hasher.sign(r, k) as f32;
            }
        }
        let sketch = xla::Literal::vec1(table)
            .reshape(&[self.rows as i64, self.width as i64])
            .map_err(wrap)?;
        let b = xla::Literal::vec1(&buckets)
            .reshape(&[self.rows as i64, self.batch as i64])
            .map_err(wrap)?;
        let s = xla::Literal::vec1(&signs)
            .reshape(&[self.rows as i64, self.batch as i64])
            .map_err(wrap)?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[sketch, b, s])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let out = result.to_tuple1().map_err(wrap)?;
        let ests: Vec<f32> = out.to_vec::<f32>().map_err(wrap)?;
        Ok(ests[..keys.len()].iter().map(|&v| v as f64).collect())
    }
}

// Integration tests live in rust/tests/xla_runtime.rs (they require
// `make artifacts` to have run).
