//! Scenario engine: end-to-end workloads with hard accuracy gates.
//!
//! A *scenario* drives a realistic workload through a live [`Engine`] —
//! in-process, over a served TCP session, or across a 3-node cluster —
//! computes exact ground truth with an independent pass, and checks the
//! served answers against declared thresholds. Every check is a
//! [`Gate`]; a failing gate makes [`ScenarioReport::check`] (and hence
//! `worp scenario <name>`) fail loudly, so CI treats accuracy
//! regressions exactly like compile errors.
//!
//! The four scenarios map onto the paper's headline claims:
//!
//! - **`wr-vs-wor`** — the motivating comparison: ℓ2 sampling of a
//!   Zipf[2] stream, estimating `‖ν‖₂²`. The WOR bottom-k estimator must
//!   beat the WR reservoir estimator on NRMSE (Cohen–Pagh–Woodruff §1,
//!   Fig. 1), at the same sample size `k`.
//! - **`coordinated`** — two drifted daily streams sampled with a shared
//!   seed; the weighted-Jaccard estimate off the coordinated samples
//!   must land within a declared distance of the exact value, and
//!   comparing *uncoordinated* instances must be refused.
//! - **`decay`** — a served time-decayed sampler over an era-shifted
//!   stream: served answers must be bit-identical to an offline
//!   replay, match the closed-form decayed frequency, and the sample
//!   must concentrate on the recent era.
//! - **`sliding-window`** — windowed WORp vs plain 1-pass on the same
//!   era-shifted stream: the windowed sample must surface strictly more
//!   of the final era's hot keys.
//!
//! Scenarios whose samplers are clock- or RNG-coupled
//! (`parallel_safe() == false`: decayed, WR reservoir, windowed) refuse
//! `--cluster` with a typed config error — a sharded clock would skew
//! their answers, which is exactly the property the engine enforces.

pub mod coordinated;
pub mod decay;
pub mod sliding_window;
pub mod wr_vs_wor;

use crate::cluster::{ClusterClient, ClusterSpec, Member, RetryPolicy};
use crate::data::{Element, ElementBlock};
use crate::engine::client::Client;
use crate::engine::proto::InstanceSpec;
use crate::engine::server::{ServeOpts, Server};
use crate::engine::{Engine, EngineOpts};
use crate::error::{Error, Result};
use crate::estimate::similarity::SimilarityReport;
use crate::sampler::Sample;
use std::fmt;
use std::sync::Arc;

/// Every scenario name [`run`] accepts (canonical spellings).
pub const SCENARIOS: &[&str] = &["decay", "coordinated", "wr-vs-wor", "sliding-window"];

/// Where the scenario's engine lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// In-process [`Engine`] (no sockets).
    Local,
    /// One engine behind a loopback [`Server`], driven through [`Client`].
    Served,
    /// Three engines behind loopback servers, driven through
    /// [`ClusterClient`] on the merge law.
    Cluster,
}

impl Mode {
    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "local" => Ok(Mode::Local),
            "serve" | "served" => Ok(Mode::Served),
            "cluster" => Ok(Mode::Cluster),
            other => Err(Error::Config(format!(
                "unknown scenario mode {other:?} (expected local|serve|cluster)"
            ))),
        }
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Local => "local",
            Mode::Served => "serve",
            Mode::Cluster => "cluster",
        }
    }
}

/// Knobs every scenario accepts; `0` means "the scenario's default".
#[derive(Clone, Copy, Debug)]
pub struct ScenarioOpts {
    /// Engine placement.
    pub mode: Mode,
    /// Sample size override (0 = scenario default).
    pub k: usize,
    /// Base randomization seed.
    pub seed: u64,
    /// Repetition count for NRMSE-style gates (0 = scenario default).
    pub runs: usize,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts { mode: Mode::Local, k: 0, seed: 0x5EED_5CE0, runs: 0 }
    }
}

impl ScenarioOpts {
    fn k_or(&self, default: usize) -> usize {
        if self.k == 0 {
            default
        } else {
            self.k
        }
    }

    fn runs_or(&self, default: usize) -> usize {
        if self.runs == 0 {
            default
        } else {
            self.runs
        }
    }
}

/// One pass/fail accuracy check with its evidence.
#[derive(Clone, Debug)]
pub struct Gate {
    /// What was checked.
    pub what: String,
    /// The measured value.
    pub observed: f64,
    /// The declared bound it was held against.
    pub threshold: f64,
    /// Whether the check passed.
    pub pass: bool,
}

impl Gate {
    /// Passes when `observed < threshold`.
    pub fn below(what: impl Into<String>, observed: f64, threshold: f64) -> Gate {
        Gate { what: what.into(), observed, threshold, pass: observed < threshold }
    }

    /// Passes when `observed >= threshold`.
    pub fn at_least(what: impl Into<String>, observed: f64, threshold: f64) -> Gate {
        Gate { what: what.into(), observed, threshold, pass: observed >= threshold }
    }
}

/// The outcome of one scenario run: every gate, pass or fail.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name.
    pub scenario: String,
    /// Mode it ran under.
    pub mode: Mode,
    /// All accuracy gates, in evaluation order.
    pub gates: Vec<Gate>,
}

impl ScenarioReport {
    fn new(scenario: &str, mode: Mode) -> ScenarioReport {
        ScenarioReport { scenario: scenario.to_string(), mode, gates: Vec::new() }
    }

    fn push(&mut self, gate: Gate) {
        self.gates.push(gate);
    }

    /// True when every gate passed.
    pub fn passed(&self) -> bool {
        !self.gates.is_empty() && self.gates.iter().all(|g| g.pass)
    }

    /// `Err` naming every failed gate (what `worp scenario` propagates
    /// so the process exits non-zero on an accuracy regression).
    pub fn check(&self) -> Result<()> {
        if self.gates.is_empty() {
            return Err(Error::Runtime(format!(
                "scenario {:?} evaluated no gates",
                self.scenario
            )));
        }
        let failed: Vec<String> = self
            .gates
            .iter()
            .filter(|g| !g.pass)
            .map(|g| {
                format!("{} (observed {:.4e}, threshold {:.4e})", g.what, g.observed, g.threshold)
            })
            .collect();
        if failed.is_empty() {
            Ok(())
        } else {
            Err(Error::Runtime(format!(
                "scenario {:?} failed {} gate(s): {}",
                self.scenario,
                failed.len(),
                failed.join("; ")
            )))
        }
    }
}

impl fmt::Display for ScenarioReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "scenario {} [{}]", self.scenario, self.mode.name())?;
        for g in &self.gates {
            writeln!(
                f,
                "  [{}] {:<58} observed {:>12.4e}  threshold {:>12.4e}",
                if g.pass { "PASS" } else { "FAIL" },
                g.what,
                g.observed,
                g.threshold,
            )?;
        }
        write!(f, "  => {}", if self.passed() { "PASS" } else { "FAIL" })
    }
}

/// Reject cluster placement for single-clock scenarios.
fn require_single_node(scenario: &str, mode: Mode) -> Result<()> {
    if mode == Mode::Cluster {
        return Err(Error::Config(format!(
            "scenario {scenario:?} drives a clock-coupled sampler (parallel_safe = false) \
             and cannot run sharded across a cluster — use --serve or local mode"
        )));
    }
    Ok(())
}

/// A fully-defaulted instance spec (paper-default sketch shape, ppswor
/// randomization) — scenarios override only what they exercise.
fn base_spec(method: &str, p: f64, k: usize, seed: u64, n: usize) -> InstanceSpec {
    InstanceSpec {
        method: method.to_string(),
        dist: "ppswor".to_string(),
        p,
        k,
        q: 2.0,
        seed,
        n,
        delta: 0.01,
        eps: 1.0 / 3.0,
        rows: 0,
        width: 0,
        window: 0,
        buckets: 0,
        decay: String::new(),
        decay_rate: 0.0,
        coordinate: String::new(),
    }
}

/// Ingest chunk size: small enough to exercise the batch paths, large
/// enough to stay off the syscall floor in served modes.
const CHUNK: usize = 4096;

/// The live engine a scenario drives, behind one placement-agnostic
/// surface: the same workload code runs in-process, served, or
/// clustered.
pub struct Host {
    mode: Mode,
    inner: HostInner,
}

enum HostInner {
    Local(Arc<Engine>),
    Served {
        server: Server,
        client: Client,
    },
    Cluster {
        servers: Vec<Server>,
        client: ClusterClient,
    },
}

impl Host {
    /// Spin up the requested placement on loopback (served / cluster
    /// modes bind OS-assigned ports, so parallel CI runs never collide).
    pub fn start(mode: Mode) -> Result<Host> {
        let inner = match mode {
            Mode::Local => HostInner::Local(Arc::new(Engine::new(EngineOpts::new(2, 1024)?))),
            Mode::Served => {
                let engine = Arc::new(Engine::new(EngineOpts::new(2, 1024)?));
                let server = Server::start(engine, "127.0.0.1:0", ServeOpts::default())?;
                let client = Client::connect(&server.local_addr().to_string())?;
                HostInner::Served { server, client }
            }
            Mode::Cluster => {
                // Placement depends only on member *names*, so bind each
                // server first and fill the real addresses in afterwards —
                // the stamp covers name + slices and survives the fixup.
                const SLICES: usize = 16;
                let names = ["alpha", "beta", "gamma"];
                let skeleton = ClusterSpec {
                    name: "scenario".to_string(),
                    slices: SLICES,
                    members: names
                        .iter()
                        .map(|n| Member { name: n.to_string(), addr: "0.0.0.0:0".to_string() })
                        .collect(),
                };
                let mut servers = Vec::with_capacity(names.len());
                let mut members = Vec::with_capacity(names.len());
                for n in names {
                    let owned = skeleton.owned_slices(n)?;
                    let engine = Arc::new(Engine::with_ownership(
                        EngineOpts::new(1, 1024)?,
                        SLICES,
                        &owned,
                        skeleton.stamp(),
                    )?);
                    let server = Server::start(engine, "127.0.0.1:0", ServeOpts::default())?;
                    members.push(Member {
                        name: n.to_string(),
                        addr: server.local_addr().to_string(),
                    });
                    servers.push(server);
                }
                let spec =
                    ClusterSpec { name: "scenario".to_string(), slices: SLICES, members };
                let client = ClusterClient::connect_with(spec, RetryPolicy::default())?;
                HostInner::Cluster { servers, client }
            }
        };
        Ok(Host { mode, inner })
    }

    /// The placement this host runs.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Whether the placement tracks creation seeds and can *refuse*
    /// uncoordinated similarity queries (the cluster computes similarity
    /// client-side from merged samples and has no seed registry).
    pub fn tracks_seeds(&self) -> bool {
        !matches!(self.inner, HostInner::Cluster { .. })
    }

    /// Create a named instance. In local mode the coordinate reference
    /// is resolved here, mirroring what the server's `CREATE` handler
    /// does for the wire modes.
    pub fn create(&mut self, name: &str, spec: &InstanceSpec) -> Result<()> {
        match &mut self.inner {
            HostInner::Local(engine) => {
                let mut spec = spec.clone();
                if !spec.coordinate.is_empty() {
                    spec.seed = engine.seed_of(&spec.coordinate)?;
                    spec.coordinate.clear();
                }
                engine.create(name, &spec.to_worp()?)
            }
            HostInner::Served { client, .. } => client.create(name, spec),
            HostInner::Cluster { client, .. } => client.create(name, spec),
        }
    }

    /// Stream elements in, in [`CHUNK`]-sized blocks.
    pub fn ingest(&mut self, name: &str, elems: &[Element]) -> Result<()> {
        for chunk in elems.chunks(CHUNK) {
            let block = ElementBlock::from_elements(chunk);
            match &mut self.inner {
                HostInner::Local(engine) => engine.ingest(name, &block).map(|_| ())?,
                HostInner::Served { client, .. } => client.ingest(name, &block).map(|_| ())?,
                HostInner::Cluster { client, .. } => client.ingest(name, &block).map(|_| ())?,
            }
        }
        Ok(())
    }

    /// Flush pending partial blocks.
    pub fn flush(&mut self, name: &str) -> Result<()> {
        match &mut self.inner {
            HostInner::Local(engine) => engine.flush(name).map(|_| ()),
            HostInner::Served { client, .. } => client.flush(name).map(|_| ()),
            HostInner::Cluster { client, .. } => client.flush(name).map(|_| ()),
        }
    }

    /// The instance's current WOR sample.
    pub fn sample(&mut self, name: &str) -> Result<Sample> {
        match &mut self.inner {
            HostInner::Local(engine) => engine.sample(name),
            HostInner::Served { client, .. } => client.sample(name),
            HostInner::Cluster { client, .. } => client.sample(name),
        }
    }

    /// Moment estimate `‖ν‖_{p'}^{p'}` off the current sample.
    pub fn moment(&mut self, name: &str, p_prime: f64) -> Result<f64> {
        match &mut self.inner {
            HostInner::Local(engine) => engine.moment(name, p_prime),
            HostInner::Served { client, .. } => client.moment(name, p_prime),
            HostInner::Cluster { client, .. } => client.moment(name, p_prime),
        }
    }

    /// Similarity report over two instances' samples. Local / served
    /// placements enforce seed compatibility server-side; the cluster
    /// estimates client-side from the two merged samples.
    pub fn similarity(&mut self, a: &str, b: &str) -> Result<SimilarityReport> {
        match &mut self.inner {
            HostInner::Local(engine) => engine.similarity(a, b),
            HostInner::Served { client, .. } => client.similarity(a, b),
            HostInner::Cluster { client, .. } => {
                let sa = client.sample(a)?;
                let sb = client.sample(b)?;
                crate::estimate::similarity::report(&sa, &sb)
            }
        }
    }

    /// Drop an instance (scenarios clean up so repeated runs against a
    /// long-lived server never collide on names).
    pub fn drop_instance(&mut self, name: &str) -> Result<()> {
        match &mut self.inner {
            HostInner::Local(engine) => engine.drop_instance(name),
            HostInner::Served { client, .. } => client.drop_instance(name),
            HostInner::Cluster { client, .. } => client.drop_instance(name),
        }
    }

    /// Stop every loopback server this host started.
    pub fn shutdown(self) {
        match self.inner {
            HostInner::Local(_) => {}
            HostInner::Served { mut server, client } => {
                drop(client);
                server.stop();
            }
            HostInner::Cluster { mut servers, client } => {
                drop(client);
                for s in &mut servers {
                    s.stop();
                }
            }
        }
    }
}

/// Run one scenario by name. The report carries every gate; callers
/// decide whether to print, assert, or both (the CLI does both).
pub fn run(name: &str, opts: &ScenarioOpts) -> Result<ScenarioReport> {
    match name {
        "decay" => decay::run(opts),
        "coordinated" => coordinated::run(opts),
        "wr-vs-wor" | "wr_vs_wor" | "wr" => wr_vs_wor::run(opts),
        "sliding-window" | "sliding_window" | "window" => sliding_window::run(opts),
        other => Err(Error::Config(format!(
            "unknown scenario {other:?} (expected one of {})",
            SCENARIOS.join("|")
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_canonical_spellings() {
        assert_eq!(Mode::parse("local").unwrap(), Mode::Local);
        assert_eq!(Mode::parse("serve").unwrap(), Mode::Served);
        assert_eq!(Mode::parse("served").unwrap(), Mode::Served);
        assert_eq!(Mode::parse("cluster").unwrap(), Mode::Cluster);
        assert!(Mode::parse("remote").is_err());
        for m in [Mode::Local, Mode::Served, Mode::Cluster] {
            assert_eq!(Mode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn gates_compare_on_the_declared_side() {
        assert!(Gate::below("x", 1.0, 2.0).pass);
        assert!(!Gate::below("x", 2.0, 2.0).pass);
        assert!(Gate::at_least("x", 2.0, 2.0).pass);
        assert!(!Gate::at_least("x", 1.0, 2.0).pass);
    }

    #[test]
    fn report_check_names_the_failures() {
        let mut r = ScenarioReport::new("t", Mode::Local);
        assert!(r.check().is_err(), "no gates evaluated is a failure");
        r.push(Gate::below("good", 1.0, 2.0));
        assert!(r.check().is_ok());
        assert!(r.passed());
        r.push(Gate::below("nrmse ordering", 3.0, 2.0));
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("nrmse ordering"), "{err}");
        assert!(!r.passed());
        let shown = r.to_string();
        assert!(shown.contains("PASS") && shown.contains("FAIL"), "{shown}");
    }

    #[test]
    fn unknown_scenario_is_a_config_error() {
        let opts = ScenarioOpts::default();
        assert!(matches!(run("nope", &opts), Err(Error::Config(_))));
        // single-clock scenarios refuse cluster placement up front
        let cl = ScenarioOpts { mode: Mode::Cluster, ..ScenarioOpts::default() };
        for s in ["decay", "wr-vs-wor", "sliding-window"] {
            assert!(matches!(run(s, &cl), Err(Error::Config(_))), "{s} accepted --cluster");
        }
    }

    #[test]
    fn cluster_host_round_trips_a_parallel_safe_instance() {
        let mut host = Host::start(Mode::Cluster).unwrap();
        assert!(!host.tracks_seeds());
        let spec = base_spec("exact", 1.0, 8, 7, 100);
        host.create("scn/ct", &spec).unwrap();
        let elems: Vec<Element> =
            (0..500u64).map(|i| Element::new(i % 40, 1.0)).collect();
        host.ingest("scn/ct", &elems).unwrap();
        host.flush("scn/ct").unwrap();
        let s = host.sample("scn/ct").unwrap();
        assert_eq!(s.len(), 8);
        let m = host.moment("scn/ct", 1.0).unwrap();
        assert!((m - 500.0).abs() < 1e-6, "exact first moment, got {m}");
        host.drop_instance("scn/ct").unwrap();
        host.shutdown();
    }
}
