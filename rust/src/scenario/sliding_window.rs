//! `sliding-window` — windowed WORp vs plain 1-pass WORp on an
//! era-shifted stream, served end to end.
//!
//! Workload: four eras; era `e` sends 60 % of its elements to its own
//! fifty hot keys (`e·100 .. e·100+50`) and the rest uniformly over the
//! whole domain. A window covering only the tail of the final era must
//! surface that era's hot set, while the unwindowed 1-pass sampler —
//! which weighs all history equally — splits its sample across every
//! era's hot keys.
//!
//! Gate: the windowed sample contains strictly more final-era hot keys
//! than the 1-pass sample, plus an absolute floor on the windowed hot
//! fraction so the ordering cannot pass with both samplers degenerate.

use super::{base_spec, require_single_node, Gate, Host, ScenarioOpts, ScenarioReport};
use crate::data::Element;
use crate::error::Result;
use crate::util::rng::Rng;

const ERAS: u64 = 4;
const ERA_LEN: u64 = 20_000;
const HOT: u64 = 50;
const DOMAIN: usize = 10_000;
const WINDOW: u64 = 10_000;
const BUCKETS: usize = 10;
const DEFAULT_K: usize = 50;

fn era_stream(seed: u64) -> Vec<Element> {
    let mut rng = Rng::new(seed ^ 0x57AB_1E57);
    let mut elems = Vec::with_capacity((ERAS * ERA_LEN) as usize);
    for era in 0..ERAS {
        for _ in 0..ERA_LEN {
            let key = if rng.uniform() < 0.6 {
                era * 100 + rng.below(HOT)
            } else {
                rng.below(DOMAIN as u64)
            };
            elems.push(Element::new(key, 1.0));
        }
    }
    elems
}

fn hot_hits(keys: &[u64]) -> usize {
    let last = (ERAS - 1) * 100..(ERAS - 1) * 100 + HOT;
    keys.iter().filter(|k| last.contains(k)).count()
}

/// Run the windowed-vs-1-pass comparison; see the module docs.
pub fn run(opts: &ScenarioOpts) -> Result<ScenarioReport> {
    require_single_node("sliding-window", opts.mode)?;
    let k = opts.k_or(DEFAULT_K);
    let elems = era_stream(opts.seed);

    let mut host = Host::start(opts.mode)?;
    let windowed = "scenario/windowed";
    let unwindowed = "scenario/unwindowed";
    let mut w_spec = base_spec("windowed", 1.0, k, opts.seed, DOMAIN);
    w_spec.window = WINDOW;
    w_spec.buckets = BUCKETS;
    host.create(windowed, &w_spec)?;
    host.create(unwindowed, &base_spec("1pass", 1.0, k, opts.seed, DOMAIN))?;
    host.ingest(windowed, &elems)?;
    host.ingest(unwindowed, &elems)?;
    host.flush(windowed)?;
    host.flush(unwindowed)?;
    let w_sample = host.sample(windowed)?;
    let u_sample = host.sample(unwindowed)?;
    host.drop_instance(windowed)?;
    host.drop_instance(unwindowed)?;
    host.shutdown();

    let w_hot = hot_hits(&w_sample.keys());
    let u_hot = hot_hits(&u_sample.keys());
    let mut report = ScenarioReport::new("sliding-window", opts.mode);
    report.push(Gate::at_least(
        format!("windowed minus 1-pass final-era hot keys at k={k}"),
        w_hot as f64 - u_hot as f64,
        1.0,
    ));
    report.push(Gate::at_least(
        "windowed sample's final-era hot fraction".to_string(),
        w_hot as f64 / (w_sample.len().max(1) as f64),
        0.4,
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_run_prefers_the_recent_era() {
        let report = run(&ScenarioOpts::default()).unwrap();
        report.check().unwrap();
    }

    #[test]
    fn era_stream_is_deterministic_in_the_seed() {
        let a = era_stream(9);
        let b = era_stream(9);
        let c = era_stream(10);
        assert_eq!(a.len(), (ERAS * ERA_LEN) as usize);
        assert!(a.iter().zip(&b).all(|(x, y)| x.key == y.key));
        assert!(a.iter().zip(&c).any(|(x, y)| x.key != y.key));
    }
}
