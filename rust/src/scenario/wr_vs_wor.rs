//! `wr-vs-wor` — the paper's motivating comparison, run end to end
//! through a live engine.
//!
//! Workload: ℓ2 sampling of an aggregated Zipf[2] stream (2 000 keys),
//! estimating the second moment `‖ν‖₂²`. Each run draws one WOR
//! bottom-k sample (exact ppswor over `ν²`) and one WR reservoir sample
//! (k independent weighted draws, served by [`crate::sampler::wr_reservoir`])
//! at the same `k` and seed, then estimates the moment from each:
//!
//! - WOR: the paper's inverse-probability bottom-k estimator
//!   ([`crate::estimate::moment_estimate`], served as the `MOMENT` op).
//! - WR: the Horvitz–Thompson estimator over the *distinct* sampled
//!   keys, `Σ ν̂_x² / (1 − (1 − q_x)^k)` with `q_x = ν_x² / ‖ν‖₂²` —
//!   the classic with-replacement estimate the paper argues against.
//!
//! Gate: over the run ensemble, `NRMSE(WOR) < NRMSE(WR)` — on a Zipf[2]
//! frequency profile the WR sample keeps re-drawing the head and its
//! estimate degrades, which is the ordering Fig. 1 of the paper shows.
//! A second gate sanity-bounds the WOR error itself so the ordering
//! can't pass vacuously with both estimators broken.

use super::{base_spec, require_single_node, Gate, Host, ScenarioOpts, ScenarioReport};
use crate::data::zipf::zipf_frequencies;
use crate::data::Element;
use crate::error::Result;
use crate::estimate::wr_inclusion_prob;
use crate::sampler::Sample;
use crate::util::stats::nrmse;
use std::collections::HashSet;

const KEYS: usize = 2_000;
const ALPHA: f64 = 2.0;
const P: f64 = 2.0;
const DEFAULT_K: usize = 50;
const DEFAULT_RUNS: usize = 30;

/// HT moment estimate from a WR reservoir sample: distinct keys only,
/// each inverse-weighted by its exact k-draw inclusion probability.
/// `w_norm` is the stream's true total weight `‖ν‖_p^p` (the scenario
/// generated the stream, so the normalizer is exact — both estimators
/// compete on sampling error alone).
fn wr_ht_estimate(sample: &Sample, p_prime: f64, k: usize, w_norm: f64) -> f64 {
    let mut seen = HashSet::new();
    let mut total = 0.0;
    for e in &sample.entries {
        if !seen.insert(e.key) {
            continue;
        }
        let f = e.freq.abs();
        if f <= 0.0 {
            continue;
        }
        let q = (f.powf(P) / w_norm).min(1.0);
        let pi = wr_inclusion_prob(q, k).max(1e-300);
        total += f.powf(p_prime) / pi;
    }
    total
}

/// Run the comparison; see the module docs for the gates.
pub fn run(opts: &ScenarioOpts) -> Result<ScenarioReport> {
    require_single_node("wr-vs-wor", opts.mode)?;
    let k = opts.k_or(DEFAULT_K);
    let runs = opts.runs_or(DEFAULT_RUNS);
    let freqs = zipf_frequencies(KEYS, ALPHA, 1.0);
    let truth: f64 = freqs.iter().map(|f| f * f).sum();
    let w_norm: f64 = freqs.iter().map(|f| f.powf(P)).sum();
    // aggregated stream: one element per key, so the reservoir's element
    // weights are exactly the per-key sampling weights ν_x^p
    let elems: Vec<Element> =
        freqs.iter().enumerate().map(|(i, &f)| Element::new(i as u64, f)).collect();

    let mut host = Host::start(opts.mode)?;
    let mut wor_est = Vec::with_capacity(runs);
    let mut wr_est = Vec::with_capacity(runs);
    for r in 0..runs {
        let seed = opts.seed.wrapping_add(r as u64);
        let wor_name = format!("scenario/wor-{r}");
        host.create(&wor_name, &base_spec("exact", P, k, seed, KEYS))?;
        host.ingest(&wor_name, &elems)?;
        host.flush(&wor_name)?;
        wor_est.push(host.moment(&wor_name, P)?);
        host.drop_instance(&wor_name)?;

        let wr_name = format!("scenario/wr-{r}");
        host.create(&wr_name, &base_spec("wr", P, k, seed, KEYS))?;
        host.ingest(&wr_name, &elems)?;
        host.flush(&wr_name)?;
        let sample = host.sample(&wr_name)?;
        wr_est.push(wr_ht_estimate(&sample, P, k, w_norm));
        host.drop_instance(&wr_name)?;
    }
    host.shutdown();

    let e_wor = nrmse(&wor_est, truth);
    let e_wr = nrmse(&wr_est, truth);
    let mut report = ScenarioReport::new("wr-vs-wor", opts.mode);
    report.push(Gate::below(
        format!("NRMSE ordering: WOR beats WR at k={k} on Zipf[{ALPHA}]"),
        e_wor,
        e_wr,
    ));
    report.push(Gate::below("WOR NRMSE sane in absolute terms".to_string(), e_wor, 0.35));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Mode;

    #[test]
    fn local_run_reproduces_the_paper_ordering() {
        // small ensemble: this is the smoke the CI job runs at full size
        let opts = ScenarioOpts { mode: Mode::Local, runs: 12, ..ScenarioOpts::default() };
        let report = run(&opts).unwrap();
        report.check().unwrap();
    }

    #[test]
    fn wr_ht_estimate_is_exact_when_every_key_is_sampled() {
        use crate::sampler::SampleEntry;
        use crate::util::hashing::BottomKDist;
        // two keys, both present in the sample with exact frequencies and
        // huge k: inclusion probs ≈ 1, so the HT sum collapses to Σ ν²
        let entries = vec![
            SampleEntry { key: 1, freq: 3.0, transformed: 0.1 },
            SampleEntry { key: 2, freq: 4.0, transformed: 0.2 },
            SampleEntry { key: 1, freq: 3.0, transformed: 0.1 }, // duplicate slot
        ];
        let s = Sample { entries, tau: 0.0, p: P, dist: BottomKDist::Exp, names: None };
        let w = 9.0 + 16.0;
        let est = wr_ht_estimate(&s, P, 10_000, w);
        assert!((est - w).abs() < 1e-6 * w, "est {est} want {w}");
    }
}
