//! `decay` — served time-decayed sampling over an era-shifted stream.
//!
//! Workload: three eras of equal length; each era hammers its own ten
//! hot keys (`era·100 .. era·100+10`), one unit per element. Under
//! exponential decay with a rate that damps a whole era to below the
//! sampler's zero-frequency floor, the final sample must consist of the
//! last era's keys only.
//!
//! Gates:
//! - **served ≡ offline**: the engine-served sample (batched ingest,
//!   arbitrary chunk boundaries) is *bit-identical* to an offline
//!   scalar replay through [`DecayedWorp`] — the run-chunked tick
//!   contract, end to end;
//! - **closed form**: a hot key's served frequency matches the direct
//!   sum `Σ e^{−λ(T−t)}` over its update ticks to ~1e−9 relative — the
//!   lazy carry accumulates no real error;
//! - **recency**: every sampled key belongs to the final era.

use super::{base_spec, require_single_node, Gate, Host, ScenarioOpts, ScenarioReport};
use crate::api::StreamSummary;
use crate::data::Element;
use crate::error::Result;
use crate::sampler::decayed::DecayedWorp;
use crate::transform::decay::DecaySpec;

const ERAS: u64 = 3;
const ERA_LEN: u64 = 2_000;
const HOT: u64 = 10;
const RATE: f64 = 0.02;
const DEFAULT_K: usize = 10;

/// The era stream: element `i` of era `e` updates key
/// `e·100 + (i mod HOT)` by `1.0`.
fn era_stream() -> Vec<Element> {
    let mut elems = Vec::with_capacity((ERAS * ERA_LEN) as usize);
    for era in 0..ERAS {
        for i in 0..ERA_LEN {
            elems.push(Element::new(era * 100 + (i % HOT), 1.0));
        }
    }
    elems
}

/// Direct closed-form decayed frequency of `key` at the end of the
/// stream (tick `T = |stream|`), from first principles.
fn closed_form(elems: &[Element], key: u64, rate: f64) -> f64 {
    let t_final = elems.len() as u64;
    let mut sum = 0.0;
    for (i, e) in elems.iter().enumerate() {
        if e.key == key {
            let t = i as u64 + 1; // the implicit clock stamps now+1
            sum += e.val * (-rate * (t_final - t) as f64).exp();
        }
    }
    sum
}

/// Run the decay workload; see the module docs for the gates.
pub fn run(opts: &ScenarioOpts) -> Result<ScenarioReport> {
    require_single_node("decay", opts.mode)?;
    let k = opts.k_or(DEFAULT_K);
    let elems = era_stream();

    let mut spec = base_spec("decayed", 1.0, k, opts.seed, (ERAS * 100) as usize);
    spec.decay = "exp".to_string();
    spec.decay_rate = RATE;

    let mut host = Host::start(opts.mode)?;
    let name = "scenario/decay";
    host.create(name, &spec)?;
    host.ingest(name, &elems)?;
    host.flush(name)?;
    let served = host.sample(name)?;
    host.drop_instance(name)?;
    host.shutdown();

    // offline replay: same config through the same builder path, scalar
    // process loop — the reference the served answer must equal bit-wise
    let cfg = spec.to_worp()?.sampler_config()?;
    let mut offline = DecayedWorp::new(cfg, DecaySpec::exponential(RATE)?);
    for e in &elems {
        StreamSummary::process(&mut offline, e);
    }
    let reference = offline.sample();

    let identical = served.len() == reference.len()
        && served.tau.to_bits() == reference.tau.to_bits()
        && served
            .entries
            .iter()
            .zip(&reference.entries)
            .all(|(a, b)| {
                a.key == b.key
                    && a.freq.to_bits() == b.freq.to_bits()
                    && a.transformed.to_bits() == b.transformed.to_bits()
            });

    let mut report = ScenarioReport::new("decay", opts.mode);
    report.push(Gate::at_least(
        "served sample ≡ offline replay (bit-identical)".to_string(),
        if identical { 1.0 } else { 0.0 },
        1.0,
    ));

    // closed form for one final-era hot key, against the served answer
    let probe = (ERAS - 1) * 100;
    let want = closed_form(&elems, probe, RATE);
    let got = served
        .entries
        .iter()
        .find(|e| e.key == probe)
        .map(|e| e.freq)
        .unwrap_or(0.0);
    report.push(Gate::below(
        format!("closed-form decayed frequency of key {probe} (rel err)"),
        (got - want).abs() / want.max(1e-300),
        1e-9,
    ));

    // a whole era of decay is below the sampler's zero floor, so only the
    // final era's keys can appear at all
    let last_era = (ERAS - 1) * 100..(ERAS - 1) * 100 + HOT;
    let recent =
        served.entries.iter().filter(|e| last_era.contains(&e.key)).count() as f64;
    report.push(Gate::at_least(
        "fraction of sampled keys from the final era".to_string(),
        recent / (served.len().max(1) as f64),
        0.8,
    ));
    report.push(Gate::at_least(
        "sample is non-empty".to_string(),
        served.len() as f64,
        1.0,
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_run_passes_every_gate() {
        let report = run(&ScenarioOpts::default()).unwrap();
        report.check().unwrap();
    }

    #[test]
    fn closed_form_matches_the_sampler_primitive() {
        let elems = era_stream();
        let probe = (ERAS - 1) * 100 + 3;
        let direct = closed_form(&elems, probe, RATE);
        let spec = DecaySpec::exponential(RATE).unwrap();
        let mut s = DecayedWorp::new(
            crate::sampler::SamplerConfig::new(1.0, 4).with_seed(1),
            spec,
        );
        for e in &elems {
            StreamSummary::process(&mut s, e);
        }
        let lazy = s.decayed_freq(probe);
        assert!(
            (lazy - direct).abs() < 1e-9 * direct,
            "lazy {lazy} vs direct {direct}"
        );
    }
}
