//! `coordinated` — shared-seed sampling of two drifted daily streams,
//! with the similarity estimate gated against exact ground truth.
//!
//! Workload: day 1 is an aggregated Zipf[1.1] stream; day 2 re-weights
//! a ~30 % subset of keys (drift). Instance `a` is created normally;
//! instance `b` is created with `coordinate = a`, so the engine resolves
//! and shares `a`'s randomization seed — the paper's coordinated-sketch
//! regime, where bottom-k samples become comparable across streams.
//!
//! Gates:
//! - the weighted-Jaccard estimate off the two coordinated samples must
//!   land within a declared distance of the exact value;
//! - coordinated samples of drifted streams must overlap heavily in
//!   *keys* (that overlap is the whole point of coordination);
//! - on placements with a seed registry (local / served), querying
//!   similarity across *uncoordinated* instances must be refused with a
//!   typed error rather than silently returning near-zero overlap.
//!
//! This scenario's sampler (`exact` ppswor) is parallel-safe, so all
//! three placements — local, served, and the 3-node cluster — run it.

use super::{base_spec, Gate, Host, ScenarioOpts, ScenarioReport};
use crate::data::zipf::zipf_frequencies;
use crate::data::Element;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

const KEYS: usize = 4_000;
const ALPHA: f64 = 1.1;
const DEFAULT_K: usize = 256;
const JACCARD_TOL: f64 = 0.12;

/// Day-2 frequencies: drift ~30 % of keys by a random factor in
/// `[0.25, 1.75]`, leave the rest untouched.
fn drifted(day1: &[f64], seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xD21F_7ED0);
    day1.iter()
        .map(|&f| {
            if rng.uniform() < 0.3 {
                f * rng.range_f64(0.25, 1.75)
            } else {
                f
            }
        })
        .collect()
}

fn aggregated(freqs: &[f64]) -> Vec<Element> {
    freqs.iter().enumerate().map(|(i, &f)| Element::new(i as u64, f)).collect()
}

/// Run the coordinated-similarity workload; see the module docs.
pub fn run(opts: &ScenarioOpts) -> Result<ScenarioReport> {
    let k = opts.k_or(DEFAULT_K);
    let day1 = zipf_frequencies(KEYS, ALPHA, 1_000.0);
    let day2 = drifted(&day1, opts.seed);
    let exact_j = {
        let (mut mins, mut maxs) = (0.0f64, 0.0f64);
        for (a, b) in day1.iter().zip(&day2) {
            mins += a.min(*b);
            maxs += a.max(*b);
        }
        mins / maxs
    };

    let mut host = Host::start(opts.mode)?;
    let a = "scenario/day1";
    let b = "scenario/day2";
    host.create(a, &base_spec("exact", 1.0, k, opts.seed, KEYS))?;
    // b inherits a's seed through the coordinate reference — the spec's
    // own seed is deliberately different so the test proves resolution
    let mut spec_b = base_spec("exact", 1.0, k, opts.seed.wrapping_add(999), KEYS);
    spec_b.coordinate = a.to_string();
    host.create(b, &spec_b)?;
    host.ingest(a, &aggregated(&day1))?;
    host.ingest(b, &aggregated(&day2))?;
    host.flush(a)?;
    host.flush(b)?;

    let rep = host.similarity(a, b)?;
    let mut report = ScenarioReport::new("coordinated", opts.mode);
    report.push(Gate::below(
        format!("|estimated − exact| weighted Jaccard at k={k}"),
        (rep.jaccard - exact_j).abs(),
        JACCARD_TOL,
    ));
    report.push(Gate::at_least(
        "coordinated samples share most keys (overlap)".to_string(),
        rep.overlap,
        0.5,
    ));
    report.push(Gate::at_least(
        "min/max sums are ordered and positive".to_string(),
        if rep.min_sum > 0.0 && rep.max_sum >= rep.min_sum { 1.0 } else { 0.0 },
        1.0,
    ));

    if host.tracks_seeds() {
        // an uncoordinated instance must be refused, not quietly compared
        let c = "scenario/uncoordinated";
        host.create(c, &base_spec("exact", 1.0, k, opts.seed.wrapping_add(31_337), KEYS))?;
        host.ingest(c, &aggregated(&day2))?;
        host.flush(c)?;
        let refused = match host.similarity(a, c) {
            Err(Error::Incompatible(_)) => 1.0,
            Err(_) | Ok(_) => 0.0,
        };
        report.push(Gate::at_least(
            "similarity across different seeds is refused".to_string(),
            refused,
            1.0,
        ));
        host.drop_instance(c)?;
    }

    host.drop_instance(a)?;
    host.drop_instance(b)?;
    host.shutdown();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Mode;

    #[test]
    fn local_run_passes_every_gate() {
        let report = run(&ScenarioOpts::default()).unwrap();
        report.check().unwrap();
        assert_eq!(report.gates.len(), 4, "local mode includes the refusal gate");
    }

    #[test]
    fn drift_changes_some_keys_and_spares_others() {
        let day1 = zipf_frequencies(500, 1.1, 100.0);
        let day2 = drifted(&day1, 7);
        let changed = day1.iter().zip(&day2).filter(|(a, b)| a != b).count();
        assert!(changed > 50 && changed < 450, "drifted {changed}/500");
    }
}
