//! Hand-rolled CLI argument parsing (no `clap` offline — DESIGN.md §7).
//!
//! Grammar: `worp <subcommand> [--key value]... [--flag]...`

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // value present and not itself an option?
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(name.to_string()),
                }
            } else {
                return Err(Error::Config(format!("unexpected positional arg {a:?}")));
            }
        }
        Ok(Args { command, options, flags })
    }

    /// Option as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; errors on unparsable values.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| Error::Config(format!("cannot parse --{key} {v:?}"))),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "worp — WOR ℓp sampling pipeline (Cohen–Pagh–Woodruff 2020 reproduction)

USAGE:
    worp <command> [options]

COMMANDS:
    sample      run a WORp sampler over a generated workload
                  --config <file.toml>   launcher config (see examples/)
                  --method <1pass|2pass|tv>   (default 1pass)
                  --p <f64> --k <n> --workers <n> --alpha <f64>
                  --backend <native|xla>
    psi         calibrate Ψ_{n,k,ρ}(δ) by simulation (Appendix B.1)
                  --n <n> --k <n> --rho <f64> --delta <f64> --trials <n>
    info        print runtime / artifact status
    help        show this text
"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&["sample", "--p", "2.0", "--k", "100", "--verbose"]);
        assert_eq!(a.command, "sample");
        assert_eq!(a.get("p"), Some("2.0"));
        assert_eq!(a.parse_or::<usize>("k", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["psi"]);
        assert_eq!(a.parse_or::<f64>("rho", 2.0).unwrap(), 2.0);
        assert_eq!(a.str_or("method", "1pass"), "1pass");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["sample", "--k", "ten"]);
        assert!(a.parse_or::<usize>("k", 1).is_err());
    }

    #[test]
    fn stray_positional_rejected() {
        let r = Args::parse(["sample".into(), "oops".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn flag_before_option_parses() {
        let a = parse(&["sample", "--fast", "--k", "5"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("k"), Some("5"));
    }
}
