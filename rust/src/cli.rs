//! Command-line surface: hand-rolled argument parsing (no `clap` offline
//! — DESIGN.md §7) plus the command implementations the `worp` binary
//! dispatches to.
//!
//! Grammar: `worp <subcommand> [--key value]... [--flag]...`
//!
//! The `sample` command is method-agnostic: it builds a
//! `Box<dyn WorSampler>` through the [`Worp`] builder and hands it to
//! [`Coordinator::run_dyn`] — adding a sampler to the crate requires no
//! CLI changes beyond the builder.

use crate::api::builder::{Method, Worp};
use crate::api::{StreamSummary, WorSampler};
use crate::config::PipelineConfig;
use crate::coordinator::{Coordinator, VecSource};
use crate::data::stream::GradientStream;
use crate::data::zipf::ZipfStream;
use crate::data::Element;
use crate::error::{Error, Result};
use crate::estimate::moment_estimate;
use crate::util::fmt::{sci, Table};
use std::collections::HashMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand (only `merge-files`
    /// takes any — the input paths; other commands reject them).
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut positionals = Vec::new();
        while let Some(a) = it.next() {
            if a == "--" {
                // everything after a bare `--` is positional, so file
                // lists can never be swallowed as option values
                positionals.extend(it);
                break;
            }
            if let Some(name) = a.strip_prefix("--") {
                // value present and not itself an option?
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(name.to_string()),
                }
            } else {
                positionals.push(a);
            }
        }
        Ok(Args { command, options, flags, positionals })
    }

    /// Reject stray positionals (commands that take none call this first).
    fn no_positionals(&self) -> Result<()> {
        match self.positionals.first() {
            Some(p) => Err(Error::Config(format!("unexpected positional arg {p:?}"))),
            None => Ok(()),
        }
    }

    /// Option as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; errors on unparsable values.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| Error::Config(format!("cannot parse --{key} {v:?}"))),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "worp — WOR ℓp sampling pipeline (Cohen–Pagh–Woodruff 2020 reproduction)

USAGE:
    worp <command> [options]

COMMANDS:
    sample      run a WORp sampler over a generated workload
                  --config <worp.toml>   TOML config (see worp.example.toml);
                                         flags below override its values
                  --method <1pass|2pass|tv|windowed|exact>
                  --dist <ppswor|priority>
                  --p <f64> --k <n> --workers <n> --alpha <f64>
                  --window <n> --buckets <n>   (windowed method)
                  --backend <native|xla>
                  --checkpoint-dir <dir> --checkpoint-every <batches>
                                         snapshot shard states; a rerun
                                         resumes from existing snapshots
    shard       sketch one partition of the workload and write the
                summary state to disk (offline / multi-process merging)
                  --out <state.worp>     output file (required)
                  --shards <m> --shard-index <i>
                                         process every m-th element
                                         starting at i (default 1/0)
                  plus all `sample` workload/sampler options
    merge-files <a.worp> <b.worp> ...
                decode per-partition summaries, verify fingerprints,
                fold through the merge tree, and print the sample
                  --out <merged.worp>    also write the merged state
    serve       run the long-lived multi-tenant engine over TCP;
                SIGTERM/SIGINT drain gracefully (stop accepting, flush,
                final snapshot, exit 0)
                  --addr <host:port>     listen address (default from the
                                         [server] config section)
                  --workers <n> --batch <n>
                                         per-instance shards / block size
                  --max-connections <n>  concurrent connection cap (1024)
                  --io-threads <n>       reactor worker threads serving
                                         connections (default 4)
                  --idle-timeout <secs>  evict connections idle this long
                                         with a typed error frame
                                         (default 60; 0 disables)
                  --checkpoint-dir <dir> --checkpoint-every <ingests>
                                         periodically snapshot every
                                         instance; restored on startup
                  --cluster <worp.toml> --node <name>
                                         serve as the named member of the
                                         [cluster] section: own only the
                                         rendezvous-assigned hash slices,
                                         bind the member's address
    client <action>
                talk to a running `worp serve` (--addr <host:port>):
                  --timeout <secs>        per-op read/write + connect
                                          deadline (default 120; 0 = none)
                  ping | list
                  create   --name <ns/x>  plus `sample` sampler options
                  ingest   --name <ns/x>  stream the generated workload
                           --pipeline <n> in-flight frame window (default
                                          from [server] pipeline_window;
                                          1 = lockstep)
                  flush    --name <ns/x>
                  advance  --name <ns/x>  (multi-pass methods)
                  sample   --name <ns/x>
                  moment   --name <ns/x> --pprime <f64>
                  rankfreq --name <ns/x> --max <n>
                  stats    --name <ns/x> | stats --all (whole server)
                  snapshot --name <ns/x> --out <file.worp>
                  restore  --in <file.worp>
                  drop     --name <ns/x>
    cluster <action>
                drive a sharded cluster (--cluster <worp.toml> with a
                [cluster] section; every member already serving):
                  status                  per-member stats + placement
                  create   --name <ns/x>  on every member (sampler opts)
                  ingest   --name <ns/x>  route the workload by key hash
                  flush | sample | moment | rankfreq | drop  --name <ns/x>
                  sample   --name <ns/x> --partial
                                          answer from the reachable slices
                                          and print the typed coverage gap
                                          instead of failing on a down node
                  snapshot --name <ns/x> --out <dir>   per-member files
                  rebalance --to <new-worp.toml>
                                          move slices onto the new member
                                          set (install-before-drop; the
                                          merged sample is unchanged)
                  failover --to <new-worp.toml>
                                          rebalance that tolerates dead old
                                          owners: their slices are reported
                                          lost instead of aborting
                  watch    [--interval <secs>] [--grace <n>] [--once]
                           [--out <surviving.toml>]
                                          probe members; after --grace
                                          consecutive failures, synthesize
                                          the surviving topology, fail over
                                          onto it, and (--out) persist it
                retries/backoff/deadlines read the [cluster.retry] section
                of the --cluster file (attempts, base_ms, cap_ms,
                op_deadline_ms, probe_secs, seed)
    psi         calibrate Ψ_{n,k,ρ}(δ) by simulation (Appendix B.1)
                  --n <n> --k <n> --rho <f64> --delta <f64> --trials <n>
    scenario <decay|coordinated|wr-vs-wor|sliding-window>
                drive a whole workload through a live engine, check the
                answers against exact ground truth, and exit non-zero if
                any accuracy gate fails (the CI scenario-smoke job)
                  --serve                 drive over a loopback TCP server
                  --cluster               drive a 3-node loopback cluster
                                          (parallel-safe scenarios only)
                  --mode <local|serve|cluster>  explicit spelling
                  --k <n> --seed <n> --runs <n>  scenario overrides
    bench       scalar vs batch vs SoA-block ingestion throughput per
                summary, plus est_many query throughput, the row-major
                vs interleaved table-layout ablation and the served
                (TCP) ingest pair, written as machine-readable JSON
                  --smoke                 small CI profile (default: full)
                  --out <path>            output file (default BENCH_PR10.json)
                  --stream-len <n> --n <keys> --batch <n> --iters <n> --k <n>
    info        print runtime / artifact status
    help        show this text
"
}

/// Dispatch a parsed command line.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "sample" => {
            args.no_positionals()?;
            cmd_sample(args)
        }
        "shard" => {
            args.no_positionals()?;
            cmd_shard(args)
        }
        "merge-files" => cmd_merge_files(args),
        "serve" => {
            args.no_positionals()?;
            cmd_serve(args)
        }
        "client" => cmd_client(args),
        "cluster" => cmd_cluster(args),
        "psi" => {
            args.no_positionals()?;
            cmd_psi(args)
        }
        "scenario" => cmd_scenario(args),
        "bench" => {
            args.no_positionals()?;
            cmd_bench(args)
        }
        "info" => {
            args.no_positionals()?;
            cmd_info(args)
        }
        "" | "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command {other:?}; see `worp help`"
        ))),
    }
}

/// Resolve the launcher config: `--config <file.toml>` (if given) with
/// CLI flags layered on top.
pub fn load_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => PipelineConfig::load(path)?,
        None => PipelineConfig::default(),
    };
    // CLI overrides
    cfg.p = args.parse_or("p", cfg.p)?;
    cfg.k = args.parse_or("k", cfg.k)?;
    cfg.q = args.parse_or("q", cfg.q)?;
    cfg.eps = args.parse_or("eps", cfg.eps)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    cfg.workers = args.parse_or("workers", cfg.workers)?;
    cfg.batch = args.parse_or("batch", cfg.batch)?;
    cfg.n = args.parse_or("n", cfg.n)?;
    cfg.alpha = args.parse_or("alpha", cfg.alpha)?;
    cfg.stream_len = args.parse_or("stream-len", cfg.stream_len)?;
    cfg.rows = args.parse_or("rows", cfg.rows)?;
    cfg.width = args.parse_or("width", cfg.width)?;
    cfg.window = args.parse_or("window", cfg.window)?;
    cfg.buckets = args.parse_or("buckets", cfg.buckets)?;
    if let Some(m) = args.get("method") {
        cfg.method = m.to_string();
    }
    if let Some(d) = args.get("dist") {
        cfg.dist = d.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(w) = args.get("workload") {
        cfg.workload = w.to_string();
    }
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = d.to_string();
    }
    cfg.checkpoint_every = args.parse_or("checkpoint-every", cfg.checkpoint_every)?;
    cfg.validate()?;
    Ok(cfg)
}

fn make_stream(cfg: &PipelineConfig) -> Vec<Element> {
    match cfg.workload.as_str() {
        "gradient" => GradientStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E)
            .collect(),
        _ => ZipfStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E).collect(),
    }
}

fn cmd_sample(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let coord = Coordinator::from_config(&cfg)?;
    println!(
        "workload={} n={} alpha={} stream_len={} | p={} k={} method={} dist={} backend={} workers={}",
        cfg.workload,
        cfg.n,
        cfg.alpha,
        cfg.stream_len,
        cfg.p,
        cfg.k,
        cfg.method,
        cfg.dist,
        cfg.backend,
        cfg.workers
    );
    let elems = make_stream(&cfg);
    let (sample, metrics) = match cfg.backend.as_str() {
        // the XLA offload is a backend of the 1-pass sketch update only
        "xla" => {
            if Method::parse(&cfg.method)? != Method::OnePass {
                return Err(Error::Config(format!(
                    "backend xla supports method 1pass only (got {})",
                    cfg.method
                )));
            }
            // the single-threaded xla path has no sharded workers to
            // snapshot; refusing beats silently ignoring the request
            if !cfg.checkpoint_dir.is_empty() {
                return Err(Error::Config(
                    "checkpointing (--checkpoint-dir) is not supported with backend xla".into(),
                ));
            }
            coord.one_pass_xla(elems, &cfg.artifacts_dir)?
        }
        _ => {
            let sampler = Worp::from_config(&cfg)?.build()?;
            coord.run_dyn(&VecSource(elems), sampler)?
        }
    };
    println!("pipeline: {}", metrics.report());
    print_sample(&sample);
    Ok(())
}

/// Shared sample report: the top-key table, the threshold and the moment
/// estimates — `sample` and `merge-files` print identically, so a
/// two-process shard→merge run can be diffed against a single-process
/// one (the CI smoke does exactly that).
fn print_sample(sample: &crate::sampler::Sample) {
    let mut t = Table::new(
        &format!("top sampled keys (of {})", sample.len()),
        &["key", "freq", "transformed"],
    );
    for e in sample.entries.iter().take(15) {
        // string-keyed samples carry a dictionary — print the original key
        t.row(&[sample.label_of(e.key), sci(e.freq), sci(e.transformed)]);
    }
    t.print();
    println!("tau = {}", sci(sample.tau));
    if sample.tau > 0.0 {
        for p_prime in [1.0, 2.0] {
            println!(
                "estimated ||nu||_{p_prime}^{p_prime} = {}",
                sci(moment_estimate(sample, p_prime))
            );
        }
    }
}

/// `worp shard`: sketch one partition of the workload in this process
/// and write the summary state to `--out` — the offline half of the
/// cross-process merge path (`worp merge-files` is the other half).
/// Partitioning is by element position: with `--shards m
/// --shard-index i` this process consumes elements `i, i+m, i+2m, …`,
/// so `m` independent processes cover the stream exactly once.
fn cmd_shard(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args
        .get("out")
        .ok_or_else(|| Error::Config("shard requires --out <state.worp>".into()))?;
    let shards: usize = args.parse_or("shards", 1)?;
    let index: usize = args.parse_or("shard-index", 0)?;
    if shards == 0 || index >= shards {
        return Err(Error::Config(format!(
            "need 0 <= shard-index < shards (got {index} of {shards})"
        )));
    }
    let mut sampler = Worp::from_config(&cfg)?.build()?;
    // clock-dependent samplers (windowed: implicit per-element ticks)
    // cannot be position-partitioned — each process's clock would only
    // tick on its own elements, so per-shard windows would cover skewed
    // spans of the stream and the merged sample would silently differ
    // from a single-process run (the same hazard run_dyn serializes)
    if shards > 1 && !sampler.parallel_safe() {
        return Err(Error::Config(format!(
            "method {} depends on a stream-global clock and cannot be sharded across \
             processes; run it with --shards 1",
            sampler.name()
        )));
    }
    // stream the partition through one reusable SoA block — no second
    // materialized copy of the (possibly huge) element stream, and the
    // sampler ingests through its columnar process_block path
    let batch = cfg.batch.max(1);
    let mut block = crate::data::ElementBlock::with_capacity(batch);
    for (i, e) in make_stream(&cfg).into_iter().enumerate() {
        if i % shards != index {
            continue;
        }
        block.push(e.key, e.val);
        if block.len() == batch {
            sampler.process_block(&block);
            block.clear();
        }
    }
    if !block.is_empty() {
        sampler.process_block(&block);
    }
    let mut bytes = Vec::new();
    sampler.encode_state(&mut bytes);
    std::fs::write(out, &bytes)?;
    println!(
        "shard {index}/{shards}: method={} processed={} fingerprint={:#018x} -> {out} ({} bytes)",
        sampler.name(),
        sampler.processed(),
        sampler.fingerprint().value(),
        bytes.len()
    );
    Ok(())
}

/// `worp merge-files`: decode per-partition summary states, fold them
/// through the fingerprint-checked merge tree, and report the combined
/// sample — summaries sketched by independent processes (or machines)
/// combine exactly as the paper's composability property promises.
fn cmd_merge_files(args: &Args) -> Result<()> {
    // the hand-rolled parser cannot know which --options take values, so
    // a mistyped flag could swallow the first input path as its value;
    // merge-files therefore rejects anything but --out loudly instead of
    // silently merging fewer files than the user listed
    if let Some(k) = args.options.keys().find(|k| k.as_str() != "out") {
        return Err(Error::Config(format!(
            "merge-files does not take --{k} (only --out); use `--` before the file list \
             if a path begins with -"
        )));
    }
    if let Some(f) = args.flags.first() {
        return Err(Error::Config(format!("merge-files does not take --{f}")));
    }
    if args.positionals.is_empty() {
        return Err(Error::Config(
            "merge-files needs at least one input: worp merge-files a.worp b.worp ...".into(),
        ));
    }
    let mut summaries: Vec<Box<dyn WorSampler>> = Vec::new();
    for path in &args.positionals {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        let s = crate::codec::decode_sampler(&bytes)
            .map_err(|e| Error::Config(format!("cannot decode {path}: {e}")))?;
        println!(
            "loaded {path}: method={} processed={} fingerprint={:#018x}",
            s.name(),
            s.processed(),
            s.fingerprint().value()
        );
        summaries.push(s);
    }
    let metrics = crate::pipeline::metrics::Metrics::default();
    let merged = crate::pipeline::merge::tree_merge(summaries, &metrics, |a, b| {
        a.merge_dyn(&**b)
    })?
    .expect("at least one input");
    println!(
        "merged {} partitions: processed={} merges={}",
        args.positionals.len(),
        crate::api::StreamSummary::processed(&merged),
        metrics.merges()
    );
    if let Some(out) = args.get("out") {
        let mut bytes = Vec::new();
        merged.encode_state(&mut bytes);
        std::fs::write(out, &bytes)?;
        println!("wrote merged state -> {out} ({} bytes)", bytes.len());
    }
    match merged.sample() {
        Ok(sample) => print_sample(&sample),
        // a mid-pass multi-pass state merges fine but cannot sample yet
        Err(Error::State(m)) => println!("no sample yet: {m}"),
        Err(e) => return Err(e),
    }
    Ok(())
}

/// The process-wide termination flag, flipped by SIGTERM / SIGINT.
///
/// std-only: `signal(2)` is declared directly rather than through a
/// binding crate. The handler body is async-signal-safe — one atomic
/// store, nothing that allocates or locks.
#[cfg(unix)]
fn term_flag() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::{AtomicBool, Ordering};
    static TERM: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
    }
    &TERM
}

/// Off unix there is no std-only signal story; serve parks until killed.
#[cfg(not(unix))]
fn term_flag() -> &'static std::sync::atomic::AtomicBool {
    static TERM: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
    &TERM
}

/// `worp serve`: run the long-lived engine over TCP until terminated.
/// The engine shards every instance `--workers` ways with
/// `--batch`-element blocks (matching an offline `worp sample` run with
/// the same flags, so served and offline outputs diff clean). With
/// `--checkpoint-dir`, every instance is snapshotted there periodically
/// and restored on startup.
///
/// With `--cluster <worp.toml> --node <name>` the process serves as one
/// member of a sharded cluster: it owns only its rendezvous-assigned
/// hash slices, refuses misrouted rows, and answers the slice-granular
/// cluster queries (`QUERY_RAW`, slice transfer) a
/// [`crate::cluster::ClusterClient`] drives.
///
/// SIGTERM / SIGINT trigger a graceful drain: stop accepting
/// connections, flush every pending block, write a final snapshot of
/// every instance (if checkpointing is on), then exit 0.
fn cmd_serve(args: &Args) -> Result<()> {
    use crate::engine::server::{ServeOpts, Server};
    use crate::engine::{Engine, EngineOpts};
    use std::sync::atomic::Ordering;
    let cfg = load_config(args)?;
    let cluster = match args.get("cluster") {
        Some(path) => {
            let spec = crate::cluster::ClusterSpec::load(path)?;
            let node = args
                .get("node")
                .ok_or_else(|| Error::Config("serve --cluster also needs --node <member-name>".into()))?;
            Some((spec, node.to_string()))
        }
        None if args.get("node").is_some() => {
            return Err(Error::Config(
                "serve --node means nothing without --cluster <worp.toml>".into(),
            ));
        }
        None => None,
    };
    let engine_opts = EngineOpts::new(cfg.workers, cfg.batch)?;
    let (engine, addr, banner) = match &cluster {
        Some((spec, node)) => {
            let owned = spec.owned_slices(node)?;
            let member = spec.member(node)?;
            let engine = Engine::with_ownership(engine_opts, spec.slices, &owned, spec.stamp())?;
            // the member's spec address is the default bind; --addr still
            // wins (e.g. bind 0.0.0.0 behind NAT while peers dial the
            // public address)
            let addr = args.str_or("addr", &member.addr);
            let banner = format!(
                "cluster={} node={} slices={}/{} batch={}",
                spec.name,
                node,
                owned.len(),
                spec.slices,
                cfg.batch
            );
            (engine, addr, banner)
        }
        None => (
            Engine::new(engine_opts),
            args.str_or("addr", &cfg.server_addr),
            format!("shards={} batch={}", cfg.workers, cfg.batch),
        ),
    };
    let engine = std::sync::Arc::new(engine);
    let idle_secs: u64 = args.parse_or("idle-timeout", cfg.server_idle_timeout_secs)?;
    let mut opts = ServeOpts {
        max_frame: cfg.server_max_frame_mib << 20,
        checkpoint: None,
        max_connections: args.parse_or("max-connections", 1024)?,
        io_threads: args.parse_or("io-threads", crate::engine::server::DEFAULT_IO_THREADS)?,
        idle_timeout: (idle_secs > 0).then(|| std::time::Duration::from_secs(idle_secs)),
    };
    let mut checkpoint_dir = None;
    if !cfg.checkpoint_dir.is_empty() {
        let policy =
            crate::pipeline::CheckpointPolicy::new(cfg.checkpoint_every, cfg.checkpoint_dir.clone())?;
        if policy.dir().is_dir() {
            let restored = engine.restore_dir(policy.dir())?;
            if !restored.is_empty() {
                println!("restored {} instance(s): {}", restored.len(), restored.join(", "));
            }
        }
        checkpoint_dir = Some(policy.dir().to_path_buf());
        opts.checkpoint = Some(policy);
    }
    let mut srv = Server::start(std::sync::Arc::clone(&engine), &addr, opts)?;
    println!("worp serve: listening on {} ({banner})", srv.local_addr());
    // park until the signal handler flips the flag; connections run on
    // their own threads inside the server
    let term = term_flag();
    while !term.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    println!("worp serve: termination signal — draining");
    // drain order matters: refuse new connections first, then flush
    // pending blocks into the summaries, then write the final snapshots
    srv.stop();
    let flushed = engine.flush_all()?;
    match checkpoint_dir {
        Some(dir) => {
            let written = engine.snapshot_all(&dir)?;
            println!(
                "worp serve: flushed {flushed} pending element(s), snapshotted {written} \
                 instance(s) to {}",
                dir.display()
            );
        }
        None => println!("worp serve: flushed {flushed} pending element(s)"),
    }
    Ok(())
}

/// `worp client <action>`: drive a running `worp serve`. The `create`
/// and `ingest` actions reuse the full `sample` option surface (method,
/// p, k, workload, ...), so a served session can be set up with the very
/// flags an offline run would use — that is what lets CI diff a served
/// sample against `worp sample` byte-for-byte.
fn cmd_client(args: &Args) -> Result<()> {
    use crate::engine::client::Client;
    use crate::engine::proto::InstanceSpec;
    let action = args
        .positionals
        .first()
        .ok_or_else(|| Error::Config("client needs an action; see `worp help`".into()))?
        .clone();
    if let Some(extra) = args.positionals.get(1) {
        return Err(Error::Config(format!("unexpected positional arg {extra:?}")));
    }
    let cfg = load_config(args)?;
    let addr = args.str_or("addr", &cfg.server_addr);
    // --timeout <secs> bounds connect AND every op's read/write (0 = none)
    let timeout_secs: u64 =
        args.parse_or("timeout", crate::engine::client::DEFAULT_OP_TIMEOUT_SECS)?;
    let deadline =
        (timeout_secs > 0).then(|| std::time::Duration::from_secs(timeout_secs));
    let mut client = Client::connect_with_deadline(&addr, deadline)?;
    let name = || -> Result<String> {
        args.get("name")
            .map(str::to_string)
            .ok_or_else(|| Error::Config(format!("client {action} requires --name <ns/x>")))
    };
    match action.as_str() {
        "ping" => {
            client.ping()?;
            println!("pong ({addr})");
        }
        "create" => {
            let n = name()?;
            client.create(&n, &InstanceSpec::from_config(&cfg))?;
            println!("created {n}: method={} k={} p={}", cfg.method, cfg.k, cfg.p);
        }
        "drop" => {
            let n = name()?;
            client.drop_instance(&n)?;
            println!("dropped {n}");
        }
        "list" => {
            let infos = client.list()?;
            let mut t = Table::new(
                &format!("instances ({})", infos.len()),
                &["name", "method", "shards", "pass", "processed", "pending", "words"],
            );
            for i in &infos {
                t.row(&[
                    i.name.clone(),
                    i.method.clone(),
                    i.shards.to_string(),
                    format!("{}/{}", i.pass + 1, i.passes),
                    i.processed.to_string(),
                    i.pending.to_string(),
                    i.size_words.to_string(),
                ]);
            }
            t.print();
        }
        "ingest" => {
            let n = name()?;
            // stream the configured workload in pipelined blocks; frame
            // chunking does not affect the engine's per-shard block
            // boundaries, and acks are reconciled asynchronously inside
            // the in-flight window
            let chunk = cfg.batch.max(1);
            let window: usize = args.parse_or("pipeline", cfg.server_pipeline_window)?;
            let mut client = client.with_pipeline_window(window);
            let mut pipe = client.ingest_pipe(&n)?;
            let mut block = crate::data::ElementBlock::with_capacity(chunk);
            let mut sent = 0u64;
            for e in make_stream(&cfg) {
                block.push(e.key, e.val);
                if block.len() == chunk {
                    pipe.send(&block)?;
                    sent += block.len() as u64;
                    block.clear();
                }
            }
            if !block.is_empty() {
                sent += block.len() as u64;
                pipe.send(&block)?;
            }
            let accepted = pipe.finish()?;
            println!(
                "ingested {sent} elements into {n} (pipeline window {window}, \
                 lifetime accepted={accepted})"
            );
        }
        "flush" => {
            let n = name()?;
            println!("flushed {} pending elements from {n}", client.flush(&n)?);
        }
        "advance" => {
            let n = name()?;
            println!("{n} advanced to pass {}", client.advance(&n)? + 1);
        }
        "sample" => {
            let n = name()?;
            print_sample(&client.sample(&n)?);
        }
        "moment" => {
            let n = name()?;
            let p_prime: f64 = args.parse_or("pprime", 2.0)?;
            println!(
                "estimated ||nu||_{p_prime}^{p_prime} = {}",
                sci(client.moment(&n, p_prime)?)
            );
        }
        "rankfreq" => {
            let n = name()?;
            let max: u64 = args.parse_or("max", 20)?;
            let mut t = Table::new("estimated rank-frequency", &["rank", "freq"]);
            for p in client.rank_frequency(&n, max)? {
                t.row(&[format!("{:.2}", p.rank), sci(p.freq)]);
            }
            t.print();
        }
        "stats" if args.has_flag("all") => {
            let s = client.stats_all()?;
            println!(
                "server: elements={} batches={} merges={} snapshots={} restores={} \
                 connections={} (lifetime {})",
                s.elements,
                s.batches,
                s.merges,
                s.snapshots,
                s.restores,
                s.active_connections,
                s.total_connections
            );
            let mut t = Table::new(
                &format!("instances ({})", s.instances.len()),
                &["name", "method", "slices", "pass", "processed", "pending", "accepted"],
            );
            for i in &s.instances {
                t.row(&[
                    i.name.clone(),
                    i.method.clone(),
                    format!("{}/{}", i.shards, i.total_slices),
                    format!("{}/{}", i.pass + 1, i.passes),
                    i.processed.to_string(),
                    i.pending.to_string(),
                    i.accepted.to_string(),
                ]);
            }
            t.print();
        }
        "stats" => {
            let n = name()?;
            let i = client.stats(&n)?;
            println!(
                "{}: method={} shards={} batch={} pass={}/{} processed={} pending={} \
                 accepted={} size_words={} fingerprint={:#018x}",
                i.name,
                i.method,
                i.shards,
                i.batch,
                i.pass + 1,
                i.passes,
                i.processed,
                i.pending,
                i.accepted,
                i.size_words,
                i.fingerprint
            );
        }
        "snapshot" => {
            let n = name()?;
            let out = args
                .get("out")
                .ok_or_else(|| Error::Config("client snapshot requires --out <file.worp>".into()))?;
            let bytes = client.snapshot(&n)?;
            std::fs::write(out, &bytes)?;
            println!("snapshot of {n} -> {out} ({} bytes)", bytes.len());
        }
        "restore" => {
            let path = args
                .get("in")
                .ok_or_else(|| Error::Config("client restore requires --in <file.worp>".into()))?;
            let bytes = std::fs::read(path)
                .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
            println!("restored instance {}", client.restore(&bytes)?);
        }
        other => {
            return Err(Error::Config(format!(
                "unknown client action {other:?}; see `worp help`"
            )))
        }
    }
    Ok(())
}

/// `worp cluster <action>`: drive a whole sharded cluster through one
/// [`crate::cluster::ClusterClient`] — the spec comes from the
/// `[cluster]` section of `--cluster <worp.toml>` (or `--config`), and
/// every member must be a running `worp serve --cluster ... --node ...`.
/// Print what a failover/tolerant rebalance actually did.
fn print_failover(report: &crate::cluster::FailoverReport, members: usize) {
    println!(
        "failover complete onto {members} member(s): {} slice move(s), {} slice(s) lost{}",
        report.moves,
        report.lost_slices.len(),
        if report.lost_slices.is_empty() {
            String::new()
        } else {
            format!(" {:?} — restore from snapshots to recover their rows", report.lost_slices)
        }
    );
}

/// `create`/`ingest` reuse the full `sample` option surface, so a
/// 3-node cluster session can be set up with the very flags an offline
/// run would use — the CI cluster smoke diffs the two byte-for-byte.
fn cmd_cluster(args: &Args) -> Result<()> {
    use crate::cluster::{ClusterClient, ClusterSpec, Health, RetryPolicy};
    use crate::engine::proto::InstanceSpec;
    let action = args
        .positionals
        .first()
        .ok_or_else(|| Error::Config("cluster needs an action; see `worp help`".into()))?
        .clone();
    if let Some(extra) = args.positionals.get(1) {
        return Err(Error::Config(format!("unexpected positional arg {extra:?}")));
    }
    let cfg = load_config(args)?;
    let spec_path = args.get("cluster").or_else(|| args.get("config")).ok_or_else(|| {
        Error::Config(
            "cluster commands need --cluster <worp.toml> (a file with a [cluster] section)".into(),
        )
    })?;
    // the retry policy rides in the same file ([cluster.retry] section)
    let doc = crate::config::Document::load(spec_path)?;
    let spec = ClusterSpec::from_document(&doc)?;
    let policy = RetryPolicy::from_document(&doc);
    let mut cc = ClusterClient::connect_with(spec, policy)?;
    let name = || -> Result<String> {
        args.get("name")
            .map(str::to_string)
            .ok_or_else(|| Error::Config(format!("cluster {action} requires --name <ns/x>")))
    };
    match action.as_str() {
        "status" => {
            let spec = cc.spec().clone();
            println!(
                "cluster {}: {} slices over {} member(s), stamp={:#018x}",
                spec.name,
                spec.slices,
                spec.members.len(),
                spec.stamp()
            );
            for (member, s) in cc.status()? {
                let m = spec.member(&member)?;
                println!(
                    "{member} ({}): owns {} slice(s) | elements={} batches={} merges={} \
                     snapshots={} restores={} connections={} (lifetime {})",
                    m.addr,
                    spec.owned_slices(&member)?.len(),
                    s.elements,
                    s.batches,
                    s.merges,
                    s.snapshots,
                    s.restores,
                    s.active_connections,
                    s.total_connections
                );
                for i in &s.instances {
                    println!(
                        "  {}: method={} slices={}/{} processed={} pending={} accepted={}",
                        i.name, i.method, i.shards, i.total_slices, i.processed, i.pending,
                        i.accepted
                    );
                }
            }
        }
        "create" => {
            let n = name()?;
            cc.create(&n, &InstanceSpec::from_config(&cfg))?;
            println!(
                "created {n} on {} member(s): method={} k={} p={}",
                cc.spec().members.len(),
                cfg.method,
                cfg.k,
                cfg.p
            );
        }
        "drop" => {
            let n = name()?;
            cc.drop_instance(&n)?;
            println!("dropped {n} from every member");
        }
        "flush" => {
            let n = name()?;
            println!("flushed {} pending elements from {n}", cc.flush(&n)?);
        }
        "ingest" => {
            let n = name()?;
            // one session for the whole workload: every member's pipe
            // streams chunks concurrently, acks reconciled in the window
            let chunk = cfg.batch.max(1);
            let mut session = cc.ingest_session(&n, chunk)?;
            for e in make_stream(&cfg) {
                session.push(e.key, e.val)?;
            }
            let sent = session.finish()?;
            println!("ingested {sent} elements into {n} across the cluster");
        }
        "sample" => {
            let n = name()?;
            if args.has_flag("partial") {
                // opt-in degraded query: answer from the reachable
                // slices and say exactly what is missing
                let (merged, cov) = cc.query_partial(&n)?;
                println!(
                    "coverage: {}/{} slice(s) answered{}",
                    cov.answered,
                    cov.owned,
                    if cov.unreachable_members.is_empty() {
                        String::new()
                    } else {
                        format!(" (unreachable: {})", cov.unreachable_members.join(", "))
                    }
                );
                if !cov.missing_slices.is_empty() {
                    println!("missing slices: {:?}", cov.missing_slices);
                }
                match merged {
                    Some(s) => print_sample(&s.sample()?),
                    None => println!("no slice answered — nothing to sample"),
                }
            } else {
                print_sample(&cc.sample(&n)?);
            }
        }
        "moment" => {
            let n = name()?;
            let p_prime: f64 = args.parse_or("pprime", 2.0)?;
            println!(
                "estimated ||nu||_{p_prime}^{p_prime} = {}",
                sci(cc.moment(&n, p_prime)?)
            );
        }
        "rankfreq" => {
            let n = name()?;
            let max: usize = args.parse_or("max", 20)?;
            let mut t = Table::new("estimated rank-frequency", &["rank", "freq"]);
            for p in cc.rank_frequency(&n, max)? {
                t.row(&[format!("{:.2}", p.rank), sci(p.freq)]);
            }
            t.print();
        }
        "snapshot" => {
            let n = name()?;
            let out = args
                .get("out")
                .ok_or_else(|| Error::Config("cluster snapshot requires --out <dir>".into()))?;
            std::fs::create_dir_all(out)?;
            for (member, bytes) in cc.snapshot(&n)? {
                let path =
                    std::path::Path::new(out).join(format!("{}.worp", member.replace('/', "_")));
                std::fs::write(&path, &bytes)?;
                println!("snapshot of {n} on {member} -> {} ({} bytes)", path.display(), bytes.len());
            }
        }
        "rebalance" => {
            let to = args.get("to").ok_or_else(|| {
                Error::Config("cluster rebalance requires --to <new-worp.toml>".into())
            })?;
            let new_spec = ClusterSpec::load(to)?;
            let moves = cc.rebalance_to(new_spec)?;
            println!(
                "rebalanced onto {} member(s): {moves} slice move(s)",
                cc.spec().members.len()
            );
        }
        "failover" => {
            // like rebalance, but an unreachable old owner loses its
            // slices instead of aborting the move
            let to = args.get("to").ok_or_else(|| {
                Error::Config("cluster failover requires --to <new-worp.toml>".into())
            })?;
            let new_spec = ClusterSpec::load(to)?;
            let report = cc.failover_to(new_spec)?;
            print_failover(&report, cc.spec().members.len());
        }
        "watch" => {
            let interval: f64 = args.parse_or("interval", 5.0f64)?;
            let grace: u32 = args.parse_or("grace", 2u32)?;
            let grace = grace.max(1);
            let once = args.has_flag("once");
            let out = args.get("out").map(str::to_string);
            cc.set_down_after(grace);
            let term = term_flag();
            println!(
                "watching cluster {}: {} member(s), probe every {interval}s, failover \
                 after {grace} consecutive failure(s){}",
                cc.spec().name,
                cc.spec().members.len(),
                if once { " (single pass)" } else { "" }
            );
            let mut round = 0u32;
            loop {
                round += 1;
                let health = cc.probe();
                let down: Vec<String> = health
                    .iter()
                    .filter(|(_, h)| *h == Health::Down)
                    .map(|(n, _)| n.clone())
                    .collect();
                let states: Vec<String> =
                    health.iter().map(|(n, h)| format!("{n}={h:?}")).collect();
                println!("probe {round}: {}", states.join(" "));
                if down.len() == cc.spec().members.len() {
                    if once {
                        return Err(Error::Unavailable(
                            "every cluster member is down — nothing to fail over to".into(),
                        ));
                    }
                    println!("every member is down — waiting for any to recover");
                } else if !down.is_empty() {
                    let surviving = cc.spec().surviving(&down)?;
                    println!(
                        "failing over: dropping {} → {} surviving member(s)",
                        down.join(", "),
                        surviving.members.len()
                    );
                    let report = cc.failover_to(surviving)?;
                    print_failover(&report, cc.spec().members.len());
                    if let Some(out) = &out {
                        // persist the retry section too — a tuned policy
                        // must survive the failover round-trip, not reset
                        // to defaults when the file is loaded back
                        std::fs::write(out, cc.spec().to_toml_with_retry(cc.policy()))?;
                        println!("surviving topology -> {out}");
                    }
                    if once {
                        return Ok(());
                    }
                } else if once && round >= grace {
                    println!("all members healthy — no failover needed");
                    return Ok(());
                }
                if term.load(std::sync::atomic::Ordering::SeqCst) {
                    println!("terminating watch");
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_secs_f64(interval.max(0.05)));
            }
        }
        other => {
            return Err(Error::Config(format!(
                "unknown cluster action {other:?}; see `worp help`"
            )))
        }
    }
    Ok(())
}

fn cmd_psi(args: &Args) -> Result<()> {
    let n = args.parse_or("n", 10_000usize)?;
    let k = args.parse_or("k", 100usize)?;
    let rho = args.parse_or("rho", 2.0f64)?;
    let delta = args.parse_or("delta", 0.01f64)?;
    let trials = args.parse_or("trials", 2_000usize)?;
    let psi = crate::psi::psi_estimate(n, k, rho, delta, trials, 0xCA11B);
    let lb2 = crate::psi::psi_lower_bound(n, k, rho, 2.0);
    println!(
        "Psi_{{n={n},k={k},rho={rho}}}(delta={delta}) ~= {psi:.5}  (thm 3.1 bound @C=2: {lb2:.5})"
    );
    // the effective constant C the simulation implies (paper App B.1)
    let ln_nk = ((n as f64) / (k as f64)).ln().max(1.0);
    let c = if rho <= 1.0 {
        1.0 / (psi * ln_nk)
    } else {
        (rho - 1.0f64).max(1.0 / ln_nk) / psi
    };
    println!("implied constant C = {c:.3} (paper: C<2 suffices for k>=10)");
    Ok(())
}

/// `worp scenario <name>`: run one end-to-end workload with hard
/// accuracy gates (see [`crate::scenario`]). Prints every gate and
/// propagates the failures, so the process exits non-zero on an
/// accuracy regression — CI runs these like tests.
fn cmd_scenario(args: &Args) -> Result<()> {
    use crate::scenario::{Mode, ScenarioOpts, SCENARIOS};
    let name = match args.positionals.as_slice() {
        [one] => one.clone(),
        [] => {
            return Err(Error::Config(format!(
                "scenario name required (one of {})",
                SCENARIOS.join("|")
            )))
        }
        more => {
            return Err(Error::Config(format!(
                "scenario takes exactly one name, got {more:?}"
            )))
        }
    };
    let mode = if args.has_flag("cluster") {
        Mode::Cluster
    } else if args.has_flag("serve") {
        Mode::Served
    } else {
        Mode::parse(&args.str_or("mode", "local"))?
    };
    let defaults = ScenarioOpts::default();
    let opts = ScenarioOpts {
        mode,
        k: args.parse_or("k", 0usize)?,
        seed: args.parse_or("seed", defaults.seed)?,
        runs: args.parse_or("runs", 0usize)?,
    };
    let report = crate::scenario::run(&name, &opts)?;
    println!("{report}");
    report.check()
}

/// `worp bench`: run the scalar/batch/block ingestion suite, the
/// est_many query suite, the table-layout ablation and the served-ingest
/// (pipelined TCP) suite, and emit the machine-readable perf artifact
/// (`BENCH_PR10.json` by default). Smoke mode is the CI profile — it
/// exists to catch panics and keep the artifact schema alive, not to
/// produce stable numbers; the regression gate compares a fresh smoke
/// artifact against the committed baseline via `python/bench_check.py`.
fn cmd_bench(args: &Args) -> Result<()> {
    let mut opts = if args.has_flag("smoke") {
        crate::perf::PerfOpts::smoke()
    } else {
        crate::perf::PerfOpts::full()
    };
    opts.stream_len = args.parse_or("stream-len", opts.stream_len)?;
    opts.n_keys = args.parse_or("n", opts.n_keys)?;
    opts.batch = args.parse_or("batch", opts.batch)?;
    opts.iters = args.parse_or("iters", opts.iters)?;
    opts.k = args.parse_or("k", opts.k)?;
    let out = args.str_or("out", "BENCH_PR10.json");
    println!(
        "bench: stream_len={} n_keys={} batch={} iters={} k={} smoke={}\n",
        opts.stream_len, opts.n_keys, opts.batch, opts.iters, opts.k, opts.smoke
    );
    let mut records = crate::perf::run_suite(&opts);
    records.extend(crate::perf::run_query_suite(&opts));
    records.extend(crate::perf::run_layout_suite(&opts));
    records.extend(crate::perf::run_served_suite(&opts));
    crate::perf::write_json(&out, &opts, &records)?;
    println!("\nwrote {} records to {out}", records.len());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    match crate::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!(
            "PJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    match crate::runtime::artifact::ArtifactDir::open(&dir) {
        Ok(a) => {
            for s in a.specs() {
                println!(
                    "artifact {}: file={:?} rows={} width={} batch={}",
                    s.name, s.file, s.rows, s.width, s.batch
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&["sample", "--p", "2.0", "--k", "100", "--verbose"]);
        assert_eq!(a.command, "sample");
        assert_eq!(a.get("p"), Some("2.0"));
        assert_eq!(a.parse_or::<usize>("k", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["psi"]);
        assert_eq!(a.parse_or::<f64>("rho", 2.0).unwrap(), 2.0);
        assert_eq!(a.str_or("method", "1pass"), "1pass");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["sample", "--k", "ten"]);
        assert!(a.parse_or::<usize>("k", 1).is_err());
    }

    #[test]
    fn stray_positional_rejected_for_commands_that_take_none() {
        let a = parse(&["sample", "oops"]);
        assert_eq!(a.positionals, vec!["oops".to_string()]);
        assert!(dispatch(&a).is_err());
        // merge-files *does* take positionals (they are the inputs)
        let a = parse(&["merge-files", "a.worp", "b.worp"]);
        assert_eq!(a.positionals.len(), 2);
    }

    #[test]
    fn merge_files_rejects_unknown_options_instead_of_swallowing_inputs() {
        // a mistyped flag would otherwise consume the first input path as
        // its value and silently merge fewer files than listed
        let a = parse(&["merge-files", "--verbose", "a.worp", "b.worp"]);
        assert_eq!(a.positionals.len(), 1); // a.worp was swallowed...
        let err = dispatch(&a).unwrap_err();
        assert!(err.to_string().contains("--verbose"), "{err}"); // ...but we refuse
        // `--` makes every following token positional
        let a = parse(&["merge-files", "--", "--weird-name.worp", "b.worp"]);
        assert_eq!(
            a.positionals,
            vec!["--weird-name.worp".to_string(), "b.worp".to_string()]
        );
    }

    #[test]
    fn shard_then_merge_files_equals_single_process_sample() {
        // the cross-process merge path end-to-end: two `worp shard`
        // invocations over complementary partitions, merged from disk,
        // must reproduce the single-process exact sample bit-for-bit
        let dir = std::env::temp_dir().join("worp_cli_shard_merge_test");
        std::fs::create_dir_all(&dir).unwrap();
        let a_path = dir.join("a.worp");
        let b_path = dir.join("b.worp");
        let common = [
            "--method", "exact", "--k", "8", "--n", "200", "--stream-len", "5000",
            "--seed", "9",
        ];
        for (idx, path) in [(0, &a_path), (1, &b_path)] {
            let mut argv = vec!["shard".to_string()];
            argv.extend(common.iter().map(|s| s.to_string()));
            argv.extend([
                "--shards".into(),
                "2".into(),
                "--shard-index".into(),
                idx.to_string(),
                "--out".into(),
                path.to_str().unwrap().into(),
            ]);
            dispatch(&Args::parse(argv).unwrap()).unwrap();
        }
        // merge from disk and compare against the whole-stream sampler
        let merged = {
            let a = crate::codec::decode_sampler(&std::fs::read(&a_path).unwrap()).unwrap();
            let b = crate::codec::decode_sampler(&std::fs::read(&b_path).unwrap()).unwrap();
            let mut m = a;
            m.merge_dyn(&*b).unwrap();
            m.sample().unwrap()
        };
        let whole = {
            let mut argv = vec!["sample".to_string()];
            argv.extend(common.iter().map(|s| s.to_string()));
            let cfg = load_config(&Args::parse(argv).unwrap()).unwrap();
            let mut s = Worp::from_config(&cfg).unwrap().build().unwrap();
            for e in make_stream(&cfg) {
                s.process(&e);
            }
            s.sample().unwrap()
        };
        assert_eq!(merged.keys(), whole.keys());
        assert_eq!(merged.tau, whole.tau);
        // the merge-files command itself accepts the same files
        let argv = vec![
            "merge-files".to_string(),
            a_path.to_str().unwrap().to_string(),
            b_path.to_str().unwrap().to_string(),
        ];
        dispatch(&Args::parse(argv).unwrap()).unwrap();
    }

    #[test]
    fn merge_files_rejects_mismatched_fingerprints() {
        let dir = std::env::temp_dir().join("worp_cli_merge_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        // same method, different seeds: decode succeeds, merge must fail
        let mut paths = Vec::new();
        for seed in [1u64, 2] {
            let mut s = Worp::p(1.0).k(4).seed(seed).exact().build().unwrap();
            s.process(&Element::new(7, 1.0));
            let mut bytes = Vec::new();
            s.encode_state(&mut bytes);
            let p = dir.join(format!("s{seed}.worp"));
            std::fs::write(&p, &bytes).unwrap();
            paths.push(p.to_str().unwrap().to_string());
        }
        let mut argv = vec!["merge-files".to_string()];
        argv.extend(paths);
        let err = dispatch(&Args::parse(argv).unwrap()).unwrap_err();
        assert!(
            matches!(err, Error::Incompatible(_)),
            "expected fingerprint mismatch, got {err}"
        );
    }

    #[test]
    fn client_requires_an_action_and_serve_takes_no_positionals() {
        let err = dispatch(&parse(&["client"])).unwrap_err();
        assert!(err.to_string().contains("action"), "{err}");
        let err = dispatch(&parse(&["client", "sample", "extra"])).unwrap_err();
        assert!(err.to_string().contains("unexpected"), "{err}");
        let err = dispatch(&parse(&["serve", "oops"])).unwrap_err();
        assert!(err.to_string().contains("unexpected"), "{err}");
    }

    #[test]
    fn cluster_requires_an_action_and_a_spec_file() {
        let err = dispatch(&parse(&["cluster"])).unwrap_err();
        assert!(err.to_string().contains("action"), "{err}");
        let err = dispatch(&parse(&["cluster", "status"])).unwrap_err();
        assert!(err.to_string().contains("--cluster"), "{err}");
        let err = dispatch(&parse(&["cluster", "status", "extra"])).unwrap_err();
        assert!(err.to_string().contains("unexpected"), "{err}");
        // serve --node without --cluster is refused before binding anything
        let err = dispatch(&parse(&["serve", "--node", "a"])).unwrap_err();
        assert!(err.to_string().contains("--cluster"), "{err}");
    }

    #[test]
    fn flag_before_option_parses() {
        let a = parse(&["sample", "--fast", "--k", "5"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("k"), Some("5"));
    }

    #[test]
    fn load_config_layers_cli_over_file_defaults() {
        let a = parse(&["sample", "--method", "exact", "--dist", "priority", "--k", "7"]);
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.method, "exact");
        assert_eq!(cfg.dist, "priority");
        assert_eq!(cfg.k, 7);
        // topology flags reach the pipeline/engine config (the serve
        // determinism contract depends on --batch being honored)
        let a = parse(&["serve", "--workers", "3", "--batch", "512"]);
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.batch, 512);
        // bad method spelling surfaces as a config error
        let a = parse(&["sample", "--method", "zeropass"]);
        assert!(load_config(&a).is_err());
    }

    #[test]
    fn config_file_roundtrips_through_load_config() {
        let dir = std::env::temp_dir().join("worp_cli_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("worp.toml");
        std::fs::write(
            &path,
            "[sampler]\nmethod = \"2pass\"\nk = 33\n\n[pipeline]\nworkers = 3\n",
        )
        .unwrap();
        let a = parse(&["sample", "--config", path.to_str().unwrap()]);
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.method, "2pass");
        assert_eq!(cfg.k, 33);
        assert_eq!(cfg.workers, 3);
        // CLI still wins over the file
        let a = parse(&["sample", "--config", path.to_str().unwrap(), "--k", "5"]);
        assert_eq!(load_config(&a).unwrap().k, 5);
    }
}
