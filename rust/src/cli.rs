//! Command-line surface: hand-rolled argument parsing (no `clap` offline
//! — DESIGN.md §7) plus the command implementations the `worp` binary
//! dispatches to.
//!
//! Grammar: `worp <subcommand> [--key value]... [--flag]...`
//!
//! The `sample` command is method-agnostic: it builds a
//! `Box<dyn WorSampler>` through the [`Worp`] builder and hands it to
//! [`Coordinator::run_dyn`] — adding a sampler to the crate requires no
//! CLI changes beyond the builder.

use crate::api::builder::{Method, Worp};
use crate::config::PipelineConfig;
use crate::coordinator::{Coordinator, VecSource};
use crate::data::stream::GradientStream;
use crate::data::zipf::ZipfStream;
use crate::data::Element;
use crate::error::{Error, Result};
use crate::estimate::moment_estimate;
use crate::util::fmt::{sci, Table};
use std::collections::HashMap;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // value present and not itself an option?
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        options.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(name.to_string()),
                }
            } else {
                return Err(Error::Config(format!("unexpected positional arg {a:?}")));
            }
        }
        Ok(Args { command, options, flags })
    }

    /// Option as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; errors on unparsable values.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| Error::Config(format!("cannot parse --{key} {v:?}"))),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "worp — WOR ℓp sampling pipeline (Cohen–Pagh–Woodruff 2020 reproduction)

USAGE:
    worp <command> [options]

COMMANDS:
    sample      run a WORp sampler over a generated workload
                  --config <worp.toml>   TOML config (see worp.example.toml);
                                         flags below override its values
                  --method <1pass|2pass|tv|windowed|exact>
                  --dist <ppswor|priority>
                  --p <f64> --k <n> --workers <n> --alpha <f64>
                  --window <n> --buckets <n>   (windowed method)
                  --backend <native|xla>
    psi         calibrate Ψ_{n,k,ρ}(δ) by simulation (Appendix B.1)
                  --n <n> --k <n> --rho <f64> --delta <f64> --trials <n>
    bench       batch-vs-scalar ingestion throughput per summary,
                written as machine-readable JSON
                  --smoke                 small CI profile (default: full)
                  --out <path>            output file (default BENCH_PR2.json)
                  --stream-len <n> --n <keys> --batch <n> --iters <n> --k <n>
    info        print runtime / artifact status
    help        show this text
"
}

/// Dispatch a parsed command line.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "sample" => cmd_sample(args),
        "psi" => cmd_psi(args),
        "bench" => cmd_bench(args),
        "info" => cmd_info(args),
        "" | "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command {other:?}; see `worp help`"
        ))),
    }
}

/// Resolve the launcher config: `--config <file.toml>` (if given) with
/// CLI flags layered on top.
pub fn load_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => PipelineConfig::load(path)?,
        None => PipelineConfig::default(),
    };
    // CLI overrides
    cfg.p = args.parse_or("p", cfg.p)?;
    cfg.k = args.parse_or("k", cfg.k)?;
    cfg.q = args.parse_or("q", cfg.q)?;
    cfg.eps = args.parse_or("eps", cfg.eps)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    cfg.workers = args.parse_or("workers", cfg.workers)?;
    cfg.n = args.parse_or("n", cfg.n)?;
    cfg.alpha = args.parse_or("alpha", cfg.alpha)?;
    cfg.stream_len = args.parse_or("stream-len", cfg.stream_len)?;
    cfg.rows = args.parse_or("rows", cfg.rows)?;
    cfg.width = args.parse_or("width", cfg.width)?;
    cfg.window = args.parse_or("window", cfg.window)?;
    cfg.buckets = args.parse_or("buckets", cfg.buckets)?;
    if let Some(m) = args.get("method") {
        cfg.method = m.to_string();
    }
    if let Some(d) = args.get("dist") {
        cfg.dist = d.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(w) = args.get("workload") {
        cfg.workload = w.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn make_stream(cfg: &PipelineConfig) -> Vec<Element> {
    match cfg.workload.as_str() {
        "gradient" => GradientStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E)
            .collect(),
        _ => ZipfStream::new(cfg.n, cfg.alpha, cfg.stream_len, cfg.seed ^ 0xE1E).collect(),
    }
}

fn cmd_sample(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let coord = Coordinator::from_config(&cfg)?;
    println!(
        "workload={} n={} alpha={} stream_len={} | p={} k={} method={} dist={} backend={} workers={}",
        cfg.workload,
        cfg.n,
        cfg.alpha,
        cfg.stream_len,
        cfg.p,
        cfg.k,
        cfg.method,
        cfg.dist,
        cfg.backend,
        cfg.workers
    );
    let elems = make_stream(&cfg);
    let (sample, metrics) = match cfg.backend.as_str() {
        // the XLA offload is a backend of the 1-pass sketch update only
        "xla" => {
            if Method::parse(&cfg.method)? != Method::OnePass {
                return Err(Error::Config(format!(
                    "backend xla supports method 1pass only (got {})",
                    cfg.method
                )));
            }
            coord.one_pass_xla(elems, &cfg.artifacts_dir)?
        }
        _ => {
            let sampler = Worp::from_config(&cfg)?.build()?;
            coord.run_dyn(&VecSource(elems), sampler)?
        }
    };
    println!("pipeline: {}", metrics.report());
    let mut t = Table::new(
        &format!("top sampled keys (of {})", sample.len()),
        &["key", "freq", "transformed"],
    );
    for e in sample.entries.iter().take(15) {
        t.row(&[e.key.to_string(), sci(e.freq), sci(e.transformed)]);
    }
    t.print();
    println!("tau = {}", sci(sample.tau));
    if sample.tau > 0.0 {
        for p_prime in [1.0, 2.0] {
            println!(
                "estimated ||nu||_{p_prime}^{p_prime} = {}",
                sci(moment_estimate(&sample, p_prime))
            );
        }
    }
    Ok(())
}

fn cmd_psi(args: &Args) -> Result<()> {
    let n = args.parse_or("n", 10_000usize)?;
    let k = args.parse_or("k", 100usize)?;
    let rho = args.parse_or("rho", 2.0f64)?;
    let delta = args.parse_or("delta", 0.01f64)?;
    let trials = args.parse_or("trials", 2_000usize)?;
    let psi = crate::psi::psi_estimate(n, k, rho, delta, trials, 0xCA11B);
    let lb2 = crate::psi::psi_lower_bound(n, k, rho, 2.0);
    println!(
        "Psi_{{n={n},k={k},rho={rho}}}(delta={delta}) ~= {psi:.5}  (thm 3.1 bound @C=2: {lb2:.5})"
    );
    // the effective constant C the simulation implies (paper App B.1)
    let ln_nk = ((n as f64) / (k as f64)).ln().max(1.0);
    let c = if rho <= 1.0 {
        1.0 / (psi * ln_nk)
    } else {
        (rho - 1.0f64).max(1.0 / ln_nk) / psi
    };
    println!("implied constant C = {c:.3} (paper: C<2 suffices for k>=10)");
    Ok(())
}

/// `worp bench`: run the batch-vs-scalar ingestion suite and emit the
/// machine-readable perf artifact (`BENCH_PR2.json` by default). Smoke
/// mode is the CI profile — it exists to catch panics and keep the
/// artifact schema alive, not to produce stable numbers.
fn cmd_bench(args: &Args) -> Result<()> {
    let mut opts = if args.has_flag("smoke") {
        crate::perf::PerfOpts::smoke()
    } else {
        crate::perf::PerfOpts::full()
    };
    opts.stream_len = args.parse_or("stream-len", opts.stream_len)?;
    opts.n_keys = args.parse_or("n", opts.n_keys)?;
    opts.batch = args.parse_or("batch", opts.batch)?;
    opts.iters = args.parse_or("iters", opts.iters)?;
    opts.k = args.parse_or("k", opts.k)?;
    let out = args.str_or("out", "BENCH_PR2.json");
    println!(
        "bench: stream_len={} n_keys={} batch={} iters={} k={} smoke={}\n",
        opts.stream_len, opts.n_keys, opts.batch, opts.iters, opts.k, opts.smoke
    );
    let records = crate::perf::run_suite(&opts);
    crate::perf::write_json(&out, &opts, &records)?;
    println!("\nwrote {} records to {out}", records.len());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    match crate::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!(
            "PJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    match crate::runtime::artifact::ArtifactDir::open(&dir) {
        Ok(a) => {
            for s in a.specs() {
                println!(
                    "artifact {}: file={:?} rows={} width={} batch={}",
                    s.name, s.file, s.rows, s.width, s.batch
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&["sample", "--p", "2.0", "--k", "100", "--verbose"]);
        assert_eq!(a.command, "sample");
        assert_eq!(a.get("p"), Some("2.0"));
        assert_eq!(a.parse_or::<usize>("k", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["psi"]);
        assert_eq!(a.parse_or::<f64>("rho", 2.0).unwrap(), 2.0);
        assert_eq!(a.str_or("method", "1pass"), "1pass");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["sample", "--k", "ten"]);
        assert!(a.parse_or::<usize>("k", 1).is_err());
    }

    #[test]
    fn stray_positional_rejected() {
        let r = Args::parse(["sample".into(), "oops".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn flag_before_option_parses() {
        let a = parse(&["sample", "--fast", "--k", "5"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("k"), Some("5"));
    }

    #[test]
    fn load_config_layers_cli_over_file_defaults() {
        let a = parse(&["sample", "--method", "exact", "--dist", "priority", "--k", "7"]);
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.method, "exact");
        assert_eq!(cfg.dist, "priority");
        assert_eq!(cfg.k, 7);
        // bad method spelling surfaces as a config error
        let a = parse(&["sample", "--method", "zeropass"]);
        assert!(load_config(&a).is_err());
    }

    #[test]
    fn config_file_roundtrips_through_load_config() {
        let dir = std::env::temp_dir().join("worp_cli_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("worp.toml");
        std::fs::write(
            &path,
            "[sampler]\nmethod = \"2pass\"\nk = 33\n\n[pipeline]\nworkers = 3\n",
        )
        .unwrap();
        let a = parse(&["sample", "--config", path.to_str().unwrap()]);
        let cfg = load_config(&a).unwrap();
        assert_eq!(cfg.method, "2pass");
        assert_eq!(cfg.k, 33);
        assert_eq!(cfg.workers, 3);
        // CLI still wins over the file
        let a = parse(&["sample", "--config", path.to_str().unwrap(), "--k", "5"]);
        assert_eq!(load_config(&a).unwrap().k, 5);
    }
}
