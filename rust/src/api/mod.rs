//! The unified summary API: every sampler and sketch in this crate is a
//! [`StreamSummary`]; the composable ones are [`Mergeable`]; the ones
//! that produce an output implement [`Finalize`]; multi-pass methods are
//! first-class state machines via [`MultiPass`]; and every WOR sampler
//! can be driven behind `Box<dyn `[`WorSampler`]`>` for dynamic dispatch
//! (the CLI / pipeline path) while generic call sites keep static
//! dispatch.
//!
//! This is the paper's composability story surfaced at the API level
//! (Cohen–Pagh–Woodruff 2020; cf. "Composable Sketches for Functions of
//! Frequencies"): a WOR ℓp sampler *is* a mergeable sketch, so
//! distributed / sharded execution falls out of one `merge` property.
//! [`crate::pipeline::run_sharded`] accepts any `StreamSummary`, the
//! merge tree folds any `Mergeable`, and [`crate::coordinator`] drives
//! any `WorSampler` — no per-sampler glue anywhere.
//!
//! # Merge safety
//!
//! Merging summaries built with different seeds or shapes silently
//! corrupts estimates, so [`Mergeable::merge`] *always* compares
//! [`Fingerprint`]s first and fails loudly with
//! [`Error::Incompatible`] on mismatch. Implementations provide
//! [`Mergeable::merge_unchecked`]; callers use `merge`.
//!
//! # Construction
//!
//! Use the [`builder::Worp`] facade:
//!
//! ```no_run
//! use worp::api::{StreamSummary, WorSampler};
//! use worp::Worp;
//!
//! let mut s = Worp::p(1.0).k(64).one_pass().seed(7).build().unwrap();
//! s.process(&worp::data::Element::new(42, 1.0));
//! let sample = s.sample().unwrap();
//! # let _ = sample;
//! ```

pub mod builder;

use crate::data::{Element, ElementBlock};
use crate::error::{Error, Result};
use crate::sampler::{Sample, SamplerConfig};
use crate::sketch::countmin::CountMin;
use crate::sketch::countsketch::CountSketch;
use crate::sketch::spacesaving::SpaceSaving;
use crate::sketch::{AnyRhh, RhhSketch};
use crate::util::hashing::{hash64, hash_bytes, BottomKDist};
use std::any::Any;

// ---------------------------------------------------------------------------
// Fingerprint

/// A compatibility fingerprint: a digest of everything that must agree
/// for two summaries to be mergeable (concrete type, seed, shape, power,
/// distribution, pass index, ...). Equal fingerprints ⇒ compatible.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint(u64);

impl Fingerprint {
    /// Start a fingerprint from a type tag (usually the summary's name).
    pub fn new(tag: &str) -> Self {
        Fingerprint(hash_bytes(0xF16E_5EED, tag.as_bytes()))
    }

    /// Fold an integer component into the fingerprint.
    pub fn with(self, x: u64) -> Self {
        Fingerprint(hash64(self.0, x))
    }

    /// Fold a float component (by bit pattern).
    pub fn with_f64(self, x: f64) -> Self {
        self.with(x.to_bits())
    }

    /// Fold the bottom-k distribution choice.
    pub fn with_dist(self, d: BottomKDist) -> Self {
        self.with(match d {
            BottomKDist::Exp => 1,
            BottomKDist::Uniform => 2,
        })
    }

    /// The digest value.
    pub fn value(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of the shared [`SamplerConfig`] components (everything
/// that defines the randomization and sketch shape).
pub fn config_fingerprint(tag: &str, cfg: &SamplerConfig) -> Fingerprint {
    Fingerprint::new(tag)
        .with_f64(cfg.p)
        .with(cfg.k as u64)
        .with_f64(cfg.q)
        .with(cfg.seed)
        .with(cfg.n as u64)
        .with_f64(cfg.delta)
        .with_f64(cfg.eps)
        .with(cfg.rows as u64)
        .with(cfg.width as u64)
        .with_dist(cfg.dist)
}

// ---------------------------------------------------------------------------
// Core traits

/// Anything that consumes a stream of [`Element`]s and maintains a
/// bounded summary: sketches, samplers, pass states, sinks.
pub trait StreamSummary {
    /// Process one element.
    fn process(&mut self, e: &Element);

    /// Process a micro-batch. The default is a plain loop; concrete
    /// summaries may override it with a vectorized / amortized path
    /// (e.g. [`crate::sampler::worp1::OnePassWorp`] defers candidate
    /// maintenance to once per batch).
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            self.process(e);
        }
    }

    /// Process a structure-of-arrays micro-batch (§Perf L3-7) — the unit
    /// the sharded pipeline moves. The default bridges to
    /// [`StreamSummary::process_batch`] through a materialized AoS copy
    /// (bit-identical by construction, one allocation per call); every
    /// hot summary overrides it with a true columnar path that reads the
    /// key/value columns directly and allocates nothing.
    fn process_block(&mut self, block: &ElementBlock) {
        self.process_batch(&block.to_elements());
    }

    /// Summary size in memory words (f64/u64 cells).
    fn size_words(&self) -> usize;

    /// Elements processed so far (in the current pass, for multi-pass
    /// summaries).
    fn processed(&self) -> u64;
}

/// A composable summary: merging the summaries of a sharded stream is
/// equivalent to summarizing the whole stream.
pub trait Mergeable: StreamSummary {
    /// Digest of everything that must agree for a merge to be sound.
    fn fingerprint(&self) -> Fingerprint;

    /// Merge `other` into `self` without the compatibility check.
    /// Prefer [`Mergeable::merge`].
    fn merge_unchecked(&mut self, other: &Self) -> Result<()>;

    /// Fail with [`Error::Incompatible`] unless the fingerprints agree.
    fn check_compatible(&self, other: &Self) -> Result<()> {
        let (a, b) = (self.fingerprint(), other.fingerprint());
        if a != b {
            return Err(Error::Incompatible(format!(
                "fingerprint mismatch: {:#018x} vs {:#018x} — summaries were built \
                 with different seeds, shapes or parameters",
                a.value(),
                b.value()
            )));
        }
        Ok(())
    }

    /// Checked merge: verifies compatibility, then merges.
    fn merge(&mut self, other: &Self) -> Result<()> {
        self.check_compatible(other)?;
        self.merge_unchecked(other)
    }
}

/// A summary with a portable binary form — the persistence half of the
/// composability story: `encode_into` appends one self-contained,
/// versioned [`crate::codec`] envelope (magic, version, type tag, payload
/// length, fingerprint, checksum, payload); `decode` reconstructs the
/// summary from such an envelope.
///
/// Contract (verified generically by `tests/persist_contract.rs`):
///
/// - `decode(encode(s))` preserves the fingerprint, the final output
///   (sample / estimates) and merge-compatibility of `s`;
/// - encoding is canonical — logically-equal summaries encode to
///   byte-identical envelopes — so
///   `merge(decode(encode(a)), decode(encode(b))) ≡ merge(a, b)`
///   bit-for-bit;
/// - `decode` **never panics**: every malformed input maps to
///   [`Error::Codec`] (see the corruption suite).
pub trait Persist {
    /// Append the full envelope for this summary to `out`.
    fn encode_into(&self, out: &mut Vec<u8>);

    /// Decode a summary from an envelope produced by
    /// [`Persist::encode_into`].
    fn decode(bytes: &[u8]) -> Result<Self>
    where
        Self: Sized;

    /// Convenience: encode into a fresh buffer.
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }
}

/// A summary with a final output (a [`Sample`] for WOR samplers, a draw
/// for single samplers, ...). Finalization never consumes the summary:
/// streaming can continue afterwards.
pub trait Finalize {
    /// The output type.
    type Output;

    /// Produce the output from the current state.
    fn finalize(&self) -> Self::Output;
}

/// Pass structure of a summary. Single-pass summaries use the defaults;
/// multi-pass methods (2-pass WORp) override all three and model the
/// pass-I → pass-II handoff as an explicit state transition.
pub trait MultiPass {
    /// Total number of passes over the stream (≥ 1).
    fn passes(&self) -> usize {
        1
    }

    /// Current pass index (0-based).
    fn pass(&self) -> usize {
        0
    }

    /// Seal the current pass and arm the next. Errors with
    /// [`Error::State`] when there is no next pass.
    fn advance(&mut self) -> Result<()> {
        Err(Error::State(
            "single-pass summary has no next pass to advance to".into(),
        ))
    }
}

/// Object-safe facade over every WOR sampler: stream in, [`Sample`] out,
/// mergeable across shards, clonable into workers. Built by
/// [`builder::Worp`]; driven by [`crate::coordinator::Coordinator::run_dyn`].
pub trait WorSampler: StreamSummary + MultiPass + Send {
    /// Extract the WOR sample. Errors with [`Error::State`] when the
    /// sampler still has passes to run (see [`MultiPass`]).
    fn sample(&self) -> Result<Sample>;

    /// Compatibility digest (same contract as [`Mergeable::fingerprint`]).
    fn fingerprint(&self) -> Fingerprint;

    /// Merge another sampler of the *same concrete type and fingerprint*;
    /// anything else fails with [`Error::Incompatible`].
    fn merge_dyn(&mut self, other: &dyn WorSampler) -> Result<()>;

    /// Clone into a fresh box (workers clone the leader's prototype).
    fn clone_box(&self) -> Box<dyn WorSampler>;

    /// Downcast support for [`WorSampler::merge_dyn`].
    fn as_any(&self) -> &dyn Any;

    /// Short method name for diagnostics ("1pass", "2pass", ...).
    fn name(&self) -> &'static str;

    /// Append this sampler's [`Persist`] envelope to `out` — the
    /// object-safe face of [`Persist::encode_into`]. The inverse is
    /// [`crate::codec::decode_sampler`], which dispatches on the
    /// envelope's type tag to rebuild the concrete type behind
    /// `Box<dyn WorSampler>`.
    fn encode_state(&self, out: &mut Vec<u8>);

    /// Whether sharding this sampler across parallel workers preserves
    /// its semantics. `false` for summaries whose [`StreamSummary::process`]
    /// depends on a stream-global clock (the windowed sampler's implicit
    /// per-element ticks are shard-local, so per-shard windows would
    /// cover different spans of the stream); the coordinator serializes
    /// such samplers onto one worker instead of merging skewed clocks.
    fn parallel_safe(&self) -> bool {
        true
    }
}

impl Clone for Box<dyn WorSampler> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Boxed summaries are summaries (lets `Box<dyn WorSampler>` flow through
/// the sharded pipeline and the merge tree unchanged).
impl<T: StreamSummary + ?Sized> StreamSummary for Box<T> {
    fn process(&mut self, e: &Element) {
        (**self).process(e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        (**self).process_batch(batch)
    }

    fn process_block(&mut self, block: &ElementBlock) {
        (**self).process_block(block)
    }

    fn size_words(&self) -> usize {
        (**self).size_words()
    }

    fn processed(&self) -> u64 {
        (**self).processed()
    }
}

// ---------------------------------------------------------------------------
// Sketch impls (the samplers implement the traits in their own modules)

impl StreamSummary for CountSketch {
    fn process(&mut self, e: &Element) {
        RhhSketch::process(self, e)
    }

    /// Columnar batch path (§Perf L3-6): block hashing + row-major sweeps.
    fn process_batch(&mut self, batch: &[Element]) {
        CountSketch::process_batch(self, batch)
    }

    /// SoA block path (§Perf L3-7): hashes straight off the key column.
    fn process_block(&mut self, block: &ElementBlock) {
        CountSketch::process_cols(self, &block.keys, &block.vals)
    }

    fn size_words(&self) -> usize {
        RhhSketch::size_words(self)
    }

    fn processed(&self) -> u64 {
        CountSketch::processed(self)
    }
}

impl Mergeable for CountSketch {
    fn fingerprint(&self) -> Fingerprint {
        let p = self.params();
        Fingerprint::new("countsketch")
            .with(p.rows as u64)
            .with(p.width as u64)
            .with(p.seed)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        RhhSketch::merge(self, other)
    }
}

impl StreamSummary for CountMin {
    fn process(&mut self, e: &Element) {
        RhhSketch::process(self, e)
    }

    /// Columnar batch path (§Perf L3-6).
    fn process_batch(&mut self, batch: &[Element]) {
        CountMin::process_batch(self, batch)
    }

    /// SoA block path (§Perf L3-7).
    fn process_block(&mut self, block: &ElementBlock) {
        CountMin::process_cols(self, &block.keys, &block.vals)
    }

    fn size_words(&self) -> usize {
        RhhSketch::size_words(self)
    }

    fn processed(&self) -> u64 {
        CountMin::processed(self)
    }
}

impl Mergeable for CountMin {
    fn fingerprint(&self) -> Fingerprint {
        let p = self.params();
        Fingerprint::new("countmin")
            .with(p.rows as u64)
            .with(p.width as u64)
            .with(p.seed)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        RhhSketch::merge(self, other)
    }
}

impl StreamSummary for AnyRhh {
    fn process(&mut self, e: &Element) {
        RhhSketch::process(self, e)
    }

    /// Columnar batch path (§Perf L3-6), dispatched to the wrapped sketch.
    fn process_batch(&mut self, batch: &[Element]) {
        AnyRhh::process_batch(self, batch)
    }

    /// SoA block path (§Perf L3-7), dispatched to the wrapped sketch.
    fn process_block(&mut self, block: &ElementBlock) {
        AnyRhh::process_cols(self, &block.keys, &block.vals)
    }

    fn size_words(&self) -> usize {
        RhhSketch::size_words(self)
    }

    fn processed(&self) -> u64 {
        AnyRhh::processed(self)
    }
}

impl Mergeable for AnyRhh {
    fn fingerprint(&self) -> Fingerprint {
        let p = self.params();
        Fingerprint::new("anyrhh")
            .with_f64(self.q())
            .with(p.rows as u64)
            .with(p.width as u64)
            .with(p.seed)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        RhhSketch::merge(self, other)
    }
}

impl StreamSummary for SpaceSaving<u64> {
    fn process(&mut self, e: &Element) {
        SpaceSaving::process(self, e.key, e.val)
    }

    /// Deferred-heap batch path (§Perf L3-6): hoisted bookkeeping plus the
    /// lazy-deletion eviction heap.
    fn process_batch(&mut self, batch: &[Element]) {
        SpaceSaving::process_elements(self, batch)
    }

    /// SoA block path (§Perf L3-7): updates stream off the dense columns.
    fn process_block(&mut self, block: &ElementBlock) {
        SpaceSaving::process_cols(self, &block.keys, &block.vals)
    }

    fn size_words(&self) -> usize {
        SpaceSaving::size_words(self)
    }

    fn processed(&self) -> u64 {
        SpaceSaving::processed(self)
    }
}

impl Mergeable for SpaceSaving<u64> {
    fn fingerprint(&self) -> Fingerprint {
        Fingerprint::new("spacesaving").with(self.capacity() as u64)
    }

    fn merge_unchecked(&mut self, other: &Self) -> Result<()> {
        SpaceSaving::merge(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchParams;

    #[test]
    fn fingerprints_separate_components() {
        let base = Fingerprint::new("x").with(1).with_f64(2.0);
        assert_eq!(base, Fingerprint::new("x").with(1).with_f64(2.0));
        assert_ne!(base, Fingerprint::new("y").with(1).with_f64(2.0));
        assert_ne!(base, Fingerprint::new("x").with(2).with_f64(2.0));
        assert_ne!(base, Fingerprint::new("x").with(1).with_f64(2.5));
        assert_ne!(
            Fingerprint::new("x").with_dist(BottomKDist::Exp),
            Fingerprint::new("x").with_dist(BottomKDist::Uniform)
        );
    }

    #[test]
    fn sketch_merge_checks_fingerprint() {
        let mut a = CountSketch::new(SketchParams::new(5, 64, 1));
        let b = CountSketch::new(SketchParams::new(5, 64, 2));
        let err = Mergeable::merge(&mut a, &b).unwrap_err();
        assert!(matches!(err, Error::Incompatible(_)), "{err}");
        let c = CountSketch::new(SketchParams::new(5, 64, 1));
        assert!(Mergeable::merge(&mut a, &c).is_ok());
    }

    #[test]
    fn batch_default_equals_loop() {
        let params = SketchParams::new(5, 128, 9);
        let mut a = CountSketch::new(params);
        let mut b = CountSketch::new(params);
        let batch: Vec<Element> = (0..100u64)
            .map(|i| Element::new(i % 13, i as f64 - 50.0))
            .collect();
        for e in &batch {
            StreamSummary::process(&mut a, e);
        }
        StreamSummary::process_batch(&mut b, &batch);
        assert_eq!(a.table(), b.table());
        assert_eq!(StreamSummary::processed(&a), StreamSummary::processed(&b));
    }

    #[test]
    fn block_default_bridges_to_batch() {
        // a summary with no override must see the identical elements
        // through process_block as through process_batch
        struct Collect(Vec<Element>);
        impl StreamSummary for Collect {
            fn process(&mut self, e: &Element) {
                self.0.push(*e);
            }
            fn size_words(&self) -> usize {
                0
            }
            fn processed(&self) -> u64 {
                self.0.len() as u64
            }
        }
        let elems: Vec<Element> = (0..10u64).map(|i| Element::new(i, i as f64)).collect();
        let block = crate::data::ElementBlock::from_elements(&elems);
        let mut c = Collect(Vec::new());
        c.process_block(&block);
        assert_eq!(c.0, elems);
    }

    #[test]
    fn sketch_block_overrides_bit_identical_to_scalar() {
        let params = SketchParams::new(5, 128, 11);
        let mut scalar = CountSketch::new(params);
        let mut blocked = CountSketch::new(params);
        let elems: Vec<Element> = (0..200u64)
            .map(|i| Element::new(i % 17, i as f64 - 100.0))
            .collect();
        for e in &elems {
            StreamSummary::process(&mut scalar, e);
        }
        for c in elems.chunks(33) {
            let block = crate::data::ElementBlock::from_elements(c);
            StreamSummary::process_block(&mut blocked, &block);
        }
        assert_eq!(scalar.table(), blocked.table());
        assert_eq!(
            StreamSummary::processed(&scalar),
            StreamSummary::processed(&blocked)
        );
    }

    #[test]
    fn boxed_summary_delegates() {
        let mut boxed: Box<CountSketch> = Box::new(CountSketch::new(SketchParams::new(3, 32, 7)));
        StreamSummary::process(&mut boxed, &Element::new(5, 2.0));
        assert_eq!(StreamSummary::processed(&boxed), 1);
        assert_eq!(StreamSummary::size_words(&boxed), 96);
    }
}
