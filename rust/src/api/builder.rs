//! The `Worp` builder facade: one fluent entry point that configures and
//! constructs any of the crate's WOR samplers behind
//! `Box<dyn `[`WorSampler`]`>`.
//!
//! ```no_run
//! use worp::Worp;
//!
//! // ℓ1, k = 64, 1-pass, priority (sequential Poisson) randomization.
//! let sampler = Worp::p(1.0).k(64).one_pass().priority().seed(7).build().unwrap();
//! # let _ = sampler;
//! ```
//!
//! Generic call sites that want static dispatch use the typed
//! constructors ([`Worp::build_one_pass`], [`Worp::build_two_pass`],
//! [`Worp::build_exact`]) or the concrete types directly.

use super::WorSampler;
use crate::config::PipelineConfig;
use crate::error::{Error, Result};
use crate::sampler::decayed::DecayedWorp;
use crate::sampler::exact::ExactWor;
use crate::sampler::tv1pass::{SamplerKind, TvSampler, TvSamplerConfig};
use crate::sampler::windowed::WindowedWorp;
use crate::sampler::worp1::OnePassWorp;
use crate::sampler::worp2::TwoPassWorp;
use crate::sampler::wr_reservoir::WrReservoir;
use crate::sampler::SamplerConfig;
use crate::transform::DecaySpec;
use crate::util::hashing::BottomKDist;

/// The sampling method a [`Worp`] builder constructs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// 1-pass WORp (paper §5): composable sketch, approximate frequencies.
    OnePass,
    /// 2-pass WORp (paper §4): exact p-ppswor sample in two passes.
    TwoPass,
    /// Algorithm 1 (paper §6): 1-pass, polynomially-small TV distance.
    Tv,
    /// Sliding-window 1-pass WORp (paper Conclusion).
    Windowed,
    /// Exact streaming baseline: aggregates frequencies, perfect bottom-k
    /// sample (linear memory — the "perfect WOR" of Figs 1–2).
    Exact,
    /// Streaming with-replacement reservoir (exponential-jump E–S with
    /// the with-replacement extension) — the honest WR baseline the
    /// scenario gates compare WOR against.
    Wr,
    /// Exact bottom-k over time-decayed frequencies (exponential /
    /// polynomial forward decay, run-chunked ticks).
    Decayed,
}

impl Method {
    /// Parse the CLI / config spelling of a method.
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "1pass" | "one-pass" | "onepass" => Ok(Method::OnePass),
            "2pass" | "two-pass" | "twopass" => Ok(Method::TwoPass),
            "tv" => Ok(Method::Tv),
            "windowed" | "window" => Ok(Method::Windowed),
            "exact" | "perfect" => Ok(Method::Exact),
            "wr" | "wr-reservoir" | "reservoir" => Ok(Method::Wr),
            "decayed" | "decay" => Ok(Method::Decayed),
            other => Err(Error::Config(format!(
                "unknown method {other:?} (expected 1pass|2pass|tv|windowed|exact|wr|decayed)"
            ))),
        }
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            Method::OnePass => "1pass",
            Method::TwoPass => "2pass",
            Method::Tv => "tv",
            Method::Windowed => "windowed",
            Method::Exact => "exact",
            Method::Wr => "wr",
            Method::Decayed => "decayed",
        }
    }
}

/// Fluent builder for every WOR sampler in the crate. Start with
/// [`Worp::p`]; defaults match the paper's experiments (§7).
#[derive(Clone, Debug)]
pub struct Worp {
    p: f64,
    k: usize,
    q: f64,
    seed: u64,
    n: usize,
    delta: f64,
    eps: f64,
    rows: usize,
    width: usize,
    dist: BottomKDist,
    method: Method,
    window: u64,
    buckets: usize,
    tv_kind: SamplerKind,
    tv_r: usize,
    decay: Option<DecaySpec>,
}

impl Worp {
    /// Start a builder for ℓp sampling with power `p ∈ (0, 2]`.
    pub fn p(p: f64) -> Worp {
        Worp {
            p,
            k: 64,
            q: 2.0,
            seed: 1,
            n: 10_000,
            delta: 0.01,
            eps: 1.0 / 3.0,
            rows: 0,
            width: 0,
            dist: BottomKDist::Exp,
            method: Method::OnePass,
            window: 0,
            buckets: 8,
            tv_kind: SamplerKind::Oracle,
            tv_r: 0,
            decay: None,
        }
    }

    /// Sample size `k ≥ 1`.
    pub fn k(mut self, k: usize) -> Worp {
        self.k = k;
        self
    }

    /// Shared randomization seed (transform + sketch hashes). Samplers
    /// that should be mergeable or coordinated must share it.
    pub fn seed(mut self, seed: u64) -> Worp {
        self.seed = seed;
        self
    }

    /// Key-domain size `n` used for Ψ calibration.
    pub fn domain(mut self, n: usize) -> Worp {
        self.n = n;
        self
    }

    /// rHH norm `q ∈ {1, 2}` (2 = CountSketch, 1 = CountMin; needs q ≥ p).
    pub fn q(mut self, q: f64) -> Worp {
        self.q = q;
        self
    }

    /// Target failure probability δ.
    pub fn delta(mut self, delta: f64) -> Worp {
        self.delta = delta;
        self
    }

    /// 1-pass accuracy parameter ε ∈ (0, 1/3].
    pub fn eps(mut self, eps: f64) -> Worp {
        self.eps = eps;
        self
    }

    /// Explicit sketch shape (rows must be odd); 0-width derives the
    /// width from the Ψ calibration.
    pub fn sketch_shape(mut self, rows: usize, width: usize) -> Worp {
        self.rows = rows;
        self.width = width;
        self
    }

    /// ppswor randomization (`D = Exp[1]`, the paper default).
    pub fn ppswor(mut self) -> Worp {
        self.dist = BottomKDist::Exp;
        self
    }

    /// Priority (sequential Poisson) randomization (`D = U[0,1]`).
    pub fn priority(mut self) -> Worp {
        self.dist = BottomKDist::Uniform;
        self
    }

    /// Select the 1-pass WORp method.
    pub fn one_pass(mut self) -> Worp {
        self.method = Method::OnePass;
        self
    }

    /// Select the 2-pass WORp method (exact sample, two stream passes).
    pub fn two_pass(mut self) -> Worp {
        self.method = Method::TwoPass;
        self
    }

    /// Select the exact streaming baseline (linear memory).
    pub fn exact(mut self) -> Worp {
        self.method = Method::Exact;
        self
    }

    /// Select the low-TV Algorithm 1 with the exact-oracle substrate.
    pub fn tv(mut self) -> Worp {
        self.method = Method::Tv;
        self.tv_kind = SamplerKind::Oracle;
        self
    }

    /// Select Algorithm 1 with the sketch-based precision-sampler
    /// substrate (honest 1-pass memory profile).
    pub fn tv_precision(mut self) -> Worp {
        self.method = Method::Tv;
        self.tv_kind = SamplerKind::Precision;
        self
    }

    /// Override Algorithm 1's single-sampler count `r` (default `Θ(k log n)`).
    pub fn tv_r(mut self, r: usize) -> Worp {
        self.tv_r = r;
        self
    }

    /// Select the sliding-window method over the last `window` time units
    /// split into `buckets` sub-sketches.
    pub fn windowed(mut self, window: u64, buckets: usize) -> Worp {
        self.method = Method::Windowed;
        self.window = window;
        self.buckets = buckets;
        self
    }

    /// Select the streaming with-replacement reservoir baseline.
    pub fn wr(mut self) -> Worp {
        self.method = Method::Wr;
        self
    }

    /// Select the time-decayed exact sampler with the given decay spec
    /// (see [`DecaySpec::exponential`] / [`DecaySpec::polynomial`]).
    pub fn decayed(mut self, spec: DecaySpec) -> Worp {
        self.method = Method::Decayed;
        self.decay = Some(spec);
        self
    }

    /// Select a method by enum (CLI / config path).
    pub fn method(mut self, m: Method) -> Worp {
        self.method = m;
        self
    }

    /// Seed a builder from the launcher config (method, dist, and all
    /// sampler/sketch parameters).
    pub fn from_config(cfg: &PipelineConfig) -> Result<Worp> {
        cfg.validate()?;
        let mut w = Worp::p(cfg.p)
            .k(cfg.k)
            .q(cfg.q)
            .seed(cfg.seed)
            .domain(cfg.n)
            .delta(cfg.delta)
            .eps(cfg.eps)
            .sketch_shape(cfg.rows, cfg.width)
            .method(Method::parse(&cfg.method)?);
        w = match cfg.dist.as_str() {
            "priority" => w.priority(),
            _ => w.ppswor(),
        };
        if cfg.window > 0 {
            w.window = cfg.window;
            w.buckets = cfg.buckets.max(1);
        }
        if !cfg.decay.is_empty() {
            w.decay = Some(DecaySpec::parse(&cfg.decay, cfg.decay_rate)?);
        }
        Ok(w)
    }

    /// The chosen method.
    pub fn selected_method(&self) -> Method {
        self.method
    }

    /// The shared randomization seed this builder prescribes (what the
    /// engine records for coordinated instance creation).
    pub fn seed_value(&self) -> u64 {
        self.seed
    }

    /// Validate and materialize the [`SamplerConfig`] this builder
    /// prescribes (errors instead of panicking on bad parameters).
    pub fn sampler_config(&self) -> Result<SamplerConfig> {
        if !(self.p > 0.0 && self.p <= 2.0) {
            return Err(Error::Config(format!("p must be in (0,2], got {}", self.p)));
        }
        if self.k == 0 {
            return Err(Error::Config("k must be positive".into()));
        }
        if self.q != 1.0 && self.q != 2.0 {
            return Err(Error::Config(format!("q must be 1 or 2, got {}", self.q)));
        }
        if self.q < self.p {
            return Err(Error::Config(format!(
                "need q >= p for the rHH reduction (q={}, p={})",
                self.q, self.p
            )));
        }
        if self.rows > 0 && self.rows % 2 == 0 {
            return Err(Error::Config(format!(
                "sketch rows must be odd for the median estimator, got {}",
                self.rows
            )));
        }
        if !(self.eps > 0.0 && self.eps <= 1.0 / 3.0 + 1e-12) {
            return Err(Error::Config(format!(
                "eps must be in (0, 1/3], got {}",
                self.eps
            )));
        }
        if !(self.delta > 0.0 && self.delta < 1.0) {
            return Err(Error::Config(format!(
                "delta must be in (0,1), got {}",
                self.delta
            )));
        }
        Ok(SamplerConfig {
            p: self.p,
            k: self.k,
            q: self.q,
            seed: self.seed,
            n: self.n,
            delta: self.delta,
            eps: self.eps,
            rows: self.rows,
            width: self.width,
            dist: self.dist,
        })
    }

    /// Build the selected sampler behind `Box<dyn WorSampler>`.
    pub fn build(&self) -> Result<Box<dyn WorSampler>> {
        let cfg = self.sampler_config()?;
        Ok(match self.method {
            Method::OnePass => Box::new(OnePassWorp::new(cfg)),
            Method::TwoPass => Box::new(TwoPassWorp::new(cfg)),
            Method::Exact => Box::new(ExactWor::new(cfg)),
            Method::Wr => Box::new(WrReservoir::new(cfg)),
            Method::Decayed => {
                let spec = self.decay.ok_or_else(|| {
                    Error::Config(
                        "decayed method requires a decay spec (.decayed(spec) / decay = \
                         \"exp\"|\"poly\" + decay_rate in config)"
                            .into(),
                    )
                })?;
                Box::new(DecayedWorp::new(cfg, spec))
            }
            Method::Windowed => {
                if self.window == 0 || self.buckets == 0 {
                    return Err(Error::Config(
                        "windowed method requires .windowed(window, buckets) with window > 0"
                            .into(),
                    ));
                }
                if self.q < 2.0 {
                    return Err(Error::Config(
                        "windowed WORp requires the CountSketch (q=2) path".into(),
                    ));
                }
                Box::new(WindowedWorp::new(cfg, self.window, self.buckets))
            }
            Method::Tv => {
                // Algorithm 1 draws successive-WOR (ppswor-style) tuples;
                // it has no bottom-k transform to re-randomize, so a
                // priority request cannot be honored — fail loudly.
                if self.dist != BottomKDist::Exp {
                    return Err(Error::Config(
                        "tv method draws ppswor-style tuples; dist = priority is not supported"
                            .into(),
                    ));
                }
                let mut tvc =
                    TvSamplerConfig::new(self.p, self.k, self.n, self.seed, self.tv_kind);
                if self.rows > 0 {
                    tvc.rhh_rows = self.rows;
                }
                if self.width > 0 {
                    tvc.rhh_width = self.width;
                }
                if self.tv_r > 0 {
                    tvc = tvc.with_r(self.tv_r);
                }
                Box::new(TvSampler::new(tvc))
            }
        })
    }

    /// Statically-typed 1-pass construction (generic call sites).
    pub fn build_one_pass(&self) -> Result<OnePassWorp> {
        Ok(OnePassWorp::new(self.sampler_config()?))
    }

    /// Statically-typed 2-pass construction.
    pub fn build_two_pass(&self) -> Result<TwoPassWorp> {
        Ok(TwoPassWorp::new(self.sampler_config()?))
    }

    /// Statically-typed exact-baseline construction.
    pub fn build_exact(&self) -> Result<ExactWor> {
        Ok(ExactWor::new(self.sampler_config()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrips() {
        for m in [
            Method::OnePass,
            Method::TwoPass,
            Method::Tv,
            Method::Windowed,
            Method::Exact,
            Method::Wr,
            Method::Decayed,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn builder_wires_config() {
        let w = Worp::p(2.0)
            .k(32)
            .seed(9)
            .domain(500)
            .sketch_shape(5, 128)
            .priority();
        let cfg = w.sampler_config().unwrap();
        assert_eq!(cfg.p, 2.0);
        assert_eq!(cfg.k, 32);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.n, 500);
        assert_eq!(cfg.rows, 5);
        assert_eq!(cfg.width, 128);
        assert_eq!(cfg.dist, BottomKDist::Uniform);
    }

    #[test]
    fn invalid_parameters_error_instead_of_panicking() {
        assert!(Worp::p(3.0).sampler_config().is_err());
        assert!(Worp::p(1.0).k(0).sampler_config().is_err());
        assert!(Worp::p(2.0).q(1.0).sampler_config().is_err()); // q < p
        assert!(Worp::p(1.0).q(1.5).sampler_config().is_err());
        assert!(Worp::p(1.0).sketch_shape(4, 64).sampler_config().is_err());
        assert!(Worp::p(1.0).eps(0.9).sampler_config().is_err());
    }

    #[test]
    fn build_constructs_every_method() {
        assert_eq!(Worp::p(1.0).one_pass().build().unwrap().name(), "1pass");
        assert_eq!(Worp::p(1.0).two_pass().build().unwrap().name(), "2pass");
        assert_eq!(Worp::p(1.0).exact().build().unwrap().name(), "exact");
        assert_eq!(Worp::p(1.0).k(4).tv().build().unwrap().name(), "tv");
        assert_eq!(
            Worp::p(1.0).windowed(100, 10).build().unwrap().name(),
            "windowed"
        );
        assert_eq!(Worp::p(1.0).wr().build().unwrap().name(), "wr");
        assert_eq!(
            Worp::p(1.0)
                .decayed(DecaySpec::exponential(0.01).unwrap())
                .build()
                .unwrap()
                .name(),
            "decayed"
        );
        // decayed without a decay spec is a config error
        assert!(Worp::p(1.0).method(Method::Decayed).build().is_err());
        // windowed without a window is a config error
        assert!(Worp::p(1.0).method(Method::Windowed).build().is_err());
        // windowed on the counter path is a config error
        assert!(Worp::p(1.0).q(1.0).windowed(10, 2).build().is_err());
        // tv cannot honor a priority randomization — loud error, not a
        // silently-mislabeled sample
        assert!(Worp::p(1.0).k(4).tv().priority().build().is_err());
    }

    #[test]
    fn from_config_respects_method_and_dist() {
        let mut pc = PipelineConfig::default();
        pc.method = "2pass".into();
        pc.dist = "priority".into();
        pc.p = 0.5;
        let w = Worp::from_config(&pc).unwrap();
        assert_eq!(w.selected_method(), Method::TwoPass);
        let cfg = w.sampler_config().unwrap();
        assert_eq!(cfg.dist, BottomKDist::Uniform);
        assert_eq!(cfg.p, 0.5);
    }
}
