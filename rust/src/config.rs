//! Configuration system: a TOML-subset parser plus the typed configs the
//! launcher consumes (no `serde`/`toml` crates offline — DESIGN.md §7).
//!
//! Supported syntax: `[section]` headers, `key = value` with values of
//! type integer, float, bool, quoted string, or flat arrays of those;
//! `#` comments. A dotted header like `[cluster.retry]` is kept
//! verbatim as the section name (no TOML nesting), so sub-sections are
//! addressed as `doc.get("cluster.retry", key)`. That covers every
//! config this project ships.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Quoted string.
    Str(String),
    /// Flat array of scalars.
    Array(Vec<Value>),
}

impl Value {
    /// As f64 (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A parsed config document: `section.key -> Value` (top-level keys live in
/// the empty section `""`).
#[derive(Clone, Debug, Default)]
pub struct Document {
    entries: BTreeMap<(String, String), Value>,
}

impl Document {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Document> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: unterminated section", lineno + 1)))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| Error::Config(format!("line {}: {}", lineno + 1, e)))?;
            doc.entries.insert((section.clone(), key), val);
        }
        Ok(doc)
    }

    /// Parse from a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Document> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Document::parse(&text)
    }

    /// Get `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// f64 with default.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// i64 with default.
    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    /// usize with default.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.i64_or(section, key, default as i64).max(0) as usize
    }

    /// bool with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// string with default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Flat array of strings (`key = ["a", "b"]`). An absent key is an
    /// empty list; a present key with any non-string item is a loud
    /// config error (used by `[cluster] nodes`).
    pub fn str_array(&self, section: &str, key: &str) -> Result<Vec<String>> {
        match self.get(section, key) {
            None => Ok(Vec::new()),
            Some(Value::Array(items)) => items
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        Error::Config(format!(
                            "{section}.{key} must be an array of quoted strings"
                        ))
                    })
                })
                .collect(),
            Some(_) => Err(Error::Config(format!(
                "{section}.{key} must be an array of quoted strings"
            ))),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_array(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_array(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Pipeline launcher configuration (the `[pipeline]`, `[sampler]`,
/// `[sketch]`, `[workload]` sections of a config file — see
/// `worp.example.toml` at the repository root for a commented reference).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// ℓp power `p ∈ (0, 2]`.
    pub p: f64,
    /// Sample size `k`.
    pub k: usize,
    /// rHH moment `q ∈ {1, 2}` (2 = CountSketch, 1 = CountMin/counters).
    pub q: f64,
    /// Sampling method: "1pass", "2pass", "tv", "windowed", "exact",
    /// "wr" (streaming with-replacement reservoir) or "decayed".
    pub method: String,
    /// Bottom-k randomization: "ppswor" (Exp[1]) or "priority" (U[0,1]).
    pub dist: String,
    /// 1-pass accuracy parameter ε ∈ (0, 1/3].
    pub eps: f64,
    /// Sliding-window length in time units (0 = unwindowed; required > 0
    /// when `method = "windowed"`).
    pub window: u64,
    /// Sub-sketch buckets covering the window.
    pub buckets: usize,
    /// Time-decay family for `method = "decayed"`: "exp" or "poly"
    /// ("" = no decay configured).
    pub decay: String,
    /// Decay rate (λ per tick for "exp", exponent β for "poly"); must be
    /// a positive finite number when `decay` is set.
    pub decay_rate: f64,
    /// Shared randomization seed (defines `r_x` and sketch hashes).
    pub seed: u64,
    /// Number of shard workers.
    pub workers: usize,
    /// Elements per worker SoA block (and the checkpoint alignment unit).
    pub batch: usize,
    /// Checkpoint directory ("" = checkpointing off). When set, sharded
    /// runs snapshot worker states there and resume from existing
    /// snapshots (crash recovery).
    pub checkpoint_dir: String,
    /// Batches between worker snapshots (only used with `checkpoint_dir`).
    pub checkpoint_every: u64,
    /// Sketch rows (must be odd for CountSketch median).
    pub rows: usize,
    /// Sketch width override (0 = derive from Ψ calibration).
    pub width: usize,
    /// Failure probability target δ for Ψ calibration.
    pub delta: f64,
    /// Key domain size `n` (for KeyHash and Ψ).
    pub n: usize,
    /// Sketch-update backend: "native" or "xla".
    pub backend: String,
    /// Artifacts directory for the xla backend.
    pub artifacts_dir: String,
    /// Workload spec (used by the launcher): "zipf", "gradient", "querylog".
    pub workload: String,
    /// Zipf skew α.
    pub alpha: f64,
    /// Stream length (elements).
    pub stream_len: u64,
    /// `worp serve` listen address (the `[server]` section).
    pub server_addr: String,
    /// Maximum accepted wire-protocol frame payload, in MiB (oversized
    /// frames are answered with a typed error and the connection closed).
    pub server_max_frame_mib: usize,
    /// Seconds a served connection may sit idle (no complete frame)
    /// before the server evicts it with a typed error frame. 0 disables
    /// eviction (a slow peer still cannot stall others — reads are
    /// deadlined per frame at the default budget).
    pub server_idle_timeout_secs: u64,
    /// Default in-flight window for pipelined client ingest (frames sent
    /// before the oldest ack is reconciled). Must be ≥ 1.
    pub server_pipeline_window: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            p: 1.0,
            k: 100,
            q: 2.0,
            method: "1pass".into(),
            dist: "ppswor".into(),
            eps: 1.0 / 3.0,
            window: 0,
            buckets: 10,
            decay: String::new(),
            decay_rate: 0.0,
            seed: 42,
            workers: 4,
            batch: 4096,
            checkpoint_dir: String::new(),
            checkpoint_every: 64,
            rows: 31,
            width: 0,
            delta: 0.01,
            n: 10_000,
            backend: "native".into(),
            artifacts_dir: "artifacts".into(),
            workload: "zipf".into(),
            alpha: 1.0,
            stream_len: 1_000_000,
            server_addr: "127.0.0.1:7070".into(),
            server_max_frame_mib: 32,
            server_idle_timeout_secs: crate::engine::server::DEFAULT_IDLE_TIMEOUT_SECS,
            server_pipeline_window: crate::engine::client::DEFAULT_PIPELINE_WINDOW,
        }
    }
}

impl PipelineConfig {
    /// Read from a parsed document (missing keys keep defaults).
    pub fn from_document(doc: &Document) -> Result<PipelineConfig> {
        let d = PipelineConfig::default();
        // the channel-based router (and its backpressure window) is gone;
        // old config files still carry the key, so note-and-ignore instead
        // of erroring a previously-valid file
        if doc.get("pipeline", "channel_cap").is_some() {
            eprintln!(
                "note: pipeline.channel_cap is deprecated and ignored (the channel-based \
                 router was removed; the scan pipeline has no backpressure window)"
            );
        }
        let cfg = PipelineConfig {
            p: doc.f64_or("sampler", "p", d.p),
            k: doc.usize_or("sampler", "k", d.k),
            q: doc.f64_or("sketch", "q", d.q),
            method: doc.str_or("sampler", "method", &d.method),
            dist: doc.str_or("sampler", "dist", &d.dist),
            eps: doc.f64_or("sampler", "eps", d.eps),
            window: doc.i64_or("sampler", "window", d.window as i64).max(0) as u64,
            buckets: doc.usize_or("sampler", "buckets", d.buckets),
            decay: doc.str_or("sampler", "decay", &d.decay),
            decay_rate: doc.f64_or("sampler", "decay_rate", d.decay_rate),
            seed: doc.i64_or("sampler", "seed", d.seed as i64) as u64,
            workers: doc.usize_or("pipeline", "workers", d.workers),
            batch: doc.usize_or("pipeline", "batch", d.batch),
            checkpoint_dir: doc.str_or("pipeline", "checkpoint_dir", &d.checkpoint_dir),
            checkpoint_every: doc
                .i64_or("pipeline", "checkpoint_every", d.checkpoint_every as i64)
                .max(0) as u64,
            rows: doc.usize_or("sketch", "rows", d.rows),
            width: doc.usize_or("sketch", "width", d.width),
            delta: doc.f64_or("sketch", "delta", d.delta),
            n: doc.usize_or("workload", "n", d.n),
            backend: doc.str_or("pipeline", "backend", &d.backend),
            artifacts_dir: doc.str_or("pipeline", "artifacts_dir", &d.artifacts_dir),
            workload: doc.str_or("workload", "kind", &d.workload),
            alpha: doc.f64_or("workload", "alpha", d.alpha),
            stream_len: doc.i64_or("workload", "stream_len", d.stream_len as i64) as u64,
            server_addr: doc.str_or("server", "addr", &d.server_addr),
            server_max_frame_mib: doc.usize_or("server", "max_frame_mib", d.server_max_frame_mib),
            server_idle_timeout_secs: doc
                .i64_or("server", "idle_timeout_secs", d.server_idle_timeout_secs as i64)
                .max(0) as u64,
            server_pipeline_window: doc
                .usize_or("server", "pipeline_window", d.server_pipeline_window),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<PipelineConfig> {
        PipelineConfig::from_document(&Document::load(path)?)
    }

    /// Validate parameter ranges (paper: p ∈ (0,2], q ≥ p, q ∈ {1,2}).
    pub fn validate(&self) -> Result<()> {
        if !(self.p > 0.0 && self.p <= 2.0) {
            return Err(Error::Config(format!("p must be in (0,2], got {}", self.p)));
        }
        if self.q != 1.0 && self.q != 2.0 {
            return Err(Error::Config(format!("q must be 1 or 2, got {}", self.q)));
        }
        if self.q < self.p {
            return Err(Error::Config(format!(
                "need q >= p for the rHH reduction (q={}, p={})",
                self.q, self.p
            )));
        }
        if self.k == 0 {
            return Err(Error::Config("k must be positive".into()));
        }
        if self.rows % 2 == 0 {
            return Err(Error::Config(format!(
                "sketch rows must be odd for the median estimator, got {}",
                self.rows
            )));
        }
        if self.workers == 0 || self.batch == 0 {
            return Err(Error::Config("workers/batch must be positive".into()));
        }
        if self.server_addr.is_empty() {
            return Err(Error::Config("server.addr must not be empty".into()));
        }
        if self.server_max_frame_mib == 0 {
            return Err(Error::Config("server.max_frame_mib must be positive".into()));
        }
        if self.server_pipeline_window == 0 {
            return Err(Error::Config(
                "server.pipeline_window must be at least 1 (1 = lockstep)".into(),
            ));
        }
        if !self.checkpoint_dir.is_empty() && self.checkpoint_every == 0 {
            return Err(Error::Config(
                "checkpoint_every must be positive when checkpoint_dir is set".into(),
            ));
        }
        let method = crate::api::builder::Method::parse(&self.method)?;
        if !self.decay.is_empty() {
            crate::transform::DecaySpec::parse(&self.decay, self.decay_rate)?;
        } else if method == crate::api::builder::Method::Decayed {
            return Err(Error::Config(
                "method = \"decayed\" requires sampler.decay (\"exp\"|\"poly\") and a \
                 positive sampler.decay_rate"
                    .into(),
            ));
        }
        match self.dist.as_str() {
            "ppswor" | "priority" => {}
            d => {
                return Err(Error::Config(format!(
                    "unknown dist {d:?} (expected ppswor|priority)"
                )))
            }
        }
        if !(self.eps > 0.0 && self.eps <= 1.0 / 3.0 + 1e-12) {
            return Err(Error::Config(format!(
                "eps must be in (0, 1/3], got {}",
                self.eps
            )));
        }
        match self.backend.as_str() {
            "native" | "xla" => {}
            b => return Err(Error::Config(format!("unknown backend {b:?}"))),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# WORp pipeline config
[sampler]
p = 2.0
k = 128
seed = 7
method = "2pass"
dist = "priority"

[sketch]
q = 2 # CountSketch
rows = 5
delta = 0.01

[pipeline]
workers = 2
backend = "native"
caps = [1, 2, 3]

[workload]
kind = "zipf"
alpha = 1.5
n = 1000
stream_len = 50000
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("sampler", "p"), Some(&Value::Float(2.0)));
        assert_eq!(doc.get("sampler", "k"), Some(&Value::Int(128)));
        assert_eq!(doc.get("pipeline", "backend"), Some(&Value::Str("native".into())));
        assert_eq!(
            doc.get("pipeline", "caps"),
            Some(&Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
    }

    #[test]
    fn dotted_section_headers_are_plain_section_names() {
        // `[cluster.retry]` is not TOML nesting here — the parser keeps
        // the dotted header verbatim as the section name, which is what
        // RetryPolicy::from_document addresses it by
        let doc = Document::parse(
            "[cluster]\nname = \"x\"\n[cluster.retry]\nattempts = 7\nbase_ms = 5\n",
        )
        .unwrap();
        assert_eq!(doc.get("cluster", "name"), Some(&Value::Str("x".into())));
        assert_eq!(doc.get("cluster.retry", "attempts"), Some(&Value::Int(7)));
        assert_eq!(doc.i64_or("cluster.retry", "base_ms", 0), 5);
        // the dotted section does not shadow or leak into its parent
        assert_eq!(doc.get("cluster", "attempts"), None);
        assert_eq!(doc.get("cluster.retry", "name"), None);
    }

    #[test]
    fn comments_stripped_even_after_values() {
        let doc = Document::parse("x = 5 # five\ns = \"a#b\" # hash inside string\n").unwrap();
        assert_eq!(doc.get("", "x"), Some(&Value::Int(5)));
        assert_eq!(doc.get("", "s"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn pipeline_config_roundtrip() {
        let doc = Document::parse(SAMPLE).unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.p, 2.0);
        assert_eq!(cfg.k, 128);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.alpha, 1.5);
        assert_eq!(cfg.n, 1000);
        assert_eq!(cfg.method, "2pass");
        assert_eq!(cfg.dist, "priority");
        // defaults preserved
        assert_eq!(cfg.batch, PipelineConfig::default().batch);
        assert_eq!(cfg.eps, PipelineConfig::default().eps);
    }

    #[test]
    fn decay_keys_parse_and_validate() {
        let doc = Document::parse(
            "[sampler]\nmethod = \"decayed\"\ndecay = \"exp\"\ndecay_rate = 0.01\n",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.decay, "exp");
        assert_eq!(cfg.decay_rate, 0.01);
        // decayed without a decay spec is rejected loudly
        let mut c = PipelineConfig::default();
        c.method = "decayed".into();
        assert!(c.validate().is_err());
        // bad family / rate are rejected
        let mut c = PipelineConfig::default();
        c.decay = "linear".into();
        c.decay_rate = 1.0;
        assert!(c.validate().is_err());
        let mut c = PipelineConfig::default();
        c.decay = "poly".into();
        c.decay_rate = 0.0;
        assert!(c.validate().is_err());
        // the wr method needs no extra keys
        let mut c = PipelineConfig::default();
        c.method = "wr".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_method_and_dist() {
        let mut c = PipelineConfig::default();
        c.method = "3pass".into();
        assert!(c.validate().is_err());
        let mut c = PipelineConfig::default();
        c.dist = "uniformish".into();
        assert!(c.validate().is_err());
        let mut c = PipelineConfig::default();
        c.eps = 0.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_params() {
        let mut c = PipelineConfig::default();
        c.p = 3.0;
        assert!(c.validate().is_err()); // p > 2: classic lower bound regime
        let mut c = PipelineConfig::default();
        c.q = 1.0;
        c.p = 2.0;
        assert!(c.validate().is_err()); // q < p
        let mut c = PipelineConfig::default();
        c.rows = 4;
        assert!(c.validate().is_err()); // even rows
        let mut c = PipelineConfig::default();
        c.backend = "gpu".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn checkpoint_keys_parse_and_validate() {
        let doc = Document::parse(
            "[pipeline]\ncheckpoint_dir = \"/tmp/ck\"\ncheckpoint_every = 8\n",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.checkpoint_dir, "/tmp/ck");
        assert_eq!(cfg.checkpoint_every, 8);
        // zero interval with a directory set is rejected
        let mut c = PipelineConfig::default();
        c.checkpoint_dir = "x".into();
        c.checkpoint_every = 0;
        assert!(c.validate().is_err());
        // interval irrelevant when checkpointing is off
        let mut c = PipelineConfig::default();
        c.checkpoint_every = 0;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn channel_cap_is_deprecated_but_not_an_error() {
        // old config files still carry the retired router knob: parsing
        // must succeed (a stderr note, not an error) and ignore the value
        let doc = Document::parse("[pipeline]\nchannel_cap = 16\nworkers = 2\n").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.workers, 2);
        let doc = Document::parse("[pipeline]\nchannel_cap = 0\n").unwrap();
        assert!(PipelineConfig::from_document(&doc).is_ok(), "even 0 is ignored");
    }

    #[test]
    fn server_section_parses_and_validates() {
        let doc = Document::parse(
            "[server]\naddr = \"0.0.0.0:9999\"\nmax_frame_mib = 8\n\
             idle_timeout_secs = 5\npipeline_window = 16\n",
        )
        .unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.server_addr, "0.0.0.0:9999");
        assert_eq!(cfg.server_max_frame_mib, 8);
        assert_eq!(cfg.server_idle_timeout_secs, 5);
        assert_eq!(cfg.server_pipeline_window, 16);
        // defaults apply when the section is absent
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.server_addr, "127.0.0.1:7070");
        assert_eq!(cfg.server_idle_timeout_secs, 60);
        assert_eq!(cfg.server_pipeline_window, 32);
        // idle_timeout_secs = 0 means "eviction off" and is valid
        let doc = Document::parse("[server]\nidle_timeout_secs = 0\n").unwrap();
        let cfg = PipelineConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.server_idle_timeout_secs, 0);
        let mut c = PipelineConfig::default();
        c.server_addr = String::new();
        assert!(c.validate().is_err());
        let mut c = PipelineConfig::default();
        c.server_max_frame_mib = 0;
        assert!(c.validate().is_err());
        let mut c = PipelineConfig::default();
        c.server_pipeline_window = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn str_array_parses_and_rejects_mixed_types() {
        let doc = Document::parse("[cluster]\nnodes = [\"a=1:1\", \"b=2:2\"]\n").unwrap();
        assert_eq!(doc.str_array("cluster", "nodes").unwrap(), vec!["a=1:1", "b=2:2"]);
        assert!(doc.str_array("cluster", "absent").unwrap().is_empty());
        let doc = Document::parse("[cluster]\nnodes = [1, 2]\n").unwrap();
        assert!(doc.str_array("cluster", "nodes").is_err());
        let doc = Document::parse("[cluster]\nnodes = \"a=1:1\"\n").unwrap();
        assert!(doc.str_array("cluster", "nodes").is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = Document::parse("x == 1\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
        let err = Document::parse("[sec\n").unwrap_err();
        assert!(err.to_string().contains("unterminated section"));
    }
}
