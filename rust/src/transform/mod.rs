//! The bottom-k (p-ppswor / p-priority) transform of unaggregated data —
//! paper §2.2, Eqs. (4)–(6).
//!
//! Each input element `(x, v)` becomes `(x, v · r_x^{-1/p})` where
//! `r_x ~ D` is hash-defined per key. The top-k keys of the transformed
//! frequency vector `ν* = ν · r^{-1/p}` are a bottom-k sample by `ν^p`
//! under `D` — ppswor for `D = Exp[1]`, priority for `D = U[0,1]`.

pub mod decay;

pub use decay::{DecayKind, DecaySpec};

use crate::data::Element;
use crate::util::hashing::{BottomKDist, KeyRandomizer};

/// A p-ppswor / p-priority element transform.
#[derive(Clone, Debug)]
pub struct BottomKTransform {
    randomizer: KeyRandomizer,
    p: f64,
}

impl BottomKTransform {
    /// ppswor transform (`D = Exp[1]`) with power `p`.
    pub fn ppswor(seed: u64, p: f64) -> Self {
        assert!(p > 0.0 && p <= 2.0, "p must be in (0, 2]");
        BottomKTransform { randomizer: KeyRandomizer::ppswor(seed), p }
    }

    /// priority transform (`D = U[0,1]`) with power `p`.
    pub fn priority(seed: u64, p: f64) -> Self {
        assert!(p > 0.0 && p <= 2.0, "p must be in (0, 2]");
        BottomKTransform { randomizer: KeyRandomizer::priority(seed), p }
    }

    /// Power `p`.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// The underlying per-key randomizer.
    pub fn randomizer(&self) -> &KeyRandomizer {
        &self.randomizer
    }

    /// Distribution of `r_x`.
    pub fn dist(&self) -> BottomKDist {
        self.randomizer.dist()
    }

    /// `r_x` for a key.
    #[inline]
    pub fn r(&self, key: u64) -> f64 {
        self.randomizer.r(key)
    }

    /// The per-key multiplier `r_x^{-1/p}`.
    #[inline]
    pub fn scale(&self, key: u64) -> f64 {
        self.randomizer.scale(key, self.p)
    }

    /// Transform one element: `(x, v) -> (x, v · r_x^{-1/p})` (Eq. 5).
    #[inline]
    pub fn apply(&self, e: &Element) -> Element {
        Element::new(e.key, e.val * self.scale(e.key))
    }

    /// Columnar transform (§Perf L3-7): fill `out` with
    /// `vals[i] · r_{keys[i]}^{-1/p}` for a whole SoA block. The key
    /// column is untouched by the transform, so callers reuse the block's
    /// `keys` slice directly and only the value column is rewritten —
    /// each entry is the same float expression as
    /// [`BottomKTransform::apply`], hence bit-identical.
    pub fn apply_cols(&self, keys: &[u64], vals: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(keys.len(), vals.len());
        out.clear();
        out.reserve(keys.len());
        out.extend(keys.iter().zip(vals).map(|(&k, &v)| v * self.scale(k)));
    }

    /// Invert an (estimated) transformed frequency back to the input
    /// frequency domain: `ν̂ = ν̂* · r_x^{1/p}` (Eq. 6). Relative error is
    /// preserved exactly.
    #[inline]
    pub fn invert(&self, key: u64, transformed_freq: f64) -> f64 {
        transformed_freq * self.r(key).powf(1.0 / self.p)
    }

    /// Inclusion probability of a key with input frequency `ν_x` under a
    /// fixed threshold `τ` on transformed frequencies (ppswor:
    /// `Pr[ν_x r^{-1/p} ≥ τ] = 1 − exp(−(ν_x/τ)^p)`; priority:
    /// `min(1, (ν_x/τ)^p)`). Used by the inverse-probability estimators.
    pub fn inclusion_prob(&self, freq: f64, tau: f64) -> f64 {
        assert!(tau > 0.0);
        let ratio = (freq.abs() / tau).powf(self.p);
        match self.dist() {
            BottomKDist::Exp => 1.0 - (-ratio).exp(),
            BottomKDist::Uniform => ratio.min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn apply_matches_definition() {
        let t = BottomKTransform::ppswor(7, 2.0);
        let e = Element::new(42, 3.0);
        let out = t.apply(&e);
        assert_eq!(out.key, 42);
        let want = 3.0 * t.r(42).powf(-0.5);
        assert!((out.val - want).abs() < 1e-12);
    }

    #[test]
    fn apply_cols_bit_identical_to_apply() {
        let t = BottomKTransform::ppswor(5, 1.0);
        let keys: Vec<u64> = (0..100).map(|i| i * 7 + 3).collect();
        let vals: Vec<f64> = (0..100).map(|i| i as f64 - 50.0).collect();
        let mut out = Vec::new();
        t.apply_cols(&keys, &vals, &mut out);
        assert_eq!(out.len(), keys.len());
        for ((&k, &v), &o) in keys.iter().zip(&vals).zip(&out) {
            assert_eq!(o.to_bits(), t.apply(&Element::new(k, v)).val.to_bits());
        }
    }

    #[test]
    fn invert_roundtrips_exactly() {
        for &p in &[0.5, 1.0, 1.5, 2.0] {
            let t = BottomKTransform::ppswor(3, p);
            for key in 0..100u64 {
                let freq = 1.0 + key as f64;
                let transformed = freq * t.scale(key);
                let back = t.invert(key, transformed);
                assert!((back - freq).abs() < 1e-9 * freq, "p={p} key={key}");
            }
        }
    }

    #[test]
    fn transform_linear_over_element_splits() {
        // transforming element-by-element then aggregating = transforming
        // the aggregate (the property that makes pass I composable)
        let t = BottomKTransform::ppswor(11, 1.5);
        let parts = [2.0, -0.5, 1.5, 3.0];
        let total: f64 = parts.iter().sum();
        let sum_transformed: f64 = parts
            .iter()
            .map(|&v| t.apply(&Element::new(5, v)).val)
            .sum();
        let direct = t.apply(&Element::new(5, total)).val;
        assert!((sum_transformed - direct).abs() < 1e-9);
    }

    #[test]
    fn ppswor_inclusion_prob_formula() {
        let t = BottomKTransform::ppswor(1, 1.0);
        let p = t.inclusion_prob(2.0, 4.0);
        assert!((p - (1.0 - (-0.5f64).exp())).abs() < 1e-12);
        // monotone in frequency
        assert!(t.inclusion_prob(3.0, 4.0) > p);
    }

    #[test]
    fn priority_inclusion_prob_truncates_at_one() {
        let t = BottomKTransform::priority(1, 1.0);
        assert!((t.inclusion_prob(2.0, 4.0) - 0.5).abs() < 1e-12);
        assert_eq!(t.inclusion_prob(8.0, 4.0), 1.0);
    }

    #[test]
    fn top1_by_transformed_is_weighted_draw() {
        // with 2 keys of weights (2w, w) and p=1 ppswor, key 0 wins with
        // probability 2/3: check over many independent seeds
        let mut wins = 0;
        let trials = 4000;
        for seed in 0..trials {
            let t = BottomKTransform::ppswor(seed as u64 ^ 0xABCDE, 1.0);
            let s0 = 2.0 * t.scale(0);
            let s1 = 1.0 * t.scale(1);
            if s0 > s1 {
                wins += 1;
            }
        }
        let frac = wins as f64 / trials as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn property_order_invariant_to_monotone_power() {
        // order(w*) for sampling nu^p == order under equivalent transform
        // (paper §2.2 equivalence)
        run("bottom-k order equivalence", 20, |g: &mut Gen| {
            let p = *g.choose(&[0.5, 1.0, 2.0]);
            let seed = g.u64_below(1 << 48);
            let t = BottomKTransform::ppswor(seed, p);
            let n = g.usize_range(2, 50);
            let freqs = g.freq_vector(n, 1.0, false);
            // w^T = w^p / r  vs  w* = w / r^{1/p}: same order
            let mut by_t: Vec<usize> = (0..n).collect();
            let mut by_star: Vec<usize> = (0..n).collect();
            by_t.sort_by(|&a, &b| {
                let ta = freqs[a].powf(p) / t.r(a as u64);
                let tb = freqs[b].powf(p) / t.r(b as u64);
                tb.partial_cmp(&ta).unwrap()
            });
            by_star.sort_by(|&a, &b| {
                let sa = freqs[a] * t.scale(a as u64);
                let sb = freqs[b] * t.scale(b as u64);
                sb.partial_cmp(&sa).unwrap()
            });
            assert_eq!(by_t, by_star);
        });
    }
}
