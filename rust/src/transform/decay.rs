//! Time-decay weighting for served sampling — the transform-layer piece
//! of the scenario subsystem (`crate::scenario`).
//!
//! A decayed sampler weights an element that arrived at tick `t`, when
//! queried at tick `T ≥ t`, by `val · decay(t, T)`. Two families are
//! supported:
//!
//! - **Exponential** (`rate = λ`): `decay(t, T) = exp(-λ·(T - t))` — the
//!   classic backward exponential decay. Memoryless, so the decayed
//!   aggregate of a key can be carried forward lazily.
//! - **Polynomial** (`rate = β`): *forward decay* in the sense of
//!   Cormode–Shkapenyuk–Srivastava–Xu: `decay(t, T) =
//!   ((1 + t) / (1 + T))^β`. Polynomial backward decay
//!   (`(1 + age)^-β`) is not multiplicative in elapsed time and cannot
//!   be maintained in bounded per-key state; the forward form decays
//!   polynomially in the *ratio* of arrival times and factors as
//!   `decay(a, b) · decay(b, c) = decay(a, c)`, which is exactly what
//!   the lazy carry below needs.
//!
//! Both forms satisfy the *carry law*
//! `carry(a, b) · carry(b, c) = carry(a, c)` (up to f64 rounding), so a
//! sampler can store one `(last_tick, accumulated)` pair per key, where
//! `accumulated` is the decayed sum *as of* `last_tick`, and bring it to
//! any later tick with a single multiply — every stored factor is in
//! `[0, 1]`, so nothing ever overflows regardless of stream length.
//!
//! Ticks advance one per element (the same implicit run-chunked clock as
//! [`crate::sampler::windowed`]); `process_at` exposes the explicit
//! surface.

use crate::error::{Error, Result};

/// The decay family (see module docs for the exact weight functions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecayKind {
    /// `exp(-rate · elapsed)` — backward exponential decay.
    Exponential,
    /// `((1 + t) / (1 + T))^rate` — polynomial forward decay.
    Polynomial,
}

impl DecayKind {
    /// Canonical config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            DecayKind::Exponential => "exp",
            DecayKind::Polynomial => "poly",
        }
    }

    /// Stable wire byte (append-only, like codec type tags).
    pub fn to_byte(self) -> u8 {
        match self {
            DecayKind::Exponential => 1,
            DecayKind::Polynomial => 2,
        }
    }

    /// Parse a wire byte written by [`DecayKind::to_byte`].
    pub fn from_byte(b: u8) -> Result<DecayKind> {
        match b {
            1 => Ok(DecayKind::Exponential),
            2 => Ok(DecayKind::Polynomial),
            other => Err(Error::Codec(format!("unknown decay kind byte {other}"))),
        }
    }
}

/// A validated decay specification: family + rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecaySpec {
    kind: DecayKind,
    rate: f64,
}

impl DecaySpec {
    /// Exponential decay with `rate = λ > 0` (per tick).
    pub fn exponential(rate: f64) -> Result<DecaySpec> {
        DecaySpec { kind: DecayKind::Exponential, rate }.validated()
    }

    /// Polynomial forward decay with exponent `rate = β > 0`.
    pub fn polynomial(rate: f64) -> Result<DecaySpec> {
        DecaySpec { kind: DecayKind::Polynomial, rate }.validated()
    }

    /// Parse the CLI / config spelling of a decay family.
    pub fn parse(kind: &str, rate: f64) -> Result<DecaySpec> {
        let kind = match kind {
            "exp" | "exponential" => DecayKind::Exponential,
            "poly" | "polynomial" => DecayKind::Polynomial,
            other => {
                return Err(Error::Config(format!(
                    "unknown decay kind {other:?} (expected exp|poly)"
                )))
            }
        };
        DecaySpec { kind, rate }.validated()
    }

    fn validated(self) -> Result<DecaySpec> {
        if !(self.rate.is_finite() && self.rate > 0.0) {
            return Err(Error::Config(format!(
                "decay rate must be a positive finite number, got {}",
                self.rate
            )));
        }
        Ok(self)
    }

    /// The decay family.
    pub fn kind(&self) -> DecayKind {
        self.kind
    }

    /// The decay rate (λ for exponential, β for polynomial).
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Multiplier that brings a value last updated at tick `from` to tick
    /// `to ≥ from`. Always in `[0, 1]`; exactly `1.0` when `from == to`,
    /// so an untouched value is bit-stable.
    #[inline]
    pub fn carry(&self, from: u64, to: u64) -> f64 {
        debug_assert!(from <= to, "carry runs forward in time");
        if from == to {
            return 1.0;
        }
        match self.kind {
            DecayKind::Exponential => (-self.rate * (to - from) as f64).exp(),
            DecayKind::Polynomial => {
                ((1.0 + from as f64) / (1.0 + to as f64)).powf(self.rate)
            }
        }
    }

    /// Relative weight at query tick `now` of an element that arrived at
    /// tick `t ≤ now` (the module-doc `decay(t, T)`).
    #[inline]
    pub fn weight(&self, t: u64, now: u64) -> f64 {
        self.carry(t, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_both_spellings_and_validates_rate() {
        assert_eq!(
            DecaySpec::parse("exp", 0.5).unwrap().kind(),
            DecayKind::Exponential
        );
        assert_eq!(
            DecaySpec::parse("polynomial", 2.0).unwrap().kind(),
            DecayKind::Polynomial
        );
        assert!(DecaySpec::parse("linear", 1.0).is_err());
        assert!(DecaySpec::parse("exp", 0.0).is_err());
        assert!(DecaySpec::parse("exp", -1.0).is_err());
        assert!(DecaySpec::parse("exp", f64::NAN).is_err());
    }

    #[test]
    fn carry_is_multiplicative_and_bounded() {
        for spec in [
            DecaySpec::exponential(0.01).unwrap(),
            DecaySpec::polynomial(1.5).unwrap(),
        ] {
            assert_eq!(spec.carry(7, 7), 1.0);
            let (a, b, c) = (10u64, 250u64, 4000u64);
            let two_step = spec.carry(a, b) * spec.carry(b, c);
            let one_step = spec.carry(a, c);
            assert!((two_step - one_step).abs() < 1e-12 * one_step.max(1e-300));
            assert!(one_step > 0.0 && one_step < 1.0);
            // monotone: older contributions weigh less
            assert!(spec.carry(0, 100) < spec.carry(50, 100));
        }
    }

    #[test]
    fn exponential_matches_closed_form() {
        let spec = DecaySpec::exponential(0.25).unwrap();
        let want = (-0.25f64 * 8.0).exp();
        assert!((spec.weight(2, 10) - want).abs() < 1e-15);
    }

    #[test]
    fn kind_byte_roundtrips() {
        for k in [DecayKind::Exponential, DecayKind::Polynomial] {
            assert_eq!(DecayKind::from_byte(k.to_byte()).unwrap(), k);
        }
        assert!(DecayKind::from_byte(0).is_err());
        assert!(DecayKind::from_byte(9).is_err());
    }
}
