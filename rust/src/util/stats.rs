//! Statistics helpers used by estimators, benches and experiments:
//! means, quantiles, NRMSE, and norm/tail utilities over frequency vectors.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Returns 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Root-mean-square of a slice of errors.
pub fn rms(errs: &[f64]) -> f64 {
    if errs.is_empty() {
        return 0.0;
    }
    (errs.iter().map(|e| e * e).sum::<f64>() / errs.len() as f64).sqrt()
}

/// Normalized RMSE of estimates vs a single true value (paper Table 3):
/// `sqrt(mean((est - truth)^2)) / truth`.
pub fn nrmse(estimates: &[f64], truth: f64) -> f64 {
    assert!(truth != 0.0, "NRMSE undefined for zero truth");
    let ms = estimates
        .iter()
        .map(|e| {
            let d = e - truth;
            d * d
        })
        .sum::<f64>()
        / estimates.len().max(1) as f64;
    ms.sqrt() / truth.abs()
}

/// Empirical quantile `q ∈ [0,1]` (nearest-rank on a sorted copy).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((q * (v.len() - 1) as f64).round() as usize).min(v.len() - 1);
    v[idx]
}

/// `‖w‖_q^q` — the q-th power of the ℓq norm (sum of |w_i|^q).
pub fn lq_norm_pow(w: &[f64], q: f64) -> f64 {
    w.iter().map(|x| x.abs().powf(q)).sum()
}

/// `‖tail_k(w)‖_q^q`: remove the k largest magnitudes, then `‖·‖_q^q`
/// (paper §2, tail definition).
pub fn tail_norm_pow(w: &[f64], k: usize, q: f64) -> f64 {
    if k >= w.len() {
        return 0.0;
    }
    let mut mags: Vec<f64> = w.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    mags[k..].iter().map(|x| x.powf(q)).sum()
}

/// The k-th largest magnitude `|w_(k)|` (1-indexed: `k=1` is the max).
pub fn kth_magnitude(w: &[f64], k: usize) -> f64 {
    assert!(k >= 1 && k <= w.len());
    let mut mags: Vec<f64> = w.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    mags[k - 1]
}

/// THE deterministic ranking policy for scored keys: descending score,
/// ascending key on ties. Every candidate-truncation and sample sort over
/// `(key, score)` pairs uses this comparator so that output is a pure
/// function of the seed, never of `HashMap`/`FastSet` iteration order
/// ([`crate::sketch::topk::TopK`] and the SpaceSaving eviction heap
/// implement the same `(score, key)` total order internally on their own
/// entry types). Scores must be non-NaN.
#[inline]
pub fn rank_desc(a: &(u64, f64), b: &(u64, f64)) -> std::cmp::Ordering {
    b.1.partial_cmp(&a.1)
        .unwrap()
        .then_with(|| a.0.cmp(&b.0))
}

/// Streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0.0 when n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Merge another accumulator (parallel Welford).
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n as f64;
        self.mean += d * o.n as f64 / n as f64;
        self.n = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn nrmse_zero_for_perfect_estimates() {
        assert_eq!(nrmse(&[5.0, 5.0, 5.0], 5.0), 0.0);
        let e = nrmse(&[6.0, 4.0], 5.0);
        assert!((e - 0.2).abs() < 1e-12);
    }

    #[test]
    fn quantile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn tail_norm_removes_top_k() {
        let w = [10.0, -8.0, 3.0, 2.0, 1.0];
        // tail_2 removes 10 and -8 -> 3^2+2^2+1^2 = 14
        assert!((tail_norm_pow(&w, 2, 2.0) - 14.0).abs() < 1e-12);
        // l1 tail
        assert!((tail_norm_pow(&w, 2, 1.0) - 6.0).abs() < 1e-12);
        assert_eq!(tail_norm_pow(&w, 10, 2.0), 0.0);
    }

    #[test]
    fn kth_magnitude_ordering() {
        let w = [3.0, -7.0, 5.0];
        assert_eq!(kth_magnitude(&w, 1), 7.0);
        assert_eq!(kth_magnitude(&w, 2), 5.0);
        assert_eq!(kth_magnitude(&w, 3), 3.0);
    }

    #[test]
    fn lq_norm_pow_matches_manual() {
        let w = [1.0, -2.0, 2.0];
        assert!((lq_norm_pow(&w, 2.0) - 9.0).abs() < 1e-12);
        assert!((lq_norm_pow(&w, 1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn rank_desc_orders_by_score_then_key() {
        let mut v = vec![(3u64, 1.0), (1, 2.0), (2, 1.0), (0, 0.5)];
        v.sort_by(rank_desc);
        assert_eq!(v, vec![(1, 2.0), (2, 1.0), (3, 1.0), (0, 0.5)]);
    }

    #[test]
    fn welford_matches_batch_and_merges() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);

        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..400] {
            a.push(x);
        }
        for &x in &xs[400..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - w.mean()).abs() < 1e-9);
        assert!((a.variance() - w.variance()).abs() < 1e-9);
    }
}
