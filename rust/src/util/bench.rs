//! In-tree micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §7). Used by every target under `rust/benches/`.
//!
//! Reports wall-clock mean / p50 / p95 per iteration plus optional
//! throughput (items/s), after a warmup phase. Output is plain text so
//! `cargo bench | tee bench_output.txt` archives cleanly.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Number of measured iterations.
    pub iters: u32,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Median time per iteration.
    pub p50: Duration,
    /// 95th-percentile time per iteration.
    pub p95: Duration,
    /// Optional items processed per iteration (for throughput).
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    /// Items per second, when `items_per_iter` is known.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n as f64 / self.mean.as_secs_f64())
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitems/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitems/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitems/s", t / 1e3),
            Some(t) => format!("  {t:8.2} items/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12}{}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            tp
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup and configurable iteration count.
pub struct Bencher {
    warmup_iters: u32,
    iters: u32,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Default: 3 warmup iterations, 10 measured.
    pub fn new() -> Self {
        Bencher { warmup_iters: 3, iters: 10, results: Vec::new() }
    }

    /// Override iteration counts.
    pub fn with_iters(mut self, warmup: u32, iters: u32) -> Self {
        self.warmup_iters = warmup;
        self.iters = iters.max(1);
        self
    }

    /// Time `f`, which should perform one full iteration of the workload
    /// and return a value (returned to prevent dead-code elimination).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_items(name, None, &mut f)
    }

    /// Like [`bench`](Self::bench) but records `items` processed per
    /// iteration so throughput is reported.
    pub fn bench_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_items(name, Some(items), &mut f)
    }

    fn bench_items<T>(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let mean =
            samples.iter().sum::<Duration>() / self.iters;
        let p50 = samples[samples.len() / 2];
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean,
            p50,
            p95,
            items_per_iter: items,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the standard header row.
    pub fn header() {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p95"
        );
        println!("{}", "-".repeat(96));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_reports() {
        let mut b = Bencher::new().with_iters(1, 3);
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 3);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bencher::new().with_iters(0, 2);
        let r = b.bench_throughput("tp", 1_000, || std::thread::sleep(Duration::from_micros(100)));
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1e9, "tp={tp}");
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
