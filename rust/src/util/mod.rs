//! Shared substrates: seeded randomness, stable hashing, statistics,
//! an in-tree property-testing runner, and a micro-benchmark harness.
//!
//! These stand in for `rand`, `proptest` and `criterion`, none of which are
//! available in the offline build image (DESIGN.md §7) — and double as the
//! paper's *hash-defined randomness* substrate: the bottom-k transform
//! requires a reproducible map `key -> r_x` shared by every worker and both
//! passes, which is exactly what [`hashing::KeyRandomizer`] provides.

pub mod bench;
pub mod fastset;
pub mod fmt;
pub mod hashing;
pub mod proptest;
pub mod rng;
pub mod stats;
