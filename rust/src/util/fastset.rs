//! A minimal open-addressing u64 hash set (insert + iterate + clear).
//!
//! §Perf L3-5: the 1-pass sampler's candidate tracking inserts every
//! element's key into a map; `std::collections::HashMap` pays SipHash +
//! branchy probing per insert, which showed up as ~25% of the worp1 hot
//! loop. This set probes with the crate's own `mix64` (1 multiply-xor
//! round), stores keys flat, and grows by doubling. Zero is reserved as
//! the empty marker and stored out-of-band.

use super::rng::mix64;

/// Insert-only u64 set with open addressing.
#[derive(Clone, Debug)]
pub struct FastSet {
    slots: Vec<u64>,
    mask: usize,
    len: usize,
    has_zero: bool,
}

impl FastSet {
    /// Create with capacity for at least `cap` keys before the first grow.
    pub fn with_capacity(cap: usize) -> Self {
        let n = (2 * cap.max(8)).next_power_of_two();
        FastSet { slots: vec![0; n], mask: n - 1, len: 0, has_zero: false }
    }

    /// Number of stored keys.
    pub fn len(&self) -> usize {
        self.len + self.has_zero as usize
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a key; returns true if newly inserted.
    #[inline]
    pub fn insert(&mut self, key: u64) -> bool {
        if key == 0 {
            let new = !self.has_zero;
            self.has_zero = true;
            return new;
        }
        if (self.len + 1) * 4 >= self.slots.len() * 3 {
            self.grow();
        }
        let mut i = (mix64(key) as usize) & self.mask;
        loop {
            let s = self.slots[i];
            if s == key {
                return false;
            }
            if s == 0 {
                self.slots[i] = key;
                self.len += 1;
                return true;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// True if the key is present.
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        if key == 0 {
            return self.has_zero;
        }
        let mut i = (mix64(key) as usize) & self.mask;
        loop {
            let s = self.slots[i];
            if s == key {
                return true;
            }
            if s == 0 {
                return false;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Iterate stored keys (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.has_zero
            .then_some(0u64)
            .into_iter()
            .chain(self.slots.iter().copied().filter(|&s| s != 0))
    }

    /// Remove all keys, keeping capacity.
    pub fn clear(&mut self) {
        self.slots.fill(0);
        self.len = 0;
        self.has_zero = false;
    }

    fn grow(&mut self) {
        let new_len = self.slots.len() * 2;
        let old = std::mem::replace(&mut self.slots, vec![0; new_len]);
        self.mask = self.slots.len() - 1;
        self.len = 0;
        for s in old {
            if s != 0 {
                self.insert(s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn insert_contains_iterate() {
        let mut s = FastSet::with_capacity(4);
        assert!(s.insert(5));
        assert!(!s.insert(5));
        assert!(s.insert(0)); // reserved marker handled
        assert!(s.insert(u64::MAX));
        assert!(s.contains(5) && s.contains(0) && s.contains(u64::MAX));
        assert!(!s.contains(6));
        assert_eq!(s.len(), 3);
        let mut keys: Vec<u64> = s.iter().collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![0, 5, u64::MAX]);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(5));
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut s = FastSet::with_capacity(4);
        for k in 1..=1000u64 {
            s.insert(k);
        }
        assert_eq!(s.len(), 1000);
        for k in 1..=1000u64 {
            assert!(s.contains(k));
        }
    }

    #[test]
    fn property_matches_std_hashset() {
        run("fastset == std::HashSet", 30, |g: &mut Gen| {
            let mut fast = FastSet::with_capacity(8);
            let mut std_set = std::collections::HashSet::new();
            for _ in 0..g.usize_range(1, 500) {
                let k = g.u64_below(200);
                assert_eq!(fast.insert(k), std_set.insert(k));
            }
            assert_eq!(fast.len(), std_set.len());
            let mut a: Vec<u64> = fast.iter().collect();
            let mut b: Vec<u64> = std_set.into_iter().collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        });
    }
}
