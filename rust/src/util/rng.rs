//! Seeded pseudo-randomness: SplitMix64 (seeding / hashing finalizer) and
//! xoshiro256++ (bulk generation), plus the variate transforms the paper
//! needs: `U[0,1]`, `Exp[1]`, and Zipf.
//!
//! Everything here is deterministic given the seed — required both for the
//! composable-sketch contract (all workers must share the transform
//! randomness) and for reproducible experiments.

/// SplitMix64 step: advances `state` and returns a well-mixed 64-bit value.
///
/// This is the standard finalizer from Steele et al.; it is also the mixing
/// core of [`crate::util::hashing`].
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix a single u64 (stateless SplitMix64 finalizer).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256++ generator. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64, per the
    /// xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// The raw 256-bit generator state (for persistence: a restored
    /// summary must continue the *same* random sequence).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a previously captured state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe to take `ln` of.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `Exp[1]` variate via inverse CDF.
    #[inline]
    pub fn exp1(&mut self) -> f64 {
        -self.uniform_open().ln()
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply rejection sampling (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Random f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (used by signed-stream generators).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Geometric number of trials with success prob `q` (support `1..`).
    pub fn geometric(&mut self, q: f64) -> u64 {
        debug_assert!(q > 0.0 && q <= 1.0);
        if q >= 1.0 {
            return 1;
        }
        (self.uniform_open().ln() / (1.0 - q).ln()).ceil().max(1.0) as u64
    }
}

/// Sample from a discrete distribution given cumulative weights
/// (`cum` strictly increasing, last entry = total). Returns an index.
pub fn sample_cumulative(rng: &mut Rng, cum: &[f64]) -> usize {
    let total = *cum.last().expect("non-empty cumulative weights");
    let t = rng.uniform() * total;
    // binary search for first cum[i] > t
    match cum.binary_search_by(|c| c.partial_cmp(&t).unwrap()) {
        Ok(i) => (i + 1).min(cum.len() - 1),
        Err(i) => i.min(cum.len() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_sequence() {
        let mut a = Rng::new(77);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp1_mean_and_positivity() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let e = r.exp1();
            assert!(e > 0.0);
            sum += e;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(11);
        let mut counts = [0u64; 5];
        for _ in 0..100_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 20_000.0).abs() < 1_500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        assert!((s1 / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
    }

    #[test]
    fn geometric_mean() {
        let mut r = Rng::new(13);
        let q = 0.25;
        let n = 100_000;
        let mut sum = 0u64;
        for _ in 0..n {
            sum += r.geometric(q);
        }
        let mean = sum as f64 / n as f64;
        assert!((mean - 1.0 / q).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn cumulative_sampling_respects_weights() {
        let mut r = Rng::new(17);
        let cum = [1.0, 3.0, 6.0]; // weights 1,2,3
        let mut counts = [0u64; 3];
        for _ in 0..60_000 {
            counts[sample_cumulative(&mut r, &cum)] += 1;
        }
        assert!((counts[0] as f64 - 10_000.0).abs() < 1_200.0);
        assert!((counts[1] as f64 - 20_000.0).abs() < 1_500.0);
        assert!((counts[2] as f64 - 30_000.0).abs() < 1_500.0);
    }

    #[test]
    fn mix64_avalanche() {
        // flipping one input bit should flip ~half the output bits
        let x = 0xDEAD_BEEF_u64;
        let h = mix64(x);
        let mut total = 0;
        for b in 0..64 {
            total += (h ^ mix64(x ^ (1 << b))).count_ones();
        }
        let avg = total as f64 / 64.0;
        assert!((avg - 32.0).abs() < 6.0, "avg flipped = {avg}");
    }
}
