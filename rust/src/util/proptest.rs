//! A small in-tree property-testing runner (the `proptest` crate is not
//! available in the offline build image — DESIGN.md §7).
//!
//! Usage:
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the crate's
//! # // -rpath to libxla_extension's bundled libstdc++ (see .cargo/config.toml)
//! use worp::util::proptest::{Gen, run};
//! run("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.f64_range(-1e6, 1e6);
//!     let b = g.f64_range(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets an independent deterministic seed derived from the case
//! index; failures panic with the seed so the case can be replayed with
//! [`run_one`].

use super::rng::Rng;

/// A generator handed to property bodies; wraps a seeded [`Rng`] with
/// convenience constructors for common shapes.
pub struct Gen {
    rng: Rng,
    seed: u64,
}

impl Gen {
    /// Create a generator with an explicit seed.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    /// Seed of this case (printed on failure).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform u64 in `[0, n)`.
    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// A bool with probability `p_true`.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.uniform() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vector of `len` f64 values in `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_range(lo, hi)).collect()
    }

    /// Vector of `len` u64 keys below `key_space`.
    pub fn vec_keys(&mut self, len: usize, key_space: u64) -> Vec<u64> {
        (0..len).map(|_| self.u64_below(key_space)).collect()
    }

    /// A frequency vector with controllable skew: `n` entries
    /// `~ i^{-alpha}` jittered, some possibly negated when `signed`.
    pub fn freq_vector(&mut self, n: usize, alpha: f64, signed: bool) -> Vec<f64> {
        (0..n)
            .map(|i| {
                let base = ((i + 1) as f64).powf(-alpha) * 1000.0;
                let jitter = 0.5 + self.rng.uniform();
                let v = base * jitter;
                if signed && self.bool(0.5) {
                    -v
                } else {
                    v
                }
            })
            .collect()
    }
}

/// Run `cases` independent cases of a property. Panics (with the failing
/// seed) on the first failure.
pub fn run<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut body: F) {
    for i in 0..cases {
        let seed = 0xC0FF_EE00_0000_0000 ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (replay with run_one(seed={seed:#x})): {msg}"
            );
        }
    }
}

/// Replay a single case with a known seed.
pub fn run_one<F: FnOnce(&mut Gen)>(seed: u64, body: F) {
    let mut g = Gen::new(seed);
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("trivial", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            run("always-fails", 10, |_g| {
                panic!("boom");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay with"), "msg: {msg}");
        assert!(msg.contains("boom"), "msg: {msg}");
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        assert_eq!(a.vec_keys(16, 1000), b.vec_keys(16, 1000));
        assert_eq!(a.f64_range(0.0, 1.0), b.f64_range(0.0, 1.0));
    }

    #[test]
    fn freq_vector_shapes() {
        let mut g = Gen::new(7);
        let v = g.freq_vector(100, 1.0, false);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x > 0.0));
        let s = g.freq_vector(100, 1.0, true);
        assert!(s.iter().any(|&x| x < 0.0));
    }
}
