//! Stable, seeded hashing — the randomness substrate of the paper.
//!
//! The bottom-k transform (paper Eq. 5) needs an i.i.d.-looking map
//! `x -> r_x` from keys to `Exp[1]` (ppswor) or `U[0,1]` (priority)
//! variates that is *identical* across stream passes, shards and workers.
//! We realize it as hash-defined randomness: `r_x = G(h(seed, x))` where
//! `h` is a strong 64-bit mixer and `G` the inverse CDF.
//!
//! The same substrate provides
//! - `KeyHash : strings/u64 -> [n]` (paper §4 pass I),
//! - CountSketch / CountMin per-row bucket and sign hashes,
//! - shard routing hashes for the L3 pipeline.
//!
//! All hashes are independent given distinct `seed`/`row` tags because the
//! tag is mixed into the state before the key.

use super::rng::mix64;

/// Fixed unroll width of the lane-structured sketch kernels (§Perf
/// L3-8): [`SketchHasher::fill_coords_slice`] and the
/// CountSketch/CountMin row sweeps process `LANE` keys per straight-line
/// iteration — a shape the autovectorizer reliably turns into SIMD
/// (AVX2: 4×u64 per register, two registers per lane half). Exposed so
/// the lane-edge bit-identity tests (`tests/batch_contract.rs`) can pin
/// their block-length grid to the real boundary.
pub const LANE: usize = 8;

/// Seed-xor tag deriving the second base hash of [`KeyCoords`] — shared
/// by the scalar [`SketchHasher::coords_of`] and the `simd` lane kernel
/// so the two derivations can never drift apart.
const H2_SEED_XOR: u64 = 0x5851_F42D_4C95_7F2D;

/// Branch-free ±1.0 from a row word: the word's low bit moves straight
/// into the f64 sign-bit position over the bit pattern of `+1.0`.
/// Bit-identical to `if m & 1 == 0 { 1.0 } else { -1.0 }` for every
/// input, without the data-dependent branch the unrolled sweeps would
/// otherwise mispredict half the time.
#[inline(always)]
fn sign_of_word(m: u64) -> f64 {
    f64::from_bits(1.0f64.to_bits() | ((m & 1) << 63))
}

/// Strong stateless 64-bit hash of `(seed, key)`.
#[inline]
pub fn hash64(seed: u64, key: u64) -> u64 {
    // Two SplitMix64 finalizer rounds over seed-xor-key with distinct
    // round constants; passes avalanche tests (see unit tests).
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    h = mix64(h ^ key);
    h = mix64(h.wrapping_add(0x6A09_E667_F3BC_C909) ^ key.rotate_left(32));
    h
}

/// Stable 64-bit hash of a byte string (FNV-1a core + SplitMix
/// finalizer). Delegates to [`hash_bytes2`] so the two can never drift
/// apart — persisted envelopes depend on their documented equivalence.
#[inline]
pub fn hash_bytes(seed: u64, bytes: &[u8]) -> u64 {
    hash_bytes2(seed, bytes, &[])
}

/// Stable 64-bit hash of the concatenation `a ++ b` without materializing
/// it — identical to `hash_bytes(seed, [a, b].concat())`. The codec's
/// envelope checksum streams the header and payload through this.
#[inline]
pub fn hash_bytes2(seed: u64, a: &[u8], b: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325_u64 ^ seed;
    for &byte in a.iter().chain(b) {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    mix64(h ^ seed.rotate_left(17))
}

/// Fast 64-bit hash of a byte string, processing aligned 8-byte chunks
/// (§Perf L3-7). **Not** [`hash_bytes`]-compatible: the byte-at-a-time
/// FNV core of `hash_bytes`/`hash_bytes2` is load-bearing for every
/// persisted artifact (envelope checksums, fingerprints, golden
/// fixtures) and cannot change, so this chunked variant exists only for
/// the **non-persisted** paths — shard routing of raw string/byte keys
/// ([`crate::pipeline::shard::Router::route_bytes`]) — where only the
/// output *distribution* matters, never the exact value. The unit tests
/// below hold it to the same stability/avalanche/balance properties as
/// `hash_bytes`.
#[inline]
pub fn hash_bytes_fast(seed: u64, bytes: &[u8]) -> u64 {
    const M: u64 = 0x2545_F491_4F6C_DD1D;
    let mut h = 0xCBF2_9CE4_8422_2325_u64 ^ seed;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let mut w = [0u8; 8];
        w.copy_from_slice(c);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(M).rotate_left(29);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        // zero-padded tail; the length fold below separates "ab" from
        // "ab\0" even though their padded words collide
        let mut w = [0u8; 8];
        w[..rem.len()].copy_from_slice(rem);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(M).rotate_left(29);
    }
    mix64(h ^ (bytes.len() as u64) ^ seed.rotate_left(17))
}

/// Stable hash of a string key to a `u64` key-id. Used to map arbitrary
/// key domains into the numeric domain the randomized sketches need.
#[inline]
pub fn hash_str(seed: u64, s: &str) -> u64 {
    hash_bytes(seed, s.as_bytes())
}

/// Hash to `U[0,1)` with 53-bit resolution.
#[inline]
pub fn hash_unit(seed: u64, key: u64) -> f64 {
    (hash64(seed, key) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Hash to `U(0,1]` — strictly positive, safe for `ln`/division.
#[inline]
pub fn hash_unit_open(seed: u64, key: u64) -> f64 {
    ((hash64(seed, key) >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Hash to `Exp[1]` via inverse CDF.
#[inline]
pub fn hash_exp1(seed: u64, key: u64) -> f64 {
    -hash_unit_open(seed, key).ln()
}

/// The distribution `D` of the bottom-k randomizers `r_x` (paper §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BottomKDist {
    /// `Exp[1]` — ppswor (successive probability-proportional-to-size WOR).
    Exp,
    /// `U[0,1]` — priority (sequential Poisson) sampling.
    Uniform,
}

/// Hash-defined per-key randomness for the p-ppswor / p-priority transform.
///
/// `r(x)` is the paper's `r_x ~ D`; `scale(x, p)` is `r_x^{-1/p}`, the
/// multiplier the transform applies to every element value of key `x`
/// (paper Eq. 4/5). Deterministic across passes, shards and processes.
#[derive(Clone, Debug)]
pub struct KeyRandomizer {
    seed: u64,
    dist: BottomKDist,
}

impl KeyRandomizer {
    /// ppswor randomizer (`D = Exp[1]`).
    pub fn ppswor(seed: u64) -> Self {
        KeyRandomizer { seed, dist: BottomKDist::Exp }
    }

    /// priority randomizer (`D = U[0,1]`).
    pub fn priority(seed: u64) -> Self {
        KeyRandomizer { seed, dist: BottomKDist::Uniform }
    }

    /// The distribution this randomizer draws from.
    pub fn dist(&self) -> BottomKDist {
        self.dist
    }

    /// Seed (identifies the shared randomization; merges require equality).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The paper's `r_x`.
    #[inline]
    pub fn r(&self, key: u64) -> f64 {
        match self.dist {
            BottomKDist::Exp => hash_exp1(self.seed, key),
            BottomKDist::Uniform => hash_unit_open(self.seed, key),
        }
    }

    /// `r_x^{-1/p}` — the element-value multiplier of the transform.
    ///
    /// §Perf L3-4: `powf` fast paths for the common powers (p = 1, 2,
    /// 1/2) — `recip`/`rsqrt`-style forms are 5-10× cheaper than the
    /// general `powf` and these three cover every experiment in the paper.
    #[inline]
    pub fn scale(&self, key: u64, p: f64) -> f64 {
        let r = self.r(key);
        if p == 1.0 {
            r.recip()
        } else if p == 2.0 {
            r.sqrt().recip()
        } else if p == 0.5 {
            let ri = r.recip();
            ri * ri
        } else {
            r.powf(-1.0 / p)
        }
    }
}

/// Per-row bucket/sign hash family for CountSketch / CountMin.
///
/// Row `i` of a sketch with `width` buckets maps key `x` to bucket
/// `bucket(i, x)` with sign `sign(i, x) ∈ {-1, +1}` (CountMin ignores the
/// sign).
///
/// Perf (§Perf L3-2): rows derive from **two** base hashes via
/// Kirsch–Mitzenmacher double hashing plus one finalizer round per row —
/// `m_i = mix(h1 + i·h2)` — instead of two full `hash64` calls per row.
/// This halves-plus the hashing cost of every sketch update while keeping
/// per-row avalanche (validated by the unit tests below); KM double
/// hashing preserves the pairwise-independence-style guarantees sketching
/// needs in practice.
#[derive(Clone, Debug)]
pub struct SketchHasher {
    seed: u64,
    width: usize,
}

/// Per-key derived state: compute once, then O(1) per row.
#[derive(Clone, Copy, Debug, Default)]
pub struct KeyCoords {
    h1: u64,
    h2: u64,
}

impl KeyCoords {
    /// Mixed per-row word.
    #[inline(always)]
    fn row_word(&self, row: usize) -> u64 {
        let mut m = self.h1.wrapping_add((row as u64).wrapping_mul(self.h2));
        // one finalizer round restores avalanche after the linear combine
        m = (m ^ (m >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        m ^ (m >> 31)
    }
}

impl SketchHasher {
    /// Create a hasher for a sketch of `width` buckets per row.
    pub fn new(seed: u64, width: usize) -> Self {
        assert!(width > 0, "sketch width must be positive");
        SketchHasher { seed, width }
    }

    /// Derive the per-key state (two base hashes) once.
    #[inline]
    pub fn coords_of(&self, key: u64) -> KeyCoords {
        KeyCoords {
            h1: hash64(self.seed, key),
            // force h2 odd so rows never collapse
            h2: hash64(self.seed ^ H2_SEED_XOR, key) | 1,
        }
    }

    /// Bucket of `key` in row `row`.
    #[inline]
    pub fn bucket(&self, row: usize, key: u64) -> usize {
        self.bucket_from(&self.coords_of(key), row)
    }

    /// Bucket from precomputed key state.
    #[inline(always)]
    pub fn bucket_from(&self, c: &KeyCoords, row: usize) -> usize {
        let m = c.row_word(row);
        // multiply-shift range reduction (unbiased enough for sketching)
        (((m as u128) * (self.width as u128)) >> 64) as usize
    }

    /// Sign of `key` in row `row` (+1.0 or -1.0).
    #[inline]
    pub fn sign(&self, row: usize, key: u64) -> f64 {
        self.sign_from(&self.coords_of(key), row)
    }

    /// Sign from precomputed key state.
    #[inline(always)]
    pub fn sign_from(&self, c: &KeyCoords, row: usize) -> f64 {
        // use a bit not consumed by the bucket reduction's high bits
        sign_of_word(c.row_word(row))
    }

    /// Bucket *and* sign from precomputed key state with a single mix.
    ///
    /// §Perf L3-6: `bucket_from` + `sign_from` each re-derive the row word
    /// (one finalizer round); fusing them halves the per-(key, row) mixing
    /// in every sketch update. The bucket comes from the multiply-shift
    /// high bits, the sign from bit 0 — exactly the pair the separate
    /// accessors return.
    #[inline(always)]
    pub fn bucket_sign_from(&self, c: &KeyCoords, row: usize) -> (usize, f64) {
        let m = c.row_word(row);
        let b = (((m as u128) * (self.width as u128)) >> 64) as usize;
        (b, sign_of_word(m))
    }

    /// Columnar block hashing (§Perf L3-6): derive the per-key state for a
    /// whole micro-batch of keys in one pass into a caller-owned scratch
    /// buffer (cleared first, so steady-state batches allocate nothing).
    /// Row coordinates are then `O(1)` per (key, row) via
    /// [`SketchHasher::bucket_sign_from`] — no per-row rehash.
    #[inline]
    pub fn fill_coords<I: IntoIterator<Item = u64>>(&self, keys: I, out: &mut Vec<KeyCoords>) {
        out.clear();
        out.extend(keys.into_iter().map(|k| self.coords_of(k)));
    }

    /// [`SketchHasher::fill_coords`] over a dense key column (§Perf
    /// L3-7/L3-8): the SoA block path hands the hasher the `&[u64]` key
    /// slice of an [`crate::data::ElementBlock`].
    ///
    /// The sweep is **lane-unrolled**: `chunks_exact(LANE)` produces
    /// fixed-width straight-line iterations with no data-dependent
    /// branches, so the whole h1/h2 derivation (xor, splitmix rounds,
    /// rotate, or-with-1) autovectorizes. The scalar tail handles the
    /// `len % LANE` remainder. Each `KeyCoords` is exactly
    /// [`SketchHasher::coords_of`] of its key, so the output is
    /// bit-identical to the iterator path for every length.
    #[inline]
    pub fn fill_coords_slice(&self, keys: &[u64], out: &mut Vec<KeyCoords>) {
        out.clear();
        out.reserve(keys.len());
        #[cfg(feature = "simd")]
        {
            simd::fill_coords_lanes(self.seed, keys, out);
        }
        #[cfg(not(feature = "simd"))]
        {
            let mut chunks = keys.chunks_exact(LANE);
            for c in &mut chunks {
                let mut lane = [KeyCoords::default(); LANE];
                for i in 0..LANE {
                    lane[i] = self.coords_of(c[i]);
                }
                out.extend_from_slice(&lane);
            }
            for &k in chunks.remainder() {
                out.push(self.coords_of(k));
            }
        }
    }

    /// Sketch width (buckets per row).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

/// Explicit `std::simd` lane kernel behind the off-by-default `simd`
/// feature (`portable_simd` is nightly-only, hence the gate). The whole
/// `hash64` chain — splitmix finalizer rounds, the add/xor/rotate glue,
/// the or-with-1 of `h2` — runs per 8-wide `u64` vector with the exact
/// wrapping semantics of the scalar ops, so the derived [`KeyCoords`]
/// are **bit-identical** to [`SketchHasher::coords_of`]
/// (`simd_matches_scalar_derivation` below pins it). The default build
/// relies on the autovectorizer over the same lane-unrolled shape.
#[cfg(feature = "simd")]
mod simd {
    use super::{hash64, KeyCoords, H2_SEED_XOR, LANE};
    use std::simd::Simd;

    type V = Simd<u64, LANE>;

    /// Vector splitmix64 finalizer — `super::mix64` per lane.
    #[inline(always)]
    fn mix64v(x: V) -> V {
        // portable-SIMD integer `+`/`*` wrap by definition, matching the
        // scalar wrapping_add / wrapping_mul
        let s = x + V::splat(0x9E37_79B9_7F4A_7C15);
        let z = (s ^ (s >> V::splat(30))) * V::splat(0xBF58_476D_1CE4_E5B9);
        let z = (z ^ (z >> V::splat(27))) * V::splat(0x94D0_49BB_1331_11EB);
        z ^ (z >> V::splat(31))
    }

    /// Vector [`hash64`] over a lane of keys.
    #[inline(always)]
    fn hash64v(seed: u64, key: V) -> V {
        let h = mix64v(V::splat(seed ^ 0x9E37_79B9_7F4A_7C15) ^ key);
        // rotate_left(32) spelled as shifts (no vector rotate in std::simd)
        let rot = (key << V::splat(32)) | (key >> V::splat(32));
        mix64v((h + V::splat(0x6A09_E667_F3BC_C909)) ^ rot)
    }

    /// Fill `out` with the per-key coords of `keys`, SIMD lanes plus a
    /// scalar tail. Caller has already cleared and reserved `out`.
    pub(super) fn fill_coords_lanes(seed: u64, keys: &[u64], out: &mut Vec<KeyCoords>) {
        let seed2 = seed ^ H2_SEED_XOR;
        let mut chunks = keys.chunks_exact(LANE);
        for c in &mut chunks {
            let k = V::from_slice(c);
            let h1 = hash64v(seed, k).to_array();
            let h2 = (hash64v(seed2, k) | V::splat(1)).to_array();
            for i in 0..LANE {
                out.push(KeyCoords { h1: h1[i], h2: h2[i] });
            }
        }
        for &k in chunks.remainder() {
            out.push(KeyCoords { h1: hash64(seed, k), h2: hash64(seed2, k) | 1 });
        }
    }

    #[cfg(test)]
    mod tests {
        use super::super::SketchHasher;

        #[test]
        fn simd_matches_scalar_derivation() {
            let sh = SketchHasher::new(0xDEAD_BEEF, 64);
            let keys: Vec<u64> = (0..100).map(|i| i * 0x9E37_79B9 + 3).collect();
            let mut out = Vec::new();
            sh.fill_coords_slice(&keys, &mut out);
            for (k, c) in keys.iter().zip(&out) {
                let want = sh.coords_of(*k);
                assert_eq!((c.h1, c.h2), (want.h1, want.h2));
            }
        }
    }
}

/// `KeyHash`: map a (possibly huge / string) key domain to `[n]`
/// (paper §4, Eq. 13). Collisions are part of the analysis for n large.
#[derive(Clone, Debug)]
pub struct KeyHash {
    seed: u64,
    n: u64,
}

impl KeyHash {
    /// Hash into `[n]`.
    pub fn new(seed: u64, n: u64) -> Self {
        assert!(n > 0);
        KeyHash { seed, n }
    }

    /// Numeric key -> `[n]`.
    #[inline]
    pub fn of(&self, key: u64) -> u64 {
        (((hash64(self.seed, key) as u128) * (self.n as u128)) >> 64) as u64
    }

    /// String key -> `[n]`.
    #[inline]
    pub fn of_str(&self, key: &str) -> u64 {
        self.of(hash_str(self.seed ^ 0x517C_C1B7_2722_0A95, key))
    }

    /// Domain size `n`.
    pub fn n(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash64_is_stable_and_seed_sensitive() {
        assert_eq!(hash64(1, 42), hash64(1, 42));
        assert_ne!(hash64(1, 42), hash64(2, 42));
        assert_ne!(hash64(1, 42), hash64(1, 43));
    }

    #[test]
    fn hash64_avalanche() {
        let mut worst: f64 = 32.0;
        for b in 0..64 {
            let mut total = 0u32;
            for k in 0..256u64 {
                let x = k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                total += (hash64(7, x) ^ hash64(7, x ^ (1 << b))).count_ones();
            }
            let avg = total as f64 / 256.0;
            if (avg - 32.0).abs() > (worst - 32.0).abs() {
                worst = avg;
            }
        }
        assert!((worst - 32.0).abs() < 6.0, "worst bit avg flips = {worst}");
    }

    #[test]
    fn unit_hash_uniformity() {
        let n = 100_000u64;
        let mut sum = 0.0;
        for k in 0..n {
            let u = hash_unit(3, k);
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn exp_hash_mean_one() {
        let n = 100_000u64;
        let mut sum = 0.0;
        for k in 0..n {
            sum += hash_exp1(5, k);
        }
        assert!((sum / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn randomizer_reproducible_and_dist_specific() {
        let a = KeyRandomizer::ppswor(9);
        let b = KeyRandomizer::ppswor(9);
        let c = KeyRandomizer::priority(9);
        for k in 0..100 {
            assert_eq!(a.r(k), b.r(k));
            // Exp and Uniform draws differ (different codomain anyway)
            assert!(c.r(k) <= 1.0 && c.r(k) > 0.0);
            assert!(a.r(k) > 0.0);
        }
    }

    #[test]
    fn transform_scale_matches_definition() {
        let kr = KeyRandomizer::ppswor(11);
        for k in 0..50 {
            for &p in &[0.5, 1.0, 1.5, 2.0] {
                let want = kr.r(k).powf(-1.0 / p);
                // fast paths (recip/sqrt) differ from powf at ulp scale
                assert!((kr.scale(k, p) - want).abs() < 1e-12 * want.abs());
            }
        }
    }

    #[test]
    fn sketch_hasher_bucket_in_range_and_balanced() {
        let sh = SketchHasher::new(13, 64);
        let mut counts = vec![0u32; 64];
        for k in 0..64_000u64 {
            let b = sh.bucket(0, k);
            assert!(b < 64);
            counts[b] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 200.0, "bucket skew: {c}");
        }
    }

    #[test]
    fn sketch_hasher_signs_balanced_and_row_independent() {
        let sh = SketchHasher::new(17, 8);
        let mut pos = 0i64;
        let mut agree = 0i64;
        let n = 50_000u64;
        for k in 0..n {
            let s0 = sh.sign(0, k);
            let s1 = sh.sign(1, k);
            if s0 > 0.0 {
                pos += 1;
            }
            if s0 == s1 {
                agree += 1;
            }
        }
        assert!((pos as f64 / n as f64 - 0.5).abs() < 0.01);
        assert!((agree as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn fused_bucket_sign_matches_separate_accessors() {
        let sh = SketchHasher::new(23, 777);
        for key in 0..2_000u64 {
            let c = sh.coords_of(key);
            for row in 0..9 {
                let (b, s) = sh.bucket_sign_from(&c, row);
                assert_eq!(b, sh.bucket_from(&c, row));
                assert_eq!(s, sh.sign_from(&c, row));
            }
        }
    }

    #[test]
    fn fill_coords_matches_scalar_derivation_and_reuses_buffer() {
        let sh = SketchHasher::new(29, 64);
        let keys: Vec<u64> = (0..500).map(|i| i * 31 + 7).collect();
        let mut out = Vec::new();
        sh.fill_coords(keys.iter().copied(), &mut out);
        assert_eq!(out.len(), keys.len());
        for (k, c) in keys.iter().zip(&out) {
            let want = sh.coords_of(*k);
            assert_eq!((c.h1, c.h2), (want.h1, want.h2));
        }
        // refills clear first — no stale coords survive
        sh.fill_coords([1u64, 2].into_iter(), &mut out);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn sign_of_word_is_branch_bit_identical() {
        for m in [0u64, 1, 2, 3, u64::MAX, u64::MAX - 1, 0x8000_0000_0000_0001] {
            let branchy = if m & 1 == 0 { 1.0f64 } else { -1.0f64 };
            assert_eq!(sign_of_word(m).to_bits(), branchy.to_bits(), "word {m:#x}");
        }
    }

    #[test]
    fn fill_coords_slice_lane_edges_match_scalar() {
        // every length class around the unroll boundary: empty, single,
        // lane-1, lane, lane+1, a few full lanes plus tail
        let sh = SketchHasher::new(41, 97);
        for len in [0, 1, LANE - 1, LANE, LANE + 1, 3 * LANE + 2] {
            let keys: Vec<u64> = (0..len as u64).map(|i| i * 7919 + 13).collect();
            let mut out = Vec::new();
            sh.fill_coords_slice(&keys, &mut out);
            assert_eq!(out.len(), len);
            for (k, c) in keys.iter().zip(&out) {
                let want = sh.coords_of(*k);
                assert_eq!((c.h1, c.h2), (want.h1, want.h2), "len {len} key {k}");
            }
        }
    }

    #[test]
    fn fill_coords_slice_matches_iterator_path() {
        let sh = SketchHasher::new(31, 128);
        let keys: Vec<u64> = (0..300).map(|i| i * 977 + 5).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        sh.fill_coords(keys.iter().copied(), &mut a);
        sh.fill_coords_slice(&keys, &mut b);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.h1, x.h2), (y.h1, y.h2));
        }
        // refill clears first
        sh.fill_coords_slice(&[1, 2, 3], &mut b);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn hash_bytes_fast_stable_and_input_sensitive() {
        assert_eq!(hash_bytes_fast(1, b"shard key"), hash_bytes_fast(1, b"shard key"));
        assert_ne!(hash_bytes_fast(1, b"shard key"), hash_bytes_fast(2, b"shard key"));
        assert_ne!(hash_bytes_fast(1, b"shard key"), hash_bytes_fast(1, b"shard kez"));
        // zero-padded tails must not collide with explicit zero bytes
        assert_ne!(hash_bytes_fast(1, b"ab"), hash_bytes_fast(1, b"ab\0"));
        assert_ne!(hash_bytes_fast(1, b""), hash_bytes_fast(1, b"\0\0\0\0\0\0\0\0"));
        // exercises the exact-chunk boundary (8 and 16 bytes, no tail)
        assert_ne!(hash_bytes_fast(1, b"12345678"), hash_bytes_fast(1, b"1234567812345678"));
    }

    #[test]
    fn hash_bytes_fast_distribution_matches_slow_hash() {
        // equivalence of *distribution* (not value) with hash_bytes: both
        // hashes bucketed 64 ways over the same key corpus must be
        // near-uniform with the same tolerance — the property shard
        // routing actually needs
        let n = 64_000u64;
        let mut fast = vec![0u32; 64];
        let mut slow = vec![0u32; 64];
        for i in 0..n {
            let key = format!("user:{i}:event");
            let b = key.as_bytes();
            fast[(((hash_bytes_fast(9, b) as u128) * 64) >> 64) as usize] += 1;
            slow[(((hash_bytes(9, b) as u128) * 64) >> 64) as usize] += 1;
        }
        for bucket in 0..64 {
            assert!((fast[bucket] as f64 - 1000.0).abs() < 200.0, "fast skew: {}", fast[bucket]);
            assert!((slow[bucket] as f64 - 1000.0).abs() < 200.0, "slow skew: {}", slow[bucket]);
        }
        // and the two assignments are independent (≈ 1/64 agreement), so
        // the fast hash is not a degenerate transform of the slow one
        let agree = (0..4000u64)
            .filter(|i| {
                let key = format!("k{i}");
                let b = key.as_bytes();
                (hash_bytes_fast(9, b) >> 58) == (hash_bytes(9, b) >> 58)
            })
            .count();
        let frac = agree as f64 / 4000.0;
        assert!(frac < 0.05, "agreement {frac} too high for independent hashes");
    }

    #[test]
    fn hash_bytes_fast_avalanche() {
        // flipping any input bit flips ~half the output bits
        let mut worst: f64 = 32.0;
        let base: Vec<u8> = (0..24u8).collect();
        for byte in 0..24 {
            for bit in 0..8 {
                let mut total = 0u32;
                for s in 0..64u64 {
                    let mut flipped = base.clone();
                    flipped[byte] ^= 1 << bit;
                    total += (hash_bytes_fast(s, &base) ^ hash_bytes_fast(s, &flipped))
                        .count_ones();
                }
                let avg = total as f64 / 64.0;
                if (avg - 32.0).abs() > (worst - 32.0).abs() {
                    worst = avg;
                }
            }
        }
        assert!((worst - 32.0).abs() < 8.0, "worst bit avg flips = {worst}");
    }

    #[test]
    fn keyhash_range_and_string_stability() {
        let kh = KeyHash::new(19, 1_000);
        for k in 0..10_000u64 {
            assert!(kh.of(k) < 1_000);
        }
        assert_eq!(kh.of_str("query: foo"), kh.of_str("query: foo"));
        assert_ne!(kh.of_str("query: foo"), kh.of_str("query: bar"));
    }

    #[test]
    fn hash_bytes_differs_on_length_extension() {
        assert_ne!(hash_bytes(1, b"ab"), hash_bytes(1, b"abc"));
        assert_ne!(hash_bytes(1, b""), hash_bytes(1, b"\0"));
    }

    #[test]
    fn hash_bytes2_equals_concatenation() {
        for (a, b) in [
            (&b""[..], &b""[..]),
            (&b"head"[..], &b""[..]),
            (&b""[..], &b"tail"[..]),
            (&b"head"[..], &b"tail"[..]),
        ] {
            let concat: Vec<u8> = a.iter().chain(b).copied().collect();
            assert_eq!(hash_bytes2(7, a, b), hash_bytes(7, &concat));
        }
    }
}
