//! Plain-text table / CSV formatting for experiment reports.
//!
//! The benches regenerate the paper's tables and figure series; this module
//! renders them uniformly (aligned text table to stdout, CSV to
//! `target/experiments/` for plotting).

use std::io::Write;
use std::path::Path;

/// A simple aligned text table.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let mut line = String::new();
        for i in 0..ncol {
            line.push_str(&format!("{:<w$}  ", self.headers[i], w = widths[i]));
        }
        out.push_str(line.trim_end());
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for r in &self.rows {
            let mut line = String::new();
            for i in 0..ncol {
                line.push_str(&format!("{:<w$}  ", r[i], w = widths[i]));
            }
            out.push_str(line.trim_end());
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write the table as CSV (headers + rows) to `path`, creating parents.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }
}

/// Format a float in compact scientific notation like the paper's tables
/// (e.g. `1.16e-04`).
pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// Format a float with `d` decimals.
pub fn fixed(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("a    bbb"));
        assert!(s.contains("333"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("csv", &["k", "v"]);
        t.row(&["1".into(), "2.5".into()]);
        let p = std::env::temp_dir().join("worp_fmt_test/out.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "k,v\n1,2.5\n");
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(1.16e-4), "1.16e-4");
        assert_eq!(fixed(1.23456, 2), "1.23");
    }
}
