//! CountMin sketch (Cormode–Muthukrishnan) — ℓ1 rHH for **positive**
//! streams (paper §2.3 "(i) ℓ1 sketches ... the randomized CountMin").
//!
//! `est` returns the *minimum* over rows of the key's bucket — an
//! overestimate by at most `(ψ/k)‖tail_k(ν)‖₁` with width `O(k/ψ)` rows
//! `O(log(n/δ))`. Values must be non-negative; `process` asserts this in
//! debug builds (the paper's + column of Table 2).

use super::{RhhSketch, SketchParams};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::util::hashing::{KeyCoords, SketchHasher, LANE};

/// CountMin with min-of-rows estimation.
#[derive(Clone, Debug)]
pub struct CountMin {
    params: SketchParams,
    hasher: SketchHasher,
    table: Vec<f64>,
    processed: u64,
    /// Reusable per-batch key-coordinate buffer (§Perf L3-6).
    scratch: Vec<KeyCoords>,
}

impl CountMin {
    /// Create an empty sketch.
    pub fn new(params: SketchParams) -> Self {
        let hasher = SketchHasher::new(params.seed ^ 0xC0_FFEE, params.width);
        CountMin {
            params,
            hasher,
            table: vec![0.0; params.rows * params.width],
            processed: 0,
            scratch: Vec::new(),
        }
    }

    /// Convenience constructor.
    pub fn with_shape(rows: usize, width: usize, seed: u64) -> Self {
        Self::new(SketchParams::new(rows, width, seed))
    }

    /// Shape/seed parameters.
    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Estimate a whole column of keys into `out` (§Perf L3-7/L3-8),
    /// matching
    /// [`CountSketch::est_many`](crate::sketch::countsketch::CountSketch::est_many)'s
    /// contract: each entry is bit-identical to [`RhhSketch::est`].
    ///
    /// Keys go `LANE` at a time with the table-gather phase batched
    /// row-major — per row, the lane's reads all land in one contiguous
    /// row slice — and the min fold accumulated per key in the same row
    /// order as the scalar fold (`f64::min` is NaN-ignoring and
    /// branch-predictable, so no comparator can panic here either).
    pub fn est_many(&self, keys: &[u64], out: &mut [f64]) {
        assert_eq!(keys.len(), out.len(), "est_many requires out.len() == keys.len()");
        let rows = self.params.rows;
        let w = self.params.width;
        let mut kchunks = keys.chunks_exact(LANE);
        let mut ochunks = out.chunks_exact_mut(LANE);
        for (ks, os) in (&mut kchunks).zip(&mut ochunks) {
            let mut cs = [KeyCoords::default(); LANE];
            for i in 0..LANE {
                cs[i] = self.hasher.coords_of(ks[i]);
            }
            let mut acc = [f64::INFINITY; LANE];
            for r in 0..rows {
                let row = &self.table[r * w..(r + 1) * w];
                for i in 0..LANE {
                    acc[i] = acc[i].min(row[self.hasher.bucket_from(&cs[i], r)]);
                }
            }
            os.copy_from_slice(&acc);
        }
        for (&k, slot) in kchunks.remainder().iter().zip(ochunks.into_remainder()) {
            *slot = RhhSketch::est(self, k);
        }
    }

    /// Columnar SoA update (§Perf L3-7): hash straight off the dense key
    /// column, sweep the dense value column — same per-cell addition
    /// order as the scalar loop and the AoS batch path, so bit-identical
    /// to both.
    pub fn process_cols(&mut self, keys: &[u64], vals: &[f64]) {
        debug_assert_eq!(keys.len(), vals.len());
        debug_assert!(
            vals.iter().all(|&v| v >= 0.0),
            "CountMin requires non-negative values"
        );
        let mut coords = std::mem::take(&mut self.scratch);
        self.hasher.fill_coords_slice(keys, &mut coords);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let row = &mut self.table[r * w..(r + 1) * w];
            // lane-unrolled bucket derivation, element-order scatter
            // (§Perf L3-8) — same shape as CountSketch minus the sign
            let mut cchunks = coords.chunks_exact(LANE);
            let mut vchunks = vals.chunks_exact(LANE);
            for (cs, vs) in (&mut cchunks).zip(&mut vchunks) {
                let mut bs = [0usize; LANE];
                for i in 0..LANE {
                    bs[i] = self.hasher.bucket_from(&cs[i], r);
                }
                for i in 0..LANE {
                    row[bs[i]] += vs[i];
                }
            }
            for (c, &v) in cchunks.remainder().iter().zip(vchunks.remainder()) {
                row[self.hasher.bucket_from(c, r)] += v;
            }
        }
        self.processed += keys.len() as u64;
        self.scratch = coords;
    }

    /// Columnar micro-batch update (§Perf L3-6): one-pass block hashing,
    /// then row-major table sweeps — same pattern as
    /// [`crate::sketch::countsketch::CountSketch::process_batch`], minus
    /// the sign. Bit-identical to the scalar `process` loop.
    pub fn process_batch(&mut self, batch: &[Element]) {
        debug_assert!(
            batch.iter().all(|e| e.val >= 0.0),
            "CountMin requires non-negative values"
        );
        let mut coords = std::mem::take(&mut self.scratch);
        self.hasher.fill_coords(batch.iter().map(|e| e.key), &mut coords);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let row = &mut self.table[r * w..(r + 1) * w];
            let mut cchunks = coords.chunks_exact(LANE);
            let mut echunks = batch.chunks_exact(LANE);
            for (cs, es) in (&mut cchunks).zip(&mut echunks) {
                let mut bs = [0usize; LANE];
                for i in 0..LANE {
                    bs[i] = self.hasher.bucket_from(&cs[i], r);
                }
                for i in 0..LANE {
                    row[bs[i]] += es[i].val;
                }
            }
            for (c, e) in cchunks.remainder().iter().zip(echunks.remainder()) {
                row[self.hasher.bucket_from(c, r)] += e.val;
            }
        }
        self.processed += batch.len() as u64;
        self.scratch = coords;
    }
}

impl RhhSketch for CountMin {
    #[inline]
    fn process(&mut self, e: &Element) {
        debug_assert!(e.val >= 0.0, "CountMin requires non-negative values");
        let c = self.hasher.coords_of(e.key);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let b = self.hasher.bucket_from(&c, r);
            self.table[r * w + b] += e.val;
        }
        self.processed += 1;
    }

    fn merge(&mut self, other: &Self) -> Result<()> {
        if self.params != other.params {
            return Err(Error::Incompatible(format!(
                "CountMin params differ: {:?} vs {:?}",
                self.params, other.params
            )));
        }
        for (a, b) in self.table.iter_mut().zip(other.table.iter()) {
            *a += *b;
        }
        self.processed += other.processed;
        Ok(())
    }

    fn est(&self, key: u64) -> f64 {
        let c = self.hasher.coords_of(key);
        let w = self.params.width;
        (0..self.params.rows)
            .map(|r| self.table[r * w + self.hasher.bucket_from(&c, r)])
            .fold(f64::INFINITY, f64::min)
    }

    fn size_words(&self) -> usize {
        self.table.len()
    }
}

/// Wire payload: the shared hashed-array body (same layout as
/// CountSketch under a distinct type tag; the hasher's `^ 0xC0_FFEE`
/// seed derivation is re-applied by the constructor on decode).
impl crate::api::Persist for CountMin {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(40 + 8 * self.table.len());
        crate::codec::put_rhh_table(&mut p, &self.params, self.processed, &self.table);
        crate::codec::write_envelope(
            crate::codec::tag::COUNTMIN,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> crate::error::Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::COUNTMIN))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let (params, processed, table) = crate::codec::read_rhh_table(&mut r)?;
        r.finish("countmin")?;
        let mut s = CountMin::new(params);
        s.table = table;
        s.processed = processed;
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn overestimates_never_underestimates() {
        let mut cm = CountMin::with_shape(5, 32, 1);
        let freqs: Vec<f64> = (0..200).map(|i| 1.0 + (i % 7) as f64).collect();
        for (i, &f) in freqs.iter().enumerate() {
            cm.process(&Element::new(i as u64, f));
        }
        for (i, &f) in freqs.iter().enumerate() {
            assert!(cm.est(i as u64) >= f - 1e-12, "key {i}");
        }
    }

    #[test]
    fn l1_error_bound() {
        // error ≤ ||v||_1 / width per row, min over rows does better;
        // check the conservative bound
        let n = 1000;
        let width = 256;
        let mut cm = CountMin::with_shape(5, width, 3);
        let freqs: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-1.0) * 100.0).collect();
        let l1: f64 = freqs.iter().sum();
        for (i, &f) in freqs.iter().enumerate() {
            cm.process(&Element::new(i as u64, f));
        }
        for (i, &f) in freqs.iter().enumerate() {
            let err = cm.est(i as u64) - f;
            assert!(err >= -1e-12);
            assert!(err <= 4.0 * l1 / width as f64, "key {i}: err={err}");
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let p = SketchParams::new(3, 64, 9);
        let (mut all, mut a, mut b) = (CountMin::new(p), CountMin::new(p), CountMin::new(p));
        for i in 0..500u64 {
            let e = Element::new(i % 97, 1.0);
            all.process(&e);
            if i % 3 == 0 {
                a.process(&e);
            } else {
                b.process(&e);
            }
        }
        a.merge(&b).unwrap();
        assert_eq!(a.table, all.table);
    }

    #[test]
    fn merge_rejects_mismatch() {
        let mut a = CountMin::with_shape(3, 64, 1);
        let b = CountMin::with_shape(3, 65, 1);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn columnar_batch_is_bit_identical_to_scalar() {
        run("countmin batch == scalar", 20, |g: &mut Gen| {
            let width = g.usize_range(16, 256);
            let seed = g.u64_below(1 << 40);
            let mut scalar = CountMin::with_shape(3, width, seed);
            let mut batched = CountMin::with_shape(3, width, seed);
            let m = g.usize_range(1, 400);
            let elems: Vec<Element> = (0..m)
                .map(|_| Element::new(g.u64_below(1000), g.f64_range(0.0, 10.0)))
                .collect();
            for e in &elems {
                scalar.process(e);
            }
            for c in elems.chunks(g.usize_range(1, m + 5)) {
                batched.process_batch(c);
            }
            assert_eq!(scalar.table, batched.table);
            assert_eq!(scalar.processed(), batched.processed());
        });
    }

    #[test]
    fn soa_block_path_and_est_many_match_scalar() {
        run("countmin cols == scalar", 15, |g: &mut Gen| {
            let width = g.usize_range(16, 256);
            let seed = g.u64_below(1 << 40);
            let mut scalar = CountMin::with_shape(3, width, seed);
            let mut blocked = CountMin::with_shape(3, width, seed);
            let m = g.usize_range(1, 400);
            let elems: Vec<Element> = (0..m)
                .map(|_| Element::new(g.u64_below(1000), g.f64_range(0.0, 10.0)))
                .collect();
            for e in &elems {
                scalar.process(e);
            }
            for c in elems.chunks(g.usize_range(1, m + 5)) {
                let block = crate::data::ElementBlock::from_elements(c);
                blocked.process_cols(&block.keys, &block.vals);
            }
            assert_eq!(scalar.table, blocked.table);
            assert_eq!(scalar.processed(), blocked.processed());
            let keys: Vec<u64> = (0..300).map(|_| g.u64_below(1200)).collect();
            let mut out = vec![0.0f64; keys.len()];
            blocked.est_many(&keys, &mut out);
            for (&k, &e) in keys.iter().zip(&out) {
                assert_eq!(e.to_bits(), scalar.est(k).to_bits());
            }
        });
    }

    #[test]
    fn property_monotone_overestimate() {
        run("countmin overestimates", 25, |g: &mut Gen| {
            let mut cm = CountMin::with_shape(3, g.usize_range(16, 128), g.u64_below(1 << 40));
            let n = g.usize_range(1, 300);
            let mut truth = std::collections::HashMap::new();
            for _ in 0..n {
                let k = g.u64_below(1000);
                let v = g.f64_range(0.0, 10.0);
                cm.process(&Element::new(k, v));
                *truth.entry(k).or_insert(0.0) += v;
            }
            for (&k, &f) in &truth {
                assert!(cm.est(k) >= f - 1e-9);
            }
        });
    }
}
