//! Composable residual-heavy-hitter (rHH) sketches (paper §2.3, Table 1).
//!
//! All sketches implement [`RhhSketch`]: `process` a data element, `merge`
//! a same-shaped sketch, and `est`imate any key's frequency. A `(k, ψ)`
//! rHH sketch guarantees (paper Eq. 8)
//!
//! ```text
//! ‖ν̂ − ν‖_∞^q ≤ (ψ/k) · ‖tail_k(ν)‖_q^q
//! ```
//!
//! with q = 2 for [`countsketch::CountSketch`] (signed streams) and q = 1
//! for [`countmin::CountMin`] / [`spacesaving::SpaceSaving`] (positive
//! streams). [`topk::TopK`] is the composable pass-II structure `T`
//! (paper Lemma 4.2).

pub mod countmin;
pub mod countsketch;
pub mod spacesaving;
pub mod topk;
pub mod window;

use crate::data::Element;
use crate::error::Result;

/// Common interface of composable rHH sketches.
pub trait RhhSketch {
    /// Process one data element (key already in the numeric domain).
    fn process(&mut self, e: &Element);

    /// Merge another sketch built with the *same parameters and seed*.
    fn merge(&mut self, other: &Self) -> Result<()>;

    /// Estimate the frequency of `key`.
    fn est(&self, key: u64) -> f64;

    /// Sketch size in memory words (f64/u64 cells) — reported in the
    /// Table 2 reproduction.
    fn size_words(&self) -> usize;
}

/// A dynamically-chosen rHH sketch: CountSketch (`q=2`, signed) or
/// CountMin (`q=1`, positive) — the two columns of the paper's Table 1
/// that the WORp samplers select between.
#[derive(Clone, Debug)]
pub enum AnyRhh {
    /// ℓ2 / signed.
    CountSketch(countsketch::CountSketch),
    /// ℓ1 / positive.
    CountMin(countmin::CountMin),
}

impl AnyRhh {
    /// Build for a given `q` (2 → CountSketch, 1 → CountMin).
    pub fn for_q(q: f64, params: SketchParams) -> Self {
        if q >= 2.0 {
            AnyRhh::CountSketch(countsketch::CountSketch::new(params))
        } else {
            AnyRhh::CountMin(countmin::CountMin::new(params))
        }
    }

    /// The `q` of this sketch.
    pub fn q(&self) -> f64 {
        match self {
            AnyRhh::CountSketch(_) => 2.0,
            AnyRhh::CountMin(_) => 1.0,
        }
    }

    /// Shape/seed parameters of the wrapped sketch.
    pub fn params(&self) -> &SketchParams {
        match self {
            AnyRhh::CountSketch(s) => s.params(),
            AnyRhh::CountMin(s) => s.params(),
        }
    }

    /// Elements processed.
    pub fn processed(&self) -> u64 {
        match self {
            AnyRhh::CountSketch(s) => s.processed(),
            AnyRhh::CountMin(s) => s.processed(),
        }
    }

    /// Columnar micro-batch update — dispatches to the wrapped sketch's
    /// specialized batch path (§Perf L3-6).
    pub fn process_batch(&mut self, batch: &[Element]) {
        match self {
            AnyRhh::CountSketch(s) => s.process_batch(batch),
            AnyRhh::CountMin(s) => s.process_batch(batch),
        }
    }

    /// Columnar SoA update (§Perf L3-7) — dispatches to the wrapped
    /// sketch's `process_cols`; bit-identical to the scalar loop.
    pub fn process_cols(&mut self, keys: &[u64], vals: &[f64]) {
        match self {
            AnyRhh::CountSketch(s) => s.process_cols(keys, vals),
            AnyRhh::CountMin(s) => s.process_cols(keys, vals),
        }
    }

    /// Column estimation (§Perf L3-7) — one scratch shared across the
    /// whole key slice; each entry bit-identical to [`RhhSketch::est`].
    pub fn est_many(&self, keys: &[u64], out: &mut [f64]) {
        match self {
            AnyRhh::CountSketch(s) => s.est_many(keys, out),
            AnyRhh::CountMin(s) => s.est_many(keys, out),
        }
    }
}

impl RhhSketch for AnyRhh {
    fn process(&mut self, e: &Element) {
        match self {
            AnyRhh::CountSketch(s) => s.process(e),
            AnyRhh::CountMin(s) => s.process(e),
        }
    }

    fn merge(&mut self, other: &Self) -> Result<()> {
        match (self, other) {
            (AnyRhh::CountSketch(a), AnyRhh::CountSketch(b)) => a.merge(b),
            (AnyRhh::CountMin(a), AnyRhh::CountMin(b)) => a.merge(b),
            _ => Err(crate::error::Error::Incompatible(
                "cannot merge CountSketch with CountMin".into(),
            )),
        }
    }

    fn est(&self, key: u64) -> f64 {
        match self {
            AnyRhh::CountSketch(s) => s.est(key),
            AnyRhh::CountMin(s) => s.est(key),
        }
    }

    fn size_words(&self) -> usize {
        match self {
            AnyRhh::CountSketch(s) => s.size_words(),
            AnyRhh::CountMin(s) => s.size_words(),
        }
    }
}

/// Wire payload: `variant u8 (1 = CountSketch, 2 = CountMin)` followed by
/// the wrapped sketch as a nested envelope.
impl crate::api::Persist for AnyRhh {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        match self {
            AnyRhh::CountSketch(s) => {
                crate::codec::wire::put_u8(&mut p, 1);
                crate::codec::put_nested(&mut p, s);
            }
            AnyRhh::CountMin(s) => {
                crate::codec::wire::put_u8(&mut p, 2);
                crate::codec::put_nested(&mut p, s);
            }
        }
        crate::codec::write_envelope(
            crate::codec::tag::ANY_RHH,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> crate::error::Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::ANY_RHH))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let s = match r.u8()? {
            1 => AnyRhh::CountSketch(crate::codec::read_nested(&mut r)?),
            2 => AnyRhh::CountMin(crate::codec::read_nested(&mut r)?),
            v => {
                return Err(crate::error::Error::Codec(format!(
                    "unknown AnyRhh variant byte {v}"
                )))
            }
        };
        r.finish("anyrhh")?;
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

/// Shape/seed parameters shared by the hashed-array sketches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SketchParams {
    /// Number of hash rows (odd, for the CountSketch median).
    pub rows: usize,
    /// Buckets per row.
    pub width: usize,
    /// Hash seed (merges require equality).
    pub seed: u64,
}

impl SketchParams {
    /// Construct with validation.
    pub fn new(rows: usize, width: usize, seed: u64) -> Self {
        assert!(rows > 0 && width > 0, "sketch must have positive shape");
        SketchParams { rows, width, seed }
    }

    /// Width for a `(k, ψ)` rHH guarantee with failure prob δ over domain n:
    /// CountSketch needs `O(k/ψ)` buckets per row and `O(log(n/δ))` rows
    /// (paper Table 1). `c` is the leading constant (2 is comfortable).
    pub fn for_rhh(k: usize, psi: f64, c: f64) -> usize {
        assert!(psi > 0.0);
        ((c * k as f64 / psi).ceil() as usize).max(2 * k + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rhh_width_scales_inverse_psi() {
        let w1 = SketchParams::for_rhh(100, 0.5, 2.0);
        let w2 = SketchParams::for_rhh(100, 0.25, 2.0);
        assert!(w2 >= 2 * w1 - 1);
        assert!(w1 >= 201); // floor of 2k+1
    }

    #[test]
    #[should_panic]
    fn zero_shape_rejected() {
        SketchParams::new(0, 4, 1);
    }
}
