//! Sliding-window CountSketch — the time-decay variant the paper's
//! conclusion calls out ("streaming HH sketches that support time decay
//! (for example, sliding windows [8]) provide a respective time-decay
//! variant of sampling").
//!
//! The window of the last `window` time units is covered by a ring of
//! `buckets` sub-sketches, each spanning `window / buckets` units. A
//! materialized *active table* holds the sum of all live sub-sketches
//! (CountSketch is linear), so estimates cost the same as a plain sketch;
//! expiry subtracts the oldest sub-table. Granularity: expiry happens at
//! bucket boundaries, so the effective window is `window ± window/buckets`
//! — the standard bucketed-window trade-off.

use super::countsketch::CountSketch;
use super::{RhhSketch, SketchParams};
use crate::data::Element;
use crate::error::{Error, Result};
use std::collections::VecDeque;

/// CountSketch over a sliding window of recent elements.
#[derive(Clone, Debug)]
pub struct WindowedCountSketch {
    params: SketchParams,
    /// Window length in time units.
    window: u64,
    /// Time units per sub-sketch bucket.
    span: u64,
    /// Live sub-sketches, oldest first, tagged by bucket start time.
    ring: VecDeque<(u64, CountSketch)>,
    /// Sum of all live sub-sketch tables.
    active: CountSketch,
    /// Latest timestamp seen.
    now: u64,
}

impl WindowedCountSketch {
    /// A window of `window` time units split into `buckets` sub-sketches.
    pub fn new(params: SketchParams, window: u64, buckets: usize) -> Self {
        assert!(window > 0 && buckets > 0 && window >= buckets as u64);
        WindowedCountSketch {
            params,
            window,
            span: window / buckets as u64,
            ring: VecDeque::new(),
            active: CountSketch::new(params),
            now: 0,
        }
    }

    /// Latest timestamp processed.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Shape/seed parameters of the sub-sketches.
    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    /// Window length in time units.
    pub fn window(&self) -> u64 {
        self.window
    }

    /// Time units per sub-sketch bucket.
    pub fn span(&self) -> u64 {
        self.span
    }

    /// Number of live sub-sketches.
    pub fn live_buckets(&self) -> usize {
        self.ring.len()
    }

    /// Process an element stamped with time `t` (non-decreasing).
    pub fn process_at(&mut self, e: &Element, t: u64) {
        debug_assert!(t >= self.now, "timestamps must be non-decreasing");
        self.now = t;
        self.expire(t);
        let bucket_start = t - (t % self.span.max(1));
        let needs_new = match self.ring.back() {
            Some((start, _)) => *start != bucket_start,
            None => true,
        };
        if needs_new {
            self.ring.push_back((bucket_start, CountSketch::new(self.params)));
        }
        self.ring.back_mut().unwrap().1.process(e);
        self.active.process(e);
    }

    /// Shared run-chunking engine of the two implicit-clock batch paths:
    /// split `n` per-element ticks into *runs* that stay inside one ring
    /// bucket and cross no expiry tick, calling
    /// `apply(back_bucket, active, offset, run_len)` for each. Expiry and
    /// bucket structure change only at span boundaries and at
    /// `front.start + span + window` (the next expiry tick), so within a
    /// run the scalar loop performs the same per-cell additions in the
    /// same order — whatever `apply` feeds the two sketches is
    /// bit-identical to element-at-a-time processing. One copy of the
    /// boundary arithmetic keeps the AoS and SoA paths from drifting.
    fn process_runs<F>(&mut self, n: usize, mut apply: F)
    where
        F: FnMut(&mut CountSketch, &mut CountSketch, usize, usize),
    {
        let mut i = 0;
        let span = self.span.max(1);
        while i < n {
            let t = self.now + 1;
            self.expire(t);
            let bucket_start = t - (t % span);
            let needs_new = match self.ring.back() {
                Some((start, _)) => *start != bucket_start,
                None => true,
            };
            if needs_new {
                self.ring.push_back((bucket_start, CountSketch::new(self.params)));
            }
            // last tick of this run: stay inside the bucket and strictly
            // before the next expiry tick (expire(t) above guarantees the
            // remaining front expires only at a future tick)
            let next_expiry = self
                .ring
                .front()
                .map(|(s, _)| s + span + self.window)
                .unwrap_or(u64::MAX);
            let run_last_t = (bucket_start + span - 1).min(next_expiry - 1);
            let run_len = ((run_last_t - t + 1) as usize).min(n - i);
            let back = &mut self.ring.back_mut().unwrap().1;
            apply(back, &mut self.active, i, run_len);
            self.now = t + run_len as u64 - 1;
            i += run_len;
        }
    }

    /// Micro-batch path for the implicit-clock mode (§Perf L3-6): element
    /// `i` of the batch is stamped `now + 1 + i`, exactly like repeated
    /// [`WindowedCountSketch::process_at`] calls with per-element ticks;
    /// each run flows through the columnar [`CountSketch::process_batch`]
    /// of the back bucket and the active table (see
    /// `process_runs` for the bit-identity argument).
    pub fn process_batch_ticks(&mut self, batch: &[Element]) {
        self.process_runs(batch.len(), |back, active, i, len| {
            let chunk = &batch[i..i + len];
            back.process_batch(chunk);
            active.process_batch(chunk);
        });
    }

    /// SoA twin of [`WindowedCountSketch::process_batch_ticks`] (§Perf
    /// L3-7): the same run-chunking, but each run's sub-slices of the
    /// key/value columns flow through the columnar
    /// [`CountSketch::process_cols`] of the back bucket and the active
    /// table — bit-identical to element-at-a-time processing.
    pub fn process_cols_ticks(&mut self, keys: &[u64], vals: &[f64]) {
        debug_assert_eq!(keys.len(), vals.len());
        self.process_runs(keys.len(), |back, active, i, len| {
            back.process_cols(&keys[i..i + len], &vals[i..i + len]);
            active.process_cols(&keys[i..i + len], &vals[i..i + len]);
        });
    }

    /// Drop sub-sketches entirely outside the window ending at `t`.
    fn expire(&mut self, t: u64) {
        let cutoff = t.saturating_sub(self.window);
        while let Some((start, _)) = self.ring.front() {
            if start + self.span <= cutoff {
                let (_, old) = self.ring.pop_front().unwrap();
                // subtract the expired table from the active sum
                for (a, b) in self
                    .active
                    .table_mut()
                    .iter_mut()
                    .zip(old.table().iter())
                {
                    *a -= *b;
                }
            } else {
                break;
            }
        }
    }

    /// Estimate the windowed frequency of `key` (elements within the last
    /// `window` units, at bucket granularity).
    pub fn est(&self, key: u64) -> f64 {
        self.active.est(key)
    }

    /// Memory words across the ring plus the active table.
    pub fn size_words(&self) -> usize {
        (self.ring.len() + 1) * self.active.size_words()
    }

    /// Merge a sibling windowed sketch (same shape, window and bucket
    /// span) whose timestamps come from the same clock: rings union
    /// bucket-by-bucket (CountSketch linearity), the active table is
    /// rebuilt from the merged ring, and expiry advances to the later
    /// `now` of the two.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        if self.params != other.params || self.window != other.window || self.span != other.span
        {
            return Err(Error::Incompatible(format!(
                "windowed sketches differ: {:?}/w{}/s{} vs {:?}/w{}/s{}",
                self.params, self.window, self.span, other.params, other.window, other.span
            )));
        }
        for (start, sk) in &other.ring {
            let mine = self.ring.iter_mut().find(|(s, _)| s == start);
            match mine {
                Some((_, existing)) => existing.merge(sk)?,
                None => {
                    let pos = self
                        .ring
                        .iter()
                        .position(|(s, _)| *s > *start)
                        .unwrap_or(self.ring.len());
                    self.ring.insert(pos, (*start, sk.clone()));
                }
            }
        }
        let mut active = CountSketch::new(self.params);
        for (_, sk) in &self.ring {
            active.merge(sk)?;
        }
        self.active = active;
        self.now = self.now.max(other.now);
        self.expire(self.now);
        Ok(())
    }
}

/// Wire payload: `rows u64, width u64, seed u64, window u64, span u64,
/// now u64`, the active table as a nested CountSketch envelope, then
/// `n_ring u64` and `n × (start u64, nested CountSketch)` oldest-first.
/// The active table is persisted (not recomputed) so the float
/// accumulation order — and hence every future estimate — is
/// bit-identical across a save/load cycle.
impl crate::api::Persist for WindowedCountSketch {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::new();
        crate::codec::wire::put_usize(&mut p, self.params.rows);
        crate::codec::wire::put_usize(&mut p, self.params.width);
        crate::codec::wire::put_u64(&mut p, self.params.seed);
        crate::codec::wire::put_u64(&mut p, self.window);
        crate::codec::wire::put_u64(&mut p, self.span);
        crate::codec::wire::put_u64(&mut p, self.now);
        crate::codec::put_nested(&mut p, &self.active);
        crate::codec::wire::put_usize(&mut p, self.ring.len());
        for (start, sk) in &self.ring {
            crate::codec::wire::put_u64(&mut p, *start);
            crate::codec::put_nested(&mut p, sk);
        }
        crate::codec::write_envelope(
            crate::codec::tag::WINDOW_SKETCH,
            self.persist_fingerprint().value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::WINDOW_SKETCH))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        const SIZE_CAP: u64 = u32::MAX as u64;
        let rows = r.u64()?;
        let width = r.u64()?;
        let seed = r.u64()?;
        if rows == 0 || width == 0 || rows > SIZE_CAP || width > SIZE_CAP {
            return Err(Error::Codec(format!(
                "windowed sketch shape out of range [1, 2^32]: {rows}x{width}"
            )));
        }
        let params = SketchParams { rows: rows as usize, width: width as usize, seed };
        let window = r.u64()?;
        let span = r.u64()?;
        if window == 0 || span == 0 || span > window {
            return Err(Error::Codec(format!(
                "windowed sketch geometry invalid: window={window} span={span}"
            )));
        }
        let now = r.u64()?;
        // expiry arithmetic computes start + span + window; bound the
        // clock so a crafted near-u64::MAX timestamp cannot overflow
        // (debug panic / release wraparound) one call after decode
        if now.checked_add(span).and_then(|x| x.checked_add(window)).is_none() {
            return Err(Error::Codec(format!(
                "windowed sketch clock {now} too close to u64::MAX for window {window}"
            )));
        }
        let active: CountSketch = crate::codec::read_nested(&mut r)?;
        let n = r.seq_len(8)?;
        let mut ring = VecDeque::with_capacity(n);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let start = r.u64()?;
            if prev.is_some_and(|p| p >= start) {
                return Err(Error::Codec(
                    "windowed sketch ring buckets are not in increasing time order".into(),
                ));
            }
            if start > now {
                return Err(Error::Codec(format!(
                    "windowed sketch ring bucket starts at {start}, after the clock {now}"
                )));
            }
            prev = Some(start);
            let sk: CountSketch = crate::codec::read_nested(&mut r)?;
            if *sk.params() != params {
                return Err(Error::Codec(
                    "windowed sketch ring bucket has mismatched sketch parameters".into(),
                ));
            }
            ring.push_back((start, sk));
        }
        r.finish("windowsketch")?;
        if *active.params() != params {
            return Err(Error::Codec(
                "windowed sketch active table has mismatched sketch parameters".into(),
            ));
        }
        let w = WindowedCountSketch { params, window, span, ring, active, now };
        crate::codec::check_fingerprint(env.fingerprint, w.persist_fingerprint().value())?;
        Ok(w)
    }
}

impl WindowedCountSketch {
    /// The persistence fingerprint: everything two windowed sketches must
    /// agree on to be mergeable (shape, seed, window geometry).
    fn persist_fingerprint(&self) -> crate::api::Fingerprint {
        crate::api::Fingerprint::new("windowsketch")
            .with(self.params.rows as u64)
            .with(self.params.width as u64)
            .with(self.params.seed)
            .with(self.window)
            .with(self.span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> SketchParams {
        SketchParams::new(5, 512, 77)
    }

    #[test]
    fn estimates_recent_mass_only() {
        let mut w = WindowedCountSketch::new(params(), 100, 10);
        // key 1 at t=0..9, key 2 at t=200..209: window 100 at t=209 only
        // contains key 2
        for t in 0..10u64 {
            w.process_at(&Element::new(1, 1.0), t);
        }
        for t in 200..210u64 {
            w.process_at(&Element::new(2, 1.0), t);
        }
        assert!(w.est(1).abs() < 1e-9, "expired key: {}", w.est(1));
        assert!((w.est(2) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn window_boundary_granularity() {
        let mut w = WindowedCountSketch::new(params(), 100, 10);
        w.process_at(&Element::new(5, 3.0), 0);
        // at t = 50 the key is still inside the window
        w.process_at(&Element::new(6, 1.0), 50);
        assert!((w.est(5) - 3.0).abs() < 1e-9);
        // at t = 111 the bucket [0, 10) is fully outside [11, 111]
        w.process_at(&Element::new(6, 1.0), 111);
        assert!(w.est(5).abs() < 1e-9);
    }

    #[test]
    fn active_equals_sum_of_live_buckets() {
        let mut w = WindowedCountSketch::new(params(), 50, 5);
        let mut rng = crate::util::rng::Rng::new(3);
        for t in 0..300u64 {
            let e = Element::new(rng.below(40), rng.normal());
            w.process_at(&e, t);
        }
        // reconstruct the active table from the ring
        let mut sum = CountSketch::new(params());
        for (_, s) in &w.ring {
            sum.merge(s).unwrap();
        }
        for (a, b) in w.active.table().iter().zip(sum.table().iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(w.live_buckets() <= 6);
    }

    #[test]
    fn merge_of_time_sharded_streams_matches_whole() {
        let mut whole = WindowedCountSketch::new(params(), 100, 10);
        let mut a = WindowedCountSketch::new(params(), 100, 10);
        let mut b = WindowedCountSketch::new(params(), 100, 10);
        let mut rng = crate::util::rng::Rng::new(7);
        for t in 0..400u64 {
            let e = Element::new(rng.below(30), 1.0);
            whole.process_at(&e, t);
            if e.key % 2 == 0 {
                a.process_at(&e, t);
            } else {
                b.process_at(&e, t);
            }
        }
        a.merge(&b).unwrap();
        assert_eq!(a.now(), whole.now());
        for key in 0..30u64 {
            assert!((a.est(key) - whole.est(key)).abs() < 1e-9, "key {key}");
        }
    }

    #[test]
    fn merge_rejects_mismatched_window() {
        let mut a = WindowedCountSketch::new(params(), 100, 10);
        let b = WindowedCountSketch::new(params(), 200, 10);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn batch_ticks_bit_identical_to_scalar_ticks() {
        // window 40, 4 buckets (span 10): batches straddle bucket
        // boundaries and expiry ticks
        let mut scalar = WindowedCountSketch::new(params(), 40, 4);
        let mut batched = WindowedCountSketch::new(params(), 40, 4);
        let mut rng = crate::util::rng::Rng::new(11);
        let elems: Vec<Element> = (0..500)
            .map(|_| Element::new(rng.below(25), rng.normal()))
            .collect();
        for e in &elems {
            let t = scalar.now() + 1;
            scalar.process_at(e, t);
        }
        for chunk in elems.chunks(33) {
            batched.process_batch_ticks(chunk);
        }
        assert_eq!(scalar.now(), batched.now());
        assert_eq!(scalar.live_buckets(), batched.live_buckets());
        assert_eq!(scalar.active.table(), batched.active.table());
        for ((sa, s), (ba, b)) in scalar.ring.iter().zip(batched.ring.iter()) {
            assert_eq!(sa, ba);
            assert_eq!(s.table(), b.table());
        }
    }

    #[test]
    fn soa_cols_ticks_bit_identical_to_batch_ticks() {
        let mut batched = WindowedCountSketch::new(params(), 40, 4);
        let mut blocked = WindowedCountSketch::new(params(), 40, 4);
        let mut rng = crate::util::rng::Rng::new(23);
        let elems: Vec<Element> = (0..500)
            .map(|_| Element::new(rng.below(25), rng.normal()))
            .collect();
        for chunk in elems.chunks(37) {
            batched.process_batch_ticks(chunk);
            let block = crate::data::ElementBlock::from_elements(chunk);
            blocked.process_cols_ticks(&block.keys, &block.vals);
        }
        assert_eq!(batched.now(), blocked.now());
        assert_eq!(batched.live_buckets(), blocked.live_buckets());
        assert_eq!(batched.active.table(), blocked.active.table());
        for ((sa, s), (ba, b)) in batched.ring.iter().zip(blocked.ring.iter()) {
            assert_eq!(sa, ba);
            assert_eq!(s.table(), b.table());
        }
    }

    #[test]
    fn signed_updates_within_window_cancel() {
        let mut w = WindowedCountSketch::new(params(), 1000, 10);
        w.process_at(&Element::new(9, 5.0), 10);
        w.process_at(&Element::new(9, -5.0), 20);
        assert!(w.est(9).abs() < 1e-9);
    }
}
