//! The composable pass-II structure `T` of 2-pass WORp (paper Algorithm 2
//! and Lemma 4.2): for keys whose pass-I estimates `ν̂*_x` are among the
//! top priorities, collect **exact** frequencies in a second pass.
//!
//! Keys carry a fixed *priority* (the pass-I estimate) and an accumulating
//! *value*. Insertion: existing keys accumulate; new keys enter if the
//! table is below capacity or their priority beats the current minimum
//! (which is evicted). Because a key's priority is constant during pass II
//! and the eviction threshold only grows, any key that is in `T` at the end
//! was inserted at its first element — so its collected value is its exact
//! frequency (Lemma 4.2 part 1).
//!
//! `merge` adds up values per key and retains the top `merge_cap ≥ cap`
//! priorities (Algorithm 2: "Add up values and retain 3k top priority
//! keys").
//!
//! §Perf L3-6 (batch hot path): once the table is full, the overwhelmingly
//! common pass-II event is "unseen key whose priority is below the
//! admission threshold". That used to cost a full `O(cap)` minimum scan
//! per rejection; the minimum is now cached (priorities are fixed, so
//! hits never invalidate it) and rejections are `O(1)`. Evictions — rare,
//! since the threshold only rises — invalidate the cache and the next
//! miss rescans. Ties on priority break on the key, making eviction
//! deterministic (the old `HashMap` scan inherited per-instance random
//! iteration order).

use crate::error::{Error, Result};
use std::collections::HashMap;

/// An entry of the structure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TopKEntry {
    /// Key id.
    pub key: u64,
    /// Fixed priority (pass-I estimate `|ν̂*_x|`).
    pub priority: f64,
    /// Accumulated exact value (pass-II `ν_x`).
    pub value: f64,
}

/// `(priority, key)` ascending order — the deterministic eviction order.
#[inline]
fn pri_key_lt(a_pri: f64, a_key: u64, b_pri: f64, b_key: u64) -> bool {
    a_pri < b_pri || (a_pri == b_pri && a_key < b_key)
}

/// Composable top-k-by-priority structure with exact value collection.
#[derive(Clone, Debug)]
pub struct TopK {
    cap: usize,
    merge_cap: usize,
    entries: HashMap<u64, TopKEntry>,
    /// Cached `(key, priority)` minimum over `entries`, or `None` when it
    /// must be rescanned. Valid whenever set: hits don't change
    /// priorities, inserts below capacity update it incrementally, and
    /// evictions/merges clear it.
    min_cache: Option<(u64, f64)>,
}

impl TopK {
    /// `cap` keys held while streaming; merges may temporarily retain
    /// `merge_cap ≥ cap` (Algorithm 2 uses 2k / 3k).
    pub fn new(cap: usize, merge_cap: usize) -> Self {
        assert!(cap > 0 && merge_cap >= cap);
        TopK {
            cap,
            merge_cap,
            entries: HashMap::with_capacity(cap + 1),
            min_cache: None,
        }
    }

    /// Streaming capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Number of stored keys.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Smallest stored priority (`None` when empty).
    pub fn min_priority(&self) -> Option<f64> {
        self.entries
            .values()
            .map(|e| e.priority)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Accumulate `val` into an already-stored key. Returns `false` when
    /// the key is not stored — the caller then computes the priority and
    /// calls [`TopK::process`]. This is the batch hot path: hits skip the
    /// (expensive, sketch-backed) priority computation entirely.
    #[inline]
    pub fn accumulate(&mut self, key: u64, val: f64) -> bool {
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.value += val;
                true
            }
            None => false,
        }
    }

    /// The current `(key, priority)` minimum, from the cache when valid.
    fn min_entry(&mut self) -> (u64, f64) {
        if let Some(m) = self.min_cache {
            return m;
        }
        let m = self
            .entries
            .values()
            .map(|e| (e.key, e.priority))
            .min_by(|a, b| {
                a.1.partial_cmp(&b.1)
                    .unwrap()
                    .then_with(|| a.0.cmp(&b.0))
            })
            .expect("non-empty");
        self.min_cache = Some(m);
        m
    }

    /// Process one pass-II element. `priority` must be the key's fixed
    /// pass-I estimate `|ν̂*_x|` (recomputed by the caller via the rHH
    /// sketch — the structure does not hold the sketch).
    pub fn process(&mut self, key: u64, val: f64, priority: f64) {
        if self.accumulate(key, val) {
            return;
        }
        if self.entries.len() < self.cap {
            self.entries.insert(key, TopKEntry { key, priority, value: val });
            if let Some((ck, cp)) = self.min_cache {
                if pri_key_lt(priority, key, cp, ck) {
                    self.min_cache = Some((key, priority));
                }
            }
            return;
        }
        let (min_key, min_pri) = self.min_entry();
        // strict >: priority ties never displace an incumbent
        if priority > min_pri {
            self.entries.remove(&min_key);
            self.entries.insert(key, TopKEntry { key, priority, value: val });
            self.min_cache = None;
        }
    }

    /// Columnar SoA pass-II sweep (§Perf L3-7): stream a block's key and
    /// value columns through the accumulate-first hot path. `priority_of`
    /// is only invoked for unseen keys (the rHH-sketch estimate the
    /// caller owns), so repeat elements of stored keys — the common case
    /// on skewed streams — cost one map probe and touch no sketch at all.
    /// Update order equals the scalar element loop, so the final state is
    /// identical.
    pub fn process_cols<P: FnMut(u64) -> f64>(
        &mut self,
        keys: &[u64],
        vals: &[f64],
        mut priority_of: P,
    ) {
        debug_assert_eq!(keys.len(), vals.len());
        for (&k, &v) in keys.iter().zip(vals) {
            if !self.accumulate(k, v) {
                let priority = priority_of(k);
                self.process(k, v, priority);
            }
        }
    }

    /// Merge another structure built with the same capacities over a
    /// disjoint shard (values add; priorities agree because both sides use
    /// the same pass-I sketch). Retains top `merge_cap` priorities.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        if self.cap != other.cap || self.merge_cap != other.merge_cap {
            return Err(Error::Incompatible(format!(
                "TopK capacities differ: ({}, {}) vs ({}, {})",
                self.cap, self.merge_cap, other.cap, other.merge_cap
            )));
        }
        for (k, e) in &other.entries {
            match self.entries.get_mut(k) {
                Some(mine) => {
                    mine.value += e.value;
                    // priorities agree up to float noise; keep the larger
                    mine.priority = mine.priority.max(e.priority);
                }
                None => {
                    self.entries.insert(*k, *e);
                }
            }
        }
        if self.entries.len() > self.merge_cap {
            let mut all: Vec<TopKEntry> = self.entries.values().copied().collect();
            all.sort_by(|a, b| {
                b.priority
                    .partial_cmp(&a.priority)
                    .unwrap()
                    .then_with(|| a.key.cmp(&b.key))
            });
            all.truncate(self.merge_cap);
            self.entries = all.into_iter().map(|e| (e.key, e)).collect();
        }
        self.min_cache = None;
        Ok(())
    }

    /// Entries sorted by decreasing priority (key-tiebroken — deterministic).
    pub fn by_priority(&self) -> Vec<TopKEntry> {
        let mut v: Vec<TopKEntry> = self.entries.values().copied().collect();
        v.sort_by(|a, b| {
            b.priority
                .partial_cmp(&a.priority)
                .unwrap()
                .then_with(|| a.key.cmp(&b.key))
        });
        v
    }

    /// Entries sorted by a caller-supplied score, decreasing (key-tiebroken)
    /// — used by WORp to re-rank by the exact transformed frequency
    /// `ν_x · r_x^{-1/p}`.
    pub fn by_score<F: Fn(&TopKEntry) -> f64>(&self, score: F) -> Vec<(TopKEntry, f64)> {
        let mut v: Vec<(TopKEntry, f64)> = self
            .entries
            .values()
            .map(|e| (*e, score(e)))
            .collect();
        v.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap()
                .then_with(|| a.0.key.cmp(&b.0.key))
        });
        v
    }

    /// Memory words: 3 per slot (key, priority, value).
    pub fn size_words(&self) -> usize {
        3 * self.merge_cap
    }
}

/// Wire payload (canonical — entries sorted by key): `cap u64,
/// merge_cap u64, n u64, n × (key u64, priority f64, value f64)`. The
/// cached minimum is derived state and left cold on decode.
impl crate::api::Persist for TopK {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(24 + 24 * self.entries.len());
        crate::codec::wire::put_usize(&mut p, self.cap);
        crate::codec::wire::put_usize(&mut p, self.merge_cap);
        let mut keys: Vec<u64> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            let e = &self.entries[&k];
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_f64(&mut p, e.priority);
            crate::codec::wire::put_f64(&mut p, e.value);
        }
        crate::codec::write_envelope(
            crate::codec::tag::TOPK,
            self.persist_fingerprint().value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::TOPK))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let cap = r.u64()?;
        let merge_cap = r.u64()?;
        if cap == 0 || merge_cap < cap || merge_cap > u32::MAX as u64 {
            return Err(Error::Codec(format!(
                "TopK capacities out of range: cap={cap} merge_cap={merge_cap}"
            )));
        }
        let (cap, merge_cap) = (cap as usize, merge_cap as usize);
        let n = r.seq_len(24)?;
        if n > merge_cap {
            return Err(Error::Codec(format!(
                "TopK holds {n} entries but merge capacity is {merge_cap}"
            )));
        }
        let mut entries = HashMap::with_capacity(n + 1);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|p| p >= key) {
                return Err(Error::Codec(
                    "TopK entries are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            // non-finite priorities would poison the eviction comparators
            let priority = r.finite_f64("TopK priority")?;
            let value = r.finite_f64("TopK value")?;
            entries.insert(key, TopKEntry { key, priority, value });
        }
        r.finish("topk")?;
        let t = TopK { cap, merge_cap, entries, min_cache: None };
        crate::codec::check_fingerprint(env.fingerprint, t.persist_fingerprint().value())?;
        Ok(t)
    }
}

impl TopK {
    /// The persistence fingerprint (TopK is composable but not an
    /// [`crate::api::Mergeable`] — it keys on its capacities).
    fn persist_fingerprint(&self) -> crate::api::Fingerprint {
        crate::api::Fingerprint::new("topk")
            .with(self.cap as u64)
            .with(self.merge_cap as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn accumulates_exact_values_for_kept_keys() {
        let mut t = TopK::new(3, 4);
        t.process(1, 2.0, 10.0);
        t.process(2, 1.0, 20.0);
        t.process(1, 3.0, 10.0);
        assert_eq!(t.len(), 2);
        let top = t.by_priority();
        assert_eq!(top[0].key, 2);
        assert_eq!(top[1].value, 5.0);
    }

    #[test]
    fn eviction_keeps_higher_priorities() {
        let mut t = TopK::new(2, 2);
        t.process(1, 1.0, 5.0);
        t.process(2, 1.0, 7.0);
        t.process(3, 1.0, 6.0); // evicts key 1 (pri 5)
        let keys: Vec<u64> = t.by_priority().iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![2, 3]);
        t.process(4, 1.0, 1.0); // too low, rejected
        assert_eq!(t.len(), 2);
        assert!(t.by_priority().iter().all(|e| e.key != 4));
    }

    #[test]
    fn first_insertion_collects_full_value_thereafter() {
        // the Lemma 4.2 argument: keys above the final threshold were
        // inserted at their first element
        let mut t = TopK::new(3, 3);
        for round in 0..10 {
            t.process(100, 1.0, 50.0); // heavy, always kept
            t.process(200 + round, 1.0, round as f64); // churn
        }
        let heavy = t.by_priority()[0];
        assert_eq!(heavy.key, 100);
        assert_eq!(heavy.value, 10.0);
    }

    #[test]
    fn merge_adds_values_and_truncates() {
        let mut a = TopK::new(2, 3);
        let mut b = TopK::new(2, 3);
        a.process(1, 5.0, 10.0);
        a.process(2, 1.0, 9.0);
        b.process(1, 2.0, 10.0);
        b.process(3, 1.0, 8.0);
        a.merge(&b).unwrap();
        assert_eq!(a.len(), 3); // merge_cap
        let top = a.by_priority();
        assert_eq!(top[0].key, 1);
        assert_eq!(top[0].value, 7.0);
    }

    #[test]
    fn merge_rejects_capacity_mismatch() {
        let mut a = TopK::new(2, 3);
        let b = TopK::new(3, 3);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn by_score_reranks() {
        let mut t = TopK::new(3, 3);
        t.process(1, 100.0, 1.0);
        t.process(2, 1.0, 3.0);
        let ranked = t.by_score(|e| e.value);
        assert_eq!(ranked[0].0.key, 1);
    }

    #[test]
    fn accumulate_reports_membership() {
        let mut t = TopK::new(2, 2);
        assert!(!t.accumulate(5, 1.0));
        t.process(5, 1.0, 3.0);
        assert!(t.accumulate(5, 2.0));
        assert_eq!(t.by_priority()[0].value, 3.0);
    }

    #[test]
    fn process_cols_equals_scalar_and_skips_priorities_for_hits() {
        let mut scalar = TopK::new(3, 4);
        let mut blocked = TopK::new(3, 4);
        let updates: [(u64, f64); 7] = [
            (1, 2.0),
            (2, 1.0),
            (1, 3.0),
            (3, 1.0),
            (4, 5.0), // eviction candidate
            (1, 1.0),
            (4, 1.0),
        ];
        let pri = |k: u64| (10 * k) as f64;
        for &(k, v) in &updates {
            if !scalar.accumulate(k, v) {
                scalar.process(k, v, pri(k));
            }
        }
        let keys: Vec<u64> = updates.iter().map(|(k, _)| *k).collect();
        let vals: Vec<f64> = updates.iter().map(|(_, v)| *v).collect();
        let mut priority_calls = 0;
        blocked.process_cols(&keys, &vals, |k| {
            priority_calls += 1;
            pri(k)
        });
        assert_eq!(scalar.by_priority(), blocked.by_priority());
        // only misses pay a priority lookup: first sightings of keys
        // 1, 2, 3, 4 plus the re-sighting of key 1 after its eviction —
        // the two accumulate hits ((1, 3.0) and (4, 1.0)) pay nothing
        assert_eq!(priority_calls, 5);
    }

    #[test]
    fn eviction_deterministic_on_priority_ties() {
        // four keys, all priority 1.0, capacity 2: the (priority, key)
        // order must keep the largest keys, identically on every run
        let runs: Vec<Vec<u64>> = (0..2)
            .map(|_| {
                let mut t = TopK::new(2, 2);
                for key in [10u64, 30, 20, 40] {
                    t.process(key, 1.0, 1.0);
                }
                t.by_priority().iter().map(|e| e.key).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1]);
        // strict admission: ties never displace, so the first two stay
        assert_eq!(runs[0], vec![10, 30]);
    }

    #[test]
    fn property_cached_min_matches_rescan() {
        run("topk min cache consistent", 25, |g: &mut Gen| {
            let cap = g.usize_range(2, 8);
            let mut t = TopK::new(cap, cap);
            for _ in 0..g.usize_range(10, 300) {
                let k = g.u64_below(50);
                t.process(k, 1.0, g.f64_range(0.0, 10.0));
                if let Some((_, cp)) = t.min_cache {
                    assert_eq!(Some(cp), t.min_priority());
                }
            }
            assert!(t.len() <= cap);
        });
    }

    #[test]
    fn property_no_key_above_all_minpriorities_is_lost() {
        run("topk keeps dominant keys", 25, |g: &mut Gen| {
            let cap = g.usize_range(2, 10);
            let mut t = TopK::new(cap, cap);
            // one dominant key with max priority processed first, then churn
            t.process(9999, 1.0, 1e9);
            for _ in 0..g.usize_range(10, 500) {
                let k = g.u64_below(100);
                t.process(k, 1.0, g.f64_range(0.0, 100.0));
                t.process(9999, 1.0, 1e9);
            }
            assert_eq!(t.by_priority()[0].key, 9999);
            assert!(t.len() <= cap);
        });
    }
}
