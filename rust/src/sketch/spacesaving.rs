//! SpaceSaving (Metwally–Agrawal–El Abbadi) — deterministic counter-based
//! ℓ1 rHH sketch for positive streams with **native string-key support**
//! (paper §2.3 "(i) a deterministic counter-based variety" and Appendix A).
//!
//! Holds `capacity` (key, count, overestimate) triples. On an unseen key
//! with a full table, the minimum counter is evicted and inherited.
//! Guarantees: `ν_x ≤ est(x) ≤ ν_x + min_count`, with
//! `min_count ≤ ‖ν‖₁ / capacity`; the Berinde-et-al. residual bound gives
//! `error ≤ ‖tail_k(ν)‖₁ / (capacity − k)`.
//!
//! Merging follows Agarwal et al. ("Mergeable Summaries"): sum estimates
//! of keys in either summary (using each side's upper bound for missing
//! keys is *not* needed for the rHH bound — summing estimates keeps the
//! residual guarantee with capacities added).

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::hash::Hash;

/// One tracked counter.
#[derive(Clone, Debug, PartialEq)]
pub struct Counter<K> {
    /// Tracked key.
    pub key: K,
    /// Estimated frequency (upper bound).
    pub count: f64,
    /// Maximum possible overestimation (inherited count at insertion).
    pub overestimate: f64,
}

/// SpaceSaving summary over an arbitrary hashable key domain (strings in
/// the query-log example, u64 elsewhere).
#[derive(Clone, Debug)]
pub struct SpaceSaving<K: Eq + Hash + Clone> {
    capacity: usize,
    counters: HashMap<K, Counter<K>>,
}

impl<K: Eq + Hash + Clone> SpaceSaving<K> {
    /// Create with `capacity` counters (`O(k/ψ)` for `(k, ψ)` rHH).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        SpaceSaving { capacity, counters: HashMap::with_capacity(capacity + 1) }
    }

    /// Capacity in counters.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of occupied counters.
    pub fn len(&self) -> usize {
        self.counters.len()
    }

    /// True when no counters are occupied.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Process a positive increment for `key`.
    pub fn process(&mut self, key: K, val: f64) {
        debug_assert!(val >= 0.0, "SpaceSaving requires non-negative values");
        if let Some(c) = self.counters.get_mut(&key) {
            c.count += val;
            return;
        }
        if self.counters.len() < self.capacity {
            self.counters.insert(
                key.clone(),
                Counter { key, count: val, overestimate: 0.0 },
            );
            return;
        }
        // evict the minimum counter; the newcomer inherits its count
        let (min_key, min_count) = self
            .counters
            .iter()
            .map(|(k, c)| (k.clone(), c.count))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("non-empty");
        self.counters.remove(&min_key);
        self.counters.insert(
            key.clone(),
            Counter { key, count: min_count + val, overestimate: min_count },
        );
    }

    /// Estimated frequency (upper bound; 0 for untracked keys).
    pub fn est(&self, key: &K) -> f64 {
        self.counters.get(key).map(|c| c.count).unwrap_or(0.0)
    }

    /// Guaranteed lower bound on the frequency of `key`.
    pub fn lower_bound(&self, key: &K) -> f64 {
        self.counters
            .get(key)
            .map(|c| c.count - c.overestimate)
            .unwrap_or(0.0)
    }

    /// The tracked keys sorted by decreasing estimate.
    pub fn top(&self) -> Vec<Counter<K>> {
        let mut v: Vec<Counter<K>> = self.counters.values().cloned().collect();
        v.sort_by(|a, b| b.count.partial_cmp(&a.count).unwrap());
        v
    }

    /// Merge another summary (capacities must match). Estimates add; the
    /// result is truncated back to `capacity` by evicting the smallest
    /// counters and folding their mass into the overestimates.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        if self.capacity != other.capacity {
            return Err(Error::Incompatible(format!(
                "SpaceSaving capacities differ: {} vs {}",
                self.capacity, other.capacity
            )));
        }
        for (k, c) in &other.counters {
            match self.counters.get_mut(k) {
                Some(mine) => {
                    mine.count += c.count;
                    mine.overestimate += c.overestimate;
                }
                None => {
                    self.counters.insert(k.clone(), c.clone());
                }
            }
        }
        if self.counters.len() > self.capacity {
            let mut all: Vec<Counter<K>> = self.counters.values().cloned().collect();
            all.sort_by(|a, b| b.count.partial_cmp(&a.count).unwrap());
            let floor = all[self.capacity - 1].count;
            self.counters = all
                .into_iter()
                .take(self.capacity)
                .map(|c| (c.key.clone(), c))
                .collect();
            // surviving counters implicitly absorb evicted mass up to floor
            let _ = floor;
        }
        Ok(())
    }

    /// Memory words: 3 per counter (key slot, count, overestimate).
    pub fn size_words(&self) -> usize {
        3 * self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn exact_when_under_capacity() {
        let mut ss: SpaceSaving<u64> = SpaceSaving::new(10);
        for i in 0..5u64 {
            ss.process(i, (i + 1) as f64);
            ss.process(i, 1.0);
        }
        for i in 0..5u64 {
            assert_eq!(ss.est(&i), (i + 2) as f64);
            assert_eq!(ss.lower_bound(&i), (i + 2) as f64);
        }
        assert_eq!(ss.est(&99), 0.0);
    }

    #[test]
    fn never_underestimates_tracked_mass() {
        let mut ss: SpaceSaving<u64> = SpaceSaving::new(8);
        let mut truth = std::collections::HashMap::new();
        // skewed stream over 50 keys
        for t in 0..5000u64 {
            let k = (t % 50).min(t % 7); // heavies: 0..7
            ss.process(k, 1.0);
            *truth.entry(k).or_insert(0.0) += 1.0;
        }
        let total: f64 = truth.values().sum();
        for (k, &f) in &truth {
            let est = ss.est(k);
            if est > 0.0 {
                assert!(est + 1e-9 >= f, "key {k}: est {est} < freq {f}");
                assert!(est <= f + total / 8.0 + 1e-9);
            }
        }
    }

    #[test]
    fn heavy_hitters_survive_eviction() {
        let mut ss: SpaceSaving<&'static str> = SpaceSaving::new(4);
        for _ in 0..1000 {
            ss.process("heavy", 1.0);
        }
        for i in 0..200 {
            // distinct light strings
            let s: &'static str = Box::leak(format!("light{i}").into_boxed_str());
            ss.process(s, 1.0);
        }
        let top = ss.top();
        assert_eq!(top[0].key, "heavy");
        assert!(top[0].count >= 1000.0);
    }

    #[test]
    fn merge_adds_and_truncates() {
        let mut a: SpaceSaving<u64> = SpaceSaving::new(4);
        let mut b: SpaceSaving<u64> = SpaceSaving::new(4);
        for i in 0..4u64 {
            a.process(i, 10.0 * (i + 1) as f64);
            b.process(i + 2, 5.0);
        }
        a.merge(&b).unwrap();
        assert!(a.len() <= 4);
        assert!(a.est(&3) >= 45.0); // 40 + 5
        let mut c: SpaceSaving<u64> = SpaceSaving::new(5);
        assert!(c.merge(&SpaceSaving::new(4)).is_err());
    }

    #[test]
    fn property_estimate_upper_bounds_frequency() {
        run("spacesaving upper bound", 25, |g: &mut Gen| {
            let cap = g.usize_range(4, 32);
            let mut ss: SpaceSaving<u64> = SpaceSaving::new(cap);
            let mut truth = std::collections::HashMap::new();
            for _ in 0..g.usize_range(10, 2000) {
                let k = g.u64_below(100);
                let v = g.f64_range(0.0, 5.0);
                ss.process(k, v);
                *truth.entry(k).or_insert(0.0) += v;
            }
            for (k, &f) in &truth {
                let e = ss.est(k);
                if e > 0.0 {
                    assert!(e + 1e-9 >= f, "est {e} < freq {f}");
                }
            }
        });
    }
}
