//! SpaceSaving (Metwally–Agrawal–El Abbadi) — deterministic counter-based
//! ℓ1 rHH sketch for positive streams with **native string-key support**
//! (paper §2.3 "(i) a deterministic counter-based variety" and Appendix A).
//!
//! Holds `capacity` (key, count, overestimate) triples. On an unseen key
//! with a full table, the minimum counter is evicted and inherited.
//! Guarantees: `ν_x ≤ est(x) ≤ ν_x + min_count`, with
//! `min_count ≤ ‖ν‖₁ / capacity`; the Berinde-et-al. residual bound gives
//! `error ≤ ‖tail_k(ν)‖₁ / (capacity − k)`.
//!
//! Merging follows Agarwal et al. ("Mergeable Summaries"): sum estimates
//! of keys in either summary (using each side's upper bound for missing
//! keys is *not* needed for the rHH bound — summing estimates keeps the
//! residual guarantee with capacities added).
//!
//! §Perf L3-6 (batch hot path): eviction used to scan all `capacity`
//! counters per unseen key — `O(cap)` on exactly the miss-heavy streams
//! that stress the structure. The minimum is now tracked by a
//! **lazy-deletion min-heap** over `(count, key)`: hits never touch the
//! heap (their heap entry just goes stale); evictions pop entries,
//! refreshing stale ones in place, until the true minimum surfaces —
//! `O(log cap)` amortized. Ties break on the key, so eviction order is
//! fully deterministic (the old `HashMap` scan inherited the map's
//! per-instance random iteration order on count ties).

use crate::error::{Error, Result};
use std::collections::{BinaryHeap, HashMap};
use std::hash::Hash;

/// One tracked counter.
#[derive(Clone, Debug, PartialEq)]
pub struct Counter<K> {
    /// Tracked key.
    pub key: K,
    /// Estimated frequency (upper bound).
    pub count: f64,
    /// Maximum possible overestimation (inherited count at insertion).
    pub overestimate: f64,
}

/// Min-heap entry ordered by `(count, key)` ascending. `Ord` is reversed
/// so `BinaryHeap` (a max-heap) pops the smallest pair first. Counts are
/// finite and non-negative, so the `partial_cmp` unwrap is safe.
#[derive(Clone, Debug)]
struct HeapEntry<K> {
    count: f64,
    key: K,
}

impl<K: Eq> PartialEq for HeapEntry<K> {
    fn eq(&self, other: &Self) -> bool {
        self.count == other.count && self.key == other.key
    }
}

impl<K: Eq> Eq for HeapEntry<K> {}

impl<K: Ord> Ord for HeapEntry<K> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .count
            .partial_cmp(&self.count)
            .unwrap()
            .then_with(|| other.key.cmp(&self.key))
    }
}

impl<K: Ord> PartialOrd for HeapEntry<K> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// SpaceSaving summary over an arbitrary hashable, orderable key domain
/// (strings in the query-log example, u64 elsewhere). `Ord` is required
/// for the deterministic `(count, key)` eviction order.
#[derive(Clone, Debug)]
pub struct SpaceSaving<K: Eq + Hash + Clone + Ord> {
    capacity: usize,
    counters: HashMap<K, Counter<K>>,
    /// Lazy-deletion min-heap over (count, key); entries go stale when a
    /// counter is hit and are refreshed when popped.
    heap: BinaryHeap<HeapEntry<K>>,
    /// Elements processed (diagnostics; the unified summary API reports it).
    processed: u64,
}

impl<K: Eq + Hash + Clone + Ord> SpaceSaving<K> {
    /// Create with `capacity` counters (`O(k/ψ)` for `(k, ψ)` rHH).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        SpaceSaving {
            capacity,
            counters: HashMap::with_capacity(capacity + 1),
            heap: BinaryHeap::with_capacity(capacity + 1),
            processed: 0,
        }
    }

    /// Capacity in counters.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of occupied counters.
    pub fn len(&self) -> usize {
        self.counters.len()
    }

    /// True when no counters are occupied.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Elements processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Process a positive increment for `key`.
    pub fn process(&mut self, key: K, val: f64) {
        self.processed += 1;
        self.update(key, val);
    }

    /// Process a micro-batch of positive increments (§Perf L3-6): the
    /// per-element bookkeeping is hoisted; hits cost one map probe, and
    /// the eviction heap amortizes miss-heavy runs.
    pub fn process_batch(&mut self, batch: &[(K, f64)]) {
        for (key, val) in batch {
            self.update(key.clone(), *val);
        }
        self.processed += batch.len() as u64;
    }

    /// One update, without touching the processed counter.
    #[inline]
    fn update(&mut self, key: K, val: f64) {
        debug_assert!(val >= 0.0, "SpaceSaving requires non-negative values");
        if let Some(c) = self.counters.get_mut(&key) {
            // the key's heap entry goes stale; pop-time refresh fixes it
            c.count += val;
            return;
        }
        if self.counters.len() < self.capacity {
            self.heap.push(HeapEntry { count: val, key: key.clone() });
            self.counters.insert(
                key.clone(),
                Counter { key, count: val, overestimate: 0.0 },
            );
            return;
        }
        // evict the (count, key)-minimum counter; the newcomer inherits it
        let (min_key, min_count) = self.pop_min();
        self.counters.remove(&min_key);
        self.heap.push(HeapEntry { count: min_count + val, key: key.clone() });
        self.counters.insert(
            key.clone(),
            Counter { key, count: min_count + val, overestimate: min_count },
        );
        // Bound the lazy-deletion heap: the intended invariant is one
        // entry per live counter, but that rests on every code path
        // popping exactly what it pushes — compact back to the live set
        // if drift ever accumulates, so adversarial churn can never grow
        // the heap past 2×capacity. Rebuilding from the counters does not
        // change eviction order (pop_min converges to the same (count,
        // key) minimum with or without stale entries).
        if self.heap.len() > 2 * self.capacity {
            self.rebuild_heap();
        }
    }

    /// Pop the true minimum `(count, key)` over live counters, refreshing
    /// stale heap entries in place. The heap always holds exactly one
    /// entry per live key (possibly stale), so this terminates after at
    /// most one refresh per key.
    fn pop_min(&mut self) -> (K, f64) {
        loop {
            let e = self.heap.pop().expect("heap tracks every live counter");
            match self.counters.get(&e.key) {
                Some(c) if c.count == e.count => return (e.key, e.count),
                Some(c) => {
                    // stale: the counter grew since this entry was pushed
                    let count = c.count;
                    self.heap.push(HeapEntry { count, key: e.key });
                }
                None => {} // key merged away / rebuilt; drop the orphan
            }
        }
    }

    /// Rebuild the eviction heap from the live counters (after a merge).
    fn rebuild_heap(&mut self) {
        self.heap.clear();
        self.heap.extend(
            self.counters
                .values()
                .map(|c| HeapEntry { count: c.count, key: c.key.clone() }),
        );
    }

    /// Live size of the lazy-deletion eviction heap (diagnostics; the
    /// compaction in `update` keeps this ≤ 2 × capacity — asserted by the
    /// churn unit test below).
    pub fn eviction_heap_len(&self) -> usize {
        self.heap.len()
    }

    /// Estimated frequency (upper bound; 0 for untracked keys).
    pub fn est(&self, key: &K) -> f64 {
        self.counters.get(key).map(|c| c.count).unwrap_or(0.0)
    }

    /// Guaranteed lower bound on the frequency of `key`.
    pub fn lower_bound(&self, key: &K) -> f64 {
        self.counters
            .get(key)
            .map(|c| c.count - c.overestimate)
            .unwrap_or(0.0)
    }

    /// The tracked keys sorted by decreasing estimate (key-tiebroken, so
    /// the order is deterministic).
    pub fn top(&self) -> Vec<Counter<K>> {
        let mut v: Vec<Counter<K>> = self.counters.values().cloned().collect();
        v.sort_by(|a, b| {
            b.count
                .partial_cmp(&a.count)
                .unwrap()
                .then_with(|| a.key.cmp(&b.key))
        });
        v
    }

    /// Merge another summary (capacities must match). Estimates add; the
    /// result is truncated back to `capacity` by evicting the smallest
    /// counters and folding their mass into the overestimates.
    pub fn merge(&mut self, other: &Self) -> Result<()> {
        if self.capacity != other.capacity {
            return Err(Error::Incompatible(format!(
                "SpaceSaving capacities differ: {} vs {}",
                self.capacity, other.capacity
            )));
        }
        for (k, c) in &other.counters {
            match self.counters.get_mut(k) {
                Some(mine) => {
                    mine.count += c.count;
                    mine.overestimate += c.overestimate;
                }
                None => {
                    self.counters.insert(k.clone(), c.clone());
                }
            }
        }
        if self.counters.len() > self.capacity {
            let mut all: Vec<Counter<K>> = self.counters.values().cloned().collect();
            all.sort_by(|a, b| {
                b.count
                    .partial_cmp(&a.count)
                    .unwrap()
                    .then_with(|| a.key.cmp(&b.key))
            });
            self.counters = all
                .into_iter()
                .take(self.capacity)
                .map(|c| (c.key.clone(), c))
                .collect();
        }
        self.rebuild_heap();
        self.processed += other.processed;
        Ok(())
    }

    /// Memory words: 3 per counter (key slot, count, overestimate) plus
    /// 2 per eviction-heap slot (count, key).
    pub fn size_words(&self) -> usize {
        5 * self.capacity
    }
}

impl SpaceSaving<u64> {
    /// Micro-batch entry point over stream elements (§Perf L3-6): the
    /// per-element processed bookkeeping is hoisted to once per batch and
    /// misses amortize through the eviction heap. This is what the
    /// unified-summary batch path calls.
    pub fn process_elements(&mut self, batch: &[crate::data::Element]) {
        for e in batch {
            self.update(e.key, e.val);
        }
        self.processed += batch.len() as u64;
    }

    /// Columnar SoA entry point (§Perf L3-7): updates stream off the two
    /// dense columns with no per-element struct loads; identical update
    /// order to the scalar loop, so the summary state is the same.
    pub fn process_cols(&mut self, keys: &[u64], vals: &[f64]) {
        debug_assert_eq!(keys.len(), vals.len());
        for (&k, &v) in keys.iter().zip(vals) {
            self.update(k, v);
        }
        self.processed += keys.len() as u64;
    }
}

/// Wire payload (canonical — counters sorted by key): `capacity u64,
/// processed u64, n u64, n × (key u64, count f64, overestimate f64)`.
/// The eviction heap is derived state and rebuilt on decode.
impl crate::api::Persist for SpaceSaving<u64> {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(24 + 24 * self.counters.len());
        crate::codec::wire::put_usize(&mut p, self.capacity);
        crate::codec::wire::put_u64(&mut p, self.processed);
        let mut keys: Vec<u64> = self.counters.keys().copied().collect();
        keys.sort_unstable();
        crate::codec::wire::put_usize(&mut p, keys.len());
        for k in keys {
            let c = &self.counters[&k];
            crate::codec::wire::put_u64(&mut p, k);
            crate::codec::wire::put_f64(&mut p, c.count);
            crate::codec::wire::put_f64(&mut p, c.overestimate);
        }
        crate::codec::write_envelope(
            crate::codec::tag::SPACESAVING,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::SPACESAVING))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let capacity = r.u64()?;
        if capacity == 0 || capacity > u32::MAX as u64 {
            return Err(Error::Codec(format!(
                "SpaceSaving capacity out of range [1, 2^32]: {capacity}"
            )));
        }
        let capacity = capacity as usize;
        let processed = r.u64()?;
        let n = r.seq_len(24)?;
        if n > capacity {
            return Err(Error::Codec(format!(
                "SpaceSaving holds {n} counters but capacity is {capacity}"
            )));
        }
        let mut counters = HashMap::with_capacity(n + 1);
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let key = r.u64()?;
            if prev.is_some_and(|p| p >= key) {
                return Err(Error::Codec(
                    "SpaceSaving counters are not sorted by strictly increasing key".into(),
                ));
            }
            prev = Some(key);
            // non-finite counts would poison the heap/sort comparators
            // (which unwrap partial_cmp), so reject them at the boundary
            let count = r.finite_f64("SpaceSaving count")?;
            let overestimate = r.finite_f64("SpaceSaving overestimate")?;
            counters.insert(key, Counter { key, count, overestimate });
        }
        r.finish("spacesaving")?;
        let mut s = SpaceSaving {
            capacity,
            counters,
            heap: BinaryHeap::with_capacity(n + 1),
            processed,
        };
        s.rebuild_heap();
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Gen};

    #[test]
    fn exact_when_under_capacity() {
        let mut ss: SpaceSaving<u64> = SpaceSaving::new(10);
        for i in 0..5u64 {
            ss.process(i, (i + 1) as f64);
            ss.process(i, 1.0);
        }
        for i in 0..5u64 {
            assert_eq!(ss.est(&i), (i + 2) as f64);
            assert_eq!(ss.lower_bound(&i), (i + 2) as f64);
        }
        assert_eq!(ss.est(&99), 0.0);
        assert_eq!(ss.processed(), 10);
    }

    #[test]
    fn never_underestimates_tracked_mass() {
        let mut ss: SpaceSaving<u64> = SpaceSaving::new(8);
        let mut truth = std::collections::HashMap::new();
        // skewed stream over 50 keys
        for t in 0..5000u64 {
            let k = (t % 50).min(t % 7); // heavies: 0..7
            ss.process(k, 1.0);
            *truth.entry(k).or_insert(0.0) += 1.0;
        }
        let total: f64 = truth.values().sum();
        for (k, &f) in &truth {
            let est = ss.est(k);
            if est > 0.0 {
                assert!(est + 1e-9 >= f, "key {k}: est {est} < freq {f}");
                assert!(est <= f + total / 8.0 + 1e-9);
            }
        }
    }

    #[test]
    fn heavy_hitters_survive_eviction() {
        let mut ss: SpaceSaving<&'static str> = SpaceSaving::new(4);
        for _ in 0..1000 {
            ss.process("heavy", 1.0);
        }
        for i in 0..200 {
            // distinct light strings
            let s: &'static str = Box::leak(format!("light{i}").into_boxed_str());
            ss.process(s, 1.0);
        }
        let top = ss.top();
        assert_eq!(top[0].key, "heavy");
        assert!(top[0].count >= 1000.0);
    }

    #[test]
    fn merge_adds_and_truncates() {
        let mut a: SpaceSaving<u64> = SpaceSaving::new(4);
        let mut b: SpaceSaving<u64> = SpaceSaving::new(4);
        for i in 0..4u64 {
            a.process(i, 10.0 * (i + 1) as f64);
            b.process(i + 2, 5.0);
        }
        a.merge(&b).unwrap();
        assert!(a.len() <= 4);
        assert!(a.est(&3) >= 45.0); // 40 + 5
        let mut c: SpaceSaving<u64> = SpaceSaving::new(5);
        assert!(c.merge(&SpaceSaving::new(4)).is_err());
    }

    #[test]
    fn eviction_is_deterministic_on_count_ties() {
        // all-ones stream over more keys than capacity: counts tie
        // constantly; the (count, key) order must make runs reproducible
        let runs: Vec<Vec<u64>> = (0..2)
            .map(|_| {
                let mut ss: SpaceSaving<u64> = SpaceSaving::new(6);
                for t in 0..500u64 {
                    ss.process((t * 7) % 23, 1.0);
                }
                ss.top().into_iter().map(|c| c.key).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1]);
    }

    #[test]
    fn batch_equals_scalar_loop() {
        run("spacesaving batch == scalar", 20, |g: &mut Gen| {
            let cap = g.usize_range(2, 16);
            let mut scalar: SpaceSaving<u64> = SpaceSaving::new(cap);
            let mut batched: SpaceSaving<u64> = SpaceSaving::new(cap);
            let m = g.usize_range(1, 600);
            let updates: Vec<(u64, f64)> = (0..m)
                .map(|_| (g.u64_below(60), g.f64_range(0.0, 5.0)))
                .collect();
            for (k, v) in &updates {
                scalar.process(*k, *v);
            }
            for c in updates.chunks(g.usize_range(1, m + 3)) {
                batched.process_batch(c);
            }
            assert_eq!(scalar.processed(), batched.processed());
            let (st, bt) = (scalar.top(), batched.top());
            assert_eq!(st.len(), bt.len());
            for (a, b) in st.iter().zip(&bt) {
                assert_eq!(a.key, b.key);
                assert!((a.count - b.count).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn eviction_heap_stays_bounded_under_adversarial_churn() {
        // adversarial mix: constant hits on tracked keys (staling their
        // heap entries) interleaved with a rotating front of fresh keys
        // (forcing evictions) — the lazy-deletion heap must stay within
        // 2× capacity at every step, never growing with stream length
        let cap = 8;
        let mut ss: SpaceSaving<u64> = SpaceSaving::new(cap);
        for t in 0..20_000u64 {
            match t % 4 {
                // hits on a small hot set: stale entries
                0 | 1 => ss.process(t % 3, 1.0),
                // cold churn: unseen keys, constant evictions
                _ => ss.process(1000 + t, 1.0),
            }
            assert!(
                ss.eviction_heap_len() <= 2 * cap,
                "heap grew to {} at t={t} (cap {cap})",
                ss.eviction_heap_len()
            );
        }
        // hot keys survived the churn with exact-ish counts
        assert!(ss.est(&0) >= 1000.0);
    }

    #[test]
    fn soa_cols_equal_scalar_loop() {
        run("spacesaving cols == scalar", 15, |g: &mut Gen| {
            let cap = g.usize_range(2, 16);
            let mut scalar: SpaceSaving<u64> = SpaceSaving::new(cap);
            let mut blocked: SpaceSaving<u64> = SpaceSaving::new(cap);
            let m = g.usize_range(1, 500);
            let updates: Vec<(u64, f64)> = (0..m)
                .map(|_| (g.u64_below(60), g.f64_range(0.0, 5.0)))
                .collect();
            for (k, v) in &updates {
                scalar.process(*k, *v);
            }
            for c in updates.chunks(g.usize_range(1, m + 3)) {
                let keys: Vec<u64> = c.iter().map(|(k, _)| *k).collect();
                let vals: Vec<f64> = c.iter().map(|(_, v)| *v).collect();
                blocked.process_cols(&keys, &vals);
            }
            assert_eq!(scalar.processed(), blocked.processed());
            let (st, bt) = (scalar.top(), blocked.top());
            assert_eq!(st.len(), bt.len());
            for (a, b) in st.iter().zip(&bt) {
                assert_eq!(a.key, b.key);
                assert_eq!(a.count.to_bits(), b.count.to_bits());
                assert_eq!(a.overestimate.to_bits(), b.overestimate.to_bits());
            }
        });
    }

    #[test]
    fn property_estimate_upper_bounds_frequency() {
        run("spacesaving upper bound", 25, |g: &mut Gen| {
            let cap = g.usize_range(4, 32);
            let mut ss: SpaceSaving<u64> = SpaceSaving::new(cap);
            let mut truth = std::collections::HashMap::new();
            for _ in 0..g.usize_range(10, 2000) {
                let k = g.u64_below(100);
                let v = g.f64_range(0.0, 5.0);
                ss.process(k, v);
                *truth.entry(k).or_insert(0.0) += v;
            }
            for (k, &f) in &truth {
                let e = ss.est(k);
                if e > 0.0 {
                    assert!(e + 1e-9 >= f, "est {e} < freq {f}");
                }
            }
        });
    }
}
