//! CountSketch (Charikar–Chen–Farach-Colton) — the paper's main rHH sketch:
//! ℓ2 guarantees, signed (turnstile) streams, unbiased estimates.
//!
//! Layout: `rows × width` f64 counters. Key `x` maps in row `i` to bucket
//! `b_i(x)` with sign `s_i(x)`; `process` adds `s_i(x)·val` to each row's
//! bucket, `est` returns the **median** over rows of `s_i(x)·C[i][b_i(x)]`.
//!
//! rHH property (paper Table 1 / [52]): with `width = O(k/ψ)` and
//! `rows = O(log(n/δ))`, all keys satisfy
//! `|ν̂_x − ν_x|² ≤ (ψ/k)‖tail_k(ν)‖₂²` w.p. 1−δ.
//!
//! This struct is the **native backend**; the same update is authored as a
//! Pallas kernel (python/compile/kernels/countsketch.py) and exercised via
//! [`crate::runtime`] — tests assert both agree bit-exactly on f32 inputs.

use super::{RhhSketch, SketchParams};
use crate::data::Element;
use crate::error::{Error, Result};
use crate::util::hashing::{KeyCoords, SketchHasher, LANE};

/// CountSketch with median-of-rows estimation.
#[derive(Clone, Debug)]
pub struct CountSketch {
    params: SketchParams,
    hasher: SketchHasher,
    /// Row-major `rows × width` counters.
    table: Vec<f64>,
    /// Number of elements processed (diagnostics).
    processed: u64,
    /// Reusable per-batch key-coordinate buffer (§Perf L3-6) — steady-state
    /// batches allocate nothing.
    scratch: Vec<KeyCoords>,
}

impl CountSketch {
    /// Create an empty sketch.
    pub fn new(params: SketchParams) -> Self {
        let hasher = SketchHasher::new(params.seed, params.width);
        CountSketch {
            params,
            hasher,
            table: vec![0.0; params.rows * params.width],
            processed: 0,
            scratch: Vec::new(),
        }
    }

    /// Convenience: `rows × width`, seed.
    pub fn with_shape(rows: usize, width: usize, seed: u64) -> Self {
        Self::new(SketchParams::new(rows, width, seed))
    }

    /// Shape/seed parameters.
    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    /// Raw counter table (row-major) — used by the XLA backend to seed
    /// device buffers and by tests.
    pub fn table(&self) -> &[f64] {
        &self.table
    }

    /// Mutable raw table (the XLA backend writes results back).
    pub fn table_mut(&mut self) -> &mut [f64] {
        &mut self.table
    }

    /// Elements processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Bump the processed counter (used by the XLA offload path which
    /// updates the table out-of-band).
    pub fn note_processed(&mut self, n: u64) {
        self.processed += n;
    }

    /// The (bucket, sign) pairs of a key in every row — the exact inputs
    /// the L1 Pallas kernel receives (hashing stays in rust; DESIGN.md §4).
    pub fn key_coords(&self, key: u64) -> Vec<(usize, f64)> {
        (0..self.params.rows)
            .map(|r| (self.hasher.bucket(r, key), self.hasher.sign(r, key)))
            .collect()
    }

    /// Fill `buf` (len = rows) with the per-row signed bucket reads of
    /// `key` and select the median in place — the shared estimation
    /// kernel behind [`RhhSketch::est`] and [`CountSketch::est_many`].
    ///
    /// The sweep is split into a **derive phase** (straight-line hash →
    /// signed read per row) and a median select over `f64::total_cmp` —
    /// total order, no `unwrap`, and branch-predictable (it compiles to
    /// an integer compare on the sign-flipped bit patterns). On the
    /// finite tables the ingest boundary now guarantees, `total_cmp`
    /// ranks exactly like the old `partial_cmp().unwrap()`; if a
    /// non-finite cell ever appears anyway (a hand-built table), the
    /// median degrades deterministically instead of panicking. The
    /// median *value* is deterministic because selection only permutes
    /// equal-valued candidates.
    #[inline]
    fn est_into(&self, key: u64, buf: &mut [f64]) -> f64 {
        let c = self.hasher.coords_of(key);
        let w = self.params.width;
        for (r, slot) in buf.iter_mut().enumerate() {
            let (b, s) = self.hasher.bucket_sign_from(&c, r);
            *slot = s * self.table[r * w + b];
        }
        let mid = buf.len() / 2;
        buf.select_nth_unstable_by(mid, f64::total_cmp);
        buf[mid]
    }

    /// Estimate a whole column of keys into `out` (§Perf L3-7/L3-8).
    ///
    /// Keys are processed `LANE` at a time with the table-gather phase
    /// batched **row-major**: per row, the lane's reads all land in the
    /// same contiguous `width`-sized row slice (cache-resident across
    /// the lane) instead of striding the full table once per key. One
    /// stack scratch is shared across the entire key column, so
    /// candidate-scoring loops (worp1 shrink/sample, worp2 finalize,
    /// the cluster query fold) pay zero allocations per key. Each entry
    /// is bit-identical to [`RhhSketch::est`]: the per-key gathered
    /// values and the `total_cmp` median select are exactly
    /// [`CountSketch::est_into`]'s.
    pub fn est_many(&self, keys: &[u64], out: &mut [f64]) {
        assert_eq!(keys.len(), out.len(), "est_many requires out.len() == keys.len()");
        let rows = self.params.rows;
        let w = self.params.width;
        if rows <= 63 {
            let mut lane_buf = [0.0f64; 63 * LANE];
            let mut kchunks = keys.chunks_exact(LANE);
            let mut ochunks = out.chunks_exact_mut(LANE);
            for (ks, os) in (&mut kchunks).zip(&mut ochunks) {
                let mut cs = [KeyCoords::default(); LANE];
                for i in 0..LANE {
                    cs[i] = self.hasher.coords_of(ks[i]);
                }
                for r in 0..rows {
                    let row = &self.table[r * w..(r + 1) * w];
                    for i in 0..LANE {
                        let (b, s) = self.hasher.bucket_sign_from(&cs[i], r);
                        lane_buf[i * rows + r] = s * row[b];
                    }
                }
                for (i, slot) in os.iter_mut().enumerate() {
                    let buf = &mut lane_buf[i * rows..(i + 1) * rows];
                    buf.select_nth_unstable_by(rows / 2, f64::total_cmp);
                    *slot = buf[rows / 2];
                }
            }
            let mut buf = [0.0f64; 63];
            for (&k, slot) in kchunks.remainder().iter().zip(ochunks.into_remainder()) {
                *slot = self.est_into(k, &mut buf[..rows]);
            }
        } else {
            let mut buf = vec![0.0f64; rows];
            for (&k, slot) in keys.iter().zip(out.iter_mut()) {
                *slot = self.est_into(k, &mut buf);
            }
        }
    }

    /// Columnar SoA update (§Perf L3-7): the same row-major sweep as
    /// [`CountSketch::process_batch`], but hashing straight off the dense
    /// `keys` column and sweeping the dense `vals` column — no
    /// per-element struct loads anywhere. Per table cell the additions
    /// happen in element order, so the result is bit-identical to both
    /// the scalar loop and the AoS batch path.
    pub fn process_cols(&mut self, keys: &[u64], vals: &[f64]) {
        debug_assert_eq!(keys.len(), vals.len());
        let mut coords = std::mem::take(&mut self.scratch);
        self.hasher.fill_coords_slice(keys, &mut coords);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let row = &mut self.table[r * w..(r + 1) * w];
            // §Perf L3-8: lane-unrolled, branch-free sweep. Per LANE
            // chunk, the bucket/signed-value derivation is a fixed-width
            // straight-line loop (autovectorizable: one mix, one
            // multiply-shift, one sign-bit move, one multiply per
            // element); only the scatter stays serial, applied in
            // element order so the row cells stay bit-identical to the
            // scalar loop (`row[b] += s * v` computes the very same
            // product before the add).
            let mut cchunks = coords.chunks_exact(LANE);
            let mut vchunks = vals.chunks_exact(LANE);
            for (cs, vs) in (&mut cchunks).zip(&mut vchunks) {
                let mut bs = [0usize; LANE];
                let mut sv = [0.0f64; LANE];
                for i in 0..LANE {
                    let (b, s) = self.hasher.bucket_sign_from(&cs[i], r);
                    bs[i] = b;
                    sv[i] = s * vs[i];
                }
                for i in 0..LANE {
                    row[bs[i]] += sv[i];
                }
            }
            for (c, &v) in cchunks.remainder().iter().zip(vchunks.remainder()) {
                let (b, s) = self.hasher.bucket_sign_from(c, r);
                row[b] += s * v;
            }
        }
        self.processed += keys.len() as u64;
        self.scratch = coords;
    }

    /// Columnar micro-batch update (§Perf L3-6).
    ///
    /// Derives the per-key hash state for the whole batch in one pass,
    /// then sweeps the table **row-major**: the inner loop touches a single
    /// contiguous `width`-sized row slice (cache-resident) and pays one
    /// fused multiply-shift per (key, row) instead of two mixes plus a
    /// strided table walk per element. Per table cell the additions happen
    /// in element order — exactly as the scalar loop applies them — so the
    /// result is bit-identical to `process` called per element.
    pub fn process_batch(&mut self, batch: &[Element]) {
        let mut coords = std::mem::take(&mut self.scratch);
        self.hasher.fill_coords(batch.iter().map(|e| e.key), &mut coords);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let row = &mut self.table[r * w..(r + 1) * w];
            // same lane-unrolled sweep as process_cols, with the value
            // loads off the AoS element slice (§Perf L3-8)
            let mut cchunks = coords.chunks_exact(LANE);
            let mut echunks = batch.chunks_exact(LANE);
            for (cs, es) in (&mut cchunks).zip(&mut echunks) {
                let mut bs = [0usize; LANE];
                let mut sv = [0.0f64; LANE];
                for i in 0..LANE {
                    let (b, s) = self.hasher.bucket_sign_from(&cs[i], r);
                    bs[i] = b;
                    sv[i] = s * es[i].val;
                }
                for i in 0..LANE {
                    row[bs[i]] += sv[i];
                }
            }
            for (c, e) in cchunks.remainder().iter().zip(echunks.remainder()) {
                let (b, s) = self.hasher.bucket_sign_from(c, r);
                row[b] += s * e.val;
            }
        }
        self.processed += batch.len() as u64;
        self.scratch = coords;
    }
}

impl RhhSketch for CountSketch {
    #[inline]
    fn process(&mut self, e: &Element) {
        // §Perf L3-2: derive per-key hash state once, O(1) per row;
        // §Perf L3-6: one fused mix yields both bucket and sign
        let c = self.hasher.coords_of(e.key);
        let w = self.params.width;
        for r in 0..self.params.rows {
            let (b, s) = self.hasher.bucket_sign_from(&c, r);
            self.table[r * w + b] += s * e.val;
        }
        self.processed += 1;
    }

    fn merge(&mut self, other: &Self) -> Result<()> {
        if self.params != other.params {
            return Err(Error::Incompatible(format!(
                "CountSketch params differ: {:?} vs {:?}",
                self.params, other.params
            )));
        }
        for (a, b) in self.table.iter_mut().zip(other.table.iter()) {
            *a += *b;
        }
        self.processed += other.processed;
        Ok(())
    }

    fn est(&self, key: u64) -> f64 {
        // §Perf L3-3: stack buffer for ≤ 63 rows (no per-call allocation);
        // wide sketches pay one scratch per call — batch queries should go
        // through est_many, which shares one scratch across all keys
        let rows = self.params.rows;
        if rows <= 63 {
            let mut buf = [0.0f64; 63];
            self.est_into(key, &mut buf[..rows])
        } else {
            let mut buf = vec![0.0f64; rows];
            self.est_into(key, &mut buf)
        }
    }

    fn size_words(&self) -> usize {
        self.table.len()
    }
}

/// Wire payload: the shared hashed-array body
/// ([`crate::codec::put_rhh_table`]); the scratch buffer is transient
/// state and not persisted.
impl crate::api::Persist for CountSketch {
    fn encode_into(&self, out: &mut Vec<u8>) {
        let mut p = Vec::with_capacity(40 + 8 * self.table.len());
        crate::codec::put_rhh_table(&mut p, &self.params, self.processed, &self.table);
        crate::codec::write_envelope(
            crate::codec::tag::COUNTSKETCH,
            crate::api::Mergeable::fingerprint(self).value(),
            &p,
            out,
        );
    }

    fn decode(bytes: &[u8]) -> crate::error::Result<Self> {
        let env = crate::codec::read_envelope(bytes, Some(crate::codec::tag::COUNTSKETCH))?;
        let mut r = crate::codec::wire::Reader::new(env.payload);
        let (params, processed, table) = crate::codec::read_rhh_table(&mut r)?;
        r.finish("countsketch")?;
        let mut s = CountSketch::new(params);
        s.table = table;
        s.processed = processed;
        crate::codec::check_fingerprint(
            env.fingerprint,
            crate::api::Mergeable::fingerprint(&s).value(),
        )?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::aggregate;
    use crate::util::proptest::{run, Gen};
    use crate::util::rng::Rng;

    fn elems_from_freqs(freqs: &[f64]) -> Vec<Element> {
        freqs
            .iter()
            .enumerate()
            .filter(|(_, f)| **f != 0.0)
            .map(|(i, &f)| Element::new(i as u64, f))
            .collect()
    }

    #[test]
    fn exact_for_sparse_input() {
        // far fewer keys than buckets: estimates are exact w.h.p.
        let mut cs = CountSketch::with_shape(7, 512, 1);
        for e in elems_from_freqs(&[10.0, -3.0, 4.5]) {
            cs.process(&e);
        }
        assert!((cs.est(0) - 10.0).abs() < 1e-9);
        assert!((cs.est(1) + 3.0).abs() < 1e-9);
        assert!((cs.est(2) - 4.5).abs() < 1e-9);
        assert!(cs.est(99).abs() < 1e-9);
    }

    #[test]
    fn unbiased_signed_updates_cancel() {
        let mut cs = CountSketch::with_shape(5, 64, 2);
        cs.process(&Element::new(7, 5.0));
        cs.process(&Element::new(7, -5.0));
        assert!(cs.est(7).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_sequential() {
        let params = SketchParams::new(5, 128, 3);
        let mut all = CountSketch::new(params);
        let mut a = CountSketch::new(params);
        let mut b = CountSketch::new(params);
        let mut rng = Rng::new(4);
        let elems: Vec<Element> = (0..1000)
            .map(|_| Element::new(rng.below(200), rng.normal()))
            .collect();
        for (i, e) in elems.iter().enumerate() {
            all.process(e);
            if i % 2 == 0 {
                a.process(e);
            } else {
                b.process(e);
            }
        }
        a.merge(&b).unwrap();
        // merge adds in a different order than sequential processing, so
        // allow float round-off
        for (x, y) in a.table().iter().zip(all.table()) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
        assert_eq!(a.processed(), all.processed());
    }

    #[test]
    fn merge_rejects_mismatched_seed() {
        let mut a = CountSketch::with_shape(5, 64, 1);
        let b = CountSketch::with_shape(5, 64, 2);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn rhh_error_bound_l2() {
        // Zipf[2] frequencies: top keys are strong l2 HHs; check the
        // (k, psi) bound with width = 4k/psi.
        let n = 2_000;
        let k = 20;
        let psi = 0.5;
        let freqs: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-2.0) * 1e4).collect();
        // rows = O(log(n/delta)) is required for the *uniform* (all-keys)
        // guarantee; 21 rows covers a union bound over n=2000 keys here.
        let width = SketchParams::for_rhh(k, psi, 8.0);
        let mut cs = CountSketch::with_shape(21, width, 5);
        for e in elems_from_freqs(&freqs) {
            cs.process(&e);
        }
        let tail = crate::util::stats::tail_norm_pow(&freqs, k, 2.0);
        let bound = (psi / k as f64 * tail).sqrt();
        let mut worst = 0.0f64;
        for i in 0..n {
            worst = worst.max((cs.est(i as u64) - freqs[i]).abs());
        }
        assert!(worst <= bound, "worst={worst} bound={bound}");
    }

    #[test]
    fn property_estimates_match_aggregate_on_sparse_keys() {
        run("countsketch sparse exactness", 30, |g: &mut Gen| {
            let nkeys = g.usize_range(1, 20);
            let rows = *g.choose(&[3usize, 5, 7]);
            let width = g.usize_range(256, 1024);
            let seed = g.u64_below(u64::MAX);
            let mut cs = CountSketch::with_shape(rows, width, seed);
            let keys = g.vec_keys(nkeys, 1_000_000);
            let vals = g.vec_f64(nkeys, -100.0, 100.0);
            let elems: Vec<Element> = keys
                .iter()
                .zip(&vals)
                .map(|(&k, &v)| Element::new(k, v))
                .collect();
            for e in &elems {
                cs.process(e);
            }
            let truth = aggregate(elems.clone());
            // With ≤20 keys in ≥256 buckets × ≥3 rows, the median is exact
            // unless ≥2 rows collide for the same key (prob < 1e-3 here).
            let mut bad = 0;
            for (&k, &f) in &truth {
                if (cs.est(k) - f).abs() > 1e-9 {
                    bad += 1;
                }
            }
            assert!(bad == 0, "inexact estimates for {bad} keys (seed {:#x})", g.seed());
        });
    }

    #[test]
    fn property_merge_commutes() {
        run("countsketch merge commutes", 20, |g: &mut Gen| {
            let params = SketchParams::new(5, 64, g.u64_below(1 << 40));
            let mut ab = CountSketch::new(params);
            let mut ba = CountSketch::new(params);
            let mut a = CountSketch::new(params);
            let mut b = CountSketch::new(params);
            for _ in 0..g.usize_range(1, 200) {
                let e = Element::new(g.u64_below(500), g.f64_range(-10.0, 10.0));
                if g.bool(0.5) {
                    a.process(&e);
                } else {
                    b.process(&e);
                }
            }
            ab.merge(&a).unwrap();
            ab.merge(&b).unwrap();
            ba.merge(&b).unwrap();
            ba.merge(&a).unwrap();
            assert_eq!(ab.table(), ba.table());
        });
    }

    #[test]
    fn size_words_matches_shape() {
        let cs = CountSketch::with_shape(31, 100, 1);
        assert_eq!(cs.size_words(), 3100);
    }

    #[test]
    fn soa_block_path_bit_identical_to_batch_and_scalar() {
        run("countsketch cols == batch == scalar", 20, |g: &mut Gen| {
            let rows = *g.choose(&[1usize, 3, 7]);
            let width = g.usize_range(16, 512);
            let seed = g.u64_below(u64::MAX);
            let mut scalar = CountSketch::with_shape(rows, width, seed);
            let mut batched = CountSketch::with_shape(rows, width, seed);
            let mut blocked = CountSketch::with_shape(rows, width, seed);
            let m = g.usize_range(1, 600);
            let elems: Vec<Element> = (0..m)
                .map(|_| Element::new(g.u64_below(1 << 20), g.f64_range(-50.0, 50.0)))
                .collect();
            for e in &elems {
                scalar.process(e);
            }
            let chunk = g.usize_range(1, m + 7);
            for c in elems.chunks(chunk) {
                batched.process_batch(c);
                let block = crate::data::ElementBlock::from_elements(c);
                blocked.process_cols(&block.keys, &block.vals);
            }
            assert_eq!(scalar.table(), batched.table());
            assert_eq!(batched.table(), blocked.table());
            assert_eq!(scalar.processed(), blocked.processed());
        });
    }

    #[test]
    fn est_many_bit_identical_to_est() {
        run("countsketch est_many == est", 15, |g: &mut Gen| {
            // cover both the stack-buffer (<=63) and heap-scratch rows paths
            let rows = *g.choose(&[5usize, 7, 65]);
            let width = g.usize_range(32, 256);
            let mut cs = CountSketch::with_shape(rows, width, g.u64_below(1 << 48));
            for _ in 0..g.usize_range(1, 500) {
                cs.process(&Element::new(g.u64_below(2000), g.f64_range(-10.0, 10.0)));
            }
            let keys: Vec<u64> = (0..200).map(|_| g.u64_below(2500)).collect();
            let mut out = vec![0.0f64; keys.len()];
            cs.est_many(&keys, &mut out);
            for (&k, &e) in keys.iter().zip(&out) {
                assert_eq!(e.to_bits(), cs.est(k).to_bits(), "key {k}");
            }
        });
    }

    #[test]
    fn columnar_batch_is_bit_identical_to_scalar() {
        run("countsketch batch == scalar", 20, |g: &mut Gen| {
            let rows = *g.choose(&[1usize, 3, 7]);
            let width = g.usize_range(16, 512);
            let seed = g.u64_below(u64::MAX);
            let mut scalar = CountSketch::with_shape(rows, width, seed);
            let mut batched = CountSketch::with_shape(rows, width, seed);
            let m = g.usize_range(1, 600);
            let elems: Vec<Element> = (0..m)
                .map(|_| Element::new(g.u64_below(1 << 20), g.f64_range(-50.0, 50.0)))
                .collect();
            for e in &elems {
                scalar.process(e);
            }
            let chunk = g.usize_range(1, m + 7);
            for c in elems.chunks(chunk) {
                batched.process_batch(c);
            }
            // per-cell addition order is identical, so exact equality holds
            assert_eq!(scalar.table(), batched.table());
            assert_eq!(scalar.processed(), batched.processed());
        });
    }
}
