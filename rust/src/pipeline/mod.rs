//! The L3 streaming pipeline: sharded workers over an unaggregated
//! element stream, composable-sketch merging, and explicit backpressure.
//!
//! Topology (DESIGN.md §4):
//!
//! ```text
//! source ──router (hash shard)──▶ worker 0 ─┐
//!        ──bounded channels─────▶ worker 1 ─┼─▶ merge tree ─▶ leader
//!        (backpressure)          ...        ─┘   (composable sketches)
//! ```
//!
//! Workers own shard-local state (a pass-I WORp sketch, a pass-II
//! collector, or any [`ShardSink`]); the leader merges the per-shard
//! summaries — correctness rests exactly on the paper's composability
//! property, which the worp1/worp2 merge tests verify.

pub mod merge;
pub mod metrics;
pub mod shard;
pub mod spool;

use crate::api::{Persist, StreamSummary};
use crate::codec::{self, wire};
use crate::data::Element;
use crate::error::{Error, Result};
use metrics::Metrics;
use shard::Router;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::Arc;

/// Shard-local consumer state. Every `Send` [`StreamSummary`] is a
/// `ShardSink` via the blanket impl below — samplers, sketches, pass
/// states and `Box<dyn WorSampler>` all flow through [`run_sharded`]
/// without per-type glue. Ad-hoc closures wrap in [`FnSink`].
pub trait ShardSink: Send + 'static {
    /// Process one element routed to this shard.
    fn process(&mut self, e: &Element);

    /// Process a routed micro-batch (defaults to an element loop).
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            self.process(e);
        }
    }
}

impl<S: StreamSummary + Send + 'static> ShardSink for S {
    fn process(&mut self, e: &Element) {
        StreamSummary::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        StreamSummary::process_batch(self, batch)
    }
}

/// Adapter: drive a closure as a [`StreamSummary`] (and hence a
/// [`ShardSink`]) — handy for tests and side-effecting sinks.
pub struct FnSink<F> {
    f: F,
    processed: u64,
}

impl<F: FnMut(&Element)> FnSink<F> {
    /// Wrap a closure.
    pub fn new(f: F) -> Self {
        FnSink { f, processed: 0 }
    }
}

impl<F: FnMut(&Element)> StreamSummary for FnSink<F> {
    fn process(&mut self, e: &Element) {
        (self.f)(e);
        self.processed += 1;
    }

    /// The closure is inherently per-element; the batch path just hoists
    /// the processed counter out of the loop.
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            (self.f)(e);
        }
        self.processed += batch.len() as u64;
    }

    fn size_words(&self) -> usize {
        0
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

/// Pipeline configuration (subset of [`crate::config::PipelineConfig`]
/// relevant to the execution topology).
#[derive(Clone, Copy, Debug)]
pub struct PipelineOpts {
    /// Number of shard workers.
    pub workers: usize,
    /// Elements per micro-batch on the worker channels.
    pub batch: usize,
    /// Channel capacity in batches (the backpressure window).
    pub channel_cap: usize,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts { workers: 4, batch: 4096, channel_cap: 16 }
    }
}

impl PipelineOpts {
    /// Validated constructor.
    pub fn new(workers: usize, batch: usize, channel_cap: usize) -> Result<Self> {
        if workers == 0 || batch == 0 || channel_cap == 0 {
            return Err(Error::Pipeline(
                "workers, batch and channel_cap must be positive".into(),
            ));
        }
        Ok(PipelineOpts { workers, batch, channel_cap })
    }
}

/// Run a sharded pipeline: route `stream` across `opts.workers` workers,
/// each owning the state built by `make(shard_idx)`; returns the
/// per-shard states (in shard order) and the run metrics.
///
/// Routing is by stable key hash, so *all elements of a key land on the
/// same shard* — required for SpaceSaving/TopK composability and good for
/// locality; the hashed-array sketches are insensitive to the split.
pub fn run_sharded<S, F, I>(stream: I, opts: PipelineOpts, make: F) -> Result<(Vec<S>, Arc<Metrics>)>
where
    S: ShardSink,
    F: Fn(usize) -> S,
    I: IntoIterator<Item = Element>,
{
    let metrics = Arc::new(Metrics::default());
    let router = Router::new(opts.workers);

    // §Perf L3-6: workers return drained batch buffers to the router
    // through an unbounded pool channel, so steady-state routing reuses
    // the same `workers × (channel_cap + 2)` buffers instead of allocating
    // one per batch.
    let (pool_tx, pool_rx) = channel::<Vec<Element>>();

    let mut senders: Vec<SyncSender<Vec<Element>>> = Vec::with_capacity(opts.workers);
    let mut handles = Vec::with_capacity(opts.workers);
    for w in 0..opts.workers {
        let (tx, rx): (SyncSender<Vec<Element>>, Receiver<Vec<Element>>) =
            sync_channel(opts.channel_cap);
        senders.push(tx);
        let mut state = make(w);
        let m = Arc::clone(&metrics);
        let pool = pool_tx.clone();
        handles.push(std::thread::spawn(move || {
            for mut batch in rx {
                state.process_batch(&batch);
                m.note_batch(batch.len() as u64);
                batch.clear();
                // router may already have hung up at end-of-stream
                let _ = pool.send(batch);
            }
            state
        }));
    }
    drop(pool_tx); // only worker clones remain

    // router loop on the caller thread
    let mut buffers: Vec<Vec<Element>> = (0..opts.workers)
        .map(|_| Vec::with_capacity(opts.batch))
        .collect();
    for e in stream {
        let w = router.route(e.key);
        buffers[w].push(e);
        if buffers[w].len() == opts.batch {
            let fresh = recycled_buffer(&pool_rx, opts.batch, &metrics);
            let full = std::mem::replace(&mut buffers[w], fresh);
            send_with_backpressure(&senders[w], full, &metrics)?;
        }
    }
    for (w, buf) in buffers.into_iter().enumerate() {
        if !buf.is_empty() {
            send_with_backpressure(&senders[w], buf, &metrics)?;
        }
    }
    drop(senders);

    let mut states = Vec::with_capacity(opts.workers);
    for h in handles {
        states.push(
            h.join()
                .map_err(|_| Error::Pipeline("worker panicked".into()))?,
        );
    }
    Ok((states, metrics))
}

// ---------------------------------------------------------------------------
// Checkpointing

/// When and where a sharded run snapshots its shard states: every
/// `every_batches` micro-batches, each worker writes its summary (via
/// [`Persist`]) plus its element cursor to `dir/shard-<w>.worp`,
/// atomically (temp file + rename). A later
/// [`run_sharded_checkpointed`] over the same replayable stream resumes
/// from those files: restored shards skip exactly the elements their
/// snapshot already covers, so the finished run is bit-identical to an
/// uninterrupted one (worker batch boundaries realign because snapshots
/// are taken on batch edges).
///
/// Guardrails on resume: the file's topology stamp (shard / workers /
/// batch) and its summary fingerprint must match the current run's
/// prototype — stale snapshots from a different seed, shape, method or
/// pass fail with [`Error::Incompatible`] instead of silently mixing
/// runs. What the fingerprint cannot cover is the *stream itself*:
/// resuming over a different input stream with an identical
/// configuration is undetectable, so keep one snapshot directory per
/// (config, stream) pair.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    every_batches: u64,
    dir: PathBuf,
}

impl CheckpointPolicy {
    /// Snapshot every `every_batches` worker batches into `dir`.
    pub fn new(every_batches: u64, dir: impl Into<PathBuf>) -> Result<Self> {
        if every_batches == 0 {
            return Err(Error::Pipeline(
                "checkpoint interval must be positive (batches)".into(),
            ));
        }
        Ok(CheckpointPolicy { every_batches, dir: dir.into() })
    }

    /// Batches between snapshots.
    pub fn every_batches(&self) -> u64 {
        self.every_batches
    }

    /// Snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot file of one shard.
    pub fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard-{shard}.worp"))
    }

    /// A per-pass sub-policy (multi-pass drivers keep each pass's
    /// snapshots in their own subdirectory so they cannot collide).
    pub fn for_pass(&self, pass: usize) -> CheckpointPolicy {
        CheckpointPolicy {
            every_batches: self.every_batches,
            dir: self.dir.join(format!("pass-{pass}")),
        }
    }
}

/// Checkpoint-file topology stamp: shard index, worker count and batch
/// size. Resume validates all three — a snapshot taken under a different
/// topology routes (or batches) differently and must not be continued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct CheckpointMeta {
    shard: u16,
    workers: u16,
    batch: u32,
}

/// Byte length of the checkpoint-file header fields covered by its
/// checksum (magic, version, topology stamp, element cursor).
const CHECKPOINT_HEADER_LEN: usize = 22;

/// Write `dir/shard-<w>.worp` atomically: `WCKP` magic, version, the
/// topology stamp, the shard's element cursor, a checksum over those
/// header bytes (the summary envelope carries its own — so *every* byte
/// of the file is covered by one of the two), then the summary's
/// [`Persist`] envelope.
fn write_checkpoint<S: Persist>(
    path: &Path,
    meta: CheckpointMeta,
    elements: u64,
    state: &S,
) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&wire::CHECKPOINT_MAGIC);
    wire::put_u16(&mut buf, wire::VERSION);
    wire::put_u16(&mut buf, meta.shard);
    wire::put_u16(&mut buf, meta.workers);
    wire::put_u32(&mut buf, meta.batch);
    wire::put_u64(&mut buf, elements);
    debug_assert_eq!(buf.len(), CHECKPOINT_HEADER_LEN);
    let checksum =
        crate::util::hashing::hash_bytes(codec::CHECKSUM_SEED, &buf[..CHECKPOINT_HEADER_LEN]);
    wire::put_u64(&mut buf, checksum);
    state.encode_into(&mut buf);
    let tmp = path.with_extension("worp.tmp");
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        // flush to stable storage *before* the rename becomes visible —
        // otherwise a power loss can leave a renamed-but-truncated
        // snapshot that wedges every later resume
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a shard snapshot, or `Ok(None)` when the file does not exist.
/// Returns the state, its element cursor, and the envelope's type tag +
/// fingerprint (for the caller's compatibility check against the current
/// prototype). Corrupt bytes surface as [`Error::Codec`]; a topology
/// mismatch as [`Error::Incompatible`] — never a silent wrong resume.
fn load_checkpoint<S: Persist>(
    path: &Path,
    meta: CheckpointMeta,
) -> Result<Option<(S, u64, (u16, u64))>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut r = wire::Reader::new(&bytes);
    let magic = r.take(4)?;
    if magic != wire::CHECKPOINT_MAGIC {
        return Err(Error::Codec(format!(
            "bad checkpoint magic {magic:02x?} in {}",
            path.display()
        )));
    }
    let version = r.u16()?;
    if version != wire::VERSION {
        return Err(Error::Codec(format!(
            "unsupported checkpoint version {version} in {}",
            path.display()
        )));
    }
    let found = CheckpointMeta { shard: r.u16()?, workers: r.u16()?, batch: r.u32()? };
    let elements = r.u64()?;
    let checksum = r.u64()?;
    if crate::util::hashing::hash_bytes(codec::CHECKSUM_SEED, &bytes[..CHECKPOINT_HEADER_LEN])
        != checksum
    {
        return Err(Error::Codec(format!(
            "checkpoint header checksum mismatch in {} — the topology stamp or element \
             cursor was corrupted",
            path.display()
        )));
    }
    if found != meta {
        return Err(Error::Incompatible(format!(
            "checkpoint {} was taken under a different topology \
             (shard {}/{} batch {}, this run is shard {}/{} batch {}) — \
             remove the snapshot directory or rerun with the original topology",
            path.display(),
            found.shard,
            found.workers,
            found.batch,
            meta.shard,
            meta.workers,
            meta.batch
        )));
    }
    let envelope = r.rest();
    let state = S::decode(envelope)?;
    let header = codec::peek_header(envelope)?;
    Ok(Some((state, elements, header)))
}

/// [`run_sharded`] with crash recovery: workers snapshot their shard
/// state to `policy.dir()` every `policy.every_batches()` batches, and a
/// rerun over the same (replayable) stream resumes from whatever
/// snapshots exist — restored shards skip the elements already covered,
/// the rest of the stream flows as usual, and the result is
/// bit-identical to an uninterrupted run. [`Metrics::snapshots`] /
/// [`Metrics::restores`] count both sides.
pub fn run_sharded_checkpointed<S, F, I>(
    stream: I,
    opts: PipelineOpts,
    policy: &CheckpointPolicy,
    make: F,
) -> Result<(Vec<S>, Arc<Metrics>)>
where
    S: ShardSink + Persist,
    F: Fn(usize) -> S,
    I: IntoIterator<Item = Element>,
{
    if opts.workers > u16::MAX as usize || opts.batch > u32::MAX as usize {
        return Err(Error::Pipeline(
            "checkpointing supports at most 2^16 workers and 2^32-element batches".into(),
        ));
    }
    std::fs::create_dir_all(policy.dir())?;
    let metrics = Arc::new(Metrics::default());
    let router = Router::new(opts.workers);
    let (pool_tx, pool_rx) = channel::<Vec<Element>>();

    let mut skips: Vec<u64> = Vec::with_capacity(opts.workers);
    let mut senders: Vec<SyncSender<Vec<Element>>> = Vec::with_capacity(opts.workers);
    let mut handles = Vec::with_capacity(opts.workers);
    for w in 0..opts.workers {
        let meta = CheckpointMeta {
            shard: w as u16,
            workers: opts.workers as u16,
            batch: opts.batch as u32,
        };
        let path = policy.shard_path(w);
        let proto = make(w);
        let (mut state, done) = match load_checkpoint::<S>(&path, meta)? {
            Some((s, done, (tag, fp))) => {
                // a stale snapshot (different seed/config/method/pass)
                // must not silently resume into this run: the restored
                // envelope's type tag + fingerprint have to match what
                // the current prototype would persist as. The encode is
                // deliberately per-shard — `make(w)` may construct
                // shard-dependent prototypes, so each snapshot is checked
                // against *its own* shard's prototype (cost is only paid
                // on restore)
                let mut pb = Vec::new();
                proto.encode_into(&mut pb);
                let (ptag, pfp) = codec::peek_header(&pb)?;
                if (tag, fp) != (ptag, pfp) {
                    return Err(Error::Incompatible(format!(
                        "checkpoint {} holds a {} summary with fingerprint {fp:#018x}, but \
                         this run's configuration expects {} with {pfp:#018x} — stale \
                         snapshot directory? remove it or rerun with the original config",
                        path.display(),
                        codec::tag_name(tag),
                        codec::tag_name(ptag)
                    )));
                }
                metrics.note_restore();
                (s, done)
            }
            None => (proto, 0),
        };
        skips.push(done);
        let (tx, rx): (SyncSender<Vec<Element>>, Receiver<Vec<Element>>) =
            sync_channel(opts.channel_cap);
        senders.push(tx);
        let m = Arc::clone(&metrics);
        let pool = pool_tx.clone();
        let every = policy.every_batches();
        handles.push(std::thread::spawn(move || -> Result<S> {
            let mut elements = done;
            let mut batches = 0u64;
            for mut batch in rx {
                state.process_batch(&batch);
                m.note_batch(batch.len() as u64);
                elements += batch.len() as u64;
                batches += 1;
                // only snapshot on *full*-batch edges: a partial batch is
                // an end-of-stream flush, and a cursor that is not a
                // multiple of the batch size would misalign the resumed
                // run's batch boundaries against an uninterrupted one
                // (batch-boundary-sensitive summaries like worp1 would
                // then diverge from the bit-identical guarantee)
                if batches % every == 0 && batch.len() == meta.batch as usize {
                    write_checkpoint(&path, meta, elements, &state)?;
                    m.note_snapshot();
                }
                batch.clear();
                let _ = pool.send(batch);
            }
            Ok(state)
        }));
    }
    drop(pool_tx);

    let mut buffers: Vec<Vec<Element>> = (0..opts.workers)
        .map(|_| Vec::with_capacity(opts.batch))
        .collect();
    // a send failure usually means a worker bailed (e.g. a snapshot-write
    // I/O error closed its channel); don't return the generic channel
    // error — fall through to the join below so the worker's *real*
    // error (disk full, permission, ...) is what surfaces
    let mut route_err: Option<Error> = None;
    for e in stream {
        let w = router.route(e.key);
        // elements a restored snapshot already covers are skipped; the
        // first fresh element lands on the same batch boundary the
        // interrupted run used (snapshots are taken on full-batch edges)
        if skips[w] > 0 {
            skips[w] -= 1;
            continue;
        }
        buffers[w].push(e);
        if buffers[w].len() == opts.batch {
            let fresh = recycled_buffer(&pool_rx, opts.batch, &metrics);
            let full = std::mem::replace(&mut buffers[w], fresh);
            if let Err(e) = send_with_backpressure(&senders[w], full, &metrics) {
                route_err = Some(e);
                break;
            }
        }
    }
    if route_err.is_none() {
        for (w, buf) in buffers.into_iter().enumerate() {
            if !buf.is_empty() {
                if let Err(e) = send_with_backpressure(&senders[w], buf, &metrics) {
                    route_err = Some(e);
                    break;
                }
            }
        }
    }
    // the stream ran dry while a restored shard was still owed skipped
    // elements: the stream is shorter than (so different from) the one
    // the snapshot was taken over — fail loudly like every other stale
    // resume instead of returning a state the given stream never produced
    if route_err.is_none() {
        if let Some((w, &owed)) = skips.iter().enumerate().find(|(_, &s)| s > 0) {
            route_err = Some(Error::Incompatible(format!(
                "stream ended while shard {w} still owed {owed} snapshot-covered elements — \
                 the resumed stream is shorter than the one the checkpoint was taken over; \
                 remove the snapshot directory or supply the original stream"
            )));
        }
    }
    drop(senders);

    let mut states = Vec::with_capacity(opts.workers);
    let mut worker_err: Option<Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => states.push(s),
            Ok(Err(e)) => {
                worker_err.get_or_insert(e);
            }
            Err(_) => {
                worker_err.get_or_insert(Error::Pipeline("worker panicked".into()));
            }
        }
    }
    if let Some(e) = worker_err {
        return Err(e);
    }
    if let Some(e) = route_err {
        return Err(e);
    }
    Ok((states, metrics))
}

/// Grab a drained buffer from the worker return pool, falling back to a
/// fresh allocation when none has come back yet.
fn recycled_buffer(
    pool: &Receiver<Vec<Element>>,
    cap: usize,
    metrics: &Metrics,
) -> Vec<Element> {
    match pool.try_recv() {
        Ok(buf) => {
            metrics.note_buffer_reuse();
            buf
        }
        Err(_) => Vec::with_capacity(cap),
    }
}

fn send_with_backpressure(
    tx: &SyncSender<Vec<Element>>,
    batch: Vec<Element>,
    metrics: &Metrics,
) -> Result<()> {
    // try_send first so we can count stalls (backpressure events)
    match tx.try_send(batch) {
        Ok(()) => Ok(()),
        Err(std::sync::mpsc::TrySendError::Full(batch)) => {
            metrics.note_stall();
            tx.send(batch)
                .map_err(|_| Error::Pipeline("worker channel closed".into()))
        }
        Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
            Err(Error::Pipeline("worker channel closed".into()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::ZipfStream;
    use std::collections::HashMap;
    use std::sync::Mutex;

    #[test]
    fn all_elements_processed_exactly_once() {
        let n = 100_000u64;
        let stream = ZipfStream::new(1000, 1.0, n, 3);
        let opts = PipelineOpts::new(4, 512, 4).unwrap();
        let counted = Arc::new(Mutex::new(0u64));
        let c2 = Arc::clone(&counted);
        let (states, metrics) = run_sharded(stream, opts, move |_| {
            let c = Arc::clone(&c2);
            FnSink::new(move |_e: &Element| {
                *c.lock().unwrap() += 1;
            })
        })
        .unwrap();
        assert_eq!(metrics.elements(), n);
        assert_eq!(*counted.lock().unwrap(), n);
        let per_shard: u64 = states.iter().map(StreamSummary::processed).sum();
        assert_eq!(per_shard, n);
        assert!(metrics.batches() >= n / 512);
    }

    /// A sink that records per-key sums (for routing-invariance tests).
    /// Implements [`StreamSummary`]; `ShardSink` comes via the blanket.
    struct MapSink {
        sums: HashMap<u64, f64>,
    }

    impl StreamSummary for MapSink {
        fn process(&mut self, e: &Element) {
            *self.sums.entry(e.key).or_insert(0.0) += e.val;
        }

        fn size_words(&self) -> usize {
            2 * self.sums.len()
        }

        fn processed(&self) -> u64 {
            0
        }
    }

    #[test]
    fn key_routing_is_consistent_and_partitioned() {
        let stream: Vec<Element> = ZipfStream::new(200, 1.0, 20_000, 7).collect();
        let truth = crate::data::aggregate(stream.clone());
        let opts = PipelineOpts::new(3, 128, 4).unwrap();
        let (states, _) = run_sharded(stream, opts, |_| MapSink { sums: HashMap::new() })
            .unwrap();
        // every key appears on exactly one shard, with its exact total
        let mut seen: HashMap<u64, f64> = HashMap::new();
        for s in &states {
            for (&k, &v) in &s.sums {
                assert!(!seen.contains_key(&k), "key {k} on two shards");
                seen.insert(k, v);
            }
        }
        assert_eq!(seen.len(), truth.len());
        for (k, v) in truth {
            assert!((seen[&k] - v).abs() < 1e-9);
        }
    }

    #[test]
    fn backpressure_counted_with_tiny_channel() {
        // deterministic-by-construction stall: the single worker parks on
        // its first batch long enough for the router to fill the
        // capacity-1 channel and hit try_send Full (the old version relied
        // on a busy-loop being slower than the router — a seed-red flake
        // on fast or heavily-loaded machines)
        let stream: Vec<Element> = (0..20_000).map(|i| Element::new(i % 16, 1.0)).collect();
        let opts = PipelineOpts::new(1, 64, 1).unwrap();
        let (_, metrics) = run_sharded(stream, opts, |_| {
            let mut slept = false;
            FnSink::new(move |_e: &Element| {
                if !slept {
                    slept = true;
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
            })
        })
        .unwrap();
        assert!(metrics.stalls() > 0, "expected backpressure stalls");
    }

    #[test]
    fn invalid_opts_rejected() {
        assert!(PipelineOpts::new(0, 1, 1).is_err());
        assert!(PipelineOpts::new(1, 0, 1).is_err());
        assert!(PipelineOpts::new(1, 1, 0).is_err());
    }

    #[test]
    fn router_recycles_worker_buffers() {
        // long stream, small batches: after the first channel_cap batches
        // drain, the router must start reusing returned buffers
        let stream: Vec<Element> = (0..100_000u64).map(|i| Element::new(i % 8, 1.0)).collect();
        let opts = PipelineOpts::new(2, 128, 2).unwrap();
        let (_, metrics) = run_sharded(stream, opts, |_| {
            FnSink::new(|_e: &Element| {})
        })
        .unwrap();
        assert!(
            metrics.buffer_reuses() > 0,
            "expected recycled batch buffers, report: {}",
            metrics.report()
        );
    }
}
