//! The L3 streaming pipeline: sharded workers over an unaggregated
//! element stream, composable-sketch merging, and explicit backpressure.
//!
//! Topology (DESIGN.md §4):
//!
//! ```text
//! source ──router (hash shard)──▶ worker 0 ─┐
//!        ──bounded channels─────▶ worker 1 ─┼─▶ merge tree ─▶ leader
//!        (backpressure)          ...        ─┘   (composable sketches)
//! ```
//!
//! Workers own shard-local state (a pass-I WORp sketch, a pass-II
//! collector, or any [`ShardSink`]); the leader merges the per-shard
//! summaries — correctness rests exactly on the paper's composability
//! property, which the worp1/worp2 merge tests verify.

pub mod merge;
pub mod metrics;
pub mod shard;
pub mod spool;

use crate::api::StreamSummary;
use crate::data::Element;
use crate::error::{Error, Result};
use metrics::Metrics;
use shard::Router;
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::Arc;

/// Shard-local consumer state. Every `Send` [`StreamSummary`] is a
/// `ShardSink` via the blanket impl below — samplers, sketches, pass
/// states and `Box<dyn WorSampler>` all flow through [`run_sharded`]
/// without per-type glue. Ad-hoc closures wrap in [`FnSink`].
pub trait ShardSink: Send + 'static {
    /// Process one element routed to this shard.
    fn process(&mut self, e: &Element);

    /// Process a routed micro-batch (defaults to an element loop).
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            self.process(e);
        }
    }
}

impl<S: StreamSummary + Send + 'static> ShardSink for S {
    fn process(&mut self, e: &Element) {
        StreamSummary::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        StreamSummary::process_batch(self, batch)
    }
}

/// Adapter: drive a closure as a [`StreamSummary`] (and hence a
/// [`ShardSink`]) — handy for tests and side-effecting sinks.
pub struct FnSink<F> {
    f: F,
    processed: u64,
}

impl<F: FnMut(&Element)> FnSink<F> {
    /// Wrap a closure.
    pub fn new(f: F) -> Self {
        FnSink { f, processed: 0 }
    }
}

impl<F: FnMut(&Element)> StreamSummary for FnSink<F> {
    fn process(&mut self, e: &Element) {
        (self.f)(e);
        self.processed += 1;
    }

    /// The closure is inherently per-element; the batch path just hoists
    /// the processed counter out of the loop.
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            (self.f)(e);
        }
        self.processed += batch.len() as u64;
    }

    fn size_words(&self) -> usize {
        0
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

/// Pipeline configuration (subset of [`crate::config::PipelineConfig`]
/// relevant to the execution topology).
#[derive(Clone, Copy, Debug)]
pub struct PipelineOpts {
    /// Number of shard workers.
    pub workers: usize,
    /// Elements per micro-batch on the worker channels.
    pub batch: usize,
    /// Channel capacity in batches (the backpressure window).
    pub channel_cap: usize,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts { workers: 4, batch: 4096, channel_cap: 16 }
    }
}

impl PipelineOpts {
    /// Validated constructor.
    pub fn new(workers: usize, batch: usize, channel_cap: usize) -> Result<Self> {
        if workers == 0 || batch == 0 || channel_cap == 0 {
            return Err(Error::Pipeline(
                "workers, batch and channel_cap must be positive".into(),
            ));
        }
        Ok(PipelineOpts { workers, batch, channel_cap })
    }
}

/// Run a sharded pipeline: route `stream` across `opts.workers` workers,
/// each owning the state built by `make(shard_idx)`; returns the
/// per-shard states (in shard order) and the run metrics.
///
/// Routing is by stable key hash, so *all elements of a key land on the
/// same shard* — required for SpaceSaving/TopK composability and good for
/// locality; the hashed-array sketches are insensitive to the split.
pub fn run_sharded<S, F, I>(stream: I, opts: PipelineOpts, make: F) -> Result<(Vec<S>, Arc<Metrics>)>
where
    S: ShardSink,
    F: Fn(usize) -> S,
    I: IntoIterator<Item = Element>,
{
    let metrics = Arc::new(Metrics::default());
    let router = Router::new(opts.workers);

    // §Perf L3-6: workers return drained batch buffers to the router
    // through an unbounded pool channel, so steady-state routing reuses
    // the same `workers × (channel_cap + 2)` buffers instead of allocating
    // one per batch.
    let (pool_tx, pool_rx) = channel::<Vec<Element>>();

    let mut senders: Vec<SyncSender<Vec<Element>>> = Vec::with_capacity(opts.workers);
    let mut handles = Vec::with_capacity(opts.workers);
    for w in 0..opts.workers {
        let (tx, rx): (SyncSender<Vec<Element>>, Receiver<Vec<Element>>) =
            sync_channel(opts.channel_cap);
        senders.push(tx);
        let mut state = make(w);
        let m = Arc::clone(&metrics);
        let pool = pool_tx.clone();
        handles.push(std::thread::spawn(move || {
            for mut batch in rx {
                state.process_batch(&batch);
                m.note_batch(batch.len() as u64);
                batch.clear();
                // router may already have hung up at end-of-stream
                let _ = pool.send(batch);
            }
            state
        }));
    }
    drop(pool_tx); // only worker clones remain

    // router loop on the caller thread
    let mut buffers: Vec<Vec<Element>> = (0..opts.workers)
        .map(|_| Vec::with_capacity(opts.batch))
        .collect();
    for e in stream {
        let w = router.route(e.key);
        buffers[w].push(e);
        if buffers[w].len() == opts.batch {
            let fresh = recycled_buffer(&pool_rx, opts.batch, &metrics);
            let full = std::mem::replace(&mut buffers[w], fresh);
            send_with_backpressure(&senders[w], full, &metrics)?;
        }
    }
    for (w, buf) in buffers.into_iter().enumerate() {
        if !buf.is_empty() {
            send_with_backpressure(&senders[w], buf, &metrics)?;
        }
    }
    drop(senders);

    let mut states = Vec::with_capacity(opts.workers);
    for h in handles {
        states.push(
            h.join()
                .map_err(|_| Error::Pipeline("worker panicked".into()))?,
        );
    }
    Ok((states, metrics))
}

/// Grab a drained buffer from the worker return pool, falling back to a
/// fresh allocation when none has come back yet.
fn recycled_buffer(
    pool: &Receiver<Vec<Element>>,
    cap: usize,
    metrics: &Metrics,
) -> Vec<Element> {
    match pool.try_recv() {
        Ok(buf) => {
            metrics.note_buffer_reuse();
            buf
        }
        Err(_) => Vec::with_capacity(cap),
    }
}

fn send_with_backpressure(
    tx: &SyncSender<Vec<Element>>,
    batch: Vec<Element>,
    metrics: &Metrics,
) -> Result<()> {
    // try_send first so we can count stalls (backpressure events)
    match tx.try_send(batch) {
        Ok(()) => Ok(()),
        Err(std::sync::mpsc::TrySendError::Full(batch)) => {
            metrics.note_stall();
            tx.send(batch)
                .map_err(|_| Error::Pipeline("worker channel closed".into()))
        }
        Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
            Err(Error::Pipeline("worker channel closed".into()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::ZipfStream;
    use std::collections::HashMap;
    use std::sync::Mutex;

    #[test]
    fn all_elements_processed_exactly_once() {
        let n = 100_000u64;
        let stream = ZipfStream::new(1000, 1.0, n, 3);
        let opts = PipelineOpts::new(4, 512, 4).unwrap();
        let counted = Arc::new(Mutex::new(0u64));
        let c2 = Arc::clone(&counted);
        let (states, metrics) = run_sharded(stream, opts, move |_| {
            let c = Arc::clone(&c2);
            FnSink::new(move |_e: &Element| {
                *c.lock().unwrap() += 1;
            })
        })
        .unwrap();
        assert_eq!(metrics.elements(), n);
        assert_eq!(*counted.lock().unwrap(), n);
        let per_shard: u64 = states.iter().map(StreamSummary::processed).sum();
        assert_eq!(per_shard, n);
        assert!(metrics.batches() >= n / 512);
    }

    /// A sink that records per-key sums (for routing-invariance tests).
    /// Implements [`StreamSummary`]; `ShardSink` comes via the blanket.
    struct MapSink {
        sums: HashMap<u64, f64>,
    }

    impl StreamSummary for MapSink {
        fn process(&mut self, e: &Element) {
            *self.sums.entry(e.key).or_insert(0.0) += e.val;
        }

        fn size_words(&self) -> usize {
            2 * self.sums.len()
        }

        fn processed(&self) -> u64 {
            0
        }
    }

    #[test]
    fn key_routing_is_consistent_and_partitioned() {
        let stream: Vec<Element> = ZipfStream::new(200, 1.0, 20_000, 7).collect();
        let truth = crate::data::aggregate(stream.clone());
        let opts = PipelineOpts::new(3, 128, 4).unwrap();
        let (states, _) = run_sharded(stream, opts, |_| MapSink { sums: HashMap::new() })
            .unwrap();
        // every key appears on exactly one shard, with its exact total
        let mut seen: HashMap<u64, f64> = HashMap::new();
        for s in &states {
            for (&k, &v) in &s.sums {
                assert!(!seen.contains_key(&k), "key {k} on two shards");
                seen.insert(k, v);
            }
        }
        assert_eq!(seen.len(), truth.len());
        for (k, v) in truth {
            assert!((seen[&k] - v).abs() < 1e-9);
        }
    }

    #[test]
    fn backpressure_counted_with_tiny_channel() {
        // deterministic-by-construction stall: the single worker parks on
        // its first batch long enough for the router to fill the
        // capacity-1 channel and hit try_send Full (the old version relied
        // on a busy-loop being slower than the router — a seed-red flake
        // on fast or heavily-loaded machines)
        let stream: Vec<Element> = (0..20_000).map(|i| Element::new(i % 16, 1.0)).collect();
        let opts = PipelineOpts::new(1, 64, 1).unwrap();
        let (_, metrics) = run_sharded(stream, opts, |_| {
            let mut slept = false;
            FnSink::new(move |_e: &Element| {
                if !slept {
                    slept = true;
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
            })
        })
        .unwrap();
        assert!(metrics.stalls() > 0, "expected backpressure stalls");
    }

    #[test]
    fn invalid_opts_rejected() {
        assert!(PipelineOpts::new(0, 1, 1).is_err());
        assert!(PipelineOpts::new(1, 0, 1).is_err());
        assert!(PipelineOpts::new(1, 1, 0).is_err());
    }

    #[test]
    fn router_recycles_worker_buffers() {
        // long stream, small batches: after the first channel_cap batches
        // drain, the router must start reusing returned buffers
        let stream: Vec<Element> = (0..100_000u64).map(|i| Element::new(i % 8, 1.0)).collect();
        let opts = PipelineOpts::new(2, 128, 2).unwrap();
        let (_, metrics) = run_sharded(stream, opts, |_| {
            FnSink::new(|_e: &Element| {})
        })
        .unwrap();
        assert!(
            metrics.buffer_reuses() > 0,
            "expected recycled batch buffers, report: {}",
            metrics.report()
        );
    }
}
