//! The L3 streaming pipeline: parallel source partitioning over an
//! unaggregated element stream, composable-sketch merging, and pull-based
//! flow control.
//!
//! Topology (§Perf L3-7 — the router bottleneck is gone):
//!
//! ```text
//!            ┌─ worker 0: scan ▸ hash-filter ▸ SoA block ▸ summary ─┐
//! source ────┼─ worker 1: scan ▸ hash-filter ▸ SoA block ▸ summary ─┼─▶ merge tree ─▶ leader
//! (replayable┼─ ...                                                 ─┘   (composable sketches)
//!  scan)     └─ worker W-1 ...
//! ```
//!
//! Earlier revisions funneled every element through ONE router thread
//! that hash-routed into per-shard `Vec<Element>` batches and pushed them
//! over bounded channels — ingest was capped by that single thread no
//! matter how many workers ran. Now **each worker scans the source
//! itself** ([`ParallelSource`] — a replayable scan, so W workers iterate
//! it concurrently), keeps exactly the elements whose key-hash routes to
//! its own shard, and packs them into one reusable structure-of-arrays
//! [`ElementBlock`] that flows into the summary's columnar
//! [`crate::api::StreamSummary::process_block`] path. No channels, no
//! backpressure stalls, no router — and flow control is inherent (each
//! worker pulls at the rate it can process).
//!
//! The trade is explicit: the cheap scan + route-hash work is
//! **replicated** (every worker walks the whole stream, Θ(N) each,
//! discarding the other shards' elements), while the expensive
//! per-element summary work — sketch updates, candidate tracking — is
//! **divided** W ways. For generator, in-memory and page-cached spool
//! sources the filter costs a couple of ns/element, so removing the
//! serialized route-and-copy stage wins as long as summary work
//! dominates; for cold-disk spools note that W workers each read the
//! whole file.
//!
//! The per-shard element subsequence and its block boundaries are
//! *identical* to what the old router produced (shard w's stream in
//! order, chunked every `opts.batch` elements), and `process_block` is
//! bit-identical to `process_batch`, so the pipeline's output is
//! unchanged — `tests/partition_contract.rs` proves this against a
//! reference implementation of the old router for a grid of topologies.
//!
//! Workers own shard-local state (a pass-I WORp sketch, a pass-II
//! collector, or any [`ShardSink`]); the leader merges the per-shard
//! summaries — correctness rests exactly on the paper's composability
//! property, which the worp1/worp2 merge tests verify.

pub mod merge;
pub mod metrics;
pub mod shard;
pub mod spool;

use crate::api::{Persist, StreamSummary};
use crate::codec::{self, wire};
use crate::data::{Element, ElementBlock};
use crate::error::{Error, Result};
use metrics::Metrics;
use shard::Router;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A source that parallel workers can scan **independently and
/// concurrently**: every call to [`ParallelSource::scan`] yields a fresh
/// iterator over the *same* element sequence. In-memory slices, seeded
/// generators (wrap a closure in [`ScanFn`]) and disk spools
/// ([`spool::SpoolSource`]) all qualify; a one-shot iterator does not —
/// collect it first.
///
/// `Sync` because all workers scan through one shared reference.
pub trait ParallelSource: Sync {
    /// The scan iterator (generic so monomorphized sources pay no
    /// per-element dynamic dispatch in the worker hot loop).
    type Iter<'a>: Iterator<Item = Element>
    where
        Self: 'a;

    /// A fresh pass over the stream.
    fn scan(&self) -> Self::Iter<'_>;
}

impl ParallelSource for [Element] {
    type Iter<'a> = std::iter::Copied<std::slice::Iter<'a, Element>>
    where
        Self: 'a;

    fn scan(&self) -> Self::Iter<'_> {
        self.iter().copied()
    }
}

impl ParallelSource for Vec<Element> {
    type Iter<'a> = std::iter::Copied<std::slice::Iter<'a, Element>>
    where
        Self: 'a;

    fn scan(&self) -> Self::Iter<'_> {
        self.as_slice().scan()
    }
}

impl<T: ParallelSource + ?Sized> ParallelSource for &T {
    type Iter<'a> = T::Iter<'a>
    where
        Self: 'a;

    fn scan(&self) -> Self::Iter<'_> {
        (**self).scan()
    }
}

/// Adapter: any replayable generator closure (`Fn() -> Iterator`) is a
/// [`ParallelSource`] — e.g. `ScanFn(|| ZipfStream::new(n, a, m, seed))`
/// lets W workers each regenerate the stream instead of materializing it.
pub struct ScanFn<F>(pub F);

impl<F, I> ParallelSource for ScanFn<F>
where
    F: Fn() -> I + Sync,
    I: Iterator<Item = Element>,
{
    type Iter<'a> = I
    where
        Self: 'a;

    fn scan(&self) -> Self::Iter<'_> {
        (self.0)()
    }
}

/// Shard-local consumer state. Every `Send` [`StreamSummary`] is a
/// `ShardSink` via the blanket impl below — samplers, sketches, pass
/// states and `Box<dyn WorSampler>` all flow through [`run_sharded`]
/// without per-type glue. Ad-hoc closures wrap in [`FnSink`].
pub trait ShardSink: Send + 'static {
    /// Process one element routed to this shard.
    fn process(&mut self, e: &Element);

    /// Process a routed micro-batch (defaults to an element loop).
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            self.process(e);
        }
    }

    /// Process a routed SoA block (§Perf L3-7) — what the partitioning
    /// workers actually deliver. Defaults to bridging through
    /// [`ShardSink::process_batch`] (mirroring the `StreamSummary`
    /// default), so a direct `ShardSink` implementor that only overrode
    /// `process_batch` keeps seeing its batch path; the blanket impl
    /// forwards to the summary's columnar override.
    fn process_block(&mut self, block: &ElementBlock) {
        self.process_batch(&block.to_elements());
    }
}

impl<S: StreamSummary + Send + 'static> ShardSink for S {
    fn process(&mut self, e: &Element) {
        StreamSummary::process(self, e)
    }

    fn process_batch(&mut self, batch: &[Element]) {
        StreamSummary::process_batch(self, batch)
    }

    fn process_block(&mut self, block: &ElementBlock) {
        StreamSummary::process_block(self, block)
    }
}

/// Adapter: drive a closure as a [`StreamSummary`] (and hence a
/// [`ShardSink`]) — handy for tests and side-effecting sinks.
pub struct FnSink<F> {
    f: F,
    processed: u64,
}

impl<F: FnMut(&Element)> FnSink<F> {
    /// Wrap a closure.
    pub fn new(f: F) -> Self {
        FnSink { f, processed: 0 }
    }
}

impl<F: FnMut(&Element)> StreamSummary for FnSink<F> {
    fn process(&mut self, e: &Element) {
        (self.f)(e);
        self.processed += 1;
    }

    /// The closure is inherently per-element; the batch path just hoists
    /// the processed counter out of the loop.
    fn process_batch(&mut self, batch: &[Element]) {
        for e in batch {
            (self.f)(e);
        }
        self.processed += batch.len() as u64;
    }

    /// Per-element over the SoA columns — no AoS materialization.
    fn process_block(&mut self, block: &ElementBlock) {
        for e in block.iter() {
            (self.f)(&e);
        }
        self.processed += block.len() as u64;
    }

    fn size_words(&self) -> usize {
        0
    }

    fn processed(&self) -> u64 {
        self.processed
    }
}

/// Ingest-boundary guard (mirrors the serving engine's): every worker
/// scan rejects non-finite element values before any summary state is
/// touched. One NaN inside a sketch table would otherwise poison every
/// bucket it lands in and spread through merges — fail the run with a
/// typed error at the boundary instead.
#[inline]
fn reject_non_finite(key: u64, val: f64, at: u64) -> Result<()> {
    if val.is_finite() {
        return Ok(());
    }
    Err(Error::Codec(format!(
        "non-finite element value {val} for key {key} at stream position {at} — the \
         pipeline accepts finite f64 values only"
    )))
}

/// Pipeline configuration (subset of [`crate::config::PipelineConfig`]
/// relevant to the execution topology).
#[derive(Clone, Copy, Debug)]
pub struct PipelineOpts {
    /// Number of shard workers.
    pub workers: usize,
    /// Elements per SoA block a worker processes at a time (and the
    /// checkpoint alignment unit).
    pub batch: usize,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts { workers: 4, batch: 4096 }
    }
}

impl PipelineOpts {
    /// Validated constructor. (The retired channel-based router's
    /// `channel_cap` knob is gone — the scan pipeline has no channels;
    /// config files that still set `pipeline.channel_cap` get a
    /// deprecation note on stderr instead of an error.)
    pub fn new(workers: usize, batch: usize) -> Result<Self> {
        if workers == 0 || batch == 0 {
            return Err(Error::Pipeline(
                "workers and batch must be positive".into(),
            ));
        }
        Ok(PipelineOpts { workers, batch })
    }
}

/// Run a sharded pipeline: `opts.workers` workers each scan `source` in
/// parallel, keep the elements whose key-hash routes to their own shard,
/// and feed them as reusable SoA blocks into the state built by
/// `make(shard_idx)`; returns the per-shard states (in shard order) and
/// the run metrics.
///
/// Routing is by stable key hash, so *all elements of a key land on the
/// same shard* — required for SpaceSaving/TopK composability and good for
/// locality; the hashed-array sketches are insensitive to the split.
/// Shard w sees exactly the subsequence and block boundaries the old
/// single-threaded router delivered, so outputs are unchanged — but the
/// partitioning work itself now runs on all W workers.
pub fn run_sharded<S, F, Src>(
    source: &Src,
    opts: PipelineOpts,
    make: F,
) -> Result<(Vec<S>, Arc<Metrics>)>
where
    S: ShardSink,
    F: Fn(usize) -> S,
    Src: ParallelSource + ?Sized,
{
    let metrics = Arc::new(Metrics::default());
    let router = Router::new(opts.workers);
    let router = &router;
    let mut joined: Vec<Result<S>> = Vec::with_capacity(opts.workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let mut state = make(w);
            let m = Arc::clone(&metrics);
            handles.push(scope.spawn(move || -> Result<S> {
                // ONE block per worker, reused for the whole run: fill,
                // process, clear — steady state allocates nothing
                let mut block = ElementBlock::with_capacity(opts.batch);
                let mut fills = 0u64;
                let mut at = 0u64;
                for e in source.scan() {
                    // checked before the route filter so every worker
                    // rejects the same element at the same position
                    reject_non_finite(e.key, e.val, at)?;
                    at += 1;
                    if router.route(e.key) != w {
                        continue;
                    }
                    block.push(e.key, e.val);
                    if block.len() == opts.batch {
                        state.process_block(&block);
                        m.note_batch(block.len() as u64);
                        fills += 1;
                        if fills > 1 {
                            m.note_buffer_reuse();
                        }
                        block.clear();
                    }
                }
                if !block.is_empty() {
                    state.process_block(&block);
                    m.note_batch(block.len() as u64);
                }
                Ok(state)
            }));
        }
        // join every handle (even after a failure) so a panicking worker
        // can never poison the scope exit
        for h in handles {
            joined.push(match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::Pipeline("worker panicked".into())),
            });
        }
    });
    let mut states = Vec::with_capacity(opts.workers);
    for r in joined {
        states.push(r?);
    }
    Ok((states, metrics))
}

// ---------------------------------------------------------------------------
// Checkpointing

/// When and where a sharded run snapshots its shard states: every
/// `every_batches` full blocks, each worker writes its summary (via
/// [`Persist`]) plus its element cursor to `dir/shard-<w>.worp`,
/// atomically (temp file + rename). A later
/// [`run_sharded_checkpointed`] over the same replayable stream resumes
/// from those files: restored shards skip exactly the elements their
/// snapshot already covers, so the finished run is bit-identical to an
/// uninterrupted one (worker block boundaries realign because snapshots
/// are taken on block edges).
///
/// Guardrails on resume: the file's topology stamp (shard / workers /
/// batch) and its summary fingerprint must match the current run's
/// prototype — stale snapshots from a different seed, shape, method or
/// pass fail with [`Error::Incompatible`] instead of silently mixing
/// runs. What the fingerprint cannot cover is the *stream itself*:
/// resuming over a different input stream with an identical
/// configuration is undetectable, so keep one snapshot directory per
/// (config, stream) pair.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    every_batches: u64,
    dir: PathBuf,
}

impl CheckpointPolicy {
    /// Snapshot every `every_batches` worker blocks into `dir`.
    pub fn new(every_batches: u64, dir: impl Into<PathBuf>) -> Result<Self> {
        if every_batches == 0 {
            return Err(Error::Pipeline(
                "checkpoint interval must be positive (batches)".into(),
            ));
        }
        Ok(CheckpointPolicy { every_batches, dir: dir.into() })
    }

    /// Blocks between snapshots.
    pub fn every_batches(&self) -> u64 {
        self.every_batches
    }

    /// Snapshot directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot file of one shard.
    pub fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard-{shard}.worp"))
    }

    /// A per-pass sub-policy (multi-pass drivers keep each pass's
    /// snapshots in their own subdirectory so they cannot collide).
    pub fn for_pass(&self, pass: usize) -> CheckpointPolicy {
        CheckpointPolicy {
            every_batches: self.every_batches,
            dir: self.dir.join(format!("pass-{pass}")),
        }
    }
}

/// Checkpoint-file topology stamp: shard index, worker count and batch
/// size. Resume validates all three — a snapshot taken under a different
/// topology routes (or blocks) differently and must not be continued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct CheckpointMeta {
    shard: u16,
    workers: u16,
    batch: u32,
}

/// Byte length of the checkpoint-file header fields covered by its
/// checksum (magic, version, topology stamp, element cursor).
const CHECKPOINT_HEADER_LEN: usize = 22;

/// Write `dir/shard-<w>.worp` atomically: `WCKP` magic, version, the
/// topology stamp, the shard's element cursor, a checksum over those
/// header bytes (the summary envelope carries its own — so *every* byte
/// of the file is covered by one of the two), then the summary's
/// [`Persist`] envelope.
fn write_checkpoint<S: Persist>(
    path: &Path,
    meta: CheckpointMeta,
    elements: u64,
    state: &S,
) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&wire::CHECKPOINT_MAGIC);
    wire::put_u16(&mut buf, wire::VERSION);
    wire::put_u16(&mut buf, meta.shard);
    wire::put_u16(&mut buf, meta.workers);
    wire::put_u32(&mut buf, meta.batch);
    wire::put_u64(&mut buf, elements);
    debug_assert_eq!(buf.len(), CHECKPOINT_HEADER_LEN);
    let checksum =
        crate::util::hashing::hash_bytes(codec::CHECKSUM_SEED, &buf[..CHECKPOINT_HEADER_LEN]);
    wire::put_u64(&mut buf, checksum);
    state.encode_into(&mut buf);
    let tmp = path.with_extension("worp.tmp");
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        // flush to stable storage *before* the rename becomes visible —
        // otherwise a power loss can leave a renamed-but-truncated
        // snapshot that wedges every later resume
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a shard snapshot, or `Ok(None)` when the file does not exist.
/// Returns the state, its element cursor, and the envelope's type tag +
/// fingerprint (for the caller's compatibility check against the current
/// prototype). Corrupt bytes surface as [`Error::Codec`]; a topology
/// mismatch as [`Error::Incompatible`] — never a silent wrong resume.
fn load_checkpoint<S: Persist>(
    path: &Path,
    meta: CheckpointMeta,
) -> Result<Option<(S, u64, (u16, u64))>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut r = wire::Reader::new(&bytes);
    let magic = r.take(4)?;
    if magic != wire::CHECKPOINT_MAGIC {
        return Err(Error::Codec(format!(
            "bad checkpoint magic {magic:02x?} in {}",
            path.display()
        )));
    }
    let version = r.u16()?;
    if version != wire::VERSION {
        return Err(Error::Codec(format!(
            "unsupported checkpoint version {version} in {}",
            path.display()
        )));
    }
    let found = CheckpointMeta { shard: r.u16()?, workers: r.u16()?, batch: r.u32()? };
    let elements = r.u64()?;
    let checksum = r.u64()?;
    if crate::util::hashing::hash_bytes(codec::CHECKSUM_SEED, &bytes[..CHECKPOINT_HEADER_LEN])
        != checksum
    {
        return Err(Error::Codec(format!(
            "checkpoint header checksum mismatch in {} — the topology stamp or element \
             cursor was corrupted",
            path.display()
        )));
    }
    if found != meta {
        return Err(Error::Incompatible(format!(
            "checkpoint {} was taken under a different topology \
             (shard {}/{} batch {}, this run is shard {}/{} batch {}) — \
             remove the snapshot directory or rerun with the original topology",
            path.display(),
            found.shard,
            found.workers,
            found.batch,
            meta.shard,
            meta.workers,
            meta.batch
        )));
    }
    let envelope = r.rest();
    let state = S::decode(envelope)?;
    let header = codec::peek_header(envelope)?;
    Ok(Some((state, elements, header)))
}

/// [`run_sharded`] with crash recovery: workers snapshot their shard
/// state to `policy.dir()` every `policy.every_batches()` full blocks,
/// and a rerun over the same (replayable) stream resumes from whatever
/// snapshots exist — restored shards skip the elements already covered
/// (each worker counts its own shard's elements during its scan), the
/// rest of the stream flows as usual, and the result is bit-identical to
/// an uninterrupted run. [`Metrics::snapshots`] / [`Metrics::restores`]
/// count both sides.
pub fn run_sharded_checkpointed<S, F, Src>(
    source: &Src,
    opts: PipelineOpts,
    policy: &CheckpointPolicy,
    make: F,
) -> Result<(Vec<S>, Arc<Metrics>)>
where
    S: ShardSink + Persist,
    F: Fn(usize) -> S,
    Src: ParallelSource + ?Sized,
{
    if opts.workers > u16::MAX as usize || opts.batch > u32::MAX as usize {
        return Err(Error::Pipeline(
            "checkpointing supports at most 2^16 workers and 2^32-element batches".into(),
        ));
    }
    std::fs::create_dir_all(policy.dir())?;
    let metrics = Arc::new(Metrics::default());
    let router = Router::new(opts.workers);
    let router = &router;

    // restore (or build) every shard's state on the caller thread first,
    // so stale-snapshot incompatibilities fail before any thread spawns
    let mut restored: Vec<(S, u64, CheckpointMeta, PathBuf)> = Vec::with_capacity(opts.workers);
    for w in 0..opts.workers {
        let meta = CheckpointMeta {
            shard: w as u16,
            workers: opts.workers as u16,
            batch: opts.batch as u32,
        };
        let path = policy.shard_path(w);
        let proto = make(w);
        let (state, done) = match load_checkpoint::<S>(&path, meta)? {
            Some((s, done, (tag, fp))) => {
                // a stale snapshot (different seed/config/method/pass)
                // must not silently resume into this run: the restored
                // envelope's type tag + fingerprint have to match what
                // the current prototype would persist as. The encode is
                // deliberately per-shard — `make(w)` may construct
                // shard-dependent prototypes, so each snapshot is checked
                // against *its own* shard's prototype (cost is only paid
                // on restore)
                let mut pb = Vec::new();
                proto.encode_into(&mut pb);
                let (ptag, pfp) = codec::peek_header(&pb)?;
                if (tag, fp) != (ptag, pfp) {
                    return Err(Error::Incompatible(format!(
                        "checkpoint {} holds a {} summary with fingerprint {fp:#018x}, but \
                         this run's configuration expects {} with {pfp:#018x} — stale \
                         snapshot directory? remove it or rerun with the original config",
                        path.display(),
                        codec::tag_name(tag),
                        codec::tag_name(ptag)
                    )));
                }
                metrics.note_restore();
                (s, done)
            }
            None => (proto, 0),
        };
        restored.push((state, done, meta, path));
    }

    let every = policy.every_batches();
    let mut joined: Vec<Result<S>> = Vec::with_capacity(opts.workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(opts.workers);
        for (w, (mut state, done, meta, path)) in restored.into_iter().enumerate() {
            let m = Arc::clone(&metrics);
            handles.push(scope.spawn(move || -> Result<S> {
                let mut block = ElementBlock::with_capacity(opts.batch);
                // elements a restored snapshot already covers are skipped;
                // the first fresh element lands on the same block boundary
                // the interrupted run used (snapshots land on block edges)
                let mut skip = done;
                let mut elements = done;
                let mut batches = 0u64;
                let mut at = 0u64;
                for e in source.scan() {
                    reject_non_finite(e.key, e.val, at)?;
                    at += 1;
                    if router.route(e.key) != w {
                        continue;
                    }
                    if skip > 0 {
                        skip -= 1;
                        continue;
                    }
                    block.push(e.key, e.val);
                    if block.len() == opts.batch {
                        state.process_block(&block);
                        m.note_batch(block.len() as u64);
                        elements += block.len() as u64;
                        batches += 1;
                        if batches > 1 {
                            m.note_buffer_reuse();
                        }
                        // only snapshot on *full*-block edges: a partial
                        // block is an end-of-stream flush, and a cursor
                        // that is not a multiple of the batch size would
                        // misalign the resumed run's block boundaries
                        // against an uninterrupted one (block-boundary-
                        // sensitive summaries like worp1 would then
                        // diverge from the bit-identical guarantee)
                        if batches % every == 0 {
                            write_checkpoint(&path, meta, elements, &state)?;
                            m.note_snapshot();
                        }
                        block.clear();
                    }
                }
                // the stream ran dry while this restored shard was still
                // owed skipped elements: the stream is shorter than (so
                // different from) the one the snapshot was taken over —
                // fail loudly like every other stale resume instead of
                // returning a state the given stream never produced
                if skip > 0 {
                    return Err(Error::Incompatible(format!(
                        "stream ended while shard {w} still owed {skip} snapshot-covered \
                         elements — the resumed stream is shorter than the one the \
                         checkpoint was taken over; remove the snapshot directory or \
                         supply the original stream"
                    )));
                }
                if !block.is_empty() {
                    state.process_block(&block);
                    m.note_batch(block.len() as u64);
                }
                Ok(state)
            }));
        }
        for h in handles {
            joined.push(match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::Pipeline("worker panicked".into())),
            });
        }
    });
    let mut states = Vec::with_capacity(opts.workers);
    for r in joined {
        states.push(r?);
    }
    Ok((states, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::ZipfStream;
    use std::collections::HashMap;
    use std::sync::Mutex;

    #[test]
    fn all_elements_processed_exactly_once() {
        let n = 100_000u64;
        // a generator source: every worker regenerates (replays) the
        // stream instead of sharing a materialized copy
        let source = ScanFn(move || ZipfStream::new(1000, 1.0, n, 3));
        let opts = PipelineOpts::new(4, 512).unwrap();
        let counted = Arc::new(Mutex::new(0u64));
        let c2 = Arc::clone(&counted);
        let (states, metrics) = run_sharded(&source, opts, move |_| {
            let c = Arc::clone(&c2);
            FnSink::new(move |_e: &Element| {
                *c.lock().unwrap() += 1;
            })
        })
        .unwrap();
        assert_eq!(metrics.elements(), n);
        assert_eq!(*counted.lock().unwrap(), n);
        let per_shard: u64 = states.iter().map(StreamSummary::processed).sum();
        assert_eq!(per_shard, n);
        assert!(metrics.batches() >= n / 512);
    }

    /// A sink that records per-key sums (for routing-invariance tests).
    /// Implements [`StreamSummary`]; `ShardSink` comes via the blanket.
    struct MapSink {
        sums: HashMap<u64, f64>,
    }

    impl StreamSummary for MapSink {
        fn process(&mut self, e: &Element) {
            *self.sums.entry(e.key).or_insert(0.0) += e.val;
        }

        fn size_words(&self) -> usize {
            2 * self.sums.len()
        }

        fn processed(&self) -> u64 {
            0
        }
    }

    #[test]
    fn key_routing_is_consistent_and_partitioned() {
        let stream: Vec<Element> = ZipfStream::new(200, 1.0, 20_000, 7).collect();
        let truth = crate::data::aggregate(stream.clone());
        let opts = PipelineOpts::new(3, 128).unwrap();
        let (states, _) = run_sharded(&stream, opts, |_| MapSink { sums: HashMap::new() })
            .unwrap();
        // every key appears on exactly one shard, with its exact total
        let mut seen: HashMap<u64, f64> = HashMap::new();
        for s in &states {
            for (&k, &v) in &s.sums {
                assert!(!seen.contains_key(&k), "key {k} on two shards");
                seen.insert(k, v);
            }
        }
        assert_eq!(seen.len(), truth.len());
        for (k, v) in truth {
            assert!((seen[&k] - v).abs() < 1e-9);
        }
    }

    #[test]
    fn slow_worker_does_not_block_the_others() {
        // pull-based flow control: worker 0 sleeps on its first element
        // while the other worker must still finish its whole shard — the
        // run completes and counts every element exactly once (the old
        // router would have seen backpressure stalls here; now there is
        // no shared channel to stall on)
        let stream: Vec<Element> = (0..20_000).map(|i| Element::new(i % 16, 1.0)).collect();
        let opts = PipelineOpts::new(2, 64).unwrap();
        let (states, metrics) = run_sharded(&stream, opts, |w| {
            let mut slept = false;
            FnSink::new(move |_e: &Element| {
                if w == 0 && !slept {
                    slept = true;
                    std::thread::sleep(std::time::Duration::from_millis(100));
                }
            })
        })
        .unwrap();
        assert_eq!(metrics.elements(), 20_000);
        let per_shard: u64 = states.iter().map(StreamSummary::processed).sum();
        assert_eq!(per_shard, 20_000);
    }

    #[test]
    fn invalid_opts_rejected() {
        assert!(PipelineOpts::new(0, 1).is_err());
        assert!(PipelineOpts::new(1, 0).is_err());
        assert!(PipelineOpts::new(1, 1).is_ok());
    }

    #[test]
    fn workers_reuse_their_blocks() {
        // long stream, small blocks: after each worker's first fill, the
        // same SoA allocation must be recycled for every later block
        let stream: Vec<Element> = (0..100_000u64).map(|i| Element::new(i % 8, 1.0)).collect();
        let opts = PipelineOpts::new(2, 128).unwrap();
        let (_, metrics) = run_sharded(&stream, opts, |_| {
            FnSink::new(|_e: &Element| {})
        })
        .unwrap();
        assert!(
            metrics.buffer_reuses() > 0,
            "expected recycled SoA blocks, report: {}",
            metrics.report()
        );
        // every full block beyond each worker's first is a reuse
        assert!(metrics.buffer_reuses() >= metrics.batches().saturating_sub(2 * 2));
    }

    #[test]
    fn scan_sources_replay_identically() {
        let v: Vec<Element> = (0..100u64).map(|i| Element::new(i, i as f64)).collect();
        let a: Vec<Element> = (&v).scan().collect();
        let b: Vec<Element> = v.scan().collect();
        assert_eq!(a, b);
        let f = ScanFn(|| (0..50u64).map(|i| Element::new(i, 1.0)));
        assert_eq!(f.scan().count(), 50);
        assert_eq!(f.scan().count(), 50, "generator sources must replay");
    }
}
