//! Pipeline run metrics: lock-free counters shared between the scan
//! workers and the leader. Reported by the launcher and the benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counters for one pipeline run.
#[derive(Debug)]
pub struct Metrics {
    elements: AtomicU64,
    batches: AtomicU64,
    merges: AtomicU64,
    buffer_reuses: AtomicU64,
    snapshots: AtomicU64,
    restores: AtomicU64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            elements: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            merges: AtomicU64::new(0),
            buffer_reuses: AtomicU64::new(0),
            snapshots: AtomicU64::new(0),
            restores: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    /// Record a processed batch of `n` elements.
    pub fn note_batch(&self, n: u64) {
        self.elements.fetch_add(n, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a sketch merge.
    pub fn note_merge(&self) {
        self.merges.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a recycled micro-batch buffer (router reused a worker's
    /// drained allocation instead of allocating a fresh one).
    pub fn note_buffer_reuse(&self) {
        self.buffer_reuses.fetch_add(1, Ordering::Relaxed);
    }

    /// Total elements processed by workers.
    pub fn elements(&self) -> u64 {
        self.elements.load(Ordering::Relaxed)
    }

    /// Total batches processed.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Merges performed.
    pub fn merges(&self) -> u64 {
        self.merges.load(Ordering::Relaxed)
    }

    /// Micro-batch buffers recycled through the worker return pool.
    pub fn buffer_reuses(&self) -> u64 {
        self.buffer_reuses.load(Ordering::Relaxed)
    }

    /// Record a shard-state snapshot written to the checkpoint directory.
    pub fn note_snapshot(&self) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a shard state restored from a checkpoint at startup.
    pub fn note_restore(&self) {
        self.restores.fetch_add(1, Ordering::Relaxed);
    }

    /// Checkpoint snapshots written by workers.
    pub fn snapshots(&self) -> u64 {
        self.snapshots.load(Ordering::Relaxed)
    }

    /// Shard states restored from checkpoints.
    pub fn restores(&self) -> u64 {
        self.restores.load(Ordering::Relaxed)
    }

    /// Wall-clock since construction.
    pub fn elapsed(&self) -> std::time::Duration {
        self.started.elapsed()
    }

    /// Elements per second over the run so far.
    pub fn throughput(&self) -> f64 {
        let secs = self.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.elements() as f64 / secs
        } else {
            0.0
        }
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "elements={} batches={} merges={} buffer_reuses={} snapshots={} restores={} elapsed={:.3}s throughput={:.2}M/s",
            self.elements(),
            self.batches(),
            self.merges(),
            self.buffer_reuses(),
            self.snapshots(),
            self.restores(),
            self.elapsed().as_secs_f64(),
            self.throughput() / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.note_batch(10);
        m.note_batch(5);
        m.note_merge();
        m.note_buffer_reuse();
        m.note_snapshot();
        m.note_snapshot();
        m.note_restore();
        assert_eq!(m.elements(), 15);
        assert_eq!(m.batches(), 2);
        assert_eq!(m.merges(), 1);
        assert_eq!(m.buffer_reuses(), 1);
        assert_eq!(m.snapshots(), 2);
        assert_eq!(m.restores(), 1);
        assert!(m.report().contains("elements=15"));
        assert!(m.report().contains("buffer_reuses=1"));
        assert!(m.report().contains("snapshots=2"));
    }

    #[test]
    fn throughput_positive_after_work() {
        let m = Metrics::default();
        m.note_batch(1000);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.throughput() > 0.0);
    }
}
