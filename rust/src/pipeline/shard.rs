//! Key→shard routing. Stable hash routing keeps each key on one worker
//! (required by the counter/top-k structures; harmless for hashed-array
//! sketches) and supports rebalancing to a different worker count via
//! deterministic re-hash.

use crate::util::hashing::{hash64, hash_bytes_fast};

/// Stable hash router over `n` shards.
#[derive(Clone, Debug)]
pub struct Router {
    n: usize,
    seed: u64,
}

impl Router {
    /// Router over `n` shards with the default routing seed.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Router { n, seed: 0x5A4D_0C95 }
    }

    /// Router with an explicit seed (rebalancing epochs use new seeds).
    pub fn with_seed(n: usize, seed: u64) -> Self {
        assert!(n > 0);
        Router { n, seed }
    }

    /// Shard of a key.
    #[inline]
    pub fn route(&self, key: u64) -> usize {
        (((hash64(self.seed, key) as u128) * (self.n as u128)) >> 64) as usize
    }

    /// Shard of a raw byte key — the string-keyed ingest fan-out
    /// (partition raw records *before* the numeric
    /// [`crate::util::hashing::hash_str`] domain mapping). Routing
    /// decisions are never persisted, so this path uses the 8-byte-chunked
    /// [`hash_bytes_fast`] rather than the codec-critical byte-at-a-time
    /// `hash_bytes`; only the assignment's distribution matters, and the
    /// hashing unit tests hold both to the same balance bar.
    #[inline]
    pub fn route_bytes(&self, key: &[u8]) -> usize {
        (((hash_bytes_fast(self.seed, key) as u128) * (self.n as u128)) >> 64) as usize
    }

    /// Shard of a string key (see [`Router::route_bytes`]).
    #[inline]
    pub fn route_str(&self, key: &str) -> usize {
        self.route_bytes(key.as_bytes())
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.n
    }

    /// Expected fraction of keys that move when resizing `self.n → m`
    /// with plain range-partition re-hash (reported by rebalancing
    /// diagnostics; multiply-shift keeps moves ≈ |1 − n/m| of keys when
    /// growing).
    pub fn resize_move_fraction(&self, m: usize) -> f64 {
        if m == self.n {
            0.0
        } else if m > self.n {
            1.0 - self.n as f64 / m as f64
        } else {
            1.0 - m as f64 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_stable_and_in_range() {
        let r = Router::new(7);
        for k in 0..10_000u64 {
            let s = r.route(k);
            assert!(s < 7);
            assert_eq!(s, r.route(k));
        }
    }

    #[test]
    fn routing_balanced() {
        let r = Router::new(8);
        let mut counts = [0u32; 8];
        for k in 0..80_000u64 {
            counts[r.route(k)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn byte_routing_stable_in_range_and_balanced() {
        let r = Router::new(8);
        let mut counts = [0u32; 8];
        for k in 0..40_000u64 {
            let key = format!("query:{k}");
            let s = r.route_str(&key);
            assert!(s < 8);
            assert_eq!(s, r.route_bytes(key.as_bytes()), "str/bytes must agree");
            assert_eq!(s, r.route_str(&key), "routing must be stable");
            counts[s] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 5_000.0).abs() < 400.0, "{counts:?}");
        }
    }

    #[test]
    fn different_seeds_route_differently() {
        let a = Router::with_seed(4, 1);
        let b = Router::with_seed(4, 2);
        let moved = (0..1000u64).filter(|&k| a.route(k) != b.route(k)).count();
        assert!(moved > 500);
    }

    #[test]
    fn move_fraction_monotone() {
        let r = Router::new(4);
        assert_eq!(r.resize_move_fraction(4), 0.0);
        assert!((r.resize_move_fraction(8) - 0.5).abs() < 1e-12);
        assert!((r.resize_move_fraction(2) - 0.5).abs() < 1e-12);
    }
}
