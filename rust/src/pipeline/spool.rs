//! Disk-spooled stream source: two-pass methods must replay the stream,
//! but materializing it in memory defeats the point of sketching for
//! large inputs. `SpoolSource` writes elements to a binary temp file
//! (16 bytes per element) on the first pass and replays from disk on the
//! second — constant memory, sequential I/O.
//!
//! §Perf L3-7: reads and writes go through the codec's SoA element-record
//! helpers. The writer serializes whole [`ElementBlock`]s
//! ([`wire::put_block`]); the reader ([`SpoolScan`]) pulls runs of
//! records off disk in one `read_exact` and parses them into a reusable
//! SoA block ([`wire::read_block_into`]) — thousands of elements per
//! syscall-ish boundary instead of one 16-byte `read_exact` per element.
//! `SpoolSource` is a [`ParallelSource`]: every worker opens its own
//! reader, so W workers replay the file concurrently — each reads the
//! *full* file and keeps only its shard (cheap once the file is
//! page-cached; budget W× read I/O for cold files).

use crate::codec::wire;
use crate::coordinator::StreamSource;
use crate::data::{Element, ElementBlock};
use crate::error::Result;
use crate::pipeline::ParallelSource;
use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide spool counter: two spools created back-to-back (or on
/// parallel threads) get distinct names. The old scheme used a
/// `SystemTime` nanosecond stamp, which collides whenever the clock's
/// granularity is coarser than the spool rate — two spools in the same
/// tick silently shared (and then double-deleted) one file.
static SPOOL_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Elements buffered per disk read/write run (64 KiB of records).
const SPOOL_RUN: usize = 4096;

/// A stream spooled to a binary file.
pub struct SpoolSource {
    path: PathBuf,
    len: u64,
    /// Remove the file on drop (off for user-provided paths).
    owned: bool,
}

impl SpoolSource {
    /// Spool an element stream into `dir` (created if needed); returns the
    /// replayable source. Records are the shared 16-byte element layout of
    /// [`wire::element_to_bytes`] — the same endianness helpers the
    /// persistence codec uses — written one SoA block at a time.
    pub fn create<I: IntoIterator<Item = Element>>(
        dir: &std::path::Path,
        stream: I,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!(
            "worp-spool-{}-{}.bin",
            std::process::id(),
            SPOOL_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let mut w = BufWriter::new(File::create(&path)?);
        let mut len = 0u64;
        let mut block = ElementBlock::with_capacity(SPOOL_RUN);
        let mut bytes = Vec::with_capacity(16 * SPOOL_RUN);
        for e in stream {
            block.push(e.key, e.val);
            if block.len() == SPOOL_RUN {
                bytes.clear();
                wire::put_block(&mut bytes, &block);
                w.write_all(&bytes)?;
                len += block.len() as u64;
                block.clear();
            }
        }
        if !block.is_empty() {
            bytes.clear();
            wire::put_block(&mut bytes, &block);
            w.write_all(&bytes)?;
            len += block.len() as u64;
        }
        w.flush()?;
        Ok(SpoolSource { path, len, owned: true })
    }

    /// Number of spooled elements.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when no elements were spooled.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// On-disk size in bytes.
    pub fn bytes(&self) -> u64 {
        16 * self.len
    }

    /// Path of the spool file.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    fn open_scan(&self) -> SpoolScan {
        SpoolScan {
            file: File::open(&self.path).expect("spool file vanished"),
            remaining: self.len,
            buf: vec![0u8; 16 * SPOOL_RUN],
            block: ElementBlock::with_capacity(SPOOL_RUN),
            pos: 0,
        }
    }
}

impl Drop for SpoolSource {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Block-buffered iterator over a spool file (§Perf L3-7): refills a
/// reusable SoA block from one bulk `read_exact` per `SPOOL_RUN`
/// elements, then yields from the dense columns.
pub struct SpoolScan {
    file: File,
    remaining: u64,
    /// Raw record bytes of the current run (reused across refills).
    buf: Vec<u8>,
    /// Parsed SoA columns of the current run (reused across refills).
    block: ElementBlock,
    /// Cursor into `block`.
    pos: usize,
}

impl SpoolScan {
    fn refill(&mut self) -> Option<()> {
        if self.remaining == 0 {
            return None;
        }
        let n = (self.remaining as usize).min(SPOOL_RUN);
        // a mid-scan read failure (disk error, file truncated/replaced
        // under us) must be LOUD: with W workers scanning concurrently, a
        // silent early end-of-stream would feed one shard a prefix and
        // produce a quietly wrong merged summary. The panic surfaces as a
        // pipeline "worker panicked" error instead.
        self.file
            .read_exact(&mut self.buf[..16 * n])
            .unwrap_or_else(|e| {
                panic!("spool read failed mid-scan ({} records left): {e}", self.remaining)
            });
        self.block.clear();
        wire::read_block_into(&self.buf[..16 * n], &mut self.block)
            .expect("spool run length is a multiple of 16 by construction");
        self.remaining -= n as u64;
        self.pos = 0;
        Some(())
    }
}

impl Iterator for SpoolScan {
    type Item = Element;

    fn next(&mut self) -> Option<Element> {
        if self.pos == self.block.len() {
            self.refill()?;
        }
        let e = self.block.get(self.pos);
        self.pos += 1;
        Some(e)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.remaining as usize + (self.block.len() - self.pos);
        (left, Some(left))
    }
}

impl ParallelSource for SpoolSource {
    type Iter<'a> = SpoolScan
    where
        Self: 'a;

    fn scan(&self) -> SpoolScan {
        self.open_scan()
    }
}

impl StreamSource for SpoolSource {
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        Box::new(self.open_scan())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::ZipfStream;

    fn tmp() -> PathBuf {
        std::env::temp_dir().join("worp_spool_tests")
    }

    #[test]
    fn roundtrip_exact() {
        let elems: Vec<Element> = ZipfStream::new(100, 1.0, 10_000, 3).collect();
        let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
        assert_eq!(spool.len(), 10_000);
        assert_eq!(spool.bytes(), 160_000);
        let replay: Vec<Element> = spool.stream().collect();
        assert_eq!(replay, elems);
        // second replay identical (replayable contract)
        let replay2: Vec<Element> = spool.stream().collect();
        assert_eq!(replay2, elems);
        // the ParallelSource scan sees the same sequence
        let replay3: Vec<Element> = spool.scan().collect();
        assert_eq!(replay3, elems);
    }

    #[test]
    fn run_boundaries_roundtrip() {
        // exercise streams around the SPOOL_RUN refill boundary
        for n in [0usize, 1, SPOOL_RUN - 1, SPOOL_RUN, SPOOL_RUN + 1, 2 * SPOOL_RUN + 7] {
            let elems: Vec<Element> =
                (0..n as u64).map(|i| Element::new(i, i as f64 * 0.5)).collect();
            let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
            let replay: Vec<Element> = spool.scan().collect();
            assert_eq!(replay, elems, "n={n}");
        }
    }

    #[test]
    fn parallel_scans_are_independent() {
        let elems: Vec<Element> = (0..10_000u64).map(|i| Element::new(i, 1.0)).collect();
        let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
        std::thread::scope(|scope| {
            let spool = &spool;
            let handles: Vec<_> = (0..4)
                .map(|_| scope.spawn(move || spool.scan().count()))
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), 10_000);
            }
        });
    }

    #[test]
    fn file_removed_on_drop() {
        let spool = SpoolSource::create(&tmp(), vec![Element::new(1, 2.0)]).unwrap();
        let path = spool.path().to_path_buf();
        assert!(path.exists());
        drop(spool);
        assert!(!path.exists());
    }

    #[test]
    fn two_pass_over_spool_matches_vec_source() {
        use crate::coordinator::{Coordinator, VecSource};
        use crate::pipeline::PipelineOpts;
        use crate::sampler::SamplerConfig;

        let elems: Vec<Element> =
            crate::data::zipf::zipf_exact_stream(300, 1.3, 1e4, 2, 9);
        let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
        let cfg = SamplerConfig::new(1.0, 12)
            .with_seed(5)
            .with_domain(300)
            .with_sketch_shape(7, 1024);
        let c = Coordinator::new(cfg, PipelineOpts::new(2, 128).unwrap());
        let (a, _) = c.two_pass(&spool).unwrap();
        let (b, _) = c.two_pass(&VecSource(elems)).unwrap();
        assert_eq!(a.keys(), b.keys());
    }

    #[test]
    fn back_to_back_spools_never_collide() {
        // the old SystemTime naming collided within one clock tick; the
        // counter naming must hand every spool a distinct live file
        let spools: Vec<SpoolSource> = (0..8)
            .map(|i| {
                SpoolSource::create(&tmp(), vec![Element::new(i, i as f64)]).unwrap()
            })
            .collect();
        let mut paths: Vec<PathBuf> = spools.iter().map(|s| s.path().to_path_buf()).collect();
        paths.sort();
        paths.dedup();
        assert_eq!(paths.len(), 8, "spool paths collided");
        for (i, s) in spools.iter().enumerate() {
            let replay: Vec<Element> = s.stream().collect();
            assert_eq!(replay, vec![Element::new(i as u64, i as f64)]);
        }
    }

    #[test]
    fn empty_spool() {
        let spool = SpoolSource::create(&tmp(), Vec::<Element>::new()).unwrap();
        assert!(spool.is_empty());
        assert_eq!(spool.stream().count(), 0);
    }
}
