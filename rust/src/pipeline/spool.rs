//! Disk-spooled stream source: two-pass methods must replay the stream,
//! but materializing it in memory defeats the point of sketching for
//! large inputs. `SpoolSource` writes elements to a binary temp file
//! (16 bytes per element) on the first pass and replays from disk on the
//! second — constant memory, sequential I/O.

use crate::codec::wire;
use crate::coordinator::StreamSource;
use crate::data::Element;
use crate::error::Result;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide spool counter: two spools created back-to-back (or on
/// parallel threads) get distinct names. The old scheme used a
/// `SystemTime` nanosecond stamp, which collides whenever the clock's
/// granularity is coarser than the spool rate — two spools in the same
/// tick silently shared (and then double-deleted) one file.
static SPOOL_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A stream spooled to a binary file.
pub struct SpoolSource {
    path: PathBuf,
    len: u64,
    /// Remove the file on drop (off for user-provided paths).
    owned: bool,
}

impl SpoolSource {
    /// Spool an element stream into `dir` (created if needed); returns the
    /// replayable source. Records are the shared 16-byte element layout of
    /// [`wire::element_to_bytes`] — the same endianness helpers the
    /// persistence codec uses.
    pub fn create<I: IntoIterator<Item = Element>>(
        dir: &std::path::Path,
        stream: I,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!(
            "worp-spool-{}-{}.bin",
            std::process::id(),
            SPOOL_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let mut w = BufWriter::new(File::create(&path)?);
        let mut len = 0u64;
        for e in stream {
            w.write_all(&wire::element_to_bytes(&e))?;
            len += 1;
        }
        w.flush()?;
        Ok(SpoolSource { path, len, owned: true })
    }

    /// Number of spooled elements.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when no elements were spooled.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// On-disk size in bytes.
    pub fn bytes(&self) -> u64 {
        16 * self.len
    }

    /// Path of the spool file.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for SpoolSource {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Iterator over a spool file.
pub struct SpoolIter {
    reader: BufReader<File>,
    remaining: u64,
}

impl Iterator for SpoolIter {
    type Item = Element;

    fn next(&mut self) -> Option<Element> {
        if self.remaining == 0 {
            return None;
        }
        let mut rec = [0u8; 16];
        self.reader.read_exact(&mut rec).ok()?;
        self.remaining -= 1;
        Some(wire::element_from_bytes(&rec))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining as usize, Some(self.remaining as usize))
    }
}

impl StreamSource for SpoolSource {
    fn stream(&self) -> Box<dyn Iterator<Item = Element> + Send + '_> {
        let file = File::open(&self.path).expect("spool file vanished");
        Box::new(SpoolIter { reader: BufReader::new(file), remaining: self.len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::ZipfStream;

    fn tmp() -> PathBuf {
        std::env::temp_dir().join("worp_spool_tests")
    }

    #[test]
    fn roundtrip_exact() {
        let elems: Vec<Element> = ZipfStream::new(100, 1.0, 10_000, 3).collect();
        let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
        assert_eq!(spool.len(), 10_000);
        assert_eq!(spool.bytes(), 160_000);
        let replay: Vec<Element> = spool.stream().collect();
        assert_eq!(replay, elems);
        // second replay identical (replayable contract)
        let replay2: Vec<Element> = spool.stream().collect();
        assert_eq!(replay2, elems);
    }

    #[test]
    fn file_removed_on_drop() {
        let spool = SpoolSource::create(&tmp(), vec![Element::new(1, 2.0)]).unwrap();
        let path = spool.path().to_path_buf();
        assert!(path.exists());
        drop(spool);
        assert!(!path.exists());
    }

    #[test]
    fn two_pass_over_spool_matches_vec_source() {
        use crate::coordinator::{Coordinator, VecSource};
        use crate::pipeline::PipelineOpts;
        use crate::sampler::SamplerConfig;

        let elems: Vec<Element> =
            crate::data::zipf::zipf_exact_stream(300, 1.3, 1e4, 2, 9);
        let spool = SpoolSource::create(&tmp(), elems.iter().copied()).unwrap();
        let cfg = SamplerConfig::new(1.0, 12)
            .with_seed(5)
            .with_domain(300)
            .with_sketch_shape(7, 1024);
        let c = Coordinator::new(cfg, PipelineOpts::new(2, 128, 4).unwrap());
        let (a, _) = c.two_pass(&spool).unwrap();
        let (b, _) = c.two_pass(&VecSource(elems)).unwrap();
        assert_eq!(a.keys(), b.keys());
    }

    #[test]
    fn back_to_back_spools_never_collide() {
        // the old SystemTime naming collided within one clock tick; the
        // counter naming must hand every spool a distinct live file
        let spools: Vec<SpoolSource> = (0..8)
            .map(|i| {
                SpoolSource::create(&tmp(), vec![Element::new(i, i as f64)]).unwrap()
            })
            .collect();
        let mut paths: Vec<PathBuf> = spools.iter().map(|s| s.path().to_path_buf()).collect();
        paths.sort();
        paths.dedup();
        assert_eq!(paths.len(), 8, "spool paths collided");
        for (i, s) in spools.iter().enumerate() {
            let replay: Vec<Element> = s.stream().collect();
            assert_eq!(replay, vec![Element::new(i as u64, i as f64)]);
        }
    }

    #[test]
    fn empty_spool() {
        let spool = SpoolSource::create(&tmp(), Vec::<Element>::new()).unwrap();
        assert!(spool.is_empty());
        assert_eq!(spool.stream().count(), 0);
    }
}
