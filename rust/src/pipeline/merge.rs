//! Merge tree: fold per-shard composable summaries into one, pairwise,
//! tree-shaped (log-depth — the order a distributed reduce would use),
//! counting merges in [`super::metrics::Metrics`].
//!
//! [`merge_all`] is the typed entry point over any
//! [`crate::api::Mergeable`]; [`tree_merge`] is the closure-driven
//! engine (used directly for dynamic summaries like
//! `Box<dyn WorSampler>` whose merge goes through `merge_dyn`).

use crate::error::Result;
use crate::pipeline::metrics::Metrics;

/// Tree-merge any [`crate::api::Mergeable`] summaries (compatibility
/// fingerprints are checked on every pairwise merge). Returns `None`
/// for empty input.
pub fn merge_all<S: crate::api::Mergeable>(
    items: Vec<S>,
    metrics: &Metrics,
) -> Result<Option<S>> {
    tree_merge(items, metrics, |a, b| crate::api::Mergeable::merge(a, b))
}

/// Pairwise tree-merge of summaries using `merge(acc, other)`.
/// Consumes the vector and returns the root. Returns `None` for empty
/// input.
pub fn tree_merge<S, F>(mut items: Vec<S>, metrics: &Metrics, mut merge: F) -> Result<Option<S>>
where
    F: FnMut(&mut S, &S) -> Result<()>,
{
    if items.is_empty() {
        return Ok(None);
    }
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                merge(&mut a, &b)?;
                metrics.note_merge();
            }
            next.push(a);
        }
        items = next;
    }
    Ok(items.pop())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Element;
    use crate::sketch::countsketch::CountSketch;
    use crate::sketch::{RhhSketch, SketchParams};

    #[test]
    fn tree_merge_equals_sequential_merge() {
        let params = SketchParams::new(5, 64, 9);
        let mut shards: Vec<CountSketch> = (0..5).map(|_| CountSketch::new(params)).collect();
        let mut reference = CountSketch::new(params);
        for i in 0..1000u64 {
            let e = Element::new(i % 97, (i % 13) as f64 - 6.0);
            shards[(i % 5) as usize].process(&e);
            reference.process(&e);
        }
        let metrics = Metrics::default();
        let merged = tree_merge(shards, &metrics, |a, b| a.merge(b))
            .unwrap()
            .unwrap();
        for (x, y) in merged.table().iter().zip(reference.table()) {
            assert!((x - y).abs() < 1e-9);
        }
        assert_eq!(metrics.merges(), 4); // n-1 merges for n shards
    }

    #[test]
    fn empty_and_singleton() {
        let metrics = Metrics::default();
        let none: Option<i32> = tree_merge(Vec::<i32>::new(), &metrics, |_, _| Ok(())).unwrap();
        assert!(none.is_none());
        let one = tree_merge(vec![42], &metrics, |_, _| Ok(())).unwrap();
        assert_eq!(one, Some(42));
        assert_eq!(metrics.merges(), 0);
    }

    #[test]
    fn merge_errors_propagate() {
        let metrics = Metrics::default();
        let r = tree_merge(vec![1, 2], &metrics, |_, _| {
            Err(crate::error::Error::Incompatible("nope".into()))
        });
        assert!(r.is_err());
    }

    #[test]
    fn merge_all_checks_fingerprints() {
        let metrics = Metrics::default();
        // same shape, different seed: the typed merge tree must refuse
        let shards = vec![
            CountSketch::new(SketchParams::new(3, 32, 1)),
            CountSketch::new(SketchParams::new(3, 32, 2)),
        ];
        let r = merge_all(shards, &metrics);
        assert!(matches!(r, Err(crate::error::Error::Incompatible(_))));
        // compatible shards fold fine
        let shards = vec![
            CountSketch::new(SketchParams::new(3, 32, 1)),
            CountSketch::new(SketchParams::new(3, 32, 1)),
            CountSketch::new(SketchParams::new(3, 32, 1)),
        ];
        assert!(merge_all(shards, &metrics).unwrap().is_some());
    }
}
