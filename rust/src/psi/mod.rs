//! Ψ calibration — paper Appendix B.1.
//!
//! `Ψ_{n,k,ρ}(δ)` (Eq. 9) is the largest rHH parameter ψ such that for
//! *any* input frequencies and any conditioned order, the top-k transformed
//! frequencies are `ℓq(k, ψ)` residual heavy hitters with probability
//! ≥ 1−δ. By the domination result (Lemma C.1) it suffices to bound the
//! tail of
//!
//! ```text
//! R_{n,k,ρ} = Σ_{i=k+1}^{n} (Σ_{j≤k} Z_j)^ρ / (Σ_{j≤i} Z_j)^ρ,  Z ~ Exp[1]
//! ```
//!
//! and `Ψ(δ)` solves `Pr[R ≥ k/ψ] = δ`: simulate i.i.d. draws of `R`, take
//! the (1−δ)-quantile `z'`, return `k/z'`.
//!
//! Theorem 3.1 lower bounds: `Ψ ≥ 1/(C ln(n/k))` for ρ=1 and
//! `Ψ ≥ max{ρ−1, 1/ln(n/k)}/C` for ρ>1, with C < 2 empirically for
//! δ=0.01, k ≥ 10 (the `psi_calibration` bench reproduces this).

use crate::util::rng::Rng;
use crate::util::stats::quantile;

/// Draw one sample of `R_{n,k,ρ}` (Definition B.1).
///
/// Uses the prefix-sum form: with `S_i = Σ_{j≤i} Z_j`,
/// `R = S_k^ρ · Σ_{i=k+1}^n S_i^{-ρ}`.
pub fn sample_r(rng: &mut Rng, n: usize, k: usize, rho: f64) -> f64 {
    assert!(k >= 1 && n > k, "need 1 <= k < n");
    let mut s = 0.0;
    for _ in 0..k {
        s += rng.exp1();
    }
    let sk = s;
    let log_sk = sk.ln();
    let mut total = 0.0;
    for _ in k..n {
        s += rng.exp1();
        // (sk / s)^rho via exp/ln for stability at large rho
        total += (rho * (log_sk - s.ln())).exp();
    }
    total
}

/// Monte-Carlo estimate of `Ψ_{n,k,ρ}(δ)` from `trials` i.i.d. draws of
/// `R_{n,k,ρ}` (Appendix B.1): `Ψ ≈ k / quantile_{1−δ}(R)`.
pub fn psi_estimate(n: usize, k: usize, rho: f64, delta: f64, trials: usize, seed: u64) -> f64 {
    assert!(delta > 0.0 && delta < 1.0);
    assert!(trials >= 10);
    let mut rng = Rng::new(seed);
    let draws: Vec<f64> = (0..trials).map(|_| sample_r(&mut rng, n, k, rho)).collect();
    let z = quantile(&draws, 1.0 - delta);
    k as f64 / z
}

/// The Theorem 3.1 analytic lower bound with constant `c`.
pub fn psi_lower_bound(n: usize, k: usize, rho: f64, c: f64) -> f64 {
    let ln_nk = ((n as f64) / (k as f64)).ln().max(1.0);
    if rho <= 1.0 {
        1.0 / (c * ln_nk)
    } else {
        (rho - 1.0).max(1.0 / ln_nk) / c
    }
}

/// A process-wide cache of calibrated Ψ values so repeated sampler
/// construction does not redo the Monte-Carlo (keys are rounded params).
#[derive(Default)]
pub struct PsiCache {
    map: std::sync::Mutex<std::collections::HashMap<(usize, usize, u64, u64), f64>>,
}

impl PsiCache {
    /// Shared global cache.
    pub fn global() -> &'static PsiCache {
        static CACHE: once_cell::sync::Lazy<PsiCache> = once_cell::sync::Lazy::new(PsiCache::default);
        &CACHE
    }

    /// Get (or compute) `Ψ_{n,k,ρ}(δ)` with a default trial budget.
    pub fn get(&self, n: usize, k: usize, rho: f64, delta: f64) -> f64 {
        let key = (n, k, (rho * 1e6) as u64, (delta * 1e9) as u64);
        if let Some(v) = self.map.lock().unwrap().get(&key) {
            return *v;
        }
        // trials scale with 1/delta so the quantile is resolved
        let trials = ((10.0 / delta) as usize).clamp(1_000, 20_000);
        let v = psi_estimate(n, k, rho, delta, trials, 0x9_51_C0DE);
        self.map.lock().unwrap().insert(key, v);
        v
    }
}

/// Derived sketch parameter `ψ` for WORp given sampler settings
/// (paper §4: `ψ ← Ψ_{n,k,ρ}(δ) / (3q)` for the 2-pass method, §5:
/// `ψ ← ε^q Ψ_{n,k+1,ρ}` for 1-pass).
pub fn worp_psi_two_pass(n: usize, k: usize, p: f64, q: f64, delta: f64) -> f64 {
    let rho = q / p;
    PsiCache::global().get(n, k + 1, rho, delta) / (3.0 * q)
}

/// 1-pass ψ with accuracy parameter ε ∈ (0, 1/3].
pub fn worp_psi_one_pass(n: usize, k: usize, p: f64, q: f64, delta: f64, eps: f64) -> f64 {
    assert!(eps > 0.0 && eps <= 1.0 / 3.0 + 1e-12);
    let rho = q / p;
    eps.powf(q) * PsiCache::global().get(n, k + 1, rho, delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_r_positive_and_finite() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let r = sample_r(&mut rng, 1000, 10, 2.0);
            assert!(r.is_finite() && r > 0.0);
        }
    }

    #[test]
    fn r_mean_close_to_back_of_envelope_rho2() {
        // S_{n,k,2} ≈ k for rho=2 (sum k^2/i^2 ≈ k); empirical mean should
        // be within a factor ~1.5
        let mut rng = Rng::new(2);
        let (n, k) = (2000, 50);
        let m: f64 = (0..300).map(|_| sample_r(&mut rng, n, k, 2.0)).sum::<f64>() / 300.0;
        assert!(m > 0.4 * k as f64 && m < 2.5 * k as f64, "mean={m}");
    }

    #[test]
    fn r_grows_like_k_log_for_rho1() {
        let mut rng = Rng::new(3);
        let (n, k) = (10_000, 20);
        let m: f64 = (0..200).map(|_| sample_r(&mut rng, n, k, 1.0)).sum::<f64>() / 200.0;
        let pred = k as f64 * ((n as f64 / k as f64).ln());
        assert!(m > 0.5 * pred && m < 2.0 * pred, "mean={m} pred={pred}");
    }

    #[test]
    fn psi_estimate_in_theorem_band() {
        // paper App B.1: C = 2 suffices for delta=0.01, k >= 10
        for &rho in &[1.0, 2.0] {
            let psi = psi_estimate(10_000, 100, rho, 0.01, 4_000, 7);
            let lb = psi_lower_bound(10_000, 100, rho, 2.0);
            assert!(psi >= lb, "rho={rho}: psi={psi} < lb={lb}");
            assert!(psi <= 1.0, "psi={psi} should be <= 1");
        }
    }

    #[test]
    fn psi_decreasing_in_n_increasing_in_k_for_rho1() {
        let p_small_n = psi_estimate(1_000, 50, 1.0, 0.05, 2_000, 5);
        let p_large_n = psi_estimate(100_000, 50, 1.0, 0.05, 2_000, 5);
        assert!(p_large_n < p_small_n);
    }

    #[test]
    fn cache_returns_stable_values() {
        let c = PsiCache::global();
        let a = c.get(5_000, 64, 2.0, 0.01);
        let b = c.get(5_000, 64, 2.0, 0.01);
        assert_eq!(a, b);
        assert!(a > 0.0 && a < 2.0);
    }

    #[test]
    fn derived_psis_scale_correctly() {
        let two = worp_psi_two_pass(10_000, 100, 1.0, 2.0, 0.01);
        let one_coarse = worp_psi_one_pass(10_000, 100, 1.0, 2.0, 0.01, 1.0 / 3.0);
        let one_fine = worp_psi_one_pass(10_000, 100, 1.0, 2.0, 0.01, 0.1);
        assert!(one_fine < one_coarse, "smaller eps -> smaller psi -> bigger sketch");
        assert!(two > 0.0 && one_coarse > 0.0);
    }
}
