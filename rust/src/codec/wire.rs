//! Shared little-endian wire primitives for every on-disk format in the
//! crate: the persistence envelopes of [`super`], the checkpoint files of
//! [`crate::pipeline`], and the element records of
//! [`crate::pipeline::spool::SpoolSource`] all write through these
//! helpers, so endianness and record layout are defined in exactly one
//! place.
//!
//! Reading goes through [`Reader`], whose every accessor is bounds-checked
//! and returns [`Error::Codec`] instead of panicking — the decode path
//! must survive arbitrary untrusted bytes. Sequence lengths are validated
//! against the bytes actually remaining *before* any allocation
//! ([`Reader::seq_len`]), so a length-field lie cannot trigger an OOM.

use crate::data::{Element, ElementBlock};
use crate::error::{Error, Result};

/// Magic prefix of a persistence envelope (`*.worp` files).
pub const ENVELOPE_MAGIC: [u8; 4] = *b"WORP";

/// Magic prefix of a pipeline checkpoint file.
pub const CHECKPOINT_MAGIC: [u8; 4] = *b"WCKP";

/// Current wire-format version. Bump on any layout change; decoders
/// reject other versions with [`Error::Codec`].
pub const VERSION: u16 = 1;

/// Append a `u8`.
#[inline]
pub fn put_u8(out: &mut Vec<u8>, x: u8) {
    out.push(x);
}

/// Append a little-endian `u16`.
#[inline]
pub fn put_u16(out: &mut Vec<u8>, x: u16) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a little-endian `u32`.
#[inline]
pub fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a little-endian `u64`.
#[inline]
pub fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a `usize` as a little-endian `u64`.
#[inline]
pub fn put_usize(out: &mut Vec<u8>, x: usize) {
    put_u64(out, x as u64);
}

/// Append an `f64` by IEEE-754 bit pattern (sign of zero and NaN payloads
/// round-trip exactly).
#[inline]
pub fn put_f64(out: &mut Vec<u8>, x: f64) {
    put_u64(out, x.to_bits());
}

/// The 16-byte on-disk record of one [`Element`] (key then value, both
/// little-endian) — the spool file format.
#[inline]
pub fn element_to_bytes(e: &Element) -> [u8; 16] {
    let mut b = [0u8; 16];
    b[..8].copy_from_slice(&e.key.to_le_bytes());
    b[8..].copy_from_slice(&e.val.to_le_bytes());
    b
}

/// Decode a 16-byte element record.
#[inline]
pub fn element_from_bytes(b: &[u8; 16]) -> Element {
    let (key, val) = element_parts_from_bytes(b);
    Element::new(key, val)
}

/// Decode a 16-byte element record into its columns (§Perf L3-7): the
/// SoA block path appends key and value to separate arrays without ever
/// materializing an [`Element`] struct.
#[inline]
pub fn element_parts_from_bytes(b: &[u8; 16]) -> (u64, f64) {
    let mut kb = [0u8; 8];
    let mut vb = [0u8; 8];
    kb.copy_from_slice(&b[..8]);
    vb.copy_from_slice(&b[8..]);
    (u64::from_le_bytes(kb), f64::from_le_bytes(vb))
}

/// Append one element record from its columns — the writing half of the
/// SoA path ([`element_to_bytes`] is the AoS equivalent; both produce
/// the identical 16-byte layout).
#[inline]
pub fn put_element_parts(out: &mut Vec<u8>, key: u64, val: f64) {
    put_u64(out, key);
    put_f64(out, val);
}

/// Serialize a whole [`ElementBlock`] as consecutive 16-byte element
/// records, reading straight off the SoA columns.
pub fn put_block(out: &mut Vec<u8>, block: &ElementBlock) {
    out.reserve(16 * block.len());
    for (&key, &val) in block.keys.iter().zip(&block.vals) {
        put_element_parts(out, key, val);
    }
}

/// Parse a run of 16-byte element records into the SoA columns of
/// `block` (appending). `bytes.len()` must be a multiple of 16.
pub fn read_block_into(bytes: &[u8], block: &mut ElementBlock) -> Result<()> {
    if bytes.len() % 16 != 0 {
        return Err(Error::Codec(format!(
            "element-record run of {} bytes is not a multiple of 16",
            bytes.len()
        )));
    }
    let n = bytes.len() / 16;
    block.keys.reserve(n);
    block.vals.reserve(n);
    for rec in bytes.chunks_exact(16) {
        let mut b = [0u8; 16];
        b.copy_from_slice(rec);
        let (key, val) = element_parts_from_bytes(&b);
        block.push(key, val);
    }
    Ok(())
}

/// Bounds-checked cursor over untrusted bytes. Every failure is a typed
/// [`Error::Codec`]; nothing here panics or allocates from unvalidated
/// lengths.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Start reading at the front of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(Error::Codec(format!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Everything not yet consumed (consumes it).
    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    /// Next `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Next little-endian `u16`.
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Next little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// Next `f64` by bit pattern.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Next `f64`, rejecting NaN/infinity (for configuration scalars that
    /// later flow into assertions or comparisons).
    pub fn finite_f64(&mut self, what: &str) -> Result<f64> {
        let x = self.f64()?;
        if !x.is_finite() {
            return Err(Error::Codec(format!("{what} is not finite: {x}")));
        }
        Ok(x)
    }

    /// A sequence length prefix: reads a `u64` and validates
    /// `len * elem_bytes` against the bytes actually remaining, so the
    /// caller can allocate `len` slots without trusting the field.
    pub fn seq_len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let need = n.checked_mul(elem_bytes.max(1) as u64);
        match need {
            Some(need) if need <= self.remaining() as u64 => Ok(n as usize),
            _ => Err(Error::Codec(format!(
                "length field lies: {n} records of {elem_bytes} bytes exceed the {} remaining",
                self.remaining()
            ))),
        }
    }

    /// Assert the input is fully consumed (trailing garbage is malformed).
    pub fn finish(self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Codec(format!(
                "{} trailing bytes after {what} payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        let mut v = Vec::new();
        put_u8(&mut v, 7);
        put_u16(&mut v, 0xABCD);
        put_u32(&mut v, 0xDEAD_BEEF);
        put_u64(&mut v, u64::MAX - 1);
        put_f64(&mut v, -0.0);
        put_f64(&mut v, f64::NAN);
        let mut r = Reader::new(&v);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xABCD);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        // -0.0 round-trips by bits
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        r.finish("test").unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let v = vec![1u8, 2, 3];
        let mut r = Reader::new(&v);
        assert!(r.u64().is_err());
        let mut r = Reader::new(&v);
        assert!(r.take(4).is_err());
        assert_eq!(r.take(3).unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn seq_len_rejects_lies_before_allocating() {
        let mut v = Vec::new();
        put_u64(&mut v, u64::MAX); // astronomically large count
        let mut r = Reader::new(&v);
        assert!(r.seq_len(8).is_err());
        // honest length passes
        let mut v = Vec::new();
        put_u64(&mut v, 2);
        put_u64(&mut v, 1);
        put_u64(&mut v, 2);
        let mut r = Reader::new(&v);
        assert_eq!(r.seq_len(8).unwrap(), 2);
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let v = vec![0u8; 4];
        let mut r = Reader::new(&v);
        let _ = r.u16().unwrap();
        assert!(r.finish("x").is_err());
    }

    #[test]
    fn element_record_is_16_bytes_and_roundtrips() {
        let e = Element::new(0xFEED_F00D, -3.25);
        let b = element_to_bytes(&e);
        assert_eq!(element_from_bytes(&b), e);
    }

    #[test]
    fn element_parts_agree_with_struct_helpers() {
        let e = Element::new(0xDEAD_BEEF, -7.125);
        let mut via_parts = Vec::new();
        put_element_parts(&mut via_parts, e.key, e.val);
        assert_eq!(via_parts.as_slice(), &element_to_bytes(&e)[..]);
        let mut b = [0u8; 16];
        b.copy_from_slice(&via_parts);
        assert_eq!(element_parts_from_bytes(&b), (e.key, e.val));
    }

    #[test]
    fn block_records_roundtrip_and_match_element_records() {
        let elems = vec![
            Element::new(1, 0.5),
            Element::new(u64::MAX, -0.0),
            Element::new(42, f64::MIN_POSITIVE),
        ];
        let block = ElementBlock::from_elements(&elems);
        let mut via_block = Vec::new();
        put_block(&mut via_block, &block);
        let mut via_elems = Vec::new();
        for e in &elems {
            via_elems.extend_from_slice(&element_to_bytes(e));
        }
        assert_eq!(via_block, via_elems, "SoA and AoS writers must agree byte-for-byte");
        let mut back = ElementBlock::new();
        read_block_into(&via_block, &mut back).unwrap();
        assert_eq!(back.keys, block.keys);
        assert_eq!(back.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   block.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        // ragged runs are malformed, not a panic
        assert!(read_block_into(&via_block[..17], &mut back).is_err());
    }

    #[test]
    fn finite_f64_rejects_nan_and_inf() {
        let mut v = Vec::new();
        put_f64(&mut v, f64::INFINITY);
        assert!(Reader::new(&v).finite_f64("p").is_err());
        let mut v = Vec::new();
        put_f64(&mut v, 1.5);
        assert_eq!(Reader::new(&v).finite_f64("p").unwrap(), 1.5);
    }
}
