//! Versioned binary persistence for every composable summary — the wire
//! format behind [`crate::api::Persist`], pipeline checkpointing and the
//! `worp shard` / `worp merge-files` cross-process merge path.
//!
//! Like the rest of the crate this is std-only and hand-rolled (no serde
//! offline — DESIGN.md §7), in the same spirit as
//! [`crate::pipeline::spool`], with which it shares the
//! [`wire`] endianness helpers.
//!
//! # Envelope layout
//!
//! Every encoded summary is one self-contained *envelope* (all integers
//! little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic           "WORP"
//!      4     2  version         wire::VERSION (currently 1)
//!      6     2  type tag        see [`tag`]
//!      8     8  payload length  must equal exactly the bytes that follow
//!              the 32-byte header
//!     16     8  fingerprint     Mergeable/WorSampler fingerprint of the
//!              encoded summary — recomputed after decode and compared
//!     24     8  checksum        hash_bytes(CHECKSUM_SEED, header[0..24]
//!              ++ payload) — covers the header fields too, so any
//!              single corrupted bit anywhere in the envelope is caught
//!     32     …  payload         per-type layout (each type's Persist impl)
//! ```
//!
//! # Versioning rules
//!
//! - Any change to the envelope or to a type's payload layout bumps
//!   [`wire::VERSION`]; decoders accept exactly one version (no silent
//!   cross-version reads — summaries are cheap to rebuild, corrupt merges
//!   are not).
//! - Type tags are append-only: a tag is never reused for a different
//!   layout.
//! - Encoding is *canonical*: unordered containers (hash maps/sets) are
//!   written sorted by key, so logically-equal summaries encode to
//!   byte-identical envelopes — the golden-vector tests and the
//!   `merge ∘ decode ∘ encode ≡ merge` law in `tests/persist_contract.rs`
//!   rely on this.
//!
//! # Safety against untrusted input
//!
//! `decode` never panics: every malformed input — truncation, bad magic,
//! unknown version/tag, payload-length or checksum or fingerprint
//! mismatch, length-field lies — maps to [`Error::Codec`]. Sequence
//! lengths are validated against the remaining byte count *before* any
//! allocation ([`wire::Reader::seq_len`]), so hostile lengths cannot OOM.

pub mod wire;

use crate::api::{Persist, WorSampler};
use crate::error::{Error, Result};
use crate::sampler::SamplerConfig;
use crate::sketch::SketchParams;
use crate::util::hashing::{hash_bytes2, BottomKDist};

/// Seed of the payload checksum (a keyed FNV/SplitMix digest via
/// [`hash_bytes2`] — corruption detection, not cryptographic integrity).
pub const CHECKSUM_SEED: u64 = 0xC0DE_C0DE_5EED_0001;

/// Size of the fixed envelope header in bytes.
pub const HEADER_LEN: usize = 32;

/// Stable type tags (append-only; see module docs).
pub mod tag {
    /// [`crate::sketch::countsketch::CountSketch`]
    pub const COUNTSKETCH: u16 = 1;
    /// [`crate::sketch::countmin::CountMin`]
    pub const COUNTMIN: u16 = 2;
    /// [`crate::sketch::AnyRhh`]
    pub const ANY_RHH: u16 = 3;
    /// [`crate::sketch::spacesaving::SpaceSaving`]`<u64>`
    pub const SPACESAVING: u16 = 4;
    /// [`crate::sketch::topk::TopK`]
    pub const TOPK: u16 = 5;
    /// [`crate::sketch::window::WindowedCountSketch`]
    pub const WINDOW_SKETCH: u16 = 6;
    /// [`crate::sampler::exact::ExactWor`]
    pub const EXACT_WOR: u16 = 7;
    /// [`crate::sampler::worp1::OnePassWorp`]
    pub const WORP1: u16 = 8;
    /// [`crate::sampler::worp2::TwoPassWorpPass1`]
    pub const WORP2_PASS1: u16 = 9;
    /// [`crate::sampler::worp2::TwoPassWorpPass2`]
    pub const WORP2_PASS2: u16 = 10;
    /// [`crate::sampler::worp2::TwoPassWorp`]
    pub const WORP2: u16 = 11;
    /// [`crate::sampler::tv1pass::TvSampler`]
    pub const TV: u16 = 12;
    /// [`crate::sampler::windowed::WindowedWorp`]
    pub const WINDOWED_WORP: u16 = 13;
    /// [`crate::sampler::perfect_lp::OracleSampler`]
    pub const ORACLE_LP: u16 = 14;
    /// [`crate::sampler::perfect_lp::PrecisionSampler`]
    pub const PRECISION_LP: u16 = 15;
    /// [`crate::engine::Engine`] instance snapshot (per-shard sampler
    /// envelopes plus their pending SoA blocks).
    pub const ENGINE_SNAPSHOT: u16 = 16;
    /// Partially-owned [`crate::engine::Engine`] instance snapshot: a
    /// cluster node owns a subset of an instance's hash slices, so the
    /// payload carries the total slice count plus an explicit slice
    /// index per stored slot. Fully-owned instances keep encoding as
    /// [`ENGINE_SNAPSHOT`] byte-for-byte (golden fixtures stay valid).
    pub const ENGINE_SNAPSHOT_SLICED: u16 = 17;
    /// One hash slice of an engine instance in transit (sampler state +
    /// pending block + placement metadata) — the unit cluster
    /// rebalancing drains from an old owner and installs on a new one.
    pub const SLICE_SNAPSHOT: u16 = 18;
    /// A [`crate::cluster::ClusterSpec`]: named members with addresses
    /// plus the slice count; the envelope fingerprint is the cluster
    /// membership stamp (name + slice count — membership excluded so
    /// cross-epoch rebalance installs are not refused).
    pub const CLUSTER_SPEC: u16 = 19;
    /// [`crate::sampler::wr_reservoir::WrReservoir`] — streaming
    /// with-replacement reservoir (exponential-jump slots + RNG state,
    /// nested CountSketch).
    pub const WR_RESERVOIR: u16 = 20;
    /// [`crate::sampler::decayed::DecayedWorp`] — exact bottom-k over
    /// time-decayed frequencies (per-key lazy-carry entries + clock).
    pub const DECAYED_WORP: u16 = 21;
}

/// Human-readable name of a type tag (for diagnostics).
pub fn tag_name(t: u16) -> &'static str {
    match t {
        tag::COUNTSKETCH => "countsketch",
        tag::COUNTMIN => "countmin",
        tag::ANY_RHH => "anyrhh",
        tag::SPACESAVING => "spacesaving",
        tag::TOPK => "topk",
        tag::WINDOW_SKETCH => "windowsketch",
        tag::EXACT_WOR => "exact",
        tag::WORP1 => "1pass",
        tag::WORP2_PASS1 => "2pass-pass1",
        tag::WORP2_PASS2 => "2pass-pass2",
        tag::WORP2 => "2pass",
        tag::TV => "tv",
        tag::WINDOWED_WORP => "windowed",
        tag::ORACLE_LP => "oracle-lp",
        tag::PRECISION_LP => "precision-lp",
        tag::ENGINE_SNAPSHOT => "engine-snapshot",
        tag::ENGINE_SNAPSHOT_SLICED => "engine-snapshot-sliced",
        tag::SLICE_SNAPSHOT => "slice-snapshot",
        tag::CLUSTER_SPEC => "cluster-spec",
        tag::WR_RESERVOIR => "wr",
        tag::DECAYED_WORP => "decayed",
        _ => "unknown",
    }
}

/// Append a complete envelope (header + payload) to `out`.
pub fn write_envelope(type_tag: u16, fingerprint: u64, payload: &[u8], out: &mut Vec<u8>) {
    let start = out.len();
    out.extend_from_slice(&wire::ENVELOPE_MAGIC);
    wire::put_u16(out, wire::VERSION);
    wire::put_u16(out, type_tag);
    wire::put_u64(out, payload.len() as u64);
    wire::put_u64(out, fingerprint);
    let checksum = hash_bytes2(CHECKSUM_SEED, &out[start..start + 24], payload);
    wire::put_u64(out, checksum);
    out.extend_from_slice(payload);
}

/// A validated envelope view: header fields plus the checksummed payload.
pub struct Envelope<'a> {
    /// The type tag of the encoded summary.
    pub type_tag: u16,
    /// The fingerprint recorded at encode time.
    pub fingerprint: u64,
    /// The payload bytes (checksum already verified).
    pub payload: &'a [u8],
}

/// Parse the validated-but-unchecksummed header fields (magic + version
/// verified): `(type_tag, payload_len, fingerprint)` plus the reader
/// positioned at the checksum field. One parser serves both the full
/// [`read_envelope`] and the cheap [`peek_header`], so the header logic
/// cannot drift between them.
fn parse_header(bytes: &[u8]) -> Result<(u16, u64, u64, wire::Reader<'_>)> {
    let mut r = wire::Reader::new(bytes);
    let magic = r.take(4)?;
    if magic != wire::ENVELOPE_MAGIC {
        return Err(Error::Codec(format!(
            "bad magic {:02x?} (expected {:02x?} — not a worp summary file?)",
            magic,
            wire::ENVELOPE_MAGIC
        )));
    }
    let version = r.u16()?;
    if version != wire::VERSION {
        return Err(Error::Codec(format!(
            "unsupported format version {version} (this build reads version {})",
            wire::VERSION
        )));
    }
    let type_tag = r.u16()?;
    let payload_len = r.u64()?;
    let fingerprint = r.u64()?;
    Ok((type_tag, payload_len, fingerprint, r))
}

/// Parse and validate an envelope. `expect_tag = Some(t)` additionally
/// demands the type tag be `t` (the typed `Persist::decode` path);
/// `None` accepts any known layout owner (the `Box<dyn WorSampler>`
/// dispatch peeks the tag itself).
pub fn read_envelope(bytes: &[u8], expect_tag: Option<u16>) -> Result<Envelope<'_>> {
    let (type_tag, payload_len, fingerprint, mut r) = parse_header(bytes)?;
    if let Some(want) = expect_tag {
        if type_tag != want {
            return Err(Error::Codec(format!(
                "type tag mismatch: file holds a {} (tag {type_tag}), expected {} (tag {want})",
                tag_name(type_tag),
                tag_name(want)
            )));
        }
    }
    let checksum = r.u64()?;
    let payload = r.rest();
    if payload_len != payload.len() as u64 {
        return Err(Error::Codec(format!(
            "payload length field says {payload_len} bytes but {} follow the header",
            payload.len()
        )));
    }
    // the checksum covers the first 24 header bytes plus the payload, so
    // every corrupted bit anywhere in the envelope lands here (or in one
    // of the field checks above)
    if hash_bytes2(CHECKSUM_SEED, &bytes[..24], payload) != checksum {
        return Err(Error::Codec(
            "envelope checksum mismatch — the bytes were corrupted in transit or at rest".into(),
        ));
    }
    Ok(Envelope { type_tag, fingerprint, payload })
}

/// Compare the fingerprint recorded in the envelope header against the
/// one recomputed from the decoded summary — a corrupted-but-plausible
/// configuration fails here instead of poisoning a later merge.
pub fn check_fingerprint(header: u64, recomputed: u64) -> Result<()> {
    if header != recomputed {
        return Err(Error::Codec(format!(
            "fingerprint mismatch: header records {header:#018x} but the decoded summary \
             fingerprints to {recomputed:#018x}",
        )));
    }
    Ok(())
}

/// Append a nested summary as a length-prefixed full envelope (composite
/// summaries embed their parts this way).
pub fn put_nested<T: Persist>(out: &mut Vec<u8>, inner: &T) {
    let mut tmp = Vec::new();
    inner.encode_into(&mut tmp);
    wire::put_usize(out, tmp.len());
    out.extend_from_slice(&tmp);
}

/// Read the byte slice of a nested envelope written by [`put_nested`].
pub fn take_nested<'a>(r: &mut wire::Reader<'a>) -> Result<&'a [u8]> {
    let n = r.seq_len(1)?;
    r.take(n)
}

/// Decode a nested envelope written by [`put_nested`].
pub fn read_nested<T: Persist>(r: &mut wire::Reader<'_>) -> Result<T> {
    T::decode(take_nested(r)?)
}

// ---------------------------------------------------------------------------
// SamplerConfig payload fragment (shared by every WORp sampler codec)

/// Append a [`SamplerConfig`] fragment: `p f64, k u64, q f64, seed u64,
/// n u64, delta f64, eps f64, rows u64, width u64, dist u8 (1=Exp,
/// 2=Uniform)`.
pub fn put_sampler_config(out: &mut Vec<u8>, cfg: &SamplerConfig) {
    wire::put_f64(out, cfg.p);
    wire::put_usize(out, cfg.k);
    wire::put_f64(out, cfg.q);
    wire::put_u64(out, cfg.seed);
    wire::put_usize(out, cfg.n);
    wire::put_f64(out, cfg.delta);
    wire::put_f64(out, cfg.eps);
    wire::put_usize(out, cfg.rows);
    wire::put_usize(out, cfg.width);
    wire::put_u8(out, dist_to_byte(cfg.dist));
}

/// Read and validate a [`SamplerConfig`] fragment. The checks mirror the
/// constructor asserts the decode path bypasses (decoding must never
/// panic): `p ∈ (0, 2]` keeps the transform constructible, `k ≥ 1`
/// keeps sample extraction sane, and sizes are capped so derived
/// capacities cannot overflow.
pub fn read_sampler_config(r: &mut wire::Reader<'_>) -> Result<SamplerConfig> {
    const SIZE_CAP: u64 = u32::MAX as u64;
    let p = r.finite_f64("p")?;
    let k = r.u64()?;
    let q = r.finite_f64("q")?;
    let seed = r.u64()?;
    let n = r.u64()?;
    let delta = r.finite_f64("delta")?;
    let eps = r.finite_f64("eps")?;
    let rows = r.u64()?;
    let width = r.u64()?;
    let dist = dist_from_byte(r.u8()?)?;
    validate_p(p, "sampler config")?;
    if k == 0 || k > SIZE_CAP {
        return Err(Error::Codec(format!("k out of range [1, 2^32]: {k}")));
    }
    if n > SIZE_CAP || rows > SIZE_CAP || width > SIZE_CAP {
        return Err(Error::Codec(format!(
            "config sizes exceed the 2^32 cap: n={n} rows={rows} width={width}"
        )));
    }
    // mirror the builder's validation: these ranges keep the Ψ
    // calibration (certify / resolved-width paths) assert-free, so a
    // hostile config cannot smuggle a panic past decode
    if q != 1.0 && q != 2.0 {
        return Err(Error::Codec(format!("q must be 1 or 2: {q}")));
    }
    if q < p {
        return Err(Error::Codec(format!("need q >= p (q={q}, p={p})")));
    }
    if !(delta > 0.0 && delta < 1.0) {
        return Err(Error::Codec(format!("delta out of range (0,1): {delta}")));
    }
    if !(eps > 0.0 && eps <= 1.0 / 3.0 + 1e-12) {
        return Err(Error::Codec(format!("eps out of range (0, 1/3]: {eps}")));
    }
    Ok(SamplerConfig {
        p,
        k: k as usize,
        q,
        seed,
        n: n as usize,
        delta,
        eps,
        rows: rows as usize,
        width: width as usize,
        dist,
    })
}

// ---------------------------------------------------------------------------
// Hashed-array sketch payload fragment (CountSketch / CountMin share it)

/// Append a hashed-array sketch body: `rows u64, width u64, seed u64,
/// processed u64, table_len u64, table f64×len` (row-major).
pub fn put_rhh_table(out: &mut Vec<u8>, params: &SketchParams, processed: u64, table: &[f64]) {
    wire::put_usize(out, params.rows);
    wire::put_usize(out, params.width);
    wire::put_u64(out, params.seed);
    wire::put_u64(out, processed);
    wire::put_usize(out, table.len());
    for &c in table {
        wire::put_f64(out, c);
    }
}

/// Read and validate a hashed-array sketch body: the shape must be
/// positive, below the 2^32 cap, and agree exactly with the table length
/// (which [`wire::Reader::seq_len`] has already bounded by the remaining
/// bytes, so no hostile allocation is possible). Table cells must be
/// finite — NaN/∞ would poison the `partial_cmp().unwrap()` comparators
/// in the median/min estimators one call after decode.
pub fn read_rhh_table(r: &mut wire::Reader<'_>) -> Result<(SketchParams, u64, Vec<f64>)> {
    const SIZE_CAP: u64 = u32::MAX as u64;
    let rows = r.u64()?;
    let width = r.u64()?;
    let seed = r.u64()?;
    let processed = r.u64()?;
    if rows == 0 || width == 0 || rows > SIZE_CAP || width > SIZE_CAP {
        return Err(Error::Codec(format!(
            "sketch shape out of range [1, 2^32]: {rows}x{width}"
        )));
    }
    let n = r.seq_len(8)?;
    if (rows as usize).checked_mul(width as usize) != Some(n) {
        return Err(Error::Codec(format!(
            "table length {n} does not match shape {rows}x{width}"
        )));
    }
    let mut table = Vec::with_capacity(n);
    for _ in 0..n {
        table.push(r.finite_f64("sketch table cell")?);
    }
    Ok((
        SketchParams { rows: rows as usize, width: width as usize, seed },
        processed,
        table,
    ))
}

// ---------------------------------------------------------------------------
// Strings and samples (shared by the engine wire protocol and snapshots)

/// Append a length-prefixed UTF-8 string (`u64` length, then the bytes).
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    wire::put_usize(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

/// Read a length-prefixed UTF-8 string written by [`put_str`]. The
/// length is validated against the remaining bytes before allocation and
/// the bytes must be valid UTF-8 — anything else is [`Error::Codec`].
pub fn read_str(r: &mut wire::Reader<'_>) -> Result<String> {
    let n = r.seq_len(1)?;
    let bytes = r.take(n)?;
    String::from_utf8(bytes.to_vec())
        .map_err(|_| Error::Codec("string field is not valid UTF-8".into()))
}

/// Append the canonical encoding of a [`Sample`] — what the engine wire
/// protocol ships for `sample` queries: entry count, per entry
/// `key u64, freq f64, transformed f64`, then `tau f64, p f64, dist u8`,
/// then the key dictionary (count, then key-sorted `id u64, string`
/// pairs; count 0 ⇔ no dictionary). Canonical because entries keep their
/// rank order and the dictionary iterates a `BTreeMap`.
pub fn put_sample(out: &mut Vec<u8>, s: &crate::sampler::Sample) {
    wire::put_usize(out, s.entries.len());
    for e in &s.entries {
        wire::put_u64(out, e.key);
        wire::put_f64(out, e.freq);
        wire::put_f64(out, e.transformed);
    }
    wire::put_f64(out, s.tau);
    wire::put_f64(out, s.p);
    put_u8_dist(out, s.dist);
    match &s.names {
        Some(names) => {
            wire::put_usize(out, names.len());
            for (id, name) in names {
                wire::put_u64(out, *id);
                put_str(out, name);
            }
        }
        None => wire::put_usize(out, 0),
    }
}

#[inline]
fn put_u8_dist(out: &mut Vec<u8>, d: BottomKDist) {
    wire::put_u8(out, dist_to_byte(d));
}

/// Decode a [`Sample`] written by [`put_sample`]. Never panics on
/// hostile bytes: lengths are bounded before allocation, `p` must be in
/// `(0, 2]` and `tau` finite and non-negative (both flow straight into
/// [`crate::sampler::Sample::inclusion_prob`]). An empty dictionary
/// decodes as `None`.
pub fn read_sample(r: &mut wire::Reader<'_>) -> Result<crate::sampler::Sample> {
    let n = r.seq_len(24)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let key = r.u64()?;
        let freq = r.f64()?;
        let transformed = r.f64()?;
        entries.push(crate::sampler::SampleEntry { key, freq, transformed });
    }
    let tau = r.finite_f64("sample tau")?;
    if tau < 0.0 {
        return Err(Error::Codec(format!("sample tau must be >= 0: {tau}")));
    }
    let p = r.finite_f64("sample p")?;
    validate_p(p, "sample")?;
    let dist = dist_from_byte(r.u8()?)?;
    let dn = r.seq_len(16)?;
    let names = if dn == 0 {
        None
    } else {
        let mut names = crate::sampler::KeyDict::new();
        for _ in 0..dn {
            let id = r.u64()?;
            let name = read_str(r)?;
            names.insert(id, name);
        }
        Some(names)
    };
    Ok(crate::sampler::Sample { entries, tau, p, dist, names })
}

/// Append a [`SimilarityReport`](crate::estimate::similarity::SimilarityReport)
/// (the WRPC `SIMILARITY` ok-response payload): four `f64`s in field
/// order.
pub fn put_similarity(out: &mut Vec<u8>, r: &crate::estimate::similarity::SimilarityReport) {
    wire::put_f64(out, r.min_sum);
    wire::put_f64(out, r.max_sum);
    wire::put_f64(out, r.jaccard);
    wire::put_f64(out, r.overlap);
}

/// Decode a similarity report written by [`put_similarity`] (finite
/// fields only — every one flows into accuracy-gate arithmetic).
pub fn read_similarity(
    r: &mut wire::Reader<'_>,
) -> Result<crate::estimate::similarity::SimilarityReport> {
    Ok(crate::estimate::similarity::SimilarityReport {
        min_sum: r.finite_f64("similarity min_sum")?,
        max_sum: r.finite_f64("similarity max_sum")?,
        jaccard: r.finite_f64("similarity jaccard")?,
        overlap: r.finite_f64("similarity overlap")?,
    })
}

/// Validate a decoded power `p ∈ (0, 2]` — the single source of truth
/// for every decoder (the transform constructor asserts this range, so
/// an unchecked hostile `p` would panic one call after decode).
pub fn validate_p(p: f64, what: &str) -> Result<()> {
    if !(p > 0.0 && p <= 2.0) {
        return Err(Error::Codec(format!("{what}: p out of range (0,2]: {p}")));
    }
    Ok(())
}

/// Wire byte of a bottom-k distribution.
pub fn dist_to_byte(d: BottomKDist) -> u8 {
    match d {
        BottomKDist::Exp => 1,
        BottomKDist::Uniform => 2,
    }
}

/// Parse a bottom-k distribution byte.
pub fn dist_from_byte(b: u8) -> Result<BottomKDist> {
    match b {
        1 => Ok(BottomKDist::Exp),
        2 => Ok(BottomKDist::Uniform),
        other => Err(Error::Codec(format!("unknown dist byte {other}"))),
    }
}

// ---------------------------------------------------------------------------
// Box<dyn WorSampler>: type-tagged dynamic decode

/// Cheaply read an envelope's type tag and fingerprint (magic + version
/// validated, no checksum pass) — dispatchers and compatibility checks
/// peek these, then let the typed decode do the full validation over the
/// same bytes exactly once.
pub fn peek_header(bytes: &[u8]) -> Result<(u16, u64)> {
    let (type_tag, _payload_len, fingerprint, _r) = parse_header(bytes)?;
    Ok((type_tag, fingerprint))
}

/// The type tag alone (see [`peek_header`]).
pub fn peek_type_tag(bytes: &[u8]) -> Result<u16> {
    Ok(peek_header(bytes)?.0)
}

/// Decode any WOR sampler behind `Box<dyn WorSampler>` by dispatching on
/// the envelope's type tag — the inverse of
/// [`WorSampler::encode_state`]. Unknown or non-sampler tags fail with
/// [`Error::Codec`].
pub fn decode_sampler(bytes: &[u8]) -> Result<Box<dyn WorSampler>> {
    Ok(match peek_type_tag(bytes)? {
        tag::WORP1 => Box::new(crate::sampler::worp1::OnePassWorp::decode(bytes)?),
        tag::WORP2 => Box::new(crate::sampler::worp2::TwoPassWorp::decode(bytes)?),
        tag::TV => Box::new(crate::sampler::tv1pass::TvSampler::decode(bytes)?),
        tag::WINDOWED_WORP => Box::new(crate::sampler::windowed::WindowedWorp::decode(bytes)?),
        tag::EXACT_WOR => Box::new(crate::sampler::exact::ExactWor::decode(bytes)?),
        tag::WR_RESERVOIR => {
            Box::new(crate::sampler::wr_reservoir::WrReservoir::decode(bytes)?)
        }
        tag::DECAYED_WORP => Box::new(crate::sampler::decayed::DecayedWorp::decode(bytes)?),
        t => {
            return Err(Error::Codec(format!(
                "type tag {t} ({}) is not a WOR sampler",
                tag_name(t)
            )))
        }
    })
}

/// `Box<dyn WorSampler>` persists through the type-tagged envelope: the
/// encode side delegates to the boxed sampler, the decode side dispatches
/// on the tag. This is what lets the checkpointed pipeline snapshot the
/// dynamic (CLI/builder) path with zero per-method glue.
impl Persist for Box<dyn WorSampler> {
    fn encode_into(&self, out: &mut Vec<u8>) {
        self.encode_state(out);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        decode_sampler(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_roundtrips_and_validates() {
        let payload = b"hello payload";
        let mut buf = Vec::new();
        write_envelope(tag::COUNTSKETCH, 0xFEED, payload, &mut buf);
        assert_eq!(buf.len(), HEADER_LEN + payload.len());
        let env = read_envelope(&buf, Some(tag::COUNTSKETCH)).unwrap();
        assert_eq!(env.type_tag, tag::COUNTSKETCH);
        assert_eq!(env.fingerprint, 0xFEED);
        assert_eq!(env.payload, payload);
        // any expected-tag mismatch is loud
        let err = read_envelope(&buf, Some(tag::TOPK)).unwrap_err();
        assert!(matches!(err, Error::Codec(_)), "{err}");
    }

    #[test]
    fn corrupted_envelopes_are_codec_errors() {
        let mut buf = Vec::new();
        write_envelope(tag::TOPK, 1, b"abcdef", &mut buf);
        // magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(read_envelope(&bad, None), Err(Error::Codec(_))));
        // version
        let mut bad = buf.clone();
        bad[4] = 0xFF;
        assert!(matches!(read_envelope(&bad, None), Err(Error::Codec(_))));
        // payload bit flip -> checksum
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(matches!(read_envelope(&bad, None), Err(Error::Codec(_))));
        // length-field lie
        let mut bad = buf.clone();
        bad[8] = bad[8].wrapping_add(1);
        assert!(matches!(read_envelope(&bad, None), Err(Error::Codec(_))));
        // truncation at every prefix
        for cut in 0..buf.len() {
            assert!(
                read_envelope(&buf[..cut], None).is_err(),
                "prefix of {cut} bytes decoded"
            );
        }
    }

    #[test]
    fn sampler_config_fragment_roundtrips() {
        let cfg = SamplerConfig::new(1.5, 12)
            .with_seed(99)
            .with_domain(4444)
            .with_sketch_shape(5, 777)
            .with_priority();
        let mut out = Vec::new();
        put_sampler_config(&mut out, &cfg);
        let mut r = wire::Reader::new(&out);
        let back = read_sampler_config(&mut r).unwrap();
        r.finish("cfg").unwrap();
        assert_eq!(back.p, cfg.p);
        assert_eq!(back.k, cfg.k);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.n, cfg.n);
        assert_eq!(back.rows, cfg.rows);
        assert_eq!(back.width, cfg.width);
        assert_eq!(back.dist, cfg.dist);
    }

    #[test]
    fn sample_encoding_roundtrips_with_and_without_names() {
        use crate::sampler::{KeyDict, Sample, SampleEntry};
        use crate::util::hashing::BottomKDist;
        let mut names = KeyDict::new();
        names.insert(7, "seven".to_string());
        names.insert(1, "one".to_string());
        for names in [None, Some(names)] {
            let s = Sample {
                entries: vec![
                    SampleEntry { key: 7, freq: 3.5, transformed: 9.25 },
                    SampleEntry { key: 1, freq: 1.0, transformed: 2.0 },
                ],
                tau: 1.5,
                p: 1.0,
                dist: BottomKDist::Exp,
                names,
            };
            let mut buf = Vec::new();
            put_sample(&mut buf, &s);
            let mut r = wire::Reader::new(&buf);
            let back = read_sample(&mut r).unwrap();
            r.finish("sample").unwrap();
            assert_eq!(back.entries, s.entries);
            assert_eq!(back.tau, s.tau);
            assert_eq!(back.p, s.p);
            assert_eq!(back.dist, s.dist);
            assert_eq!(back.names, s.names);
            // canonical: re-encoding the decoded sample is byte-identical
            let mut buf2 = Vec::new();
            put_sample(&mut buf2, &back);
            assert_eq!(buf, buf2);
        }
    }

    #[test]
    fn sample_decoding_rejects_hostile_values() {
        use crate::sampler::{Sample, SampleEntry};
        use crate::util::hashing::BottomKDist;
        let s = Sample {
            entries: vec![SampleEntry { key: 1, freq: 1.0, transformed: 1.0 }],
            tau: 1.0,
            p: 1.0,
            dist: BottomKDist::Exp,
            names: None,
        };
        let mut buf = Vec::new();
        put_sample(&mut buf, &s);
        // truncation at every prefix errors, never panics
        for cut in 0..buf.len() {
            assert!(read_sample(&mut wire::Reader::new(&buf[..cut])).is_err());
        }
        // entry-count lie
        let mut bad = buf.clone();
        bad[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_sample(&mut wire::Reader::new(&bad)).is_err());
        // p out of range (tau at offset 8+24, p follows)
        let mut bad = buf.clone();
        bad[40..48].copy_from_slice(&3.5f64.to_bits().to_le_bytes());
        assert!(read_sample(&mut wire::Reader::new(&bad)).is_err());
        // negative tau
        let mut bad = buf;
        bad[32..40].copy_from_slice(&(-1.0f64).to_bits().to_le_bytes());
        assert!(read_sample(&mut wire::Reader::new(&bad)).is_err());
    }

    #[test]
    fn sampler_config_fragment_rejects_hostile_values() {
        let good = SamplerConfig::new(1.0, 4);
        let mut base = Vec::new();
        put_sampler_config(&mut base, &good);
        // p = 3.0 (out of range)
        let mut bad = base.clone();
        bad[..8].copy_from_slice(&3.0f64.to_bits().to_le_bytes());
        assert!(read_sampler_config(&mut wire::Reader::new(&bad)).is_err());
        // p = NaN
        let mut bad = base.clone();
        bad[..8].copy_from_slice(&f64::NAN.to_bits().to_le_bytes());
        assert!(read_sampler_config(&mut wire::Reader::new(&bad)).is_err());
        // k = 0
        let mut bad = base.clone();
        bad[8..16].copy_from_slice(&0u64.to_le_bytes());
        assert!(read_sampler_config(&mut wire::Reader::new(&bad)).is_err());
        // dist byte = 9
        let mut bad = base.clone();
        let last = bad.len() - 1;
        bad[last] = 9;
        assert!(read_sampler_config(&mut wire::Reader::new(&bad)).is_err());
    }
}
